"""Hazard layer for the pipeline scheduler (DESIGN.md §Pipeline).

Three families of proof around the §2.3 dependency tokens:

* **Token-queue underflow** — handcrafted streams whose pops have no
  matching push must raise :class:`VTAHazardError` inside
  :class:`TokenQueues` (shared by every simulator backend) and be
  rejected statically by ``validate_program`` under the stable
  ``dep-token-hazard`` constraint id.
* **Concurrent races** — streams whose tokens *balance* (the dry run
  passes) but leave two modules unordered on overlapping SRAM must be
  caught by :func:`check_concurrent_hazards`: RAW (a LOAD INP/WGT the
  GEMM reads without a token edge) and WAR (a STORE draining an ACC/OUT
  window the next GEMM overwrites).
* **Legal relaxations never deadlock** — token streams that are legal
  by construction (every pop has an earlier matching push in program
  order, the §2.3 counter guarantee) replay through ``TokenQueues`` and
  the three-module timeline without a hazard, with the makespan bounded
  by [max module busy, serial sum].  Seeded deterministic sweep for
  tier-1; the same property runs under hypothesis when installed.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.core.cycle_model import insn_cycles, simulate_pipeline
from repro.core.errors import CompileError
from repro.core.gemm_compiler import AluImmOp, compile_matmul
from repro.core.hwconfig import vta_default
from repro.core.pipeline_schedule import (check_concurrent_hazards,
                                          check_program_hazards)
from repro.core.simulator import (FunctionalSimulator, TokenQueues,
                                  VTAHazardError, run_program)
from repro.harden.guards import validate_program

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # optional dev dependency
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Handcrafted stream builders (dep flags as kwargs)
# ---------------------------------------------------------------------------

def _dep(insn, **flags):
    for name, value in flags.items():
        setattr(insn.dep, name, value)
    return insn


def _load_inp(**flags):                                  # Load module
    return _dep(isa.MemInsn(isa.Opcode.LOAD, isa.MemId.INP, sram_base=0,
                            dram_base=0, y_size=1, x_size=16, x_stride=16),
                **flags)


def _load_wgt(**flags):                                  # Load module
    return _dep(isa.MemInsn(isa.Opcode.LOAD, isa.MemId.WGT, sram_base=0,
                            dram_base=0, y_size=1, x_size=1, x_stride=1),
                **flags)


def _load_acc(**flags):                                  # Compute module
    return _dep(isa.MemInsn(isa.Opcode.LOAD, isa.MemId.ACC, sram_base=0,
                            dram_base=0, y_size=1, x_size=16, x_stride=16),
                **flags)


def _store(**flags):                                     # Store module
    return _dep(isa.MemInsn(isa.Opcode.STORE, isa.MemId.OUT, sram_base=0,
                            dram_base=0, y_size=1, x_size=16, x_stride=16),
                **flags)


def _gemm(reset=0, **flags):                             # Compute module
    return _dep(isa.GemInsn(reset=reset, uop_bgn=0, uop_end=1,
                            iter_out=1, iter_in=16, acc_factor_in=1,
                            inp_factor_in=1), **flags)


def _finish(**flags):
    return _dep(isa.FinishInsn(), **flags)


# ---------------------------------------------------------------------------
# TokenQueues: underflow raises, accounting counts
# ---------------------------------------------------------------------------

def test_pop_on_empty_queue_raises():
    tq = TokenQueues()
    with pytest.raises(VTAHazardError, match="pops empty queue"):
        tq.pre(_gemm(pop_prev=1))
    tq = TokenQueues()
    with pytest.raises(VTAHazardError, match="pops empty queue"):
        tq.pre(_store(pop_prev=1))


def test_edge_modules_have_no_outer_neighbour():
    tq = TokenQueues()
    with pytest.raises(VTAHazardError, match="nonexistent neighbour"):
        tq.pre(_load_inp(pop_prev=1))        # nothing upstream of Load
    tq = TokenQueues()
    with pytest.raises(VTAHazardError, match="nonexistent neighbour"):
        tq.post(_store(push_next=1))         # nothing downstream of Store


def test_fifo_pop_matches_push_order_and_accounting():
    """pop #k happens-after push #k: two pushes then two pops drain the
    queue; a third pop underflows.  The accounting counters see all the
    traffic and the depth-2 high water."""
    tq = TokenQueues()
    for _ in range(2):
        tq.post(_load_wgt(push_next=1))
    assert tq.high_water == 2
    for _ in range(2):
        tq.pre(_gemm(pop_prev=1))
    assert (tq.pops, tq.pushes) == (2, 2)
    with pytest.raises(VTAHazardError):
        tq.pre(_gemm(pop_prev=1))


def test_oracle_simulator_surfaces_underflow():
    """The pop fires in ``pre`` — the backend raises before executing the
    hazardous instruction."""
    sim = FunctionalSimulator(vta_default(), np.zeros(4096, dtype=np.uint8))
    with pytest.raises(VTAHazardError):
        sim.run([_gemm(reset=1, pop_prev=1), _finish()])


def test_sim_report_dep_accounting_by_schedule():
    """SimReport token counters: the pipelined stream's producer queues
    reach depth 2 (double-buffered waves in flight); serialized stays at
    1.  Pops never exceed pushes on any legal stream."""
    rng = np.random.default_rng(13)
    A = rng.integers(-128, 128, (48, 64)).astype(np.int8)
    B = rng.integers(-128, 128, (64, 32)).astype(np.int8)
    water = {}
    for schedule in ("serialized", "pipelined"):
        prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()],
                              schedule=schedule)
        _, rep = run_program(prog, backend="fast")
        assert 0 < rep.dep_pops <= rep.dep_pushes
        water[schedule] = rep.dep_queue_high_water
    assert water == {"serialized": 1, "pipelined": 2}


# ---------------------------------------------------------------------------
# Concurrent-hazard checker: handcrafted RAW / WAR races
# ---------------------------------------------------------------------------

def test_checker_rejects_pop_without_matching_push():
    with pytest.raises(VTAHazardError, match="deadlock"):
        check_concurrent_hazards(vta_default(),
                                 [_gemm(reset=1, pop_prev=1), _finish()])


def test_raw_race_load_vs_gemm_detected():
    """Tokens balance (there are none), but the GEMM reads INP/WGT the
    Load module may still be writing — a RAW race across modules."""
    insns = [_load_inp(), _load_wgt(), _gemm(reset=1), _gemm(), _finish()]
    with pytest.raises(VTAHazardError, match="races"):
        check_concurrent_hazards(vta_default(), insns)


def test_token_edge_orders_the_same_raw_stream():
    """One push/pop pair on the (load→compute) queue orders every load
    before every compute access (module order supplies the rest)."""
    insns = [_load_inp(), _load_wgt(push_next=1),
             _gemm(reset=1, pop_prev=1), _gemm(), _finish()]
    check_concurrent_hazards(vta_default(), insns)     # must not raise


def test_war_race_store_vs_next_gemm_detected():
    """The store drains an ACC/OUT window; a later GEMM reset overwrites
    the same ACC range with no token path from the store — the WAR race
    double-buffering exists to avoid."""
    insns = [_load_inp(), _load_wgt(push_next=1),
             _gemm(reset=1, pop_prev=1), _gemm(push_next=1),
             _store(pop_prev=1),
             _gemm(reset=1),                 # races the draining store
             _finish()]
    with pytest.raises(VTAHazardError, match="races"):
        check_concurrent_hazards(vta_default(), insns)


def test_store_release_token_orders_the_same_war_stream():
    insns = [_load_inp(), _load_wgt(push_next=1),
             _gemm(reset=1, pop_prev=1), _gemm(push_next=1),
             _store(pop_prev=1, push_prev=1),
             _gemm(reset=1, pop_next=1),     # waits for the store release
             _finish()]
    check_concurrent_hazards(vta_default(), insns)     # must not raise


@pytest.mark.parametrize("schedule", ["serialized", "pipelined"])
def test_compiled_streams_prove_hazard_free(schedule):
    """Both emission schemes discharge the proof obligation, with exact
    UOP-replayed GEMM/ALU ranges from the program's uop segment."""
    rng = np.random.default_rng(29)
    A = rng.integers(-128, 128, (64, 96)).astype(np.int8)
    B = rng.integers(-128, 128, (96, 48)).astype(np.int8)
    X = rng.integers(-10**5, 10**5, (64, 48)).astype(np.int32)
    prog = compile_matmul(A, B, X=X, alu_ops=[AluImmOp.relu()],
                          schedule=schedule)
    assert prog.schedule == schedule
    check_program_hazards(prog)
    validate_program(prog)


# ---------------------------------------------------------------------------
# Validator rejections under the stable `dep-token-hazard` constraint id
# ---------------------------------------------------------------------------

def _pipelined_program():
    rng = np.random.default_rng(21)
    A = rng.integers(-128, 128, (48, 64)).astype(np.int8)
    B = rng.integers(-128, 128, (64, 32)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()],
                          schedule="pipelined")
    assert prog.schedule == "pipelined"
    return prog


def _resync(prog):
    """Re-encode the mutated stream so the round-trip check passes and
    the token checks are what rejects."""
    prog.segments["insn"] = isa.encode_stream(prog.instructions)
    prog._harden_validated_segs = None


def _expect_hazard(prog):
    with pytest.raises(CompileError) as exc:
        validate_program(prog)
    assert exc.value.constraint == "dep-token-hazard", exc.value


def test_validator_rejects_unmatched_pop_in_pipelined_stream():
    """Dropping a producer push starves a later pop: the dry run (step 4)
    deadlocks and the validator rejects."""
    prog = _pipelined_program()
    lw = next(i for i in prog.instructions
              if isinstance(i, isa.MemInsn)
              and i.memory_type == isa.MemId.WGT and i.dep.push_next)
    lw.dep.push_next = 0
    _resync(prog)
    _expect_hazard(prog)


def test_validator_rejects_balanced_but_racy_stream():
    """Dropping a store's wait token keeps the queues balanced (the dry
    run passes: pushes simply accumulate) but un-orders the store from
    the GEMMs filling the same ACC window — the concurrent-hazard check
    (step 5) must reject it."""
    prog = _pipelined_program()
    st_insn = next(i for i in prog.instructions
                   if isinstance(i, isa.MemInsn)
                   and i.opcode == isa.Opcode.STORE)
    assert st_insn.dep.pop_prev
    st_insn.dep.pop_prev = 0
    _resync(prog)
    _expect_hazard(prog)


# ---------------------------------------------------------------------------
# Legal relaxations never deadlock (seeded sweep + hypothesis property)
# ---------------------------------------------------------------------------

_MAKERS = {"load": _load_inp, "compute": _load_acc, "store": _store}


def _random_legal_stream(draw_int, draw_bool):
    """A token stream legal by construction: pops are only drawn against
    queues with an earlier unmatched push, mirroring the §2.3 counters."""
    counters = {q: 0 for q in (("load", "compute"), ("compute", "load"),
                               ("compute", "store"), ("store", "compute"))}
    insns = []
    for _ in range(draw_int(1, 48)):
        mod = ("load", "compute", "store")[draw_int(0, 2)]
        insn = _MAKERS[mod]()
        prev, nxt = TokenQueues._PREV[mod], TokenQueues._NEXT[mod]
        if prev and counters[(prev, mod)] and draw_bool():
            insn.dep.pop_prev = 1
            counters[(prev, mod)] -= 1
        if nxt and counters[(nxt, mod)] and draw_bool():
            insn.dep.pop_next = 1
            counters[(nxt, mod)] -= 1
        if prev and draw_bool():
            insn.dep.push_prev = 1
            counters[(mod, prev)] += 1
        if nxt and draw_bool():
            insn.dep.push_next = 1
            counters[(mod, nxt)] += 1
        insns.append(insn)
    return insns


def _assert_stream_safe(insns):
    tq = TokenQueues()
    for insn in insns:               # in-order replay: must never raise
        tq.pre(insn)
        tq.post(insn)
    rep = simulate_pipeline(insns)   # three-module timeline completes
    serial_sum = sum(insn_cycles(i) for i in insns)
    assert max(rep.busy_cycles.values()) <= rep.makespan_cycles <= serial_sum


def test_seeded_legal_relaxations_never_deadlock():
    rng = np.random.default_rng(42)
    for _ in range(60):
        insns = _random_legal_stream(
            lambda lo, hi: int(rng.integers(lo, hi + 1)),
            lambda: bool(rng.integers(2)))
        _assert_stream_safe(insns)


if HAS_HYPOTHESIS:
    @settings(max_examples=80, deadline=None)
    @given(data=st.data())
    def test_hypothesis_legal_relaxations_never_deadlock(data):
        insns = _random_legal_stream(
            lambda lo, hi: data.draw(st.integers(lo, hi)),
            lambda: data.draw(st.booleans()))
        _assert_stream_safe(insns)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_legal_relaxations_never_deadlock():
        pass
