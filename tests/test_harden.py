"""Runtime integrity guards + fault injector (DESIGN.md §Hardening).

The contract under test, per detection layer:

* **Golden image / CRC** — every persistent fault class (DRAM segment
  flips, instruction-word flips) is detected before or after the serve,
  the network is restored from the golden snapshot, and the retried
  request returns the bit-exact golden output.
* **Stream validator** — field-level mutation of the decoded instruction
  objects (which leaves the segment bytes — and hence the CRCs —
  untouched) is caught by the decode→re-encode round-trip; structurally
  invalid streams are rejected with stable ``constraint`` ids.
* **Zero false positives** — on clean programs the validator accepts,
  the CRCs verify, guarded serving reports ``clean``, and the
  dual-execution shadow agrees bit-for-bit (seeded sweep as the tier-1
  floor; a hypothesis property when the optional dependency is
  installed).
* **Injector determinism** — same seed ⇒ same fault plan, byte for
  byte: campaigns are reproducible artifacts.
"""

import time

import numpy as np
import pytest

from repro.core import isa
from repro.core.errors import CompileError
from repro.core.gemm_compiler import AluImmOp, compile_matmul
from repro.core.network_compiler import compile_network
from repro.core.simulator import run_program
from repro.harden import (FAULT_CLASSES, FaultInjector, GuardPolicy,
                          Watchdog, WatchdogTimeout, capture_golden,
                          guarded_serve_one, restore_network,
                          validate_network, validate_program,
                          verify_network)
from repro.harden.faults import estimate_footprint
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # optional dev dependency
    HAS_HYPOTHESIS = False


@pytest.fixture(scope="module")
def lenet():
    return compile_network(lenet5_specs(lenet5_random_weights(0)),
                           synthetic_digit(0))


@pytest.fixture(scope="module")
def golden_out(lenet):
    return lenet.serve_one(synthetic_digit(1))


IMG = synthetic_digit(1)


# ---------------------------------------------------------------------------
# Golden image + CRC verification
# ---------------------------------------------------------------------------

def test_clean_guarded_serve_is_clean(lenet, golden_out):
    out, rep = lenet.serve_one(IMG, guard=GuardPolicy())
    assert rep.outcome == "clean" and rep.detections == 0
    np.testing.assert_array_equal(out, golden_out)


def test_capture_refuses_corrupted_program(lenet):
    prog = lenet.layers[0].program
    original = prog.segments["wgt"]
    data = bytearray(original)
    data[0] ^= 0x10
    prog.segments["wgt"] = bytes(data)     # SEU: bypasses set_segment
    try:
        with pytest.raises(ValueError, match="refusing to snapshot"):
            capture_golden(lenet)
    finally:
        prog.segments["wgt"] = original


def test_verify_names_the_corrupted_layer_segment(lenet):
    golden = capture_golden(lenet)
    assert verify_network(lenet, golden) == []
    prog = lenet.layers[2].program
    original = prog.segments["uop"]
    data = bytearray(original)
    data[3] ^= 0x01
    prog.segments["uop"] = bytes(data)
    assert verify_network(lenet, golden) == [f"{prog.name}:uop"]
    restored = restore_network(lenet, golden, layers=[2])
    assert restored == 1
    assert verify_network(lenet, golden) == []


@pytest.mark.parametrize("fault_class",
                         ["dram-wgt", "dram-uop", "dram-bias", "insn-bits"])
def test_persistent_faults_detected_and_recovered(lenet, golden_out,
                                                  fault_class):
    """Every persistent fault class: detected by CRC, recovered to the
    bit-exact golden output — never silently wrong."""
    inj = FaultInjector(seed=101)
    for _ in range(5):
        spec, hook = inj.inject(lenet, fault_class)
        if fault_class == "insn-bits":
            try:
                inj.materialize(lenet, spec)    # device fetch of the flip
            except ValueError:
                pass                            # undecodable: CRC still fires
        out, rep = lenet.serve_one(IMG, guard=GuardPolicy(),
                                   fault_hook=hook)
        assert rep.outcome == "recovered", spec.describe()
        assert rep.crc_failures, spec.describe()
        np.testing.assert_array_equal(out, golden_out)


def test_insn_field_mutation_caught_by_roundtrip(lenet, golden_out):
    """Mutating a decoded instruction leaves every CRC intact — only the
    decode→re-encode round-trip can see it."""
    inj = FaultInjector(seed=55)
    for _ in range(5):
        spec, hook = inj.inject(lenet, "insn-field")
        out, rep = lenet.serve_one(IMG, guard=GuardPolicy(),
                                   fault_hook=hook)
        assert rep.outcome == "recovered", spec.describe()
        assert rep.validation_errors and not rep.crc_failures
        np.testing.assert_array_equal(out, golden_out)


def test_sram_transients_never_corrupt_output(lenet, golden_out):
    """Transient SRAM flips under dual execution: masked or recovered,
    never a wrong output."""
    inj = FaultInjector(seed=77)
    policy = GuardPolicy(dual_execute=True, dual_backend="fast")
    outcomes = set()
    for _ in range(30):
        spec, hook = inj.inject(lenet, "sram")
        out, rep = lenet.serve_one(IMG, guard=policy, fault_hook=hook)
        assert out is not None, spec.describe()
        np.testing.assert_array_equal(out, golden_out)
        outcomes.add(rep.outcome)
    assert outcomes <= {"clean", "recovered"}


def test_guarded_batched_serve_recovers(lenet, golden_out):
    inj = FaultInjector(seed=9)
    imgs = [synthetic_digit(s) for s in range(3)] + [IMG]
    plain, _ = lenet.serve(imgs)
    inj.inject(lenet, "dram-wgt")
    outs, sims, reps = lenet.serve(imgs, guard=GuardPolicy())
    assert len(reps) == 4 and all(r.outcome == "recovered" for r in reps)
    for got, want in zip(outs, plain):
        np.testing.assert_array_equal(got, want)


def test_unrecoverable_returns_none_not_garbage(lenet):
    """When recovery is impossible the caller gets None + "failed" —
    the guards never hand back unverified data."""
    inj = FaultInjector(seed=13)

    def always_corrupt(sim, layer_idx, insn_idx):
        # re-corrupt a segment at every instruction boundary: restore
        # can never win
        prog = lenet.layers[0].program
        data = bytearray(prog.segments["wgt"])
        data[0] ^= 0xFF
        prog.segments["wgt"] = bytes(data)

    out, rep = lenet.serve_one(IMG, guard=GuardPolicy(max_retries=2),
                               fault_hook=always_corrupt)
    assert out is None and rep.outcome == "failed" and not rep.ok
    assert rep.retries == 2
    restore_network(lenet, lenet._harden_golden)   # clean up for peers


# ---------------------------------------------------------------------------
# Injector determinism
# ---------------------------------------------------------------------------

def test_injector_is_deterministic(lenet):
    """plan() is a pure draw — two same-seed injectors produce the same
    campaign, byte for byte, without touching the network."""
    plans = []
    for _ in range(2):
        inj = FaultInjector(seed=2026)
        specs = []
        for cls in FAULT_CLASSES:
            for _ in range(4):
                specs.append(inj.plan(lenet, cls).describe())
        plans.append(specs)
    assert plans[0] == plans[1]
    # and distinct seeds draw distinct campaigns
    other = [FaultInjector(seed=2027).plan(lenet, cls).describe()
             for cls in FAULT_CLASSES for _ in range(4)]
    assert other != plans[0]


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

def test_watchdog_trips_on_deadline():
    wd = Watchdog(0.05)
    try:
        wd.arm()
        wd.check()                      # fresh arm: no trip
        time.sleep(0.2)
        with pytest.raises(WatchdogTimeout):
            wd.check()
        wd.arm()                        # re-arm clears the trip
        wd.check()
    finally:
        wd.stop()


def test_watchdog_policy_fails_hung_serve(lenet):
    def hung(sim, layer_idx, insn_idx):
        time.sleep(0.15)

    policy = GuardPolicy(deadline_s=0.2, max_retries=0)
    out, rep = lenet.serve_one(IMG, guard=policy, fault_hook=hung)
    assert out is None and rep.watchdog_tripped
    assert rep.outcome == "failed"


# ---------------------------------------------------------------------------
# Overflow / saturation observability
# ---------------------------------------------------------------------------

def test_saturation_counter_counts_clipped_lanes():
    rng = np.random.default_rng(0)
    A = rng.integers(-128, 128, (8, 32)).astype(np.int8)
    B = rng.integers(-128, 128, (32, 8)).astype(np.int8)
    prog = compile_matmul(A, B)        # raw A·B clips hard at int8
    out_plain, rep = run_program(prog, backend="fast",
                                 count_overflows=True)
    assert rep.acc_saturation_lanes > 0
    assert rep.acc_overflow_lanes == 0   # int32 accumulators never wrap here
    # counters are pure observability: output identical with them off
    out_off, rep_off = run_program(prog, backend="fast")
    np.testing.assert_array_equal(out_plain, out_off)
    assert rep_off.acc_saturation_lanes == 0


def test_overflow_counter_counts_wrapped_accumulators():
    A = np.full((1, 16), 127, dtype=np.int8)
    B = np.full((16, 16), 127, dtype=np.int8)
    X = np.full((1, 16), 2**31 - 1, dtype=np.int32)   # preload at INT32_MAX
    prog = compile_matmul(A, B, X=X)
    for backend in ("oracle", "fast"):
        _, rep = run_program(prog, backend=backend, count_overflows=True)
        assert rep.acc_overflow_lanes > 0, backend


# ---------------------------------------------------------------------------
# Zero false positives on clean programs (+ validator acceptance)
# ---------------------------------------------------------------------------

def _random_matmul(rng):
    m, k, n = (int(rng.integers(1, 40)) for _ in range(3))
    A = rng.integers(-128, 128, (m, k)).astype(np.int8)
    B = rng.integers(-128, 128, (k, n)).astype(np.int8)
    ops = [AluImmOp.relu()] if rng.random() < 0.5 else []
    return compile_matmul(A, B, alu_ops=ops)


def test_validator_accepts_clean_programs_seeded():
    rng = np.random.default_rng(42)
    for _ in range(15):
        validate_program(_random_matmul(rng))     # must not raise


def test_validator_accepts_clean_network(lenet):
    assert validate_network(lenet) == []


def test_dual_execution_bit_identical_when_clean(lenet, golden_out):
    out, rep = lenet.serve_one(
        IMG, guard=GuardPolicy(dual_execute=True, dual_backend="oracle"))
    assert rep.outcome == "clean" and rep.dual_mismatches == 0
    np.testing.assert_array_equal(out, golden_out)


def test_footprint_estimate_flags_geometry_bombs(lenet):
    from repro.harden.guards import MAX_INSN_FOOTPRINT
    for layer in lenet.layers:
        assert (estimate_footprint(layer.program.instructions)
                <= MAX_INSN_FOOTPRINT)
    bomb = isa.GemInsn(uop_bgn=0, uop_end=2**14 - 1, iter_out=2**14 - 1,
                       iter_in=2**14 - 1)
    assert estimate_footprint([bomb]) > MAX_INSN_FOOTPRINT


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_validator_zero_false_positives_property(seed):
        """Any compile_matmul program validates, CRC-verifies, and runs
        identically with guards-grade counters on."""
        rng = np.random.default_rng(seed)
        prog = _random_matmul(rng)
        validate_program(prog)
        out_a, _ = run_program(prog, backend="fast")
        out_b, _ = run_program(prog, backend="fast", count_overflows=True)
        np.testing.assert_array_equal(out_a, out_b)
