"""Threaded async serving engine tests (DESIGN.md §Serving).

The contracts: every engine-served result is bit-identical to a direct
``NetworkProgram.serve`` of the same images (both batch backends, lenet5
+ resnet8); the batch former honours its max-batch/max-wait policy edge
cases (``max_wait=0`` immediate dispatch, ``max_batch=1`` degeneracy);
backpressure is a typed ``QueueFull``; shutdown drains in-flight
requests (or cancels them, typed, when asked not to); unknown backends
are refused with stable constraint ids through both the engine path and
the ``serve``/``serve_one`` front doors; and the accounting audit is
clean after every drain.

Hypothesis-free: tier-1 floor.
"""

import numpy as np
import pytest

from repro.core.errors import CompileError
from repro.core.network_compiler import (SERVE_BACKENDS, SERVE_ONE_BACKENDS,
                                         compile_network)
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)
from repro.serving.vta import (BatchPolicy, QueueClosed, QueueFull,
                               VTAServingEngine, request_images, serve_all)


@pytest.fixture(scope="module")
def lenet():
    return compile_network(lenet5_specs(lenet5_random_weights(0)),
                           synthetic_digit(0))


@pytest.fixture(scope="module")
def resnet8():
    from repro.models.resnet8 import compile_resnet8
    net, _ = compile_resnet8()
    return net


# ---------------------------------------------------------------------------
# Differential bit-identity: engine == direct serve, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_lenet_engine_bit_identical_to_direct_serve(lenet, backend):
    if backend == "pallas":
        pytest.importorskip("jax")
    images = request_images(lenet, 6, seed=1)
    policy = BatchPolicy(max_batch=4, max_wait_s=0.002)
    with VTAServingEngine(lenet, policy=policy,
                          backends=(backend,)) as engine:
        outs, tickets = serve_all(engine, images)
    direct, _ = lenet.serve(images, backend=backend)
    np.testing.assert_array_equal(outs, direct)
    # and identical to the default batched path (cross-backend identity)
    base, _ = lenet.serve(images)
    np.testing.assert_array_equal(outs, base)
    assert engine.metrics.audit() == []
    assert engine.metrics.drained()
    assert all(t.record.backend == backend for t in tickets)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_resnet8_engine_bit_identical_to_direct_serve(resnet8, backend):
    if backend == "pallas":
        pytest.importorskip("jax")
    images = request_images(resnet8, 3, seed=2)
    policy = BatchPolicy(max_batch=2, max_wait_s=0.002)
    with VTAServingEngine(resnet8, policy=policy,
                          backends=(backend,)) as engine:
        outs, _ = serve_all(engine, images)
    direct, _ = resnet8.serve(images, backend=backend)
    np.testing.assert_array_equal(outs, direct)
    assert engine.metrics.audit() == []


def test_mixed_backend_worker_pool(lenet):
    """batched + pallas workers drain one queue; whichever worker serves
    a request is unobservable in the results."""
    pytest.importorskip("jax")
    images = request_images(lenet, 8, seed=3)
    with VTAServingEngine(lenet,
                          policy=BatchPolicy(max_batch=2, max_wait_s=0.0),
                          backends=("batched", "pallas")) as engine:
        outs, tickets = serve_all(engine, images)
    direct, _ = lenet.serve(images)
    np.testing.assert_array_equal(outs, direct)
    assert {t.record.backend for t in tickets} <= {"batched", "pallas"}
    assert engine.metrics.audit() == []


# ---------------------------------------------------------------------------
# Batch-former edge cases
# ---------------------------------------------------------------------------

def test_max_wait_zero_dispatches_immediately(lenet):
    """max_wait=0: a lone request must never wait for batchmates."""
    images = request_images(lenet, 4, seed=4)
    policy = BatchPolicy(max_batch=8, max_wait_s=0.0)
    with VTAServingEngine(lenet, policy=policy) as engine:
        for img in images:                 # serial: one in flight at a time
            ticket = engine.submit(img)
            ticket.result(timeout=60.0)
            assert ticket.record.batch_size == 1
            assert ticket.record.padded_size == 1
    assert engine.metrics.summary()["mean_batch_occupancy"] == 1.0


def test_max_batch_one_degeneracy(lenet):
    """max_batch=1 serves every request alone regardless of queue depth."""
    images = request_images(lenet, 5, seed=5)
    policy = BatchPolicy(max_batch=1, max_wait_s=0.05)
    with VTAServingEngine(lenet, policy=policy) as engine:
        outs, tickets = serve_all(engine, images)
    direct, _ = lenet.serve(images)
    np.testing.assert_array_equal(outs, direct)
    assert all(t.record.batch_size == 1 and t.record.padded_size == 1
               for t in tickets)


def test_batches_pad_up_the_compiled_ladder(lenet):
    """A 3-deep queue at max_batch=4 forms one padded batch: occupancy 3,
    executed rows 4 (the next ladder rung)."""
    images = request_images(lenet, 3, seed=6)
    policy = BatchPolicy(max_batch=4, max_wait_s=0.2)
    engine = VTAServingEngine(lenet, policy=policy)
    tickets = [engine.submit(img) for img in images]  # queued pre-start
    with engine:
        outs = np.stack([t.result(timeout=60.0) for t in tickets])
    direct, _ = lenet.serve(images)
    np.testing.assert_array_equal(outs, direct)
    assert [t.record.batch_size for t in tickets] == [3, 3, 3]
    assert [t.record.padded_size for t in tickets] == [4, 4, 4]


def test_backpressure_rejects_with_queue_full(lenet):
    """Admissions beyond max_depth raise typed QueueFull; the queue
    recovers once drained."""
    images = request_images(lenet, 4, seed=7)
    policy = BatchPolicy(max_batch=2, max_wait_s=0.0, max_depth=2)
    engine = VTAServingEngine(lenet, policy=policy)   # not started: no drain
    t0 = engine.submit(images[0])
    t1 = engine.submit(images[1])
    with pytest.raises(QueueFull) as exc:
        engine.submit(images[2])
    assert exc.value.depth == 2 and exc.value.max_depth == 2
    assert engine.metrics.rejected == 1
    with engine:                                      # start → drain → stop
        np.testing.assert_array_equal(t0.result(timeout=60.0),
                                      lenet.serve([images[0]])[0][0])
        t1.result(timeout=60.0)
    assert engine.metrics.drained()
    assert engine.metrics.audit() == []


def test_shutdown_drains_in_flight_requests(lenet):
    """shutdown(drain=True) serves every queued request before joining."""
    images = request_images(lenet, 6, seed=8)
    policy = BatchPolicy(max_batch=4, max_wait_s=0.05)
    engine = VTAServingEngine(lenet, policy=policy)
    tickets = [engine.submit(img) for img in images]  # queued pre-start
    engine.start()
    engine.shutdown(drain=True)                       # immediate shutdown
    assert all(t.done() for t in tickets)
    direct, _ = lenet.serve(images)
    np.testing.assert_array_equal(
        np.stack([t.result() for t in tickets]), direct)
    with pytest.raises(QueueClosed):
        engine.submit(images[0])
    engine.shutdown()                                 # idempotent


def test_shutdown_without_drain_cancels_typed(lenet):
    images = request_images(lenet, 3, seed=9)
    engine = VTAServingEngine(
        lenet, policy=BatchPolicy(max_batch=8, max_wait_s=10.0))
    tickets = [engine.submit(img) for img in images]
    engine.start()
    engine.shutdown(drain=False)
    resolved = 0
    for t in tickets:
        try:
            t.result(timeout=60.0)
            resolved += 1                  # a worker may have grabbed it
        except QueueClosed:
            pass
    assert engine.metrics.cancelled + resolved == len(tickets)
    assert engine.metrics.drained()


# ---------------------------------------------------------------------------
# Typed refusals: unknown backends through every front door
# ---------------------------------------------------------------------------

def test_engine_refuses_unknown_and_per_image_backends(lenet):
    for bad in ("weird", "fast", "oracle"):
        with pytest.raises(CompileError, match="backend") as exc:
            VTAServingEngine(lenet, backends=(bad,))
        assert exc.value.constraint == "serve-backend"
    with pytest.raises(ValueError, match="at least one"):
        VTAServingEngine(lenet, backends=())


def test_serve_refuses_unknown_backend_typed(lenet):
    images = request_images(lenet, 2, seed=10)
    with pytest.raises(CompileError) as exc:
        lenet.serve(images, backend="weird")
    assert exc.value.constraint == "serve-backend"
    assert all(b in str(exc.value) for b in SERVE_BACKENDS)


def test_serve_one_refuses_batch_backends_typed(lenet):
    img = request_images(lenet, 1, seed=11)[0]
    for bad in ("batched", "weird"):
        with pytest.raises(CompileError) as exc:
            lenet.serve_one(img, backend=bad)
        assert exc.value.constraint == "serve-one-backend"
        assert all(b in str(exc.value) for b in SERVE_ONE_BACKENDS)


def test_guarded_engine_requires_batched_workers(lenet):
    from repro.harden import GuardPolicy
    with pytest.raises(CompileError) as exc:
        VTAServingEngine(lenet, backends=("batched", "pallas"),
                         guard=GuardPolicy())
    assert exc.value.constraint == "serve-guard-backend"


def test_engine_rejects_mis_shaped_request(lenet):
    engine = VTAServingEngine(lenet)
    with pytest.raises(ValueError, match="signature"):
        engine.submit(np.zeros((1, 3, 32, 32), np.int8))
    assert engine.metrics.submitted == 0


class _ExplodingNet:
    """Minimal NetworkProgram stand-in whose serve always raises —
    exercises the engine's failure path without corrupting a real net."""

    def input_signature(self):
        return ((1, 8, 8), np.dtype(np.int8))

    def padded_batch_sizes(self, max_batch):
        from repro.serving.vta import pad_ladder
        return pad_ladder(max_batch)

    def serve(self, images, backend="batched", guard=None):
        raise RuntimeError("boom")


def test_execution_failure_resolves_tickets_typed():
    """A serve() that raises must fail the tickets with ServingError —
    never leave them unresolved or silently wrong."""
    from repro.serving.vta import ServingError
    net = _ExplodingNet()
    engine = VTAServingEngine(net, policy=BatchPolicy(max_batch=2,
                                                      max_wait_s=0.0),
                              warmup=False)
    with engine:
        ticket = engine.submit(np.zeros((1, 8, 8), np.int8))
        with pytest.raises(ServingError, match="boom"):
            ticket.result(timeout=60.0)
    assert engine.metrics.failed == 1
    assert engine.metrics.drained()


def test_engine_start_is_single_shot_and_result_times_out(lenet):
    engine = VTAServingEngine(lenet, warmup=False)
    ticket = engine.submit(request_images(lenet, 1, seed=13)[0])
    with pytest.raises(TimeoutError):     # no workers started yet
        ticket.result(timeout=0.01)
    engine.start()
    ticket.result(timeout=60.0)
    with pytest.raises(RuntimeError, match="started"):
        engine.start()
    engine.shutdown()


# ---------------------------------------------------------------------------
# Guarded serving under load
# ---------------------------------------------------------------------------

def test_guarded_engine_serves_clean_and_bit_identical(lenet):
    from repro.harden import GuardPolicy
    images = request_images(lenet, 4, seed=12)
    policy = BatchPolicy(max_batch=2, max_wait_s=0.002)
    with VTAServingEngine(lenet, policy=policy,
                          guard=GuardPolicy()) as engine:
        outs, tickets = serve_all(engine, images)
    direct, _ = lenet.serve(images)
    np.testing.assert_array_equal(outs, direct)
    assert all(t.guard_report is not None
               and t.guard_report.outcome == "clean" for t in tickets)
    assert engine.metrics.audit() == []
