"""Pallas kernel correctness sweeps — interpret mode vs the ref.py oracles.

Every kernel is swept over shapes/dtypes and asserted against the pure-jnp
oracle (per the deliverable (c) contract)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# vta_gemm
# ---------------------------------------------------------------------------

GEMM_SHAPES = [(8, 128, 128), (100, 300, 200), (256, 256, 256),
               (1, 17, 5), (130, 200, 140), (512, 128, 384)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
def test_vta_gemm_shapes(m, k, n):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    out = ops.vta_matmul_pallas(a, b)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.vta_gemm_ref(a, b)))


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("shift", [0, 3, 8])
@pytest.mark.parametrize("saturate", [False, True])
def test_vta_gemm_epilogues(relu, shift, saturate):
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.integers(-128, 128, (64, 96)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (96, 80)), jnp.int8)
    bias = jnp.asarray(rng.integers(-5000, 5000, (80,)), jnp.int32)
    out = ops.vta_matmul_pallas(a, b, bias, relu=relu, shift=shift,
                                saturate=saturate)
    expect = ref.vta_gemm_ref(a, b, bias, relu=relu, shift=shift,
                              saturate=saturate)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("out_dtype", [jnp.int8, jnp.int32])
def test_vta_gemm_out_dtypes(out_dtype):
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(-128, 128, (32, 64)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (64, 32)), jnp.int8)
    out = ops.vta_matmul_pallas(a, b, out_dtype=out_dtype)
    assert out.dtype == out_dtype
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.vta_gemm_ref(a, b, out_dtype=out_dtype)))


@given(m=st.integers(1, 64), k=st.integers(1, 96), n=st.integers(1, 64),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_vta_gemm_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    out = ops.vta_matmul_pallas(a, b, relu=True, shift=2)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.vta_gemm_ref(a, b, relu=True, shift=2)))


def test_vta_gemm_matches_core_simulator():
    """Cross-validation: the Pallas kernel (truncating mode) must agree with
    the paper-faithful core/ functional simulator on the same matrices."""
    from repro.core.gemm_compiler import compile_matmul
    from repro.core.simulator import run_program
    rng = np.random.default_rng(17)
    A = rng.integers(-128, 128, (48, 80), dtype=np.int64).astype(np.int8)
    B = rng.integers(-128, 128, (80, 32), dtype=np.int64).astype(np.int8)
    sim_out, _ = run_program(compile_matmul(A, B))
    kern_out = ops.vta_matmul_pallas(jnp.asarray(A), jnp.asarray(B),
                                     saturate=False)
    np.testing.assert_array_equal(sim_out, np.asarray(kern_out))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (b, h, hkv, sq, skv, d)
    (1, 4, 4, 64, 64, 32),      # MHA
    (2, 4, 2, 64, 64, 32),      # GQA 2:1
    (1, 8, 1, 32, 32, 16),      # MQA (gemma3 kv=1)
    (1, 2, 2, 48, 96, 32),      # cross-shaped (prefill continuation)
]


@pytest.mark.parametrize("b,h,hkv,sq,skv,d", ATTN_CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_attention_shapes(b, h, hkv, sq, skv, d, causal):
    rng = np.random.default_rng(b + h + sq)
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, skv, d)), jnp.float32)
    off = skv - sq if causal and skv > sq else 0
    out = ops.attention_pallas(q, k, v, causal=causal, q_offset=off,
                               block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_dtypes(dtype):
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), dtype)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), dtype)
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), dtype)
    out = ops.attention_pallas(q, k, v, block_q=16, block_k=16)
    expect = ref.attention_ref(q, k, v)
    assert out.dtype == dtype
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_attention_sliding_window():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    out = ops.attention_pallas(q, k, v, causal=True, window=16,
                               block_q=16, block_k=16)
    expect = ref.attention_ref(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


def test_attention_q_offset_decode_chunk():
    """Chunked prefill: q block starting at position 32 of a 64-long KV."""
    rng = np.random.default_rng(13)
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 16)), jnp.float32)
    out = ops.attention_pallas(q, k, v, causal=True, q_offset=32,
                               block_q=16, block_k=16)
    expect = ref.attention_ref(q, k, v, causal=True, q_offset=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@given(sq=st.sampled_from([16, 32, 48]), skv=st.sampled_from([16, 32, 64]),
       h=st.sampled_from([1, 2, 4]), g=st.sampled_from([1, 2]),
       seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_attention_property(sq, skv, h, g, seed):
    if h % g:
        g = 1
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, h, sq, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, h // g, skv, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, h // g, skv, 16)), jnp.float32)
    off = max(0, skv - sq)
    out = ops.attention_pallas(q, k, v, causal=True, q_offset=off,
                               block_q=16, block_k=16)
    expect = ref.attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)
