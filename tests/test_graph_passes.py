"""Graph IR + pass pipeline tests (DESIGN.md §Graph).

One test per declared pass invariant, plus a seeded random-DAG fuzz whose
contract is the certification property of the whole front end: every
generated graph either compiles — and then executes bit-identically to
the graph's integer reference on both simulator backends — or raises a
typed :class:`CompileError`.  **Never wrong bytes.**

Hypothesis-free: part of the tier-1 floor.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.core.errors import CompileError
from repro.graph import (GraphBuilder, compile_graph, evaluate_graph,
                         infer_shapes, linearize, plan_requant)


def _w(rng, *shape):
    return rng.integers(-6, 7, shape, dtype=np.int64).astype(np.int8)


def _b(rng, n):
    return rng.integers(-30, 31, (n,), dtype=np.int64).astype(np.int32)


def _mini_resnet(rng, shifts_pinned=False):
    """A one-join residual graph on a (1, 4, 8, 8) input."""
    q = (lambda i: [4, 5, 9, 2][i]) if shifts_pinned else (lambda i: None)
    bld = GraphBuilder("mini")
    x = bld.input("image", shape=(1, 4, 8, 8))
    v = bld.conv("s1", x, _w(rng, 8, 4, 3, 3), _b(rng, 8), padding=1)
    v = bld.relu("s1_r", v)
    v = bld.requant("s1_q", v, shift=q(0))
    skip = v
    v = bld.conv("b1a", skip, _w(rng, 8, 8, 3, 3), _b(rng, 8), padding=1)
    v = bld.relu("b1a_r", v)
    v = bld.requant("b1a_q", v, shift=q(1))
    v = bld.conv("b1b", v, _w(rng, 8, 8, 3, 3), _b(rng, 8), padding=1)
    v = bld.requant("b1b_q", v, shift=q(2))
    v = bld.add("j1", v, skip)
    v = bld.relu("j1_r", v)
    v = bld.requant("j1_q", v, shift=q(3))
    v = bld.flatten("flat", v)
    v = bld.fc("head", v, _w(rng, 8 * 8 * 8, 10), _b(rng, 10))
    v = bld.requant("head_q", v)
    bld.output(v)
    return bld.build()


def _images(rng, n, shape=(1, 4, 8, 8)):
    return [rng.integers(-40, 41, shape, dtype=np.int64).astype(np.int8)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# IR structural verification
# ---------------------------------------------------------------------------

def test_builder_rejects_unknown_refs_duplicates_and_bad_arity():
    bld = GraphBuilder("bad")
    bld.input("x", shape=(1, 1, 4, 4))
    with pytest.raises(CompileError, match="unknown value"):
        bld.relu("r", "nope")
    with pytest.raises(CompileError, match="duplicate"):
        bld.input("x", shape=(1, 1, 4, 4))
    with pytest.raises(CompileError, match="pool mode"):
        bld.pool("p", "x", mode="avg3x3")


def test_graph_verify_certifies_acyclicity():
    """A hand-mutated graph with a cycle must fail topological
    verification (the DAG certificate the passes rely on)."""
    rng = np.random.default_rng(0)
    g = _mini_resnet(rng)
    g.nodes["s1_r"].inputs = ("j1_q",)      # back edge: s1_r reads a later value
    with pytest.raises(CompileError, match="cycle"):
        g.verify()


# ---------------------------------------------------------------------------
# Pass 1: shape inference
# ---------------------------------------------------------------------------

def test_infer_shapes_resolves_every_value():
    rng = np.random.default_rng(1)
    g = _mini_resnet(rng)
    shapes = infer_shapes(g)
    assert set(shapes) == set(g.nodes)            # invariant: all resolved
    assert shapes["s1_q"] == (1, 8, 8, 8)
    assert shapes["j1"] == (1, 8, 8, 8)
    assert shapes["flat"] == (1, 512)
    assert shapes["head_q"] == (1, 10)


def test_infer_shapes_rejects_mismatched_add_and_channels():
    rng = np.random.default_rng(2)
    bld = GraphBuilder("bad")
    x = bld.input("x", shape=(1, 4, 8, 8))
    a = bld.requant("qa", bld.conv("c1", x, _w(rng, 8, 4, 3, 3), padding=1))
    d = bld.requant("qd", bld.conv("c2", x, _w(rng, 6, 4, 3, 3), padding=1))
    j = bld.add("j", a, d)
    bld.output(j)
    with pytest.raises(CompileError, match="add operands"):
        infer_shapes(bld.build())

    bld2 = GraphBuilder("bad2")
    x = bld2.input("x", shape=(1, 3, 8, 8))
    v = bld2.conv("c", x, _w(rng, 8, 4, 3, 3))       # expects 4 channels
    bld2.output(v)
    with pytest.raises(CompileError, match="channel mismatch"):
        infer_shapes(bld2.build())


# ---------------------------------------------------------------------------
# Pass 2: requant planning across joins
# ---------------------------------------------------------------------------

def test_plan_requant_equalises_scales_at_every_join():
    """Invariant: after planning, both operands of every add carry the
    same scale exponent (exp - pre_shift equal on both sides)."""
    rng = np.random.default_rng(3)
    g = _mini_resnet(rng)
    plan = plan_requant(g, _images(rng, 4))
    for name, node in g.nodes.items():
        if node.kind != "add":
            continue
        (ra, rb), (pa, pb) = node.inputs, node.pre_shifts
        assert plan.exps[ra] - pa == plan.exps[rb] - pb
        assert pa >= 0 and pb >= 0
    # every requant got a concrete shift
    assert all(g.nodes[q].shift is not None
               for q in g.nodes if g.nodes[q].kind == "requant")


def test_plan_requant_weight_exp_moves_the_join_pre_shift():
    """``weight_exp`` shifts the scale bookkeeping (not the arithmetic):
    declaring the branch convs one octave finer each must surface as a
    2-octave pre-shift on the skip operand."""
    rng = np.random.default_rng(4)
    seed_imgs = _images(rng, 4)
    g0 = _mini_resnet(np.random.default_rng(4))
    plan0 = plan_requant(g0, seed_imgs)
    base_pa, base_pb = g0.nodes["j1"].pre_shifts

    g1 = _mini_resnet(np.random.default_rng(4))
    g1.nodes["b1a"].weight_exp = plan0.shifts["b1a_q"]
    g1.nodes["b1b"].weight_exp = plan0.shifts["b1b_q"]
    g1.nodes["s1"].weight_exp = plan0.shifts["s1_q"]
    plan1 = plan_requant(g1, seed_imgs)
    pa1, pb1 = g1.nodes["j1"].pre_shifts
    # raw-integer scales: branch is far coarser, skip gets a large shift;
    # calibrated weight scales: operands land together
    assert base_pb > 0 and pb1 == 0 and base_pa == pa1 == 0
    # weight_exp is bookkeeping only: the magnitude-driven shifts
    # upstream of the join are untouched (downstream values change,
    # because the join's pre-shifts changed what flows through it)
    for q in ("s1_q", "b1a_q", "b1b_q"):
        assert plan1.shifts[q] == plan0.shifts[q]
    assert plan1.exps["j1"] == plan1.exps["b1b_q"] - pa1


def test_plan_requant_enforces_int8_feed_and_avg_pool_floor():
    rng = np.random.default_rng(5)
    bld = GraphBuilder("no_requant")
    x = bld.input("x", shape=(1, 2, 6, 6))
    v = bld.conv("c1", x, _w(rng, 4, 2, 3, 3), _b(rng, 4))
    v = bld.conv("c2", v, _w(rng, 4, 4, 3, 3))    # conv fed by raw int32 acc
    bld.output(v)
    with pytest.raises(CompileError, match="int8"):
        plan_requant(bld.build(), _images(rng, 2, (1, 2, 6, 6)))

    bld2 = GraphBuilder("tiny_avg")
    x = bld2.input("x", shape=(1, 1, 4, 4))
    v = bld2.conv("c", x, np.ones((1, 1, 1, 1), dtype=np.int8))
    v = bld2.pool("p", v, "avg2x2")
    v = bld2.requant("q", v)
    bld2.output(v)
    g2 = bld2.build()
    # all-ones weights on a tiny input: magnitudes alone would plan < 2,
    # but the device folds the avg-pool ÷4 into the same SHR
    plan = plan_requant(g2, [np.ones((1, 1, 4, 4), dtype=np.int8)], margin=0)
    assert plan.shifts["q"] >= 2


# ---------------------------------------------------------------------------
# Pass 3: linearization
# ---------------------------------------------------------------------------

def test_linearize_respects_data_dependencies_and_covers_every_node():
    rng = np.random.default_rng(6)
    g = _mini_resnet(rng)
    plan_requant(g, _images(rng, 2))
    steps = linearize(g)
    materialized = set(g.input_names)
    covered = set(g.input_names)
    for step in steps:
        assert step.input_value in materialized       # dependency order
        if step.residual_source is not None:
            assert step.residual_source in materialized
        assert not (set(step.node_names) & covered)   # exactly-once cover
        covered.update(step.node_names)
        materialized.add(step.output_value)
    assert covered == set(g.nodes)                    # full coverage
    res = [s for s in steps if s.residual_source is not None]
    assert [s.name for s in res] == ["b1b"]
    assert res[0].residual_source == "s1_q"
    assert res[0].relu and res[0].residual_shift is not None


def test_linearize_folds_branch_pre_shift_into_requant():
    """(x >> q) >> pre == x >> (q + pre): the branch operand's
    scale-equalising shift must fold into the pre-add requant."""
    rng = np.random.default_rng(7)
    g = _mini_resnet(rng)
    plan_requant(g, _images(rng, 2))
    g.nodes["j1"].pre_shifts = (1, g.nodes["j1"].pre_shifts[1] + 1)
    step = [s for s in linearize(g) if s.residual_source is not None][0]
    assert step.requant_shift == g.nodes["b1b_q"].shift + 1


def test_linearize_rejects_unfusable_patterns():
    rng = np.random.default_rng(8)

    def base(bld):
        x = bld.input("x", shape=(1, 2, 8, 8))
        return bld.conv("c", x, _w(rng, 4, 2, 3, 3), padding=1)

    bld = GraphBuilder("no_requant")
    v = base(bld)
    bld.output(v)                                  # raw acc as output
    with pytest.raises(CompileError, match="consumer"):
        linearize(bld.build())

    bld = GraphBuilder("relu_twice")
    v = base(bld)
    v = bld.relu("r1", v)
    v = bld.relu("r2", v)
    v = bld.requant("q", v, shift=8)
    bld.output(v)
    with pytest.raises(CompileError, match="requant"):
        linearize(bld.build())

    bld = GraphBuilder("pool_after_join")
    v = base(bld)
    q = bld.requant("q", v, shift=8)
    bld2 = bld.conv("c2", q, _w(rng, 4, 4, 3, 3), padding=1)
    q2 = bld.requant("q2", bld2, shift=8)
    j = bld.add("j", q2, q)
    p = bld.pool("p", j, "max2x2")                 # pool of a join value
    out = bld.requant("q3", p, shift=2)
    bld.output(out)
    g = bld.build()
    g.nodes["j"].pre_shifts = (0, 0)
    with pytest.raises(CompileError):
        linearize(g)


# ---------------------------------------------------------------------------
# Random-DAG fuzz: compile or CompileError — never wrong bytes
# ---------------------------------------------------------------------------

def _random_graph(rng):
    """A random small DAG: residual blocks, pools, stride-2 downsampling
    chains, GAP heads, branches, an fc head — with a chance of
    deliberately broken structure (bad channel counts, missing requants,
    joins of mismatched shapes, stride grids that drop pixels, GAP on
    non-power-of-two maps)."""
    bld = GraphBuilder("fuzz")
    c = int(rng.integers(1, 5))
    hw = int(rng.choice([4, 6, 8]))
    x = bld.input("image", shape=(1, c, hw, hw))
    vals = [("image", c, hw)]                      # (name, channels, extent)
    uid = [0]

    def fresh(prefix):
        uid[0] += 1
        return f"{prefix}{uid[0]}"

    def conv_chain(src, sc, shw, *, relu=True, pool=None, requant=True,
                   breakage=0.0, stride=1):
        f = int(rng.integers(1, 7))
        if stride == 2:
            # k3/p1 halving or the k2/p0 projection geometry — on odd
            # extents the k2 grid drops a pixel (a wanted rejection path)
            k, pad = (3, 1) if rng.random() < 0.5 else (2, 0)
        else:
            k = int(rng.choice([1, 3]))
            pad = (k - 1) // 2
        in_c = sc if rng.random() >= breakage else sc + 1   # maybe broken
        v = bld.conv(fresh("c"), src, _w(rng, f, in_c, k, k), _b(rng, f),
                     stride=stride, padding=pad)
        shw = (shw + 2 * pad - k) // stride + 1
        if relu:
            v = bld.relu(fresh("r"), v)
        if pool and shw % 2 == 0:
            v = bld.pool(fresh("p"), v, pool)
            shw //= 2
        if requant:
            v = bld.requant(fresh("q"), v)
        return v, f, shw

    depth = int(rng.integers(1, 4))
    for _ in range(depth):
        src, sc, shw = vals[int(rng.integers(0, len(vals)))]
        kind = rng.random()
        if kind < 0.3 and shw >= 4:               # residual block
            a, fa, _ = conv_chain(src, sc, shw, relu=True)
            bvi = bld.conv(fresh("c"), a, _w(rng, sc, fa, 3, 3),
                           _b(rng, sc), padding=1)
            bq = bld.requant(fresh("q"), bvi)
            j = bld.add(fresh("j"), bq, src)
            j = bld.relu(fresh("r"), j)
            v = bld.requant(fresh("q"), j)
            vals.append((v, sc, shw))
        elif kind < 0.4:                           # deliberately unfused add
            other, oc, ohw = vals[int(rng.integers(0, len(vals)))]
            j = bld.add(fresh("j"), src, other)
            v = bld.requant(fresh("q"), j)
            vals.append((v, sc, shw))
        elif kind < 0.55 and shw >= 3:             # stride-2 downsampling
            v, f, shw2 = conv_chain(src, sc, shw, relu=bool(rng.integers(2)),
                                    stride=2)
            vals.append((v, f, shw2))
        else:                                      # plain conv chain
            pool = rng.choice([None, "max2x2", "avg2x2"])
            requant = rng.random() > 0.1           # sometimes missing
            v, f, shw2 = conv_chain(src, sc, shw, relu=bool(rng.integers(2)),
                                    pool=pool, requant=requant,
                                    breakage=0.15)
            vals.append((v, f, shw2))
    src, sc, shw = vals[int(rng.integers(0, len(vals)))]
    tail = rng.random()
    if tail < 0.25 and shw >= 1:                   # GAP head (maybe non-pow2)
        v = bld.conv(fresh("c"), src, _w(rng, sc, sc, 1, 1), _b(rng, sc))
        v = bld.relu(fresh("r"), v)
        v = bld.global_avg_pool(fresh("g"), v)
        v = bld.requant(fresh("q"), v)
        v = bld.flatten(fresh("f"), v)
        v = bld.fc(fresh("h"), v, _w(rng, sc, 5), _b(rng, 5))
        v = bld.requant(fresh("q"), v)
        bld.output(v)
    elif tail < 0.8:
        v = bld.flatten(fresh("f"), src)
        v = bld.fc(fresh("h"), v, _w(rng, sc * shw * shw, 5), _b(rng, 5))
        v = bld.requant(fresh("q"), v)
        bld.output(v)
    else:
        bld.output(src)                            # maybe an invalid output
    return bld.build(), (1, c, hw, hw)


@pytest.mark.parametrize("seed", range(40))
def test_fuzz_random_dags_compile_or_raise_never_wrong_bytes(seed):
    rng = np.random.default_rng(1000 + seed)
    try:
        graph, in_shape = _random_graph(rng)
    except CompileError:
        return                                     # builder-level rejection
    img = rng.integers(-40, 41, in_shape, dtype=np.int64).astype(np.int8)
    calib = [rng.integers(-40, 41, in_shape, dtype=np.int64).astype(np.int8)
             for _ in range(2)]
    try:
        net = compile_graph(graph, img, calib=calib + [img])
    except CompileError:
        return                                     # typed rejection is fine
    # It compiled: it must now be bit-exact against the graph reference
    # on both backends, per-image and batched.
    expected = evaluate_graph(graph, img)[graph.outputs[0]].astype(np.int8)
    out_o, _ = net.verify(backend="oracle")
    out_f, _ = net.verify(backend="fast")
    np.testing.assert_array_equal(out_o, out_f)
    np.testing.assert_array_equal(out_o.astype(np.int8), expected)
    outs, _ = net.serve([img, img])
    np.testing.assert_array_equal(outs[0].astype(np.int8), expected)
    np.testing.assert_array_equal(outs[1].astype(np.int8), expected)


def test_fuzz_produces_both_outcomes():
    """The fuzz population must contain successful compiles *and* typed
    rejections — otherwise the suite above is vacuous on one side."""
    compiled = rejected = 0
    for seed in range(40):
        rng = np.random.default_rng(1000 + seed)
        try:
            graph, in_shape = _random_graph(rng)
            img = rng.integers(-40, 41, in_shape,
                               dtype=np.int64).astype(np.int8)
            compile_graph(graph, img)
            compiled += 1
        except CompileError:
            rejected += 1
    assert compiled >= 5, f"only {compiled} fuzz graphs compiled"
    assert rejected >= 5, f"only {rejected} fuzz graphs rejected"


# ---------------------------------------------------------------------------
# The lowering's on-VTA residual contract
# ---------------------------------------------------------------------------

def test_residual_join_is_an_alu_add_on_the_vta():
    """The join must execute as a TensorAlu vector-vector ADD against an
    ACC-loaded second operand — not as host-side numpy."""
    rng = np.random.default_rng(9)
    g = _mini_resnet(rng)
    img = _images(rng, 1)[0]
    net = compile_graph(g, img, calib=_images(rng, 3) + [img])
    res = [l for l in net.layers if l.spec.residual_add]
    assert len(res) == 1
    prog = res[0].program
    adds = [i for i in prog.instructions
            if isinstance(i, isa.AluInsn)
            and i.alu_opcode == isa.AluOp.ADD and not i.use_imm]
    assert len(adds) == res[0].n_chunks           # one per chunk
    assert "res" in prog.regions                  # staged ACC operand
    res_loads = [i for i in prog.instructions
                 if isinstance(i, isa.MemInsn)
                 and i.opcode == isa.Opcode.LOAD
                 and i.memory_type == isa.MemId.ACC and i.sram_base > 0]
    assert len(res_loads) == res[0].n_chunks
