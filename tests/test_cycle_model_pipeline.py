"""Regression pins for the three-module cycle model and the pipeline
scheduler (DESIGN.md §Pipeline, EXPERIMENTS.md §Pipeline).

The concurrent timeline is deterministic, so the per-module golden
counts for lenet5 / resnet8 under both schedules are pinned *exactly* —
any drift means the scheduler's emission or the model's cost function
changed and must be re-justified.  Two invariants ride along:

* the §5.2 calibration (2972 TensorGemm cycles for serialized LeNet-5)
  must never move — pipelining is opt-in, the default stream is
  byte-identical to the pre-scheduler compiler's;
* the pipelined makespan is bounded by the serialized schedule's total
  busy cycles (it may trade a small busy premium for large stall wins,
  never the reverse).
"""

import numpy as np
import pytest

from repro.core import cycle_model
from repro.core.gemm_compiler import AluImmOp, compile_matmul
from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)

GOLDEN = {
    "lenet5": {
        "serialized": (8926, {"load": 3053, "compute": 6843, "store": 941}),
        "pipelined": (7679, {"load": 3131, "compute": 6963, "store": 959}),
    },
    "resnet8": {
        "serialized": (99201,
                       {"load": 33576, "compute": 77052, "store": 5771}),
        "pipelined": (81775,
                      {"load": 35488, "compute": 78696, "store": 5993}),
    },
}


def _lenet5_programs(schedule):
    net = compile_network(lenet5_specs(lenet5_random_weights()),
                          synthetic_digit(0), schedule=schedule)
    return [layer.program for layer in net.layers]


def _resnet8_programs(schedule):
    from repro.models.resnet8 import compile_resnet8
    net, _graph = compile_resnet8(schedule=schedule)
    return [layer.program for layer in net.layers]


@pytest.mark.parametrize("schedule", ["serialized", "pipelined"])
def test_lenet5_golden_module_counts(schedule):
    progs = _lenet5_programs(schedule)
    assert all(p.schedule == schedule for p in progs)
    rep = cycle_model.simulate_programs(progs)
    makespan, busy = GOLDEN["lenet5"][schedule]
    assert rep.makespan_cycles == makespan
    assert dict(rep.busy_cycles) == busy
    if schedule == "serialized":
        # §5.2 calibration: 2972 TensorGemm cycles (2942 loops + decode).
        cr = cycle_model.analyze_programs(progs)
        assert cr.tensor_gemm_cycles == 2972


@pytest.mark.parametrize("schedule", ["serialized", "pipelined"])
def test_resnet8_golden_module_counts(schedule):
    progs = _resnet8_programs(schedule)
    assert all(p.schedule == schedule for p in progs)
    rep = cycle_model.simulate_programs(progs)
    makespan, busy = GOLDEN["resnet8"][schedule]
    assert rep.makespan_cycles == makespan
    assert dict(rep.busy_cycles) == busy


def test_resnet8_pipelining_buys_at_least_15pct():
    """The PR's acceptance gate, pinned from the goldens so it cannot
    silently erode: pipelined makespan ≤ 0.85 × serialized."""
    serial, _ = GOLDEN["resnet8"]["serialized"]
    piped, _ = GOLDEN["resnet8"]["pipelined"]
    assert piped <= 0.85 * serial


def test_default_schedule_is_byte_identical_to_serialized():
    """Omitting ``schedule`` must emit the exact serialized stream — the
    paper-calibrated default cannot drift when pipelining lands."""
    from repro.core import isa
    rng = np.random.default_rng(3)
    A = rng.integers(-128, 128, (32, 48)).astype(np.int8)
    B = rng.integers(-128, 128, (48, 32)).astype(np.int8)
    default = compile_matmul(A, B, alu_ops=[AluImmOp.relu()])
    explicit = compile_matmul(A, B, alu_ops=[AluImmOp.relu()],
                              schedule="serialized")
    assert default.schedule == explicit.schedule == "serialized"
    assert (isa.encode_stream(default.instructions)
            == isa.encode_stream(explicit.instructions))
    assert default.segments["uop"] == explicit.segments["uop"]


def test_pipelined_makespan_bounded_by_serialized_total():
    """Model-level safety of the schedule choice, over random shapes:
    max module busy ≤ makespan ≤ total busy (the in-order sweep can
    never beat perfect overlap nor lose to full serialization), and the
    pipelined makespan stays within the serialized schedule's total busy
    cycles even when overlap buys nothing."""
    rng = np.random.default_rng(77)
    for _ in range(6):
        m, k, n = (int(rng.integers(4, 60)) for _ in range(3))
        A = rng.integers(-128, 128, (m, k)).astype(np.int8)
        B = rng.integers(-128, 128, (k, n)).astype(np.int8)
        rep = {}
        for schedule in ("serialized", "pipelined"):
            prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()],
                                  schedule=schedule)
            rep[schedule] = cycle_model.simulate_program(prog)
        for r in rep.values():
            assert (max(r.busy_cycles.values()) <= r.makespan_cycles
                    <= r.total_busy_cycles)
        assert (rep["pipelined"].makespan_cycles
                <= rep["serialized"].total_busy_cycles)
