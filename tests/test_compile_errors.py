"""Typed compiler diagnostics (certification-style traceability).

Unsupported shapes/strides/pool kinds in `compile_layer`/`compile_matmul`
must raise :class:`CompileError` — naming the layer and the violated
constraint — instead of bare asserts or anonymous ValueErrors.  The
``constraint`` identifiers are the stable, greppable part of the
contract; messages may be reworded freely.

Hypothesis-free: part of the tier-1 floor.
"""

import numpy as np
import pytest

from repro.core.errors import CompileError
from repro.core.gemm_compiler import (AluIndexedImmOp, AluPairOp,
                                      AluResidualOp, compile_matmul)
from repro.core.layer_compiler import LayerSpec, compile_layer
from repro.core import isa


def _raises(constraint, fn, *args, **kwargs):
    with pytest.raises(CompileError) as exc:
        fn(*args, **kwargs)
    err = exc.value
    assert err.constraint == constraint, \
        f"expected constraint {constraint!r}, got {err.constraint!r}"
    return err


def test_compile_error_is_a_value_error_and_names_the_layer():
    """Backwards compatibility (existing `except ValueError` call sites)
    + the traceability payload."""
    assert issubclass(CompileError, ValueError)
    err = _raises("conv-input-rank", compile_layer,
                  LayerSpec("c1", "conv", np.zeros((4, 2, 3, 3), np.int8)),
                  np.zeros((2, 8, 8), np.int8))
    assert err.layer == "c1"
    assert "c1" in str(err) and "conv-input-rank" in str(err)


def test_layer_shape_and_stride_diagnostics():
    w = np.zeros((4, 2, 3, 3), np.int8)
    t = np.zeros((1, 2, 8, 8), np.int8)
    _raises("conv-batch-one", compile_layer,
            LayerSpec("c", "conv", w), np.zeros((2, 2, 8, 8), np.int8))
    _raises("conv-weight-rank", compile_layer,
            LayerSpec("c", "conv", np.zeros((4, 18), np.int8)), t)
    _raises("conv-stride", compile_layer,
            LayerSpec("c", "conv", w, stride=0), t)
    _raises("conv-padding", compile_layer,
            LayerSpec("c", "conv", w, padding=-1), t)
    _raises("conv-channels", compile_layer,
            LayerSpec("c", "conv", np.zeros((4, 3, 3, 3), np.int8)), t)
    _raises("conv-kernel-fit", compile_layer,
            LayerSpec("c", "conv", np.zeros((4, 2, 9, 9), np.int8)), t)
    _raises("fc-shape", compile_layer,
            LayerSpec("f", "fc", np.zeros((100, 10), np.int8)),
            np.zeros((1, 64), np.int8))
    _raises("fc-weight-rank", compile_layer,
            LayerSpec("f", "fc", np.zeros((100,), np.int8)),
            np.zeros((1, 100), np.int8))
    _raises("layer-kind", compile_layer,
            LayerSpec("x", "softmax", w), t)


def test_pool_diagnostics():
    w = np.zeros((4, 2, 3, 3), np.int8)
    t = np.zeros((1, 2, 8, 8), np.int8)
    _raises("pool-kind", compile_layer,
            LayerSpec("c", "conv", w, padding=1, pool="avg3x3"), t)
    _raises("pool-needs-conv", compile_layer,
            LayerSpec("f", "fc", np.zeros((128, 10), np.int8),
                      pool="avg2x2"), np.zeros((1, 128), np.int8))
    # valid conv output 7×7 (odd) cannot 2×2-pool
    _raises("pool-even-dims", compile_layer,
            LayerSpec("c", "conv", np.zeros((4, 2, 2, 2), np.int8),
                      pool="max2x2"), t)


def test_strided_geometry_diagnostics():
    """DESIGN.md §Strided-lowering: strides > 2 and stride-2 grids that
    silently drop input pixels must raise — never wrong bytes."""
    rng = np.random.default_rng(3)
    w3 = rng.integers(-4, 5, (4, 2, 3, 3)).astype(np.int8)
    w2 = rng.integers(-4, 5, (4, 2, 2, 2)).astype(np.int8)
    t8 = rng.integers(-16, 17, (1, 2, 8, 8)).astype(np.int8)
    t9 = rng.integers(-16, 17, (1, 2, 9, 9)).astype(np.int8)
    # stride values > 2 are outside the lowering's vocabulary
    _raises("conv-stride-max", compile_layer,
            LayerSpec("c", "conv", w3, stride=3, padding=1), t9)
    _raises("conv-stride-max", compile_layer,
            LayerSpec("c", "conv", w3, stride=4), t9)
    # stride-2 on odd spatial dims without padding: the k2 window grid
    # stops one pixel short of the input edge
    _raises("conv-stride-tiling", compile_layer,
            LayerSpec("c", "conv", w2, stride=2), t9)
    # valid (pad-0) k3/s2 on even dims also leaves a dropped column
    _raises("conv-stride-tiling", compile_layer,
            LayerSpec("c", "conv", w3, stride=2), t8)
    # the supported downsampling geometries compile: k3/s2/p1 halving,
    # the k2/s2 projection shortcut, and valid k3/s2 on odd dims
    for spec, t in ((LayerSpec("ok", "conv", w3, stride=2, padding=1), t8),
                    (LayerSpec("ok", "conv", w2, stride=2), t8),
                    (LayerSpec("ok", "conv", w3, stride=2), t9)):
        layer = compile_layer(spec, t)
        assert (layer.out_h, layer.out_w) == (4, 4)


def test_gap_geometry_diagnostics():
    """Global average pooling needs a square power-of-two map (the ÷H·W
    must be one exact SHR) and never compiles a straddling tree."""
    rng = np.random.default_rng(4)
    w = rng.integers(-4, 5, (4, 2, 1, 1)).astype(np.int8)
    gap = lambda: LayerSpec("g", "conv", w, relu=True, pool="gap")
    _raises("gap-square", compile_layer, gap(),
            rng.integers(-16, 17, (1, 2, 8, 4)).astype(np.int8))
    _raises("gap-pow2", compile_layer, gap(),
            rng.integers(-16, 17, (1, 2, 6, 6)).astype(np.int8))
    # a GAP result too large for one ACC residency refuses to compile
    # (the tree's pair groups may not straddle chunks)
    from repro.core.hwconfig import VTAConfig
    tiny = VTAConfig(inp_buff_vectors=256, wgt_buff_matrices=64,
                     acc_buff_vectors=32, out_buff_vectors=64,
                     uop_buff_entries=64)
    _raises("alu-pair-group-chunk", compile_layer, gap(),
            rng.integers(-16, 17, (1, 2, 8, 8)).astype(np.int8), cfg=tiny)
    # GAP on fc raises like every pool kind
    _raises("pool-needs-conv", compile_layer,
            LayerSpec("f", "fc", np.zeros((16, 4), np.int8), pool="gap"),
            np.zeros((1, 16), np.int8))


def test_graph_builder_rejects_strided_geometry_early():
    """The graph front end applies the same constraints: stride > 2 at
    node construction, grid tiling at shape inference."""
    from repro.graph import GraphBuilder, infer_shapes
    rng = np.random.default_rng(5)
    w3 = rng.integers(-4, 5, (4, 2, 3, 3)).astype(np.int8)
    bld = GraphBuilder("bad")
    x = bld.input("x", shape=(1, 2, 8, 8))
    _raises("conv-stride-max", bld.conv, "c", x, w3, stride=3)
    v = bld.conv("c", x, w3, stride=2)             # valid k3/s2 on 8×8
    bld.output(v)
    _raises("conv-stride-tiling", infer_shapes, bld.build())

    bld2 = GraphBuilder("bad_gap")
    x = bld2.input("x", shape=(1, 2, 6, 6))
    v = bld2.conv("c", x, rng.integers(-4, 5, (4, 2, 1, 1)).astype(np.int8))
    v = bld2.global_avg_pool("g", v)
    bld2.output(v)
    _raises("gap-pow2", infer_shapes, bld2.build())


def test_requant_overflow_diagnostic():
    rng = np.random.default_rng(0)
    w = rng.integers(-6, 7, (4, 2, 3, 3)).astype(np.int8)
    t = rng.integers(-64, 65, (1, 2, 8, 8)).astype(np.int8)
    _raises("requant-int8-range", compile_layer,
            LayerSpec("c", "conv", w, requant_shift=0), t)


def test_matmul_diagnostics():
    rng = np.random.default_rng(1)
    A = rng.integers(-4, 5, (8, 6)).astype(np.int8)
    B = rng.integers(-4, 5, (6, 4)).astype(np.int8)
    _raises("gemm-shape", compile_matmul, A, B[:3])
    _raises("bias-xor-preload", compile_matmul, A, B,
            X=np.zeros((8, 4), np.int32), bias=np.zeros((4,), np.int32))
    _raises("alu-index-range", compile_matmul, A, B,
            alu_ops=[AluIndexedImmOp(isa.AluOp.SHR, 1, (10_000,))])
    _raises("alu-index-range", compile_matmul, A, B,
            alu_ops=[AluPairOp(isa.AluOp.ADD, ((0, 10_000),))])


def test_residual_pairing_diagnostics():
    rng = np.random.default_rng(2)
    A = rng.integers(-4, 5, (8, 6)).astype(np.int8)
    B = rng.integers(-4, 5, (6, 4)).astype(np.int8)
    R = np.zeros((8, 4), np.int32)
    _raises("residual-operand-op-pairing", compile_matmul, A, B, residual=R)
    _raises("residual-operand-op-pairing", compile_matmul, A, B,
            alu_ops=[AluResidualOp()])
    _raises("residual-shape", compile_matmul, A, B,
            alu_ops=[AluResidualOp()], residual=np.zeros((4, 8), np.int32))
    _raises("residual-single-op", compile_matmul, A, B,
            alu_ops=[AluResidualOp(), AluResidualOp()], residual=R)

    w = rng.integers(-4, 5, (4, 2, 3, 3)).astype(np.int8)
    t = rng.integers(-32, 33, (1, 2, 8, 8)).astype(np.int8)
    res_spec = LayerSpec("r", "conv", w, padding=1, requant_shift=8,
                         residual_add=True, residual_shift=1)
    _raises("residual-operand-missing", compile_layer, res_spec, t)
    _raises("residual-no-pool", compile_layer,
            LayerSpec("r", "conv", w, padding=1, pool="max2x2",
                      residual_add=True), t,
            residual=np.zeros((1, 4, 8, 8), np.int8))
    _raises("residual-unexpected-operand", compile_layer,
            LayerSpec("c", "conv", w, padding=1, requant_shift=8), t,
            residual=np.zeros((1, 4, 8, 8), np.int8))
    _raises("residual-shape", compile_layer, res_spec, t,
            residual=np.zeros((1, 4, 4, 4), np.int8))
