"""Per-architecture smoke tests (deliverable (f)): every assigned arch
instantiates a REDUCED same-family config and runs one forward + one train
step on CPU, asserting output shapes and the absence of NaNs.  Serving
(prefill + 2 decode steps) is exercised for every decoder."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke
from repro.models.params import init_params, param_count
from repro.models.transformer import encode, forward, model_defs, unembed_logits
from repro.optim import adamw
from repro.serving.cache import init_cache
from repro.serving.engine import decode_step, prefill
from repro.train.train_step import TrainConfig, make_train_step

# Seed-legacy LM-stack suite: fails on the container's jax/orbax versions;
# excluded from the blocking VTA-core run (pytest.ini 'legacy' marker).
pytestmark = pytest.mark.legacy

B, S = 2, 32


def _batch(cfg, rng):
    s_tok = S - cfg.frontend_prefix
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, s_tok)),
                              jnp.int32),
    }
    if cfg.frontend_prefix:
        batch["prefix_embed"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.frontend_prefix, cfg.d_model)),
            jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (B, cfg.encoder_seq, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    rng = np.random.default_rng(hash(arch) % 2**31)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg, rng)
    enc_out = (encode(params, cfg, batch["frames"])
               if cfg.encoder_layers else None)
    h, aux = forward(params, cfg, batch["tokens"],
                     prefix_embed=batch.get("prefix_embed"), enc_out=enc_out)
    s_total = batch["tokens"].shape[1] + cfg.frontend_prefix
    assert h.shape == (B, s_total, cfg.d_model)
    logits = unembed_logits(params, cfg, h)
    assert logits.shape == (B, s_total, cfg.vocab_padded)
    arr = np.asarray(logits, np.float32)[..., :cfg.vocab]
    assert np.isfinite(arr).all(), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_and_stays_finite(arch):
    cfg = get_smoke(arch)
    rng = np.random.default_rng(1)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(1), jnp.float32)
    tc = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=10))
    step = jax.jit(make_train_step(cfg, tc))
    opt = adamw.init(tc.opt, params)
    batch = _batch(cfg, rng)
    params, opt, metrics = step(params, opt, batch)
    loss0 = float(metrics["loss"])
    assert np.isfinite(loss0), f"{arch}: loss is {loss0}"
    for _ in range(2):
        params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # same batch thrice → loss must drop (the step actually learns)
    assert float(metrics["loss"]) < loss0, arch
    # params stayed finite
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serving_prefill_decode(arch):
    cfg = get_smoke(arch)
    rng = np.random.default_rng(2)
    params = init_params(model_defs(cfg), jax.random.PRNGKey(2), jnp.float32)
    batch = _batch(cfg, rng)
    enc_out = (encode(params, cfg, batch["frames"])
               if cfg.encoder_layers else None)
    cache = init_cache(cfg, B, 64, jnp.float32)
    logits, cache = prefill(params, cfg, batch["tokens"][:, :16], cache,
                            prefix_embed=batch.get("prefix_embed"),
                            frames=batch.get("frames"))
    assert logits.shape == (B, cfg.vocab_padded)
    pos = 16 + cfg.frontend_prefix
    tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)
    for t in range(2):
        logits, cache = decode_step(params, cfg, cache, tok,
                                    jnp.int32(pos + t), enc_out=enc_out)
        assert np.isfinite(np.asarray(logits, np.float32)
                           [:, :cfg.vocab]).all(), arch
        tok = jnp.argmax(logits[:, :cfg.vocab], -1).astype(jnp.int32)


def test_full_configs_match_assignment():
    """The full configs carry the exact assigned hyper-parameters."""
    from repro.configs import get_config
    spec = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch
    # family checks
    assert get_config("nemotron-4-340b").act == "sq_relu"
    assert get_config("qwen2.5-3b").qkv_bias
    assert get_config("gemma3-1b").local_ratio == 5
    assert get_config("rwkv6-7b").ssm_kind == "rwkv6"
    assert get_config("mixtral-8x22b").moe.n_experts == 8
    assert get_config("mixtral-8x22b").moe.top_k == 2
    assert get_config("moonshot-v1-16b-a3b").moe.n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").moe.top_k == 6
    assert get_config("jamba-1.5-large-398b").ssm_ratio == 7
    assert get_config("jamba-1.5-large-398b").moe.n_experts == 16
    assert get_config("whisper-base").encoder_layers == 6
    assert get_config("internvl2-26b").frontend == "vision"


def test_param_counts_in_family_range():
    """Total parameters of the full configs land near the names (sanity of
    the config translation; MoE counts are total, not active)."""
    from repro.configs import get_config
    from repro.models.transformer import model_defs
    expect = {
        "nemotron-4-340b": (300e9, 380e9),
        "qwen1.5-110b": (95e9, 125e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "gemma3-1b": (0.9e9, 1.6e9),
        "rwkv6-7b": (6e9, 9e9),
        "mixtral-8x22b": (120e9, 150e9),
        "jamba-1.5-large-398b": (330e9, 420e9),
        # assignment lists MoE 64e×1408 on every layer ⇒ 28B total / ~3B
        # active (real Moonlight mixes dense layers; DESIGN.md §Arch)
        "moonshot-v1-16b-a3b": (24e9, 32e9),
        "internvl2-26b": (18e9, 26e9),   # LM backbone only (ViT is a stub)
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(model_defs(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.0e},{hi:.0e}]"
