"""Optimizer tests: AdamW semantics, 8-bit Adam fidelity, LR schedule."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def _quadratic_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(16, 300)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(300,)), jnp.float32)}


def _run(cfg, steps=150, seed=0):
    params = _quadratic_params(seed)
    target = jax.tree.map(lambda p: p * 0 + 1.0, params)
    state = adamw.init(cfg, params)

    def loss(p):
        return sum(jnp.mean((a - t) ** 2)
                   for a, t in zip(jax.tree.leaves(p),
                                   jax.tree.leaves(target)))

    @jax.jit
    def step(params, state):
        g = jax.grad(loss)(params)
        return adamw.apply_updates(cfg, params, g, state)

    for _ in range(steps):
        params, state, metrics = step(params, state)
    return float(loss(params)), params, metrics


def test_adamw_converges():
    final, _, metrics = _run(adamw.AdamWConfig(
        lr=5e-2, weight_decay=0.0, warmup_steps=1, total_steps=200))
    assert final < 0.05
    assert np.isfinite(float(metrics["grad_norm"]))


def test_eightbit_tracks_f32():
    cfg32 = adamw.AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1,
                              total_steps=200)
    cfg8 = adamw.AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=1,
                             total_steps=200, eightbit=True)
    f32, p32, _ = _run(cfg32)
    f8, p8, _ = _run(cfg8)
    assert f8 < 0.1                     # still converges
    # trajectories stay close (quantisation noise is bounded)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p8)):
        assert float(jnp.abs(a - b).mean()) < 0.05


def test_eightbit_moment_is_param_shaped():
    """int8 moments keep the parameter shape (sharding inheritance —
    EXPERIMENTS.md §Perf iteration 'm8layout')."""
    cfg = adamw.AdamWConfig(eightbit=True)
    params = {"w": jnp.zeros((8, 300), jnp.float32)}
    st = adamw.init(cfg, params)
    assert st.mu["w"].q.shape == (8, 300)
    assert st.mu["w"].scale.shape == (8, 2)    # ceil(300/256) = 2 blocks


def test_q8_roundtrip_bounded_error():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 700)) * 10, jnp.float32)
    q, s = adamw._q8(x, 2)
    back = adamw._dq8(q, s, 2)
    err = np.abs(np.asarray(back - x))
    # power-2 code: relative error ~2/127 of magnitude + floor scale/127²
    rel = err / np.maximum(np.abs(np.asarray(x)), 1e-6)
    big = np.abs(np.asarray(x)) > np.abs(np.asarray(x)).max() / 50
    assert rel[big].max() < 0.05


def test_grad_clip():
    cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1e-3, warmup_steps=1)
    params = {"w": jnp.ones((4, 4))}
    state = adamw.init(cfg, params)
    huge = {"w": jnp.full((4, 4), 1e6)}
    new_params, _, m = adamw.apply_updates(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e5
    # step is bounded by lr regardless of gradient magnitude
    assert float(jnp.abs(new_params["w"] - params["w"]).max()) < 2 * cfg.lr


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lrs = [float(adamw.lr_at(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6            # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # decay
