"""Differential tests: the vectorized fast-path simulator vs the oracle.

The fast simulator (:mod:`repro.core.fast_simulator`) must be *bit-exact*
against the per-struct Python interpreter on every observable: the decoded
output matrix, the full DRAM image, and the SimReport counters (GeMM/ALU
loop counts, DRAM traffic, instruction trace).  These tests fuzz random
``compile_matmul`` programs (shapes, ALU post-ops, multi-chunk plans),
exercise the pair/indexed ALU forms (including vector-pair SHR), padding
loads, hazard detection, and the LeNet-5 end-to-end chain.

Deliberately hypothesis-free: this suite is part of the tier-1 floor and
must run in minimal environments.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.core.fast_simulator import FastSimulator, compile_plan, plan_for
from repro.core.gemm_compiler import (AluImmOp, AluIndexedImmOp, AluPairOp,
                                      compile_matmul)
from repro.core.hwconfig import VTAConfig, vta_default, vta_tpu
from repro.core.simulator import (FunctionalSimulator, VTAHazardError,
                                  make_simulator, run_program,
                                  verify_program)

_REPORT_FIELDS = ("gemm_loops", "gemm_reset_loops", "alu_loops",
                  "dram_bytes_read", "dram_bytes_written", "insn_executed",
                  "insn_trace")


def assert_backends_identical(prog):
    """Run both backends over the program's DRAM image; every observable
    must match bit-for-bit."""
    oracle = FunctionalSimulator(prog.config, prog.dram_image(), trace=True)
    rep_o = oracle.run(prog.instructions)
    fast = FastSimulator(prog.config, prog.dram_image(), trace=True)
    rep_f = fast.run(prog.instructions)
    np.testing.assert_array_equal(oracle.dram, fast.dram,
                                  err_msg="DRAM image diverged")
    for field in _REPORT_FIELDS:
        assert getattr(rep_o, field) == getattr(rep_f, field), field
    # SRAM end state (stronger than the DRAM check alone)
    np.testing.assert_array_equal(oracle.acc_buf, fast.acc_buf)
    np.testing.assert_array_equal(oracle.inp_buf, fast.inp_buf)
    np.testing.assert_array_equal(oracle.wgt_buf, fast.wgt_buf)
    np.testing.assert_array_equal(oracle.uop_buf, fast.uop_buf)
    return rep_o


# ---------------------------------------------------------------------------
# Differential fuzz over compile_matmul programs
# ---------------------------------------------------------------------------

def _random_alu_ops(rng):
    ops = []
    if rng.random() < 0.5:
        ops.append(AluImmOp.relu())
    if rng.random() < 0.5:
        ops.append(AluImmOp(isa.AluOp.ADD, int(rng.integers(-200, 200))))
    if rng.random() < 0.5:
        ops.append(AluImmOp(isa.AluOp.MIN, int(rng.integers(0, 128))))
    if rng.random() < 0.5:
        ops.append(AluImmOp.shr(int(rng.integers(1, 8))))
    return ops


def test_fuzz_matmul_programs():
    """Random shapes / X preloads / ALU post-ops: fast == oracle."""
    rng = np.random.default_rng(2026)
    for case in range(20):
        m, k, n = (int(rng.integers(1, 70)) for _ in range(3))
        A = rng.integers(-128, 128, (m, k)).astype(np.int8)
        B = rng.integers(-128, 128, (k, n)).astype(np.int8)
        X = None
        if rng.random() < 0.4:
            X = rng.integers(-10**6, 10**6, (m, n)).astype(np.int32)
        prog = compile_matmul(A, B, X=X, alu_ops=_random_alu_ops(rng))
        assert_backends_identical(prog)
        verify_program(prog, backend="fast")


def test_fuzz_multi_chunk_programs():
    """Tiny SRAM forces multi-chunk plans (§3.3 repetition): fast == oracle."""
    rng = np.random.default_rng(7)
    cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                    acc_buff_vectors=64, out_buff_vectors=64,
                    uop_buff_entries=32)
    for case in range(6):
        m = int(rng.integers(20, 100))
        k = int(rng.integers(20, 100))
        n = int(rng.integers(20, 80))
        A = rng.integers(-128, 128, (m, k)).astype(np.int8)
        B = rng.integers(-128, 128, (k, n)).astype(np.int8)
        prog = compile_matmul(A, B, alu_ops=_random_alu_ops(rng), cfg=cfg)
        report = assert_backends_identical(prog)
        assert report.gemm_loops == prog.gemm_loops()
        verify_program(prog, backend="fast")


def test_tpu_profile_fast_backend():
    """block_size=128 exercises the chunked einsum path."""
    rng = np.random.default_rng(3)
    A = rng.integers(-16, 16, (130, 200)).astype(np.int8)
    B = rng.integers(-16, 16, (200, 140)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()], cfg=vta_tpu())
    assert_backends_identical(prog)


# ---------------------------------------------------------------------------
# ALU pair / indexed forms — incl. the vector-pair SHR regression test
# ---------------------------------------------------------------------------

def test_alu_vector_pair_shr():
    """SHR in vector-pair form: acc[dst] >>= (acc[src] & 31), per lane.

    Regression for the dead conditional in the oracle's SHR handling — the
    imm and vector-pair branches were textually identical; this pins the
    vector-pair semantics on both backends against a numpy reference.
    """
    rng = np.random.default_rng(17)
    A = rng.integers(0, 8, (16, 16)).astype(np.int8)
    B = rng.integers(0, 8, (16, 16)).astype(np.int8)
    # acc rows hold A·B >= 0; shift row 0 by row 1's low 5 bits, etc.
    pairs = ((0, 1), (2, 3), (5, 4))
    prog = compile_matmul(A, B, alu_ops=[AluPairOp(isa.AluOp.SHR, pairs)])
    assert_backends_identical(prog)
    out, _ = run_program(prog)
    acc = A.astype(np.int64) @ B.astype(np.int64)
    for dst, src in pairs:
        acc[dst] = acc[dst] >> (acc[src] & 31)
    np.testing.assert_array_equal(
        out, (acc & 0xFF).astype(np.uint8).view(np.int8))
    verify_program(prog, backend="fast")


def test_alu_pair_and_indexed_program():
    """Pool-style program: ADD pairs into a base row + indexed SHR."""
    rng = np.random.default_rng(23)
    A = rng.integers(-16, 16, (32, 16)).astype(np.int8)
    B = rng.integers(-16, 16, (16, 16)).astype(np.int8)
    pairs = tuple((dst, src) for dst in (0, 4, 8)
                  for src in (dst + 1, dst + 2, dst + 3))
    prog = compile_matmul(A, B, alu_ops=[
        AluPairOp(isa.AluOp.ADD, pairs),
        AluIndexedImmOp(isa.AluOp.SHR, 2, (0, 4, 8)),
    ])
    assert_backends_identical(prog)
    verify_program(prog, backend="fast")


def test_alu_pair_read_after_write_falls_back():
    """A pair chain whose source is an earlier destination (read-after-
    write) must take the sequential fallback and still match the oracle."""
    rng = np.random.default_rng(31)
    A = rng.integers(-8, 8, (16, 16)).astype(np.int8)
    B = rng.integers(-8, 8, (16, 16)).astype(np.int8)
    # acc[1] += acc[2]; acc[0] += acc[1]  — second pair reads the first's dst
    prog = compile_matmul(A, B, alu_ops=[
        AluPairOp(isa.AluOp.ADD, ((1, 2), (0, 1)))])
    assert_backends_identical(prog)
    verify_program(prog, backend="fast")


# ---------------------------------------------------------------------------
# Multi-chunk indexed/pair ALU programs + uop-wave streaming (DESIGN.md §3)
# ---------------------------------------------------------------------------

_SMALL_CFG = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                       acc_buff_vectors=64, out_buff_vectors=64,
                       uop_buff_entries=32)


def _count_uop_loads(prog):
    return sum(1 for i in prog.instructions
               if isinstance(i, isa.MemInsn)
               and i.memory_type == isa.MemId.UOP)


def test_fuzz_multi_chunk_indexed_and_pair_programs():
    """Indexed-imm and pair ALU programs on multi-chunk results — the
    first NotImplementedError ceiling of PR 1.  Pairs are confined to one
    block row/col (always chunk-safe); indices scatter everywhere."""
    rng = np.random.default_rng(2027)
    for case in range(6):
        m = int(rng.integers(40, 100))
        k = int(rng.integers(20, 80))
        n = int(rng.integers(17, 60))
        A = rng.integers(-64, 64, (m, k)).astype(np.int8)
        B = rng.integers(-64, 64, (k, n)).astype(np.int8)
        rh = 16
        alpha = -(-m // rh)
        beta = -(-n // rh)
        n_vec = alpha * beta * rh
        idx = tuple(int(v) for v in
                    rng.choice(n_vec, size=min(n_vec, 40), replace=False))
        pairs = []
        for _ in range(10):
            br = int(rng.integers(0, alpha))
            bc = int(rng.integers(0, beta))
            w0, w1 = rng.choice(rh, size=2, replace=False)
            base = (br * beta + bc) * rh
            pairs.append((base + int(w0), base + int(w1)))
        prog = compile_matmul(
            A, B, cfg=_SMALL_CFG,
            alu_ops=[AluImmOp.relu(),
                     AluPairOp(isa.AluOp.ADD, tuple(pairs)),
                     AluIndexedImmOp(isa.AluOp.SHR, 2, idx)])
        assert prog.chunk_plan.n_chunks > 1
        assert_backends_identical(prog)
        verify_program(prog, backend="fast")


def test_multi_chunk_cross_row_pairs_align_chunk_boundaries():
    """Pairs that span block rows force the planner to cut only at
    group-aligned boundaries; both ends stay in one ACC window."""
    rng = np.random.default_rng(5)
    A = rng.integers(-64, 64, (80, 48)).astype(np.int8)
    B = rng.integers(-64, 64, (48, 16)).astype(np.int8)
    cfg = VTAConfig(inp_buff_vectors=256, wgt_buff_matrices=8,
                    acc_buff_vectors=32, out_buff_vectors=32,
                    uop_buff_entries=64)
    rh = 16
    pairs = ((0 * rh + 15, 1 * rh + 0), (2 * rh + 3, 3 * rh + 3))
    prog = compile_matmul(A, B, cfg=cfg,
                          alu_ops=[AluPairOp(isa.AluOp.MAX, pairs)])
    assert prog.chunk_plan.n_chunks > 1
    # every chunk boundary falls between the (0,1) and (2,3) groups
    starts = [s for s, _ in prog.chunk_plan.alpha_segs]
    assert all(s not in (1, 3) for s in starts)
    assert_backends_identical(prog)
    verify_program(prog, backend="fast")


def test_unsplittable_pair_group_is_a_clear_error():
    """A pair group wider than any admissible chunk raises ValueError
    (not a silent wrong answer, not NotImplementedError)."""
    rng = np.random.default_rng(6)
    A = rng.integers(-64, 64, (80, 48)).astype(np.int8)
    B = rng.integers(-64, 64, (48, 16)).astype(np.int8)
    cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                    acc_buff_vectors=16, out_buff_vectors=16,
                    uop_buff_entries=32)   # alpha_c == 1 block row
    with pytest.raises(ValueError, match="spans more than one SRAM chunk"):
        compile_matmul(A, B, cfg=cfg,
                       alu_ops=[AluPairOp(isa.AluOp.ADD, ((15, 16),))])


def test_fuzz_uop_wave_streaming():
    """Programs whose uop lists exceed the buffer stream LOAD_UOP waves —
    the second NotImplementedError ceiling of PR 1.  Fast == oracle on
    every observable, including the extra LOAD UOP traffic."""
    rng = np.random.default_rng(2028)
    for uop_entries in (8, 12, 20):
        cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                        acc_buff_vectors=64, out_buff_vectors=64,
                        uop_buff_entries=uop_entries)
        m = int(rng.integers(40, 90))
        k = int(rng.integers(20, 60))
        n = int(rng.integers(10, 40))
        A = rng.integers(-64, 64, (m, k)).astype(np.int8)
        B = rng.integers(-64, 64, (k, n)).astype(np.int8)
        rh = 16
        n_vec = -(-m // rh) * -(-n // rh) * rh
        idx = tuple(int(v) for v in rng.choice(n_vec, size=n_vec // 2,
                                               replace=False))
        prog = compile_matmul(A, B, cfg=cfg,
                              alu_ops=[AluImmOp.relu(),
                                       AluIndexedImmOp(isa.AluOp.ADD, 3, idx)])
        assert _count_uop_loads(prog) > 1, "expected multi-wave streaming"
        assert len(prog.uops) > uop_entries
        assert_backends_identical(prog)
        verify_program(prog, backend="fast")


def test_uop_wave_alu_list_split_across_waves():
    """One indexed ALU op bigger than the whole buffer splits into several
    AluInsns with interleaved LOAD_UOPs; total loop count is preserved."""
    rng = np.random.default_rng(9)
    A = rng.integers(-16, 16, (32, 16)).astype(np.int8)
    B = rng.integers(-16, 16, (16, 16)).astype(np.int8)
    cfg = VTAConfig(inp_buff_vectors=2048, wgt_buff_matrices=1024,
                    acc_buff_vectors=2048, out_buff_vectors=2048,
                    uop_buff_entries=8)
    idx = tuple(range(32))
    prog = compile_matmul(A, B, cfg=cfg,
                          alu_ops=[AluIndexedImmOp(isa.AluOp.SHR, 1, idx)])
    alus = [i for i in prog.instructions if isinstance(i, isa.AluInsn)]
    assert len(alus) > 1
    assert sum(a.loop_count for a in alus) == len(idx)
    assert_backends_identical(prog)
    verify_program(prog, backend="fast")


def test_padded_conv_max_pool_layer_multi_chunk():
    """Same-padded conv + 2×2 max pool compiled multi-chunk: the MAX pair
    program is re-indexed per chunk and bit-exact on both backends."""
    from repro.core.layer_compiler import LayerSpec, compile_layer, verify_layer
    rng = np.random.default_rng(44)
    cfg = VTAConfig(inp_buff_vectors=256, wgt_buff_matrices=64,
                    acc_buff_vectors=128, out_buff_vectors=128,
                    uop_buff_entries=256)
    for pool in ("max2x2", "avg2x2"):
        spec = LayerSpec(
            name=f"c_{pool}", kind="conv",
            weights=rng.integers(-8, 8, (8, 3, 3, 3)).astype(np.int8),
            bias=rng.integers(-100, 100, (8,)).astype(np.int32),
            padding=1, relu=True, pool=pool)
        inp = rng.integers(-32, 64, (1, 3, 16, 16)).astype(np.int8)
        layer = compile_layer(spec, inp, cfg=cfg)
        assert layer.n_chunks > 1
        assert layer.out_h == layer.out_w == 8   # same padding halved once
        rep_o = verify_layer(layer)
        rep_f = verify_layer(layer, backend="fast")
        assert rep_o.gemm_loops == rep_f.gemm_loops
        assert rep_o.alu_loops == rep_f.alu_loops


# ---------------------------------------------------------------------------
# LOAD padding, hazards, plan caching, backend plumbing
# ---------------------------------------------------------------------------

def test_load_with_padding_matches_oracle():
    """Handcrafted LOAD with x/y zero-padding on both sides."""
    cfg = vta_default()
    rng = np.random.default_rng(5)
    dram = rng.integers(0, 256, 4096).astype(np.uint8)
    insns = [
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.INP, sram_base=3, dram_base=2,
                    y_size=3, x_size=4, x_stride=6,
                    y_pad_0=1, y_pad_1=2, x_pad_0=1, x_pad_1=2),
        isa.FinishInsn(),
    ]
    oracle = FunctionalSimulator(cfg, dram)
    fast = FastSimulator(cfg, dram)
    rep_o = oracle.run(insns)
    rep_f = fast.run(insns)
    np.testing.assert_array_equal(oracle.inp_buf, fast.inp_buf)
    assert rep_o.dram_bytes_read == rep_f.dram_bytes_read


def test_degenerate_store_is_a_noop_on_both_backends():
    """y_size=0 STOREs move nothing; neither backend may raise."""
    cfg = vta_default()
    dram = np.zeros(4096, dtype=np.uint8)
    insns = [
        isa.MemInsn(isa.Opcode.STORE, isa.MemId.OUT, sram_base=0,
                    dram_base=100, y_size=0, x_size=4, x_stride=4),
        isa.FinishInsn(),
    ]
    oracle = FunctionalSimulator(cfg, dram)
    fast = FastSimulator(cfg, dram)
    rep_o = oracle.run(insns)
    rep_f = fast.run(insns)
    np.testing.assert_array_equal(oracle.dram, fast.dram)
    assert rep_o.dram_bytes_written == rep_f.dram_bytes_written == 0


def test_verify_layer_on_both_backends():
    """compile_layer → verify_layer: conv with ReLU + avg-pool exercises
    the pair/indexed ALU program on the fast path."""
    from repro.core.layer_compiler import LayerSpec, compile_layer, verify_layer
    rng = np.random.default_rng(41)
    spec = LayerSpec(name="c1", kind="conv",
                     weights=rng.integers(-8, 8, (6, 1, 5, 5)).astype(np.int8),
                     bias=rng.integers(-100, 100, (6,)).astype(np.int32),
                     relu=True, pool="avg2x2")
    inp = rng.integers(0, 64, (1, 1, 12, 12)).astype(np.int8)
    layer = compile_layer(spec, inp)
    rep_o = verify_layer(layer)
    rep_f = verify_layer(layer, backend="fast")
    assert rep_o.gemm_loops == rep_f.gemm_loops
    assert rep_o.alu_loops == rep_f.alu_loops


def test_fast_backend_detects_hazards():
    """Dropping a push flag trips the shared token checker on both paths."""
    rng = np.random.default_rng(1)
    A = rng.integers(-64, 64, (16, 16)).astype(np.int8)
    B = rng.integers(-64, 64, (16, 16)).astype(np.int8)
    prog = compile_matmul(A, B)
    for i in prog.instructions:
        if isinstance(i, isa.MemInsn) and i.memory_type == isa.MemId.WGT:
            i.dep.push_next = 0
    sim = FastSimulator(prog.config, prog.dram_image())
    with pytest.raises(VTAHazardError):
        sim.run(prog.instructions)


def test_plan_is_cached_on_program():
    rng = np.random.default_rng(9)
    A = rng.integers(-64, 64, (16, 16)).astype(np.int8)
    B = rng.integers(-64, 64, (16, 16)).astype(np.int8)
    prog = compile_matmul(A, B)
    plan1 = plan_for(prog)
    plan2 = plan_for(prog)
    assert plan1 is plan2
    assert plan1.n_insns == len(prog.instructions)
    # a plan compiled standalone matches the cached one's shape
    assert compile_plan(prog.config, prog.instructions).n_insns == \
        plan1.n_insns
    # replacing an instruction object invalidates the cached plan
    prog.instructions[0] = isa.MemInsn(
        isa.Opcode.LOAD, isa.MemId.UOP,
        sram_base=0, dram_base=prog.instructions[0].dram_base,
        y_size=1, x_size=len(prog.uops), x_stride=len(prog.uops))
    assert plan_for(prog) is not plan1


def test_make_simulator_backend_selection():
    cfg = vta_default()
    dram = np.zeros(64, dtype=np.uint8)
    assert isinstance(make_simulator(cfg, dram), FunctionalSimulator)
    assert isinstance(make_simulator(cfg, dram, backend="fast"),
                      FastSimulator)
    with pytest.raises(ValueError):
        make_simulator(cfg, dram, backend="warp")


def test_run_program_backends_agree():
    rng = np.random.default_rng(12)
    A = rng.integers(-64, 64, (24, 40)).astype(np.int8)
    B = rng.integers(-64, 64, (40, 24)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()])
    out_o, rep_o = run_program(prog)
    out_f, rep_f = run_program(prog, backend="fast")
    np.testing.assert_array_equal(out_o, out_f)
    assert rep_o.gemm_loops == rep_f.gemm_loops


# ---------------------------------------------------------------------------
# LeNet-5 end-to-end on the fast backend
# ---------------------------------------------------------------------------

def test_lenet5_chain_fast_backend():
    from repro.core.network_compiler import compile_network
    from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                    synthetic_digit)
    net = compile_network(lenet5_specs(lenet5_random_weights(0)),
                          synthetic_digit(0))
    out_o, reps_o = net.run_functional(check_chaining=False)
    out_f, reps_f = net.run_functional(check_chaining=False, backend="fast")
    np.testing.assert_array_equal(out_o, out_f)
    assert [r.gemm_loops for r in reps_o] == [r.gemm_loops for r in reps_f]
    assert sum(r.gemm_loops for r in reps_f) == 2942      # §5.1
    assert [r.dram_bytes_total for r in reps_o] == \
        [r.dram_bytes_total for r in reps_f]
    net.verify(backend="fast")
