"""Fault-tolerance: failure injection → restart → bit-identical trajectory;
watchdog deadline; straggler accounting; deterministic data pipeline."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, host_batch, make_global_batch
from repro.runtime.fault_tolerance import (FailureInjector, StepTimeout,
                                           StragglerStats, Watchdog,
                                           resilient_train_loop)

# Seed-legacy LM-stack suite: fails on the container's jax/orbax versions;
# excluded from the blocking VTA-core run (pytest.ini 'legacy' marker).
pytestmark = pytest.mark.legacy


# ---------------------------------------------------------------------------
# data pipeline determinism (what makes restart exact)
# ---------------------------------------------------------------------------

def test_data_deterministic_and_shardable():
    cfg = DataConfig(vocab=128, seq_len=32, global_batch=8, seed=3)
    a = host_batch(cfg, step=5, lo=0, hi=8)
    b = host_batch(cfg, step=5, lo=0, hi=8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # shard [2, 6) must equal the same rows of the full batch
    shard = host_batch(cfg, step=5, lo=2, hi=6)
    np.testing.assert_array_equal(shard["tokens"], a["tokens"][2:6])
    # different steps differ
    c = host_batch(cfg, step=6, lo=0, hi=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token
    np.testing.assert_array_equal(
        a["tokens"][:, 1:], a["labels"][:, :-1])


def test_global_batch_construction():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=4, seed=0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    batch = make_global_batch(cfg, 0, mesh)
    assert batch["tokens"].shape == (4, 16)
    ref = host_batch(cfg, 0, 0, 4)
    np.testing.assert_array_equal(np.asarray(batch["tokens"]), ref["tokens"])


# ---------------------------------------------------------------------------
# the resilient loop
# ---------------------------------------------------------------------------

def _counter_loop(tmp_path, fail_at, total=20, ckpt_every=5):
    """A deterministic 'training' whose state is a running hash of steps."""
    trace = []

    def step_fn(state, step):
        new = {"acc": (state["acc"] * 31 + step + 1) % 1_000_003}
        trace.append(step)
        return new

    report = resilient_train_loop(
        state={"acc": 0},
        step_fn=step_fn,
        save_tree_fn=lambda s: {"acc": jnp.int32(s["acc"])},
        restore_fn=lambda ck, st, s: {"acc": int(
            np.asarray(ck.restore(st, {"acc": jnp.int32(0)})["acc"]))},
        checkpointer=Checkpointer(tmp_path, keep=3),
        total_steps=total, ckpt_every=ckpt_every,
        failure_injector=FailureInjector(fail_at),
    )
    return report, trace


def test_failure_recovery_exact_state(tmp_path):
    clean, _ = _counter_loop(tmp_path / "clean", [])
    faulty, _ = _counter_loop(tmp_path / "faulty", [7, 13])
    assert faulty.restarts == 2
    assert faulty.final_step == clean.final_step == 20
    # final checkpoint content identical with/without failures
    a = Checkpointer(tmp_path / "clean").restore(20, {"acc": jnp.int64(0)})
    b = Checkpointer(tmp_path / "faulty").restore(20, {"acc": jnp.int64(0)})
    assert int(np.asarray(a["acc"])) == int(np.asarray(b["acc"]))


def test_too_many_failures_raises(tmp_path):
    # a hard failure (same step failing 7×) exhausts max_restarts=5
    with pytest.raises(RuntimeError):
        _counter_loop(tmp_path, [3] * 7, total=5)


def test_restart_resumes_from_latest(tmp_path):
    report, trace = _counter_loop(tmp_path, [12])
    # failure hits before step 12 runs; restore to ckpt @10 replays 10, 11
    assert trace.count(10) == 2 and trace.count(11) == 2
    assert trace.count(12) == 1
    assert report.restarts == 1


def test_watchdog_trips():
    w = Watchdog(deadline_s=0.1)
    try:
        w.arm()
        time.sleep(0.3)
        with pytest.raises(StepTimeout):
            w.check()
    finally:
        w.stop()


def test_watchdog_ok_within_deadline():
    w = Watchdog(deadline_s=5.0)
    try:
        w.arm()
        w.check()
        w.disarm()
    finally:
        w.stop()


def test_straggler_accounting():
    s = StragglerStats()
    for _ in range(10):
        s.update(0.1)
    assert s.slow_steps == 0
    s.update(1.0)        # 10× the EWMA
    assert s.slow_steps == 1
    assert s.ewma_s < 0.2   # slow step barely moves the EWMA


# ---------------------------------------------------------------------------
# end-to-end: tiny model, loss trajectory identical across failures
# ---------------------------------------------------------------------------

def test_training_trajectory_identical_after_restart(tmp_path):
    from repro.configs import get_smoke
    from repro.launch.train import train

    cfg = get_smoke("lm100m")
    kw = dict(steps=8, global_batch=2, seq_len=32, ckpt_every=2,
              log_every=0)
    clean = train(cfg, ckpt_dir=tmp_path / "a", **kw)
    faulty = train(cfg, ckpt_dir=tmp_path / "b", fail_at=[5], **kw)
    assert faulty.restarts == 1
    la = [m["loss"] for m in clean.metrics_history]
    lb = [m["loss"] for m in faulty.metrics_history if m["step"] > 4]
    # last-step loss identical to fp32 exactness after replay
    np.testing.assert_allclose(la[-1], lb[-1], rtol=1e-5)
