"""DRAM allocator tests — paper §2.2 Fig. 2 verbatim + Def. 1 properties."""

import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core.dram import DramAllocator


def test_fig2_example_verbatim():
    """Fig. 2: offset 0; first 256-B allocation lands on page 1 (@1000);
    second 4352-B allocation lands on page 2 (@2000–@30FF)."""
    alloc = DramAllocator(offset=0, page_bytes=4096)
    r1 = alloc.alloc("first", "inp", struct_bytes=16, count=16)   # 256 B
    assert r1.phys_addr == 0x1000
    assert r1.end == 0x1100
    r2 = alloc.alloc("wgt17", "wgt", struct_bytes=256, count=17)  # 4352 B
    assert r2.phys_addr == 0x2000
    assert r2.end == 0x3100
    # §2.2: logical address of the first WGT matrix = @2000/256 = @0020
    assert r2.logical_addr(offset=0) == 0x20


def test_def1_logical_addressing():
    alloc = DramAllocator(offset=0x8000, page_bytes=4096)
    r = alloc.alloc("inp", "inp", struct_bytes=16, count=32)
    # log = (phy - offset) // (precision · nb_elem)
    assert r.logical_addr(0x8000) == (r.phys_addr - 0x8000) // 16
    # consecutive logical addresses = consecutive structures
    assert r.logical_of(1, 0x8000) == r.logical_addr(0x8000) + 1


def test_every_allocation_starts_fresh_page():
    alloc = DramAllocator()
    a = alloc.alloc("a", "inp", 16, 1)     # 16 bytes
    b = alloc.alloc("b", "inp", 16, 1)
    assert b.phys_addr - a.phys_addr == 4096


@given(sizes=st.lists(st.tuples(st.integers(1, 512), st.integers(1, 64)),
                      min_size=1, max_size=20))
@settings(max_examples=50)
def test_allocations_never_overlap_and_are_aligned(sizes):
    alloc = DramAllocator()
    regions = [alloc.alloc(f"r{i}", "inp", sb, c)
               for i, (sb, c) in enumerate(sizes)]
    for i, r in enumerate(regions):
        # Def.-1 exactness: struct-aligned start ⇒ exact logical addresses
        assert r.phys_addr % r.struct_bytes == 0
        if 4096 % r.struct_bytes == 0:
            assert r.phys_addr % 4096 == 0    # paper's page rule holds
        for other in regions[i + 1:]:
            assert r.end <= other.phys_addr   # strictly increasing
    assert alloc.image_size() >= max(r.end for r in regions)


def test_struct_alignment_beyond_page():
    """TPU profile: 16 KiB WGT structures must start struct-aligned even
    though that exceeds the 4 KiB page (DESIGN.md §2)."""
    alloc = DramAllocator()
    alloc.alloc("inp", "inp", 128, 512)
    wgt = alloc.alloc("wgt", "wgt", 128 * 128, 4)
    assert wgt.phys_addr % (128 * 128) == 0
    assert wgt.logical_addr(0) * 128 * 128 == wgt.phys_addr


def test_duplicate_name_rejected():
    alloc = DramAllocator()
    alloc.alloc("x", "inp", 16, 1)
    with pytest.raises(ValueError):
        alloc.alloc("x", "inp", 16, 1)
