"""Tensor→matrix lowering tests (paper §4.1, Def. 3)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core.conv_lowering import (ConvGeometry, avgpool2x2_plan,
                                      conv2d_reference, global_avgpool_plan,
                                      im2row, im2row_batch, ker2col,
                                      mat2tensor, maxpool2x2_plan,
                                      tensor2mat, flatten_tensor)


def test_lenet_layer1_shapes_verbatim():
    """§4.3: (1,1,32,32) with 5×5 kernels → 784×25 input matrix."""
    t = np.zeros((1, 1, 32, 32), dtype=np.int8)
    A = im2row(t, 5, 5)
    assert A.shape == (784, 25)
    w = np.zeros((6, 1, 5, 5), dtype=np.int8)
    B = ker2col(w)
    assert B.shape == (25, 6)
    # output 784×6 → tensor (1,6,28,28); after pooling 196×6 → (1,6,14,14)
    C = np.zeros((784, 6), dtype=np.int8)
    assert mat2tensor(C, 28, 28).shape == (1, 6, 28, 28)
    assert mat2tensor(np.zeros((196, 6), np.int8), 14, 14).shape == (1, 6, 14, 14)


@given(c=st.integers(1, 4), h=st.integers(3, 12), w=st.integers(3, 12),
       f=st.integers(1, 5), k=st.integers(1, 3), stride=st.integers(1, 2),
       seed=st.integers(0, 2**16))
@settings(max_examples=60, deadline=None)
def test_def3_property(c, h, w, f, k, stride, seed):
    """Def. 3: mat2tensor(im2row(T_A) × ker2col(T_B)) == T_A ⊛ T_B."""
    if k > min(h, w):
        k = min(h, w)
    rng = np.random.default_rng(seed)
    T_A = rng.integers(-64, 64, (1, c, h, w), dtype=np.int64).astype(np.int8)
    T_B = rng.integers(-64, 64, (f, c, k, k), dtype=np.int64).astype(np.int8)
    A = im2row(T_A, k, k, stride)
    B = ker2col(T_B)
    C = A.astype(np.int64) @ B.astype(np.int64)
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    T_C = mat2tensor(C, oh, ow)
    np.testing.assert_array_equal(T_C, conv2d_reference(T_A, T_B, stride))


@given(f=st.integers(1, 6), h=st.integers(1, 8), w=st.integers(1, 8),
       seed=st.integers(0, 1000))
@settings(max_examples=40)
def test_mat2tensor_tensor2mat_inverse(f, h, w, seed):
    rng = np.random.default_rng(seed)
    m = rng.integers(-128, 128, (h * w, f), dtype=np.int64).astype(np.int8)
    np.testing.assert_array_equal(tensor2mat(mat2tensor(m, h, w)), m)


def test_flatten_is_nchw_order():
    t = np.arange(2 * 3 * 4, dtype=np.int8).reshape(1, 2, 3, 4)
    np.testing.assert_array_equal(flatten_tensor(t)[0], np.arange(24))


@given(c=st.integers(1, 4), h=st.integers(3, 10), w=st.integers(3, 10),
       f=st.integers(1, 5), k=st.integers(1, 3), stride=st.integers(1, 2),
       pad=st.integers(0, 2), seed=st.integers(0, 2**16))
@settings(max_examples=60, deadline=None)
def test_def3_property_with_padding(c, h, w, f, k, stride, pad, seed):
    """Def. 3 extended to zero-padded ("same") convolution: the padding is
    materialised host-side, so Def. 3 must keep holding verbatim."""
    rng = np.random.default_rng(seed)
    T_A = rng.integers(-64, 64, (1, c, h, w), dtype=np.int64).astype(np.int8)
    T_B = rng.integers(-64, 64, (f, c, k, k), dtype=np.int64).astype(np.int8)
    A = im2row(T_A, k, k, stride, pad)
    B = ker2col(T_B)
    C = A.astype(np.int64) @ B.astype(np.int64)
    geo = ConvGeometry(c, h, w, k, k, stride, pad)
    T_C = mat2tensor(C, geo.out_h, geo.out_w)
    np.testing.assert_array_equal(T_C, conv2d_reference(T_A, T_B, stride, pad))


def test_same_padding_preserves_spatial_dims():
    """pad=(k-1)//2 with stride 1 keeps H×W (the "same" convolutions the
    YOLO-class workloads need, DESIGN.md §3)."""
    for k in (1, 3, 5, 7):
        geo = ConvGeometry(3, 32, 32, k, k, 1, (k - 1) // 2)
        assert (geo.out_h, geo.out_w) == (32, 32)
    t = np.ones((1, 3, 32, 32), dtype=np.int8)
    assert im2row(t, 5, 5, 1, 2).shape == (1024, 75)


@given(b=st.integers(1, 5), c=st.integers(1, 4), h=st.integers(2, 10),
       w=st.integers(2, 10), kh=st.integers(1, 4), kw=st.integers(1, 4),
       stride=st.integers(1, 3), pad=st.integers(0, 3),
       seed=st.integers(0, 2**16))
@settings(max_examples=80, deadline=None)
def test_im2row_batch_equals_per_image_loop(b, c, h, w, kh, kw, stride, pad,
                                            seed):
    """``im2row_batch`` is elementwise-identical to looping ``im2row``
    over the images, across random strides / paddings / kernel sizes —
    the serving path's batched staging can never drift from the
    single-image lowering (closes the PR 3 coverage gap where only the
    e2e paths exercised it)."""
    if kh > h + 2 * pad:
        kh = h + 2 * pad
    if kw > w + 2 * pad:
        kw = w + 2 * pad
    rng = np.random.default_rng(seed)
    stack = rng.integers(-128, 128, (b, c, h, w),
                         dtype=np.int64).astype(np.int8)
    batched = im2row_batch(stack, kh, kw, stride, pad)
    for i in range(b):
        single = im2row(stack[i:i + 1], kh, kw, stride, pad)
        np.testing.assert_array_equal(batched[i], single)
    geo = ConvGeometry(c, h, w, kh, kw, stride, pad)
    assert batched.shape == (b, geo.n_positions, geo.patch_len)


def test_maxpool_plan_mirrors_avgpool_geometry():
    avg = avgpool2x2_plan(4, 4)
    mx = maxpool2x2_plan(4, 4)
    assert mx.keep_rows == avg.keep_rows
    assert mx.add_pairs == avg.add_pairs      # same windows, MAX instead of ADD
    assert (mx.mode, avg.mode) == ("max", "avg")
    assert mx.out_h == mx.out_w == 2


def test_avgpool_plan_indices():
    plan = avgpool2x2_plan(4, 4)
    assert plan.out_h == plan.out_w == 2
    assert plan.keep_rows == (0, 2, 8, 10)
    # first window accumulates rows 1, 4, 5 into row 0
    assert plan.add_pairs[:3] == ((0, 1), (0, 4), (0, 5))
    assert plan.shr_indices == plan.keep_rows
    assert (plan.div_shift, maxpool2x2_plan(4, 4).div_shift) == (2, 0)


def test_global_avgpool_plan_tree_structure():
    """DESIGN.md §Strided-lowering: log2(H·W) rounds, each with disjoint
    (dst, src) lattices, folding every row into row 0; ÷(H·W) as one SHR."""
    plan = global_avgpool_plan(4, 4)
    assert (plan.out_h, plan.out_w) == (1, 1)
    assert plan.keep_rows == plan.shr_indices == (0,)
    assert (plan.mode, plan.div_shift) == ("gap", 4)
    assert len(plan.rounds) == 4                   # log2(16)
    assert plan.rounds[0] == ((0, 1), (2, 3), (4, 5), (6, 7), (8, 9),
                              (10, 11), (12, 13), (14, 15))
    assert plan.rounds[-1] == ((0, 8),)
    assert plan.add_pairs == tuple(p for r in plan.rounds for p in r)
    for rnd in plan.rounds:                        # disjoint per round
        dsts = [d for d, _ in rnd]
        srcs = [s for _, s in rnd]
        assert len(set(dsts)) == len(dsts)
        assert not set(dsts) & set(srcs)


@given(log_hw=st.integers(0, 3), cols=st.integers(1, 6),
       seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_global_avgpool_tree_sums_every_row(log_hw, cols, seed):
    """Executing the ADD-pair program in order reduces row 0 to the
    column sum of the whole matrix — for every power-of-two map size."""
    hw = 2 ** log_hw
    plan = global_avgpool_plan(hw, hw)
    rng = np.random.default_rng(seed)
    mat = rng.integers(-10**6, 10**6, (hw * hw, cols)).astype(np.int64)
    expected = mat.sum(axis=0)
    work = mat.copy()
    for dst, src in plan.add_pairs:
        work[dst] += work[src]
    np.testing.assert_array_equal(work[0], expected)
    np.testing.assert_array_equal(expected >> plan.div_shift,
                                  mat.sum(axis=0) >> (2 * log_hw))


def test_global_avgpool_plan_rejects_bad_maps():
    with pytest.raises(ValueError, match="square"):
        global_avgpool_plan(4, 8)
    with pytest.raises(ValueError, match="power-of-two"):
        global_avgpool_plan(6, 6)
