"""Validate the loop-aware HLO cost walker against analytically-known
programs (this is the instrument the §Roofline numbers come from)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import (analyze_hlo, parse_module,
                                     shape_numel_bytes, xla_cost_analysis)


def _cost_of(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text(), n_devices=1)


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    cost = _cost_of(lambda x, y: x @ y, a, b)
    assert cost["flops_per_device"] == pytest.approx(
        2 * 128 * 256 * 64, rel=0.05)


def test_scanned_matmul_multiplies_by_trip_count():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)

    def f(x, ws):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    cost = _cost_of(f, a, w)
    expect = 12 * 2 * 64 * 64 * 64
    assert cost["flops_per_device"] == pytest.approx(expect, rel=0.2)
    # plain cost_analysis would report ~1/12 of this
    compiled = jax.jit(f).lower(a, w).compile()
    xla = xla_cost_analysis(compiled)["flops"]
    assert xla < expect / 4


def test_nested_scan_trip_counts():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def f(x):
        def outer(h, _):
            def inner(h2, _):
                return h2 @ h2, None
            h, _ = jax.lax.scan(inner, h, None, length=5)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    cost = _cost_of(f, x)
    assert cost["flops_per_device"] == pytest.approx(
        15 * 2 * 32 * 32 * 32, rel=0.2)


def test_bytes_scale_with_loops():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

    def f(x):
        def body(h, _):
            return h + 1.0, None
        h, _ = jax.lax.scan(body, x, None, length=10)
        return h

    cost = _cost_of(f, x)
    # ≥ 10 × (read + write) of 4MB
    assert cost["bytes_per_device"] >= 10 * 2 * 1024 * 1024 * 4 * 0.9


def test_shape_parsing():
    assert shape_numel_bytes("f32[2,3]{1,0}") == (6, 24)
    assert shape_numel_bytes("(s32[], bf16[4,4]{1,0})") == (17, 36)
    assert shape_numel_bytes("pred[8]") == (8, 8)


def test_parse_module_entry():
    compiled = jax.jit(lambda x: x * 2).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    comps = parse_module(compiled.as_text())
    assert "__entry__" in comps


def test_no_unknown_trips_in_scans():
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(x):
        h, _ = jax.lax.scan(lambda h, _: (h @ h, None), x, None, length=4)
        return h

    compiled = jax.jit(f).lower(x).compile()
    cost = analyze_hlo(compiled.as_text(), 1)
    assert cost["unknown_trip_whiles"] == 0
