"""resnet_tiny end-to-end tests — branching CNNs on the VTA.

The acceptance contract of the graph subsystem (DESIGN.md §Graph):
resnet_tiny (two residual joins, CIFAR-10 scale) compiles through the
graph pipeline and runs **bit-identical across the oracle, fast and
batched backends**, with each residual add executed *on the VTA* —
asserted here by counting the ALU ADD instructions and the ACC loads of
the skip operand in the compiled programs.

Hypothesis-free: part of the tier-1 floor.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.models.resnet_tiny import (compile_resnet_tiny,
                                      reference_forward_int8,
                                      synthetic_image)


@pytest.fixture(scope="module")
def resnet():
    return compile_resnet_tiny()


def test_topology_two_residual_joins_with_a_multi_chunk_one(resnet):
    net, _ = resnet
    res = [l for l in net.layers if l.spec.residual_add]
    assert [l.spec.name for l in res] == ["b1b", "b2b"]
    # block 1's 256×144 conv matrices exceed one INP residency, so its
    # residual layer is multi-chunk — the halved ACC budget
    # (ChunkPlan.acc_copies) is genuinely exercised
    assert res[0].n_chunks > 1
    assert res[0].program.chunk_plan.acc_copies == 2
    # the schedule is a DAG, not a chain: the joins read earlier buffers
    assert net.residual_sources == [None, None, 0, None, None, 3, None]
    assert net.input_sources == [-1, 0, 1, 2, 3, 4, 5]


def test_residual_adds_execute_on_the_vta(resnet):
    """Acceptance: the residual add is visible as ALU ADD instructions in
    the program (one vector-vector AluInsn per chunk, plus the ACC load
    of the skip operand beside the result window) — not host numpy."""
    net, _ = resnet
    for layer in net.layers:
        prog = layer.program
        adds = [i for i in prog.instructions
                if isinstance(i, isa.AluInsn)
                and i.alu_opcode == isa.AluOp.ADD and not i.use_imm]
        res_loads = [i for i in prog.instructions
                     if isinstance(i, isa.MemInsn)
                     and i.opcode == isa.Opcode.LOAD
                     and i.memory_type == isa.MemId.ACC and i.sram_base > 0]
        if layer.spec.residual_add:
            assert len(adds) == layer.n_chunks
            assert len(res_loads) == layer.n_chunks
            assert "res" in prog.regions
        else:
            assert not adds and not res_loads and "res" not in prog.regions


def test_bit_identical_across_oracle_fast_and_batched(resnet):
    """Acceptance: one compiled plan, three execution paths, one answer."""
    net, graph = resnet
    out_fast, reps_fast = net.verify(backend="fast")
    out_oracle, reps_oracle = net.verify(backend="oracle")
    np.testing.assert_array_equal(out_oracle, out_fast)
    assert [r.gemm_loops for r in reps_oracle] == \
        [r.gemm_loops for r in reps_fast]
    assert [r.dram_bytes_total for r in reps_oracle] == \
        [r.dram_bytes_total for r in reps_fast]
    # batched serving over mixed fresh images == per-image serve_one ==
    # the graph's integer reference
    imgs = [synthetic_image(0), synthetic_image(77), synthetic_image(78)]
    outs, reports = net.serve(imgs)
    np.testing.assert_array_equal(outs[0], out_oracle)
    for img, out in zip(imgs, outs):
        np.testing.assert_array_equal(out, net.serve_one(img,
                                                         backend="fast"))
        np.testing.assert_array_equal(out, net.serve_one(img,
                                                         backend="oracle"))
        np.testing.assert_array_equal(out, reference_forward_int8(graph,
                                                                  img))
    assert len(reports) == len(net.layers)


def test_joins_mix_both_operands(resnet):
    """The calibrated weight scales make the joins genuine residuals:
    zeroing the skip operand must change the logits (the add is not a
    degenerate no-op)."""
    net, graph = resnet
    from repro.graph import evaluate_graph
    img = synthetic_image(5)
    vals = evaluate_graph(graph, img)
    for join_name in ("b1_join", "b2_join"):
        join = graph.node(join_name)
        pa, pb = join.pre_shifts
        branch = vals[join.inputs[0]] >> pa
        skip = vals[join.inputs[1]] >> pb
        assert np.any(skip != 0), f"{join_name}: skip shifted to nothing"
        assert np.any(branch != 0), f"{join_name}: branch is degenerate"
        assert np.any(np.maximum(branch + skip, 0)
                      != np.maximum(branch, 0)), \
            f"{join_name}: the add changes nothing"


def test_plan_identity_across_serves(resnet):
    """Compile-once/serve-many: repeated serves reuse the same cached
    per-layer instruction plans (no recompilation per request)."""
    net, _ = resnet
    plans_a = net.plans()
    net.serve([synthetic_image(1), synthetic_image(2)])
    plans_b = net.plans()
    assert all(a is b for a, b in zip(plans_a, plans_b))


def test_gemm_loop_budget_is_stable(resnet):
    """The §5.1 metric for the new workload, pinned (16000 ≈ 5.4× the
    LeNet-5 2942) so instruction-schedule regressions surface here."""
    net, _ = resnet
    assert net.gemm_loops() == 16000
    assert net.chunks_per_layer() == [1, 2, 2, 2, 1, 1, 1]
