"""CIFAR-10-scale CNN end-to-end tests — the scaling step past LeNet-5.

The paper claims "strong potential for scaling its capabilities to larger
CNN architectures"; this suite pins what that takes (DESIGN.md §3):
same-padded convolutions, max pooling, and genuinely multi-chunk layer
programs, all bit-exact on both simulator backends.

Hypothesis-free: part of the tier-1 floor.
"""

import numpy as np
import pytest

from repro.core.network_compiler import compile_network
from repro.models.cifar_cnn import (calibrate_shifts,
                                    cifar_cnn_random_weights,
                                    cifar_cnn_specs, reference_forward_int8,
                                    synthetic_cifar_image)


@pytest.fixture(scope="module")
def cifar():
    weights = cifar_cnn_random_weights(seed=0)
    shifts = calibrate_shifts(
        weights, [synthetic_cifar_image(s) for s in range(1, 4)])
    net = compile_network(cifar_cnn_specs(weights, shifts),
                          synthetic_cifar_image(0))
    return weights, net


def test_first_conv_layer_is_genuinely_multi_chunk(cifar):
    """Layer 1 (conv 3→64 k5 same-pad) lowers to a 1024×75 matrix — 5120
    INP vectors against the 2048-vector buffer — so the single-chunk
    ceiling of PR 1 would have rejected it outright."""
    _, net = cifar
    l1 = net.layers[0]
    assert l1.input_matrix.shape == (1024, 75)
    assert l1.n_chunks > 1
    assert net.chunks_per_layer()[1] > 1      # layer 2 multi-chunk too
    assert (l1.out_h, l1.out_w) == (16, 16)   # same pad + one 2×2 max pool


def test_chain_bit_identical_on_oracle_and_fast(cifar):
    """Acceptance: bit-identical outputs on the oracle and fast backends,
    and both equal to the integer reference model."""
    weights, net = cifar
    out_fast, reps_fast = net.verify(backend="fast")
    out_oracle, reps_oracle = net.verify(backend="oracle")
    np.testing.assert_array_equal(out_oracle, out_fast)
    assert [r.gemm_loops for r in reps_oracle] == \
        [r.gemm_loops for r in reps_fast]
    assert [r.dram_bytes_total for r in reps_oracle] == \
        [r.dram_bytes_total for r in reps_fast]
    shifts = [l.requant_shift for l in net.layers]
    logits, _ = reference_forward_int8(weights, synthetic_cifar_image(0),
                                       shifts)
    np.testing.assert_array_equal(out_fast, logits)


def test_pooled_multi_chunk_layers_use_per_chunk_alu_uops(cifar):
    """The max-pool MAX pairs and avg-pool ADD/SHR programs of the
    multi-chunk layers are emitted per chunk: every ALU uop index must fit
    the chunk's ACC window, not the global result."""
    _, net = cifar
    for layer in net.layers[:2]:
        cfg = layer.program.config
        for u in layer.program.uops:
            assert u.acc_idx < cfg.acc_buff_vectors
            assert u.inp_idx < max(cfg.acc_buff_vectors,
                                   cfg.inp_buff_vectors)


def test_cycle_report_counts_compute_loads(cifar):
    """Multi-chunk programs add compute-module LOADs (UOP/ACC); the cycle
    model reports them separately from the paper-calibrated §5.2 total."""
    _, net = cifar
    cr = net.cycle_report()
    assert cr.compute_load_insns > 0
    assert cr.total_compute_cycles_with_loads > cr.total_compute_cycles
    assert cr.gemm_loops == net.gemm_loops() == 44040


def test_fresh_inputs_stay_bit_exact(cifar):
    """Serving path: new images through the compiled network match the
    integer reference bit-for-bit (static shifts hold via the margin)."""
    import sys
    import pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from examples.lenet5_e2e import serve_request
    weights, net = cifar
    shifts = [l.requant_shift for l in net.layers]
    rng = np.random.default_rng(99)
    for _ in range(2):
        img = rng.integers(-64, 64, (1, 3, 32, 32)).astype(np.int8)
        logits = serve_request(net, img, backend="fast")
        ref, _ = reference_forward_int8(weights, img, shifts)
        np.testing.assert_array_equal(logits, ref)
