"""Matrix-op compiler tests (paper §3.3/§3.4) — compiled programs are run on
the bit-accurate functional simulator and checked against the numpy oracle."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core import isa
from repro.core.gemm_compiler import (AluImmOp, AluIndexedImmOp, AluPairOp,
                                      compile_matmul, plan_chunks)
from repro.core.hwconfig import VTAConfig, vta_default, vta_tpu
from repro.core.simulator import (FunctionalSimulator, VTAHazardError,
                                  run_program, verify_program)


def test_section_3_4_worked_example():
    """§3.4 verbatim: 16×16 × 16×16 + ReLU → single UOP at buffer @1 with
    all fields 0; LP_OUT=1, LP_IN=16, UOP_BEGIN=1, UOP_END=2."""
    rng = np.random.default_rng(34)
    A = rng.integers(-128, 128, (16, 16), dtype=np.int64).astype(np.int8)
    B = rng.integers(-128, 128, (16, 16), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()])

    gemms = [i for i in prog.instructions
             if isinstance(i, isa.GemInsn) and not i.reset]
    assert len(gemms) == 1
    g = gemms[0]
    assert (g.iter_out, g.iter_in) == (1, 16)          # LP_OUT=1, LP_IN=16
    assert (g.uop_bgn, g.uop_end) == (1, 2)            # ε=1
    uop = prog.uops[1]
    assert (uop.acc_idx, uop.inp_idx, uop.wgt_idx) == (0, 0, 0)
    # reset uop at @0 (§3.4 "First, the VTA is reset; this requires a UOP
    # located at address @0")
    assert (prog.uops[0].acc_idx, prog.uops[0].inp_idx) == (0, 0)
    # the GeMM performs 16 loops; ReLU zeroes negatives
    report = verify_program(prog)
    assert report.gemm_loops == 16
    out, _ = run_program(prog)
    ref = np.maximum(A.astype(np.int64) @ B.astype(np.int64), 0)
    np.testing.assert_array_equal(
        out, (ref & 0xFF).astype(np.uint8).view(np.int8))


@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70),
       seed=st.integers(0, 2**16), use_x=st.booleans())
@settings(max_examples=60, deadline=None)
def test_matmul_property(m, k, n, seed, use_x):
    """C = A·B (+X) for random shapes — simulator must equal the oracle."""
    rng = np.random.default_rng(seed)
    A = rng.integers(-128, 128, (m, k), dtype=np.int64).astype(np.int8)
    B = rng.integers(-128, 128, (k, n), dtype=np.int64).astype(np.int8)
    X = (rng.integers(-10**6, 10**6, (m, n), dtype=np.int64).astype(np.int32)
         if use_x else None)
    prog = compile_matmul(A, B, X=X)
    verify_program(prog)


@given(m=st.integers(2, 40), k=st.integers(1, 40), n=st.integers(1, 40),
       seed=st.integers(0, 2**16))
@settings(max_examples=30, deadline=None)
def test_alu_postops_property(m, k, n, seed):
    rng = np.random.default_rng(seed)
    A = rng.integers(-32, 32, (m, k), dtype=np.int64).astype(np.int8)
    B = rng.integers(-32, 32, (k, n), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu(), AluImmOp.shr(3),
                                         AluImmOp(isa.AluOp.MIN, 100),
                                         AluImmOp(isa.AluOp.ADD, -5)])
    verify_program(prog)


def test_multi_chunk_exercises_buffer_limits():
    """§3.3: 'If the data do not fit into the buffers, steps 2 to 5 must be
    repeated' — shrink the SRAM so chunking kicks in, all plans valid."""
    cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                    acc_buff_vectors=64, out_buff_vectors=64,
                    uop_buff_entries=32)
    rng = np.random.default_rng(7)
    A = rng.integers(-128, 128, (80, 96), dtype=np.int64).astype(np.int8)
    B = rng.integers(-128, 128, (96, 64), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()], cfg=cfg)
    # plan must be multi-chunk
    plan = plan_chunks(cfg, 5, 6, 4, 16)
    assert not plan.single_chunk
    report = verify_program(prog)
    # loop-count invariant: loops == α·λ·β·row_height regardless of chunking
    assert report.gemm_loops == 5 * 6 * 4 * 16


@given(seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_chunked_equals_unchunked(seed):
    """Chunking is semantics-preserving: same result with tiny vs big SRAM."""
    rng = np.random.default_rng(seed)
    A = rng.integers(-64, 64, (48, 64), dtype=np.int64).astype(np.int8)
    B = rng.integers(-64, 64, (64, 48), dtype=np.int64).astype(np.int8)
    small = VTAConfig(inp_buff_vectors=32, wgt_buff_matrices=2,
                      acc_buff_vectors=32, out_buff_vectors=32,
                      uop_buff_entries=16)
    out_small, _ = run_program(compile_matmul(A, B, cfg=small))
    out_big, _ = run_program(compile_matmul(A, B))
    np.testing.assert_array_equal(out_small, out_big)


@given(m=st.integers(33, 90), k=st.integers(17, 70), n=st.integers(17, 48),
       seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_multi_chunk_indexed_and_pair_alu_property(m, k, n, seed):
    """The PR-1 single-chunk ceiling is gone: indexed-imm and pair ALU
    programs compile on multi-chunk results (uops re-indexed per chunk)
    and run bit-exact against the numpy oracle."""
    cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                    acc_buff_vectors=64, out_buff_vectors=64,
                    uop_buff_entries=64)
    rng = np.random.default_rng(seed)
    A = rng.integers(-64, 64, (m, k)).astype(np.int8)
    B = rng.integers(-64, 64, (k, n)).astype(np.int8)
    rh = 16
    alpha, beta = -(-m // rh), -(-n // rh)
    n_vec = alpha * beta * rh
    idx = tuple(int(v) for v in
                rng.choice(n_vec, size=min(n_vec, 32), replace=False))
    pairs = []
    for _ in range(8):
        base = (int(rng.integers(0, alpha)) * beta
                + int(rng.integers(0, beta))) * rh
        w0, w1 = rng.choice(rh, size=2, replace=False)
        pairs.append((base + int(w0), base + int(w1)))
    prog = compile_matmul(A, B, cfg=cfg,
                          alu_ops=[AluImmOp.relu(),
                                   AluPairOp(isa.AluOp.ADD, tuple(pairs)),
                                   AluIndexedImmOp(isa.AluOp.SHR, 2, idx)])
    assert prog.chunk_plan.n_chunks > 1
    verify_program(prog)


@given(m=st.integers(33, 80), k=st.integers(17, 60), n=st.integers(10, 40),
       uop_entries=st.integers(8, 24), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_uop_buffer_overflow_streams_waves_property(m, k, n, uop_entries,
                                                    seed):
    """Programs needing more uops than the buffer stream LOAD_UOP waves
    instead of raising; results stay bit-exact vs the oracle."""
    cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                    acc_buff_vectors=64, out_buff_vectors=64,
                    uop_buff_entries=uop_entries)
    rng = np.random.default_rng(seed)
    A = rng.integers(-64, 64, (m, k)).astype(np.int8)
    B = rng.integers(-64, 64, (k, n)).astype(np.int8)
    rh = 16
    n_vec = -(-m // rh) * -(-n // rh) * rh
    idx = tuple(int(v) for v in rng.choice(n_vec, size=n_vec // 2,
                                           replace=False))
    prog = compile_matmul(A, B, cfg=cfg,
                          alu_ops=[AluImmOp.relu(),
                                   AluIndexedImmOp(isa.AluOp.ADD, 3, idx)])
    uop_loads = sum(1 for i in prog.instructions
                    if isinstance(i, isa.MemInsn)
                    and i.memory_type == isa.MemId.UOP)
    if len(prog.uops) > uop_entries:
        assert uop_loads > 1
    verify_program(prog)


def test_pair_groups_align_chunk_boundaries():
    """Chunk segmentation never cuts through a pair group; infeasible
    groups raise a clear ValueError."""
    from repro.core.gemm_compiler import plan_chunks
    cfg = VTAConfig(inp_buff_vectors=256, wgt_buff_matrices=8,
                    acc_buff_vectors=32, out_buff_vectors=32,
                    uop_buff_entries=64)
    plan = plan_chunks(cfg, 5, 3, 1, 16, row_groups=[(0, 1), (2, 3)])
    assert plan.alpha_segs == ((0, 2), (2, 2), (4, 1))
    with pytest.raises(ValueError, match="spans more than one SRAM chunk"):
        plan_chunks(cfg, 5, 3, 1, 16, row_groups=[(0, 2)])


def test_bias_is_x_preload():
    """QKV-bias-style: bias (N,) broadcasts over rows via the ACC preload
    (C = A·B + X, §2.3)."""
    rng = np.random.default_rng(11)
    A = rng.integers(-64, 64, (20, 30), dtype=np.int64).astype(np.int8)
    B = rng.integers(-64, 64, (30, 20), dtype=np.int64).astype(np.int8)
    bias = rng.integers(-1000, 1000, (20,), dtype=np.int64).astype(np.int32)
    out, _ = run_program(compile_matmul(A, B, bias=bias))
    ref = A.astype(np.int64) @ B.astype(np.int64) + bias[None, :]
    np.testing.assert_array_equal(out, (ref & 0xFF).astype(np.uint8).view(np.int8))


def test_single_row_fc_rule():
    """Single-row matrices are not height-padded (LP_IN=1) — the rule that
    reproduces the paper's FC-layer loop counts (§5.1)."""
    rng = np.random.default_rng(5)
    A = rng.integers(-64, 64, (1, 120), dtype=np.int64).astype(np.int8)
    B = rng.integers(-64, 64, (120, 84), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B)
    report = verify_program(prog)
    assert report.gemm_loops == 8 * 1 * 6     # λ=8, LP_IN=1, α·β=6


def test_tpu_profile_compiles_and_verifies():
    cfg = vta_tpu()
    rng = np.random.default_rng(3)
    A = rng.integers(-16, 16, (130, 200), dtype=np.int64).astype(np.int8)
    B = rng.integers(-16, 16, (200, 140), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()], cfg=cfg)
    verify_program(prog)


def test_dependency_tokens_catch_hazard():
    """Dropping a push flag must trip the simulator's token checker."""
    rng = np.random.default_rng(1)
    A = rng.integers(-64, 64, (16, 16), dtype=np.int64).astype(np.int8)
    B = rng.integers(-64, 64, (16, 16), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B)
    # find the WGT load that pushes to compute and clear the flag
    for i in prog.instructions:
        if isinstance(i, isa.MemInsn) and i.memory_type == isa.MemId.WGT:
            i.dep.push_next = 0
    sim = FunctionalSimulator(prog.config, prog.dram_image())
    with pytest.raises(VTAHazardError):
        sim.run(prog.instructions)


def test_binary_artifacts_roundtrip(tmp_path):
    """The Fig. 5 binary files are written and re-decodable."""
    rng = np.random.default_rng(9)
    A = rng.integers(-64, 64, (16, 32), dtype=np.int64).astype(np.int8)
    B = rng.integers(-64, 64, (32, 16), dtype=np.int64).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()])
    files = prog.write_binaries(tmp_path)
    assert {p.name for p in files.values()} >= {
        "input.bin", "weight.bin", "uop.bin", "instructions.bin",
        "expected_out.bin"}
    insns = isa.decode_stream(files["insn"].read_bytes())
    assert isa.encode_stream(insns) == files["insn"].read_bytes()
