"""Negative-path suite: corrupted instructions must be *rejected or
flagged* — never silently produce wrong output (DESIGN.md §Hardening).

Two attack surfaces:

* **Field flips** — every integer/flag field of every instruction kind
  (LOAD, STORE, GEMM, ALU, FINISH) is mutated on a real compiled
  program.  Because the VTA wire format packs disjoint bit fields, any
  in-width value change alters the 16-byte encoding, so the validator's
  decode→re-encode round-trip must reject every single one.  The field
  universes mirror ``test_isa_roundtrip.py``; the encodings those tests
  pin as golden hex are what makes this argument sound.
* **Out-of-bounds execution** — the satellite audit of the simulators'
  silent-wraparound paths: pad spans past SRAM end (previously clipped
  without complaint by the fast backends), DRAM overruns (previously a
  context-free IndexError or numpy broadcast error after partial
  mutation), GEMM/ALU lattice overruns, and STORE UOP.  All three
  backends must now raise the typed :class:`VTABoundsError` /
  ``ValueError`` *before* mutating simulator state, and the validator
  must reject the same streams statically with stable constraint ids.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.core.errors import CompileError
from repro.core.fast_simulator import (BatchFastSimulator, FastSimulator,
                                       invalidate_plan)
from repro.core.gemm_compiler import AluImmOp, compile_matmul
from repro.core.simulator import (FunctionalSimulator, VTABoundsError,
                                  VTAHazardError)
from repro.harden.guards import validate_program

# (field, max value) universes per instruction kind — the bit widths of
# the VTA hw_spec layout, as pinned by test_isa_roundtrip golden bytes.
MEM_FIELDS = [("sram_base", 2**16 - 1), ("dram_base", 2**32 - 1),
              ("y_size", 2**16 - 1), ("x_size", 2**16 - 1),
              ("x_stride", 2**16 - 1), ("y_pad_0", 15), ("y_pad_1", 15),
              ("x_pad_0", 15), ("x_pad_1", 15)]
GEM_FIELDS = [("reset", 1), ("uop_bgn", 2**13 - 1), ("uop_end", 2**14 - 1),
              ("iter_out", 2**14 - 1), ("iter_in", 2**14 - 1),
              ("acc_factor_out", 2**11 - 1), ("acc_factor_in", 2**11 - 1),
              ("inp_factor_out", 2**11 - 1), ("inp_factor_in", 2**11 - 1),
              ("wgt_factor_out", 2**10 - 1), ("wgt_factor_in", 2**10 - 1)]
ALU_FIELDS = [("reset", 1), ("uop_bgn", 2**13 - 1), ("uop_end", 2**14 - 1),
              ("iter_out", 2**14 - 1), ("iter_in", 2**14 - 1),
              ("dst_factor_out", 2**11 - 1), ("dst_factor_in", 2**11 - 1),
              ("src_factor_out", 2**11 - 1), ("src_factor_in", 2**11 - 1),
              ("alu_opcode", 3), ("use_imm", 1), ("imm", 2**15 - 1)]
DEP_FIELDS = ["pop_prev", "pop_next", "push_prev", "push_next"]

KIND_FIELDS = {
    "load": MEM_FIELDS, "store": MEM_FIELDS,
    "gemm": GEM_FIELDS, "alu": ALU_FIELDS, "finish": [],
}


def _program():
    rng = np.random.default_rng(5)
    A = rng.integers(-128, 128, (12, 24)).astype(np.int8)
    B = rng.integers(-128, 128, (24, 12)).astype(np.int8)
    return compile_matmul(A, B, alu_ops=[AluImmOp.relu()])


def _find(prog, kind):
    for insn in prog.instructions:
        if kind == "load" and isinstance(insn, isa.MemInsn) \
                and insn.opcode == isa.Opcode.LOAD:
            return insn
        if kind == "store" and isinstance(insn, isa.MemInsn) \
                and insn.opcode == isa.Opcode.STORE:
            return insn
        if kind == "gemm" and isinstance(insn, isa.GemInsn):
            return insn
        if kind == "alu" and isinstance(insn, isa.AluInsn):
            return insn
        if kind == "finish" and isinstance(insn, isa.FinishInsn):
            return insn
    raise AssertionError(f"no {kind} instruction in program")


# ---------------------------------------------------------------------------
# Field flips: every field of every instruction kind
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", sorted(KIND_FIELDS))
def test_every_field_flip_is_rejected(kind):
    """Flip each field of one live instruction of ``kind`` — the
    round-trip validator must reject every mutation (segment bytes are
    the truth; the decoded object no longer matches them)."""
    for field, fmax in KIND_FIELDS[kind]:
        prog = _program()
        insn = _find(prog, kind)
        old = getattr(insn, field)
        setattr(insn, field, old + 1 if old < fmax else old - 1)
        with pytest.raises(CompileError) as exc:
            validate_program(prog)
        assert exc.value.constraint == "insn-roundtrip", (kind, field)


@pytest.mark.parametrize("kind", sorted(KIND_FIELDS))
@pytest.mark.parametrize("dep", DEP_FIELDS)
def test_every_dep_flag_flip_is_rejected(kind, dep):
    """Dependency-token flags are one bit each; a flipped flag deadlocks
    real hardware, so the validator must catch it statically."""
    prog = _program()
    insn = _find(prog, kind)
    setattr(insn.dep, dep, 1 - getattr(insn.dep, dep))
    with pytest.raises(CompileError) as exc:
        validate_program(prog)
    assert exc.value.constraint == "insn-roundtrip", (kind, dep)


def test_corrupted_stream_never_serves_wrong_output():
    """End to end: after any field flip, a guarded serve returns the
    golden output (recovered) — the flagged stream never executes."""
    from repro.core.network_compiler import compile_network
    from repro.harden import GuardPolicy
    from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                    synthetic_digit)
    net = compile_network(lenet5_specs(lenet5_random_weights(0)),
                          synthetic_digit(0))
    img = synthetic_digit(3)
    golden = net.serve_one(img)
    for field in ("x_size", "sram_base", "dram_base"):
        insn = _find(net.layers[1].program, "load")
        setattr(insn, field, getattr(insn, field) + 1)
        invalidate_plan(net.layers[1].program)
        out, rep = net.serve_one(img, guard=GuardPolicy())
        assert rep.outcome == "recovered" and rep.validation_errors
        np.testing.assert_array_equal(out, golden)


# ---------------------------------------------------------------------------
# Structural validator rejections (stable constraint ids)
# ---------------------------------------------------------------------------

def _resync(prog):
    """Re-encode the mutated stream into the segment so the round-trip
    passes and the *structural* checks are what rejects."""
    prog.segments["insn"] = isa.encode_stream(prog.instructions)
    prog._harden_validated_segs = None


def _expect(prog, constraint):
    with pytest.raises(CompileError) as exc:
        validate_program(prog)
    assert exc.value.constraint == constraint, exc.value


def test_validator_rejects_missing_finish():
    prog = _program()
    prog.instructions = prog.instructions[:-1]
    _resync(prog)
    _expect(prog, "finish-missing")


def test_validator_rejects_store_of_non_out():
    prog = _program()
    store = _find(prog, "store")
    store.memory_type = isa.MemId.UOP
    _resync(prog)
    _expect(prog, "store-memtype")


def test_validator_rejects_sram_overrun():
    prog = _program()
    load = _find(prog, "load")
    load.sram_base = prog.config.buffer_capacity(
        {isa.MemId.UOP: "uop", isa.MemId.INP: "inp", isa.MemId.WGT: "wgt",
         isa.MemId.ACC: "acc", isa.MemId.OUT: "out"}[load.memory_type]) - 1
    _resync(prog)
    _expect(prog, "load-sram-bounds")


def test_validator_rejects_dram_overrun():
    prog = _program()
    load = _find(prog, "load")
    load.dram_base = 2**31          # far past the image
    _resync(prog)
    _expect(prog, "load-dram-bounds")


def test_validator_rejects_region_straying():
    """A DRAM access inside the image but outside the operand's own
    region — reading another tensor's bytes — is corruption the bounds
    check alone cannot see."""
    prog = _program()
    load = _find(prog, "load")
    load.dram_base = load.dram_base + 2     # shifted off its region
    _resync(prog)
    with pytest.raises(CompileError) as exc:
        validate_program(prog)
    assert exc.value.constraint in ("load-region-containment",
                                    "load-dram-bounds")


def test_validator_rejects_lattice_bomb():
    prog = _program()
    gem = _find(prog, "gemm")
    gem.iter_out = 2**14 - 1
    gem.iter_in = 2**14 - 1
    _resync(prog)
    _expect(prog, "lattice-footprint")


def test_validator_rejects_uop_range_overrun():
    prog = _program()
    gem = _find(prog, "gemm")
    gem.uop_end = prog.config.uop_buff_entries + 7
    _resync(prog)
    _expect(prog, "uop-range")


def test_validator_rejects_gemm_acc_overrun():
    prog = _program()
    gem = _find(prog, "gemm")
    gem.acc_factor_out = 2**11 - 1
    gem.iter_out = max(gem.iter_out, 8)
    _resync(prog)
    _expect(prog, "gemm-acc-bounds")


def test_validator_rejects_dep_token_deadlock():
    prog = _program()
    first = prog.instructions[0]
    first.dep.pop_prev = 1          # pops a token nobody pushed
    _resync(prog)
    _expect(prog, "dep-token-hazard")


# ---------------------------------------------------------------------------
# Satellite audit: typed pre-mutation OOB errors in all three backends
# ---------------------------------------------------------------------------

def _backends(prog):
    image = prog.dram_image()
    yield "oracle", FunctionalSimulator(prog.config, image.copy())
    yield "fast", FastSimulator(prog.config, image.copy())
    yield "batched", BatchFastSimulator(
        prog.config, np.stack([image, image.copy()]))


def _mutated(field, value, kind="load"):
    prog = _program()
    insn = _find(prog, kind)
    setattr(insn, field, value)
    invalidate_plan(prog)
    return prog


def test_load_pad_past_sram_end_raises_everywhere():
    """Regression for the silent pad-clip: the fast backends used to drop
    padding rows past the SRAM end without complaint, silently diverging
    from the oracle."""
    kinds = {isa.MemId.UOP: "uop", isa.MemId.INP: "inp",
             isa.MemId.WGT: "wgt", isa.MemId.ACC: "acc",
             isa.MemId.OUT: "out"}
    for name, sim in _backends(_program()):
        prog = _program()
        load = _find(prog, "load")
        cap = prog.config.buffer_capacity(kinds[load.memory_type])
        load.sram_base = cap - 1                # pad rows spill past cap
        load.y_pad_1 = 4
        invalidate_plan(prog)
        with pytest.raises(VTABoundsError, match="padding|span|capacity"):
            sim.run(prog.instructions)


def test_load_dram_overrun_raises_typed_everywhere():
    """Previously a bare IndexError (oracle) or an opaque numpy broadcast
    ValueError (batched) after partial state mutation."""
    for name, sim in _backends(_program()):
        prog = _mutated("dram_base", 2**28)
        with pytest.raises(VTABoundsError, match="DRAM"):
            sim.run(prog.instructions)


def test_gemm_lattice_overrun_raises_pre_mutation():
    for name, sim in _backends(_program()):
        prog = _mutated("acc_factor_out", 2**11 - 1, kind="gemm")
        gem = _find(prog, "gemm")
        gem.iter_out = max(gem.iter_out, 8)
        invalidate_plan(prog)
        acc_before = sim.acc_buf.copy()
        with pytest.raises((VTABoundsError, VTAHazardError)):
            sim.run(prog.instructions)
        # the GEMM must not have partially committed
        np.testing.assert_array_equal(sim.acc_buf, acc_before)


def test_alu_lattice_overrun_raises_everywhere():
    for name, sim in _backends(_program()):
        prog = _mutated("dst_factor_out", 2**11 - 1, kind="alu")
        alu = _find(prog, "alu")
        alu.iter_out = max(alu.iter_out, 8)
        invalidate_plan(prog)
        with pytest.raises(VTABoundsError):
            sim.run(prog.instructions)


def test_store_uop_rejected_everywhere():
    """STORE UOP is not a VTA instruction; the oracle used to die on a
    numpy broadcast error deep in the copy loop."""
    for name, sim in _backends(_program()):
        prog = _program()
        store = _find(prog, "store")
        store.memory_type = isa.MemId.UOP
        invalidate_plan(prog)
        with pytest.raises(ValueError, match="STORE UOP"):
            sim.run(prog.instructions)


def test_uop_range_overrun_raises_everywhere():
    for name, sim in _backends(_program()):
        # past the 8192-entry UOP buffer itself, not just past the
        # program's own uop segment (zeros in between decode in-bounds)
        prog = _mutated("uop_end", 2**14 - 1, kind="gemm")
        with pytest.raises((VTABoundsError, VTAHazardError)):
            sim.run(prog.instructions)
