"""Shared pytest hooks.

``fuzz`` marker routing (pytest.ini): hypothesis tags every ``@given``
test with a ``hypothesis`` keyword — mirror it as our own ``fuzz``
marker so CI can split the suite.  The deterministic core job runs
``pytest -m "not legacy and not fuzz"``; the separate *blocking* fuzz
job runs ``pytest -m fuzz``; the local tier-1 command
(``pytest -m "not legacy"``) still runs both.
"""

import pytest


def pytest_collection_modifyitems(config, items):
    for item in items:
        if "hypothesis" in item.keywords:
            item.add_marker(pytest.mark.fuzz)
