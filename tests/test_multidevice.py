"""Multi-device GSPMD correctness, in subprocesses with 8 fake devices
(this file's tests spawn `python -c` with XLA_FLAGS so the main test
process keeps its single device).

* sharded (2×4 data×model) training == single-device training, bit-close;
* int8-compressed pod gradient all-reduce ≈ exact pod mean;
* dense sequence-sharded KV decode == replicated decode.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

# Seed-legacy LM-stack suite: fails on the container's jax/orbax versions;
# excluded from the blocking VTA-core run (pytest.ini 'legacy' marker).
pytestmark = pytest.mark.legacy

_SNIPPET_HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
sys_out = {}
"""


def _run(snippet: str) -> dict:
    code = _SNIPPET_HEADER + textwrap.dedent(snippet) + \
        "\nprint('RESULT:' + json.dumps(sys_out))\n"
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=600, cwd=".")
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT in stdout:\n{proc.stdout[-2000:]}")


def test_sharded_training_matches_single_device():
    out = _run("""
    from repro.configs import get_smoke
    from repro.launch.specs import param_pack, tree_named
    from repro.models.params import init_params
    from repro.optim import adamw
    from repro.train.train_step import TrainConfig, make_train_step

    cfg = get_smoke("qwen2.5-3b")
    tc = TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=1,
                                           total_steps=10))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
    }

    losses = {}
    for name, mesh in [
        ("single", jax.make_mesh((1, 1), ("data", "model"))),
        ("sharded", jax.make_mesh((2, 4), ("data", "model"))),
    ]:
        with jax.set_mesh(mesh):
            defs, _, specs = param_pack(cfg, mesh, jnp.float32)
            shard = tree_named(mesh, specs)
            params = jax.device_put(
                init_params(defs, jax.random.PRNGKey(0), jnp.float32), shard)
            opt = adamw.init(tc.opt, params)
            step = jax.jit(make_train_step(cfg, tc),
                           in_shardings=(shard, None, None),
                           out_shardings=(shard, None, None))
            ls = []
            for _ in range(3):
                params, opt, m = step(params, opt, batch)
                ls.append(float(m["loss"]))
            losses[name] = ls
    sys_out["single"] = losses["single"]
    sys_out["sharded"] = losses["sharded"]
    """)
    import numpy as np
    np.testing.assert_allclose(out["single"], out["sharded"],
                               rtol=2e-4, atol=2e-4)


def test_seq_sharded_decode_matches_replicated():
    out = _run("""
    from repro.configs import get_smoke
    from repro.launch.specs import cache_pack, param_pack, tree_named
    from repro.models.params import init_params
    from repro.serving.cache import init_cache
    from repro.serving.engine import decode_step, prefill

    cfg = get_smoke("qwen2.5-3b")
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)

    logits = {}
    for name, mesh in [
        ("single", jax.make_mesh((1, 1), ("data", "model"))),
        ("sharded", jax.make_mesh((2, 4), ("data", "model"))),
    ]:
        with jax.set_mesh(mesh):
            defs, _, specs = param_pack(cfg, mesh, jnp.float32)
            shard = tree_named(mesh, specs)
            params = jax.device_put(
                init_params(defs, jax.random.PRNGKey(0), jnp.float32), shard)
            _, c_specs = cache_pack(cfg, mesh, 2, 32, jnp.float32)
            cache = jax.device_put(init_cache(cfg, 2, 32, jnp.float32),
                                   tree_named(mesh, c_specs))
            lg, cache = prefill(params, cfg, toks[:, :8], cache)
            for t in range(8, 10):
                lg, cache = decode_step(params, cfg, cache, toks[:, t],
                                        jnp.int32(t))
            logits[name] = np.asarray(lg[:, :cfg.vocab]).tolist()
    sys_out.update(logits)
    """)
    import numpy as np
    np.testing.assert_allclose(out["single"], out["sharded"],
                               rtol=3e-4, atol=3e-4)


def test_compressed_pod_allreduce_close_to_mean():
    out = _run("""
    from jax.sharding import PartitionSpec as P
    from repro.train.distributed import compressed_pod_allreduce

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    g_global = rng.normal(size=(2, 64)).astype(np.float32)  # per-pod rows

    with jax.set_mesh(mesh):
        @jax.jit
        def run(g):
            return compressed_pod_allreduce(g)
        g_dev = jax.device_put(
            jnp.asarray(g_global),
            jax.NamedSharding(mesh, P("pod", None)))
        out_arr = run(g_dev)
    mean = g_global.mean(axis=0)
    got = np.asarray(out_arr)
    sys_out["max_err"] = float(np.abs(got - mean[None]).max())
    sys_out["scale"] = float(np.abs(mean).max())
    """)
    # int8 quantisation error bound: ~scale/63
    assert out["max_err"] <= out["scale"] / 63 * 2.5 + 1e-6


def test_dryrun_entrypoint_single_cell():
    """The assignment's entry point runs standalone (small arch to keep
    the subprocess quick)."""
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2.5-3b",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=900, cwd=".")
    assert "OK  qwen2.5-3b_decode_32k_16x16" in proc.stdout, \
        proc.stdout[-2000:] + proc.stderr[-2000:]
