"""PTQ + accuracy-validation subsystem tests (DESIGN.md §Quantization,
EXPERIMENTS.md §Accuracy).

Covers the three stages of `repro.quantize`: the hermetic procedural
digit dataset, the float front door (training, checkpoint round-trip),
and the model-agnostic `quantize_network` PTQ pipeline — including the
cross-backend bit-identity of quantized-from-float LeNet-5 and the
never-wrap invariant of calibration-chosen shifts (the property the
calibration-drift fix makes checkable: the wrap- and clip-advanced
scans agree at every layer iff nothing left int8).
"""

import numpy as np
import pytest

from repro.core.errors import CompileError
from repro.core.network_compiler import calibrate_network
from repro.quantize import (FloatLayer, QuantizedModel, choose_weight_exp,
                            digit_dataset, digit_image, evaluate_net,
                            float_model, init_params, load_checkpoint,
                            quantize_bias, quantize_images,
                            quantize_network, quantize_weights,
                            save_checkpoint, train_or_load)
from repro.quantize.ptq import INPUT_EXP, WEIGHT_EXP_MAX

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # optional dev dependency
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Dataset: hermetic, deterministic, balanced
# ---------------------------------------------------------------------------

class TestDigitDataset:
    def test_deterministic_across_calls(self):
        a_x, a_y = digit_dataset(12, seed=3, split="train")
        b_x, b_y = digit_dataset(12, seed=3, split="train")
        np.testing.assert_array_equal(a_x, b_x)
        np.testing.assert_array_equal(a_y, b_y)

    def test_index_stable_under_dataset_size(self):
        # image i is a pure function of (seed, split, i) — not of n
        small_x, _ = digit_dataset(4, seed=0, split="test")
        big_x, _ = digit_dataset(16, seed=0, split="test")
        np.testing.assert_array_equal(small_x, big_x[:4])

    def test_labels_balanced(self):
        _, y = digit_dataset(40, seed=1)
        np.testing.assert_array_equal(y, np.arange(40) % 10)
        assert y.dtype == np.int64

    def test_splits_disjoint_streams(self):
        tr, _ = digit_image(0, "train", 0)
        te, _ = digit_image(0, "test", 0)
        ca, _ = digit_image(0, "calib", 0)
        assert not np.array_equal(tr, te)
        assert not np.array_equal(tr, ca)

    def test_shapes_range_and_channels(self):
        x1, _ = digit_dataset(3, channels=1)
        x3, _ = digit_dataset(3, channels=3)
        assert x1.shape == (3, 1, 32, 32) and x1.dtype == np.float32
        assert x3.shape == (3, 3, 32, 32)
        for x in (x1, x3):
            assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0

    def test_validation(self):
        with pytest.raises(ValueError):
            digit_image(0, "validation", 0)
        with pytest.raises(ValueError):
            digit_image(0, "train", 0, channels=2)
        with pytest.raises(ValueError):
            digit_dataset(0)


# ---------------------------------------------------------------------------
# Quantization primitives
# ---------------------------------------------------------------------------

class TestPrimitives:
    def test_choose_weight_exp(self):
        assert choose_weight_exp(np.array([1.0])) == 6       # 64 <= 127 < 128
        assert choose_weight_exp(np.array([0.5, -0.25])) == 7
        assert choose_weight_exp(np.zeros((3, 3))) == WEIGHT_EXP_MAX
        assert choose_weight_exp(np.array([300.0])) == -2    # 75 <= 127 < 150

    def test_choose_weight_exp_maximal(self):
        for w in (np.array([0.73]), np.array([1.9, -0.01]),
                  np.array([130.0])):
            e = choose_weight_exp(w)
            m = float(np.abs(w).max())
            assert round(m * 2.0 ** e) <= 127
            assert round(m * 2.0 ** (e + 1)) > 127

    def test_quantize_weights_and_bias(self):
        w = quantize_weights(np.array([0.5, -0.5, 10.0]), 7)
        np.testing.assert_array_equal(w, [64, -64, 127])     # clipped
        assert w.dtype == np.int8
        b = quantize_bias(np.array([0.25, -1.5]), 4)
        np.testing.assert_array_equal(b, [4, -24])
        assert b.dtype == np.int32

    def test_quantize_images(self):
        q = quantize_images(np.array([[[[0.0, 0.5, 1.0, 2.0]]]]))
        np.testing.assert_array_equal(q.reshape(-1), [0, 64, 127, 127])
        assert q.dtype == np.int8


# ---------------------------------------------------------------------------
# Float front door: checkpoints
# ---------------------------------------------------------------------------

class TestCheckpoints:
    def test_roundtrip(self, tmp_path):
        params = init_params("lenet5", seed=5)
        path = tmp_path / "lenet5.npz"
        save_checkpoint(path, params)
        back = load_checkpoint(path, "lenet5")
        assert set(back) == set(params)
        for k in params:
            np.testing.assert_array_equal(back[k], params[k])

    def test_wrong_names_rejected(self, tmp_path):
        path = tmp_path / "bad.npz"
        save_checkpoint(path, {"mystery_w": np.zeros((2, 2), np.float32)})
        with pytest.raises(ValueError, match="topology"):
            load_checkpoint(path, "lenet5")

    def test_wrong_shape_rejected(self, tmp_path):
        params = init_params("lenet5")
        params["conv1_w"] = np.zeros((6, 1, 3, 3), np.float32)
        path = tmp_path / "shape.npz"
        save_checkpoint(path, params)
        with pytest.raises(ValueError, match="conv1_w"):
            load_checkpoint(path, "lenet5")

    def test_train_or_load_prefers_existing_checkpoint(self, tmp_path):
        params = init_params("lenet5", seed=9)
        path = tmp_path / "ckpt.npz"
        save_checkpoint(path, params)
        loaded = train_or_load("lenet5", checkpoint=str(path))
        for k in params:                      # loaded, not re-trained
            np.testing.assert_array_equal(loaded[k], params[k])


# ---------------------------------------------------------------------------
# Float forwards
# ---------------------------------------------------------------------------

class TestFloatForward:
    @pytest.mark.parametrize("net,channels", [("lenet5", 1),
                                              ("resnet8", 3)])
    def test_apply_shapes_and_determinism(self, net, channels):
        from repro.quantize.train import APPLY_FNS
        params = init_params(net, seed=2)
        x, _ = digit_dataset(3, seed=2, channels=channels)
        a = np.asarray(APPLY_FNS[net](params, x))
        b = np.asarray(APPLY_FNS[net](params, x))
        assert a.shape == (3, 10)
        assert np.all(np.isfinite(a))
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# quantize_network: chain path (LeNet-5)
# ---------------------------------------------------------------------------

def _lenet_qm(margin=0, calib_n=4, seed=0):
    params = init_params("lenet5", seed=seed)
    calib_x, _ = digit_dataset(calib_n, seed=seed, split="calib")
    return quantize_network(float_model("lenet5", params), calib_x,
                            margin=margin)


class TestChainPTQ:
    def test_model_shape(self):
        qm = _lenet_qm()
        assert qm.kind == "chain" and qm.input_exp == INPUT_EXP
        assert [s.name for s in qm.specs] == \
            ["l1_conv", "l2_conv", "l3_conv", "l4_fc", "l5_fc"]
        assert set(qm.weight_exps) == set(qm.shifts) == \
            {s.name for s in qm.specs}
        for s in qm.specs:
            assert s.requant_shift == qm.shifts[s.name]
            assert s.weights.dtype == np.int8

    def test_cross_backend_bit_identity(self):
        """Quantized-from-float LeNet-5 serves identically on the
        oracle, fast and batched backends (satellite d)."""
        qm = _lenet_qm()
        net = qm.compile()
        imgs = qm.calib_int
        outs, _ = net.serve(list(imgs))
        for i, img in enumerate(imgs):
            for backend in ("oracle", "fast"):
                np.testing.assert_array_equal(
                    net.serve_one(img, backend=backend), outs[i],
                    err_msg=f"{backend} != batched for image {i}")

    @pytest.mark.parametrize("margin", [0, 1])
    def test_shifts_never_wrap_on_calibration_set(self, margin):
        """Property: calibration-chosen shifts keep every layer output
        inside int8 on the calibration set — equivalently, the wrap-
        and clip-advanced scans produce identical traces."""
        qm = _lenet_qm(margin=margin)
        _, wrap_t = calibrate_network(qm.specs, qm.calib_int)
        _, clip_t = calibrate_network(qm.specs, qm.calib_int,
                                      saturate=True)
        for k, (lw, lc) in enumerate(zip(wrap_t, clip_t)):
            for a, b in zip(lw, lc):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"layer {k} wrapped (margin={margin})")

    def test_margin_adds_guard_octave(self):
        # only the first layer sees identical accumulators under both
        # margins (later layers see the re-scaled activations), so only
        # its shift is provably exactly one octave apart
        q0 = _lenet_qm(margin=0)
        q1 = _lenet_qm(margin=1)
        assert q1.shifts["l1_conv"] == q0.shifts["l1_conv"] + 1

    def test_quantize_images_method(self):
        qm = _lenet_qm()
        x = np.full((1, 1, 32, 32), 0.5, np.float32)
        np.testing.assert_array_equal(
            qm.quantize_images(x),
            quantize_images(x, input_exp=qm.input_exp))


# ---------------------------------------------------------------------------
# quantize_network: graph path (resnet8)
# ---------------------------------------------------------------------------

class TestGraphPTQ:
    def test_resnet8_quantize_compile_serve(self):
        from repro.models.resnet8 import reference_forward_int8
        params = init_params("resnet8", seed=1)
        calib_x, _ = digit_dataset(4, seed=1, split="calib", channels=3)
        qm = quantize_network(float_model("resnet8", params), calib_x,
                              margin=1)
        assert qm.kind == "graph"
        assert set(qm.weight_exps) == {
            "stem", "b1a", "b1b", "t2a", "t2p", "t2b",
            "t3a", "t3p", "t3b", "head", "fc"}
        assert all(g.weights.dtype == np.int8
                   for g in qm.graph.nodes.values()
                   if g.kind in ("conv", "fc"))
        net = qm.compile()
        for img in qm.calib_int[:2]:
            np.testing.assert_array_equal(
                net.serve_one(img, backend="fast"),
                reference_forward_int8(qm.graph, img))

    def test_integer_graph_rejected(self):
        from repro.models.resnet8 import (build_resnet8,
                                          resnet8_random_weights)
        calib_x, _ = digit_dataset(2, split="calib", channels=3)
        with pytest.raises(CompileError) as ei:
            quantize_network(build_resnet8(resnet8_random_weights()),
                             calib_x)
        assert ei.value.constraint == "ptq-float-weights"


# ---------------------------------------------------------------------------
# Validation errors
# ---------------------------------------------------------------------------

class TestValidation:
    def test_bad_layer_kind(self):
        layers = [FloatLayer("p", "pool", np.ones((2, 2), np.float32))]
        calib = np.zeros((1, 1, 2, 1), np.float32)
        with pytest.raises(CompileError) as ei:
            quantize_network(layers, calib)
        assert ei.value.constraint == "node-kind"

    def test_bad_calibration_batch(self):
        layers = [FloatLayer("a", "fc", np.ones((4, 2), np.float32))]
        with pytest.raises(CompileError) as ei:
            quantize_network(layers, np.zeros((1, 2, 2), np.float32))
        assert ei.value.constraint == "calibration"

    def test_unknown_net_rejected(self):
        with pytest.raises(ValueError, match="net must be"):
            init_params("alexnet")
        with pytest.raises(ValueError):
            float_model("alexnet", {})


# ---------------------------------------------------------------------------
# Hypothesis: never-wrap over random float fc chains
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def _fc_chain_cases(draw):
        d_in, d_mid, d_out = 8, draw(st.integers(2, 6)), 3
        rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
        w1 = rng.uniform(-1.5, 1.5, (d_in, d_mid))
        w2 = rng.uniform(-1.5, 1.5, (d_mid, d_out))
        b1 = rng.uniform(-0.5, 0.5, (d_mid,))
        imgs = rng.uniform(0.0, 1.0, (draw(st.integers(1, 4)), 1, 2, 4))
        margin = draw(st.integers(0, 1))
        return w1, b1, w2, imgs, margin

    @given(_fc_chain_cases())
    @settings(max_examples=25, deadline=None)
    def test_hypothesis_quantize_network_never_wraps(case):
        w1, b1, w2, imgs, margin = case
        layers = [
            FloatLayer("h", "fc", np.asarray(w1, np.float32),
                       bias=np.asarray(b1, np.float32), relu=True),
            FloatLayer("o", "fc", np.asarray(w2, np.float32)),
        ]
        qm = quantize_network(layers, imgs, margin=margin)
        _, wrap_t = calibrate_network(qm.specs, qm.calib_int)
        _, clip_t = calibrate_network(qm.specs, qm.calib_int,
                                      saturate=True)
        for lw, lc in zip(wrap_t, clip_t):
            for a, b in zip(lw, lc):
                np.testing.assert_array_equal(a, b)
else:                                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_quantize_network_never_wraps():
        pass


# ---------------------------------------------------------------------------
# End-to-end smoke (tiny scale; the full-scale run is the benchmark)
# ---------------------------------------------------------------------------

def test_evaluate_net_smoke(tmp_path):
    rec = evaluate_net("lenet5", train_n=96, eval_n=24, calib_n=8,
                       epochs=1, batch=16, spotcheck_n=4,
                       checkpoint=str(tmp_path / "smoke.npz"))
    assert rec["net"] == "lenet5" and rec["n_eval"] == 24
    assert 0.0 <= rec["float_top1"] <= 1.0
    assert 0.0 <= rec["int8_top1"] <= 1.0
    assert rec["pallas_spotcheck_bit_identical"] in (True, False)
    assert set(rec["shifts"]) == set(rec["weight_exps"])
    # the checkpoint was written and satisfies the topology contract
    load_checkpoint(tmp_path / "smoke.npz", "lenet5")
