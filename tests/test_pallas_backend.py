"""The Pallas backend contract (DESIGN.md §2, tests for PR 8).

Three layers of pinning:

* **kernel ↔ compiler semantics** — ``ops.vta_matmul`` (both the real
  Pallas kernel in interpret mode and the XLA reference) against a numpy
  transcription of ``gemm_compiler``'s requant reference: bias → ReLU →
  arithmetic-SHR → int8 commit, on random int8 tiles, under *both*
  ``saturate`` settings.  This is the differential test that pins the
  relu-vs-SHR order, the floor rounding of SHR, and the
  truncate-vs-saturate commit.
* **program level** — ``run_program(backend="pallas")`` /
  ``run_program_batch(backend="pallas")`` OUT bytes bit-identical to the
  oracle on fused and general (pair/indexed/residual) programs; the
  ``saturate=True`` upgrade equals ``clip`` of the pre-commit ACC.
* **network level** — LeNet-5 and resnet8 served end-to-end on
  ``backend="pallas"`` match the fast simulator bit for bit
  (``serve_one``, ``run_functional``, batched ``serve``).

Skips cleanly when jax is unavailable (the backend itself degrades to a
typed ``CompileError`` with constraint ``pallas-jax-missing``).
"""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
jnp = pytest.importorskip("jax.numpy")

from repro.core import isa                                      # noqa: E402
from repro.core.dram import DramAllocator                       # noqa: E402
from repro.core.errors import CompileError                      # noqa: E402
from repro.core.gemm_compiler import (AluImmOp, AluIndexedImmOp,  # noqa: E402
                                      AluPairOp, _wrap_int32,
                                      compile_matmul)
from repro.core.hwconfig import VTAConfig, vta_default          # noqa: E402
from repro.core.layout import truncate_int8                     # noqa: E402
from repro.core.pallas_backend import (BatchPallasSimulator,    # noqa: E402
                                       PallasSimulator, plan_pallas,
                                       run_program_pallas)
from repro.core.program import VTAProgram                       # noqa: E402
from repro.core.simulator import (BACKENDS, make_simulator,     # noqa: E402
                                  run_program, run_program_batch,
                                  verify_program)
from repro.kernels import ops as kernel_ops                     # noqa: E402


# ---------------------------------------------------------------------------
# Kernel ↔ compiler requant semantics (the PR's drift-pinning differential)
# ---------------------------------------------------------------------------

def _requant_reference(a, b, bias, *, relu, shift, saturate):
    """``gemm_compiler``'s requant semantics in plain numpy: int32-wrapped
    GEMM + preload, ReLU *before* SHR, floor-rounding arithmetic shift,
    then the commit (truncation or the saturation upgrade)."""
    acc = _wrap_int32(a.astype(np.int64) @ b.astype(np.int64))
    if bias is not None:
        acc = _wrap_int32(acc.astype(np.int64) + bias.astype(np.int64))
    if relu:
        acc = np.maximum(acc, 0)
    if shift:
        acc = _wrap_int32(acc.astype(np.int64) >> shift)
    if saturate:
        return np.clip(acc, -128, 127).astype(np.int8)
    return truncate_int8(acc)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_kernel_matches_compiler_requant_semantics(backend):
    """Random int8 tiles × {bias, relu, shift} × both saturate settings:
    the kernel epilogue must equal the compiler's requant reference
    elementwise.  The pallas leg runs the real kernel body in interpret
    mode (multi-K-block shapes included)."""
    rng = np.random.default_rng(808)
    shapes = [(16, 16, 16), (1, 129, 130), (40, 300, 24)]
    if backend == "xla":            # the lowered reference is cheap — fuzz
        shapes += [(5, 7, 3), (64, 64, 64), (33, 257, 65)]
    for m, k, n in shapes:
        a = rng.integers(-128, 128, (m, k)).astype(np.int8)
        b = rng.integers(-128, 128, (k, n)).astype(np.int8)
        bias = rng.integers(-(2 ** 20), 2 ** 20, (n,)).astype(np.int32)
        for use_bias in (False, True):
            for relu in (False, True):
                for shift in (0, 5):
                    for saturate in (False, True):
                        got = np.asarray(kernel_ops.vta_matmul(
                            jnp.asarray(a), jnp.asarray(b),
                            jnp.asarray(bias) if use_bias else None,
                            relu=relu, shift=shift, saturate=saturate,
                            backend=backend))
                        want = _requant_reference(
                            a, b, bias if use_bias else None,
                            relu=relu, shift=shift, saturate=saturate)
                        np.testing.assert_array_equal(
                            got, want,
                            err_msg=f"{backend} {(m, k, n)} bias={use_bias} "
                                    f"relu={relu} shift={shift} "
                                    f"saturate={saturate}")


def test_saturate_and_truncation_disagree_only_out_of_range():
    """The documented tolerance contract: the two commits agree wherever
    the requant ACC already fits int8 and differ (clip vs low-8-bits)
    outside — i.e. saturation is an upgrade, not a different epilogue."""
    rng = np.random.default_rng(809)
    a = rng.integers(-128, 128, (32, 64)).astype(np.int8)
    b = rng.integers(-128, 128, (64, 32)).astype(np.int8)
    acc = _wrap_int32(a.astype(np.int64) @ b.astype(np.int64))
    trunc = np.asarray(kernel_ops.vta_matmul(
        jnp.asarray(a), jnp.asarray(b), saturate=False, backend="pallas"))
    sat = np.asarray(kernel_ops.vta_matmul(
        jnp.asarray(a), jnp.asarray(b), saturate=True, backend="pallas"))
    in_range = (acc >= -128) & (acc <= 127)
    assert not in_range.all(), "tiles too small to exercise the contract"
    np.testing.assert_array_equal(trunc[in_range], sat[in_range])
    np.testing.assert_array_equal(sat, np.clip(acc, -128, 127))
    np.testing.assert_array_equal(trunc, truncate_int8(acc))


# ---------------------------------------------------------------------------
# Program-level OUT-byte identity
# ---------------------------------------------------------------------------

def _out_bytes(prog, dram):
    region = prog.regions["out"]
    start = region.phys_addr - prog.allocator.offset
    return np.asarray(dram)[..., start:start + region.nbytes]


def test_fused_program_bit_identical_to_oracle():
    """A bias+relu+shr program — the whole epilogue fuses into the
    kernel; OUT bytes equal the oracle's and the decode matches the
    compiler's expected output."""
    rng = np.random.default_rng(810)
    A = rng.integers(-128, 128, (21, 34)).astype(np.int8)
    B = rng.integers(-128, 128, (34, 19)).astype(np.int8)
    X = np.broadcast_to(
        rng.integers(-1000, 1000, (1, 19)).astype(np.int32), (21, 19)).copy()
    prog = compile_matmul(A, B, X=X,
                          alu_ops=[AluImmOp.relu(), AluImmOp.shr(4)])
    assert plan_pallas(prog).fused
    verify_program(prog, backend="pallas")
    out_o, _ = run_program(prog, backend="oracle")
    out_p, rep = run_program(prog, backend="pallas")
    np.testing.assert_array_equal(out_p, out_o)
    assert rep.gemm_loops == prog.gemm_loops()


def test_general_program_bit_identical_to_oracle():
    """Pair + indexed ops (the pool lowering shapes) force the
    kernel-GEMM + vectorised-TensorAlu path."""
    rng = np.random.default_rng(811)
    A = rng.integers(-128, 128, (16, 16)).astype(np.int8)
    B = rng.integers(-128, 128, (16, 16)).astype(np.int8)
    X = rng.integers(-(10 ** 6), 10 ** 6, (16, 16)).astype(np.int32)
    pairs = tuple((d, d + 8) for d in range(8))
    ops = [AluImmOp.relu(), AluPairOp(isa.AluOp.ADD, pairs),
           AluIndexedImmOp(isa.AluOp.SHR, 3, tuple(range(8)))]
    prog = compile_matmul(A, B, X=X, alu_ops=ops)
    assert not plan_pallas(prog).fused
    verify_program(prog, backend="pallas")
    out_o, _ = run_program(prog, backend="oracle")
    out_p, _ = run_program(prog, backend="pallas")
    np.testing.assert_array_equal(out_p, out_o)


def test_multi_chunk_program_bit_identical():
    """Tiny SRAM → §3.3 multi-chunk instruction stream; the pallas
    lowering works from the DRAM-level metadata, so the chunking must be
    invisible."""
    cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                    acc_buff_vectors=64, out_buff_vectors=64,
                    uop_buff_entries=32)
    rng = np.random.default_rng(812)
    A = rng.integers(-64, 64, (50, 40)).astype(np.int8)
    B = rng.integers(-64, 64, (40, 33)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu(), AluImmOp.shr(2)],
                          cfg=cfg)
    assert prog.chunk_plan.n_chunks > 1
    verify_program(prog, backend="pallas")


def test_program_saturate_upgrade_clips_requant_acc():
    """``saturate=True`` at the program level == clip of the requant ACC
    (relu+shr applied, before the int8 commit) — and differs from the
    truncation path on an overflowing program."""
    rng = np.random.default_rng(813)
    A = rng.integers(-128, 128, (8, 128)).astype(np.int8)
    B = rng.integers(-128, 128, (128, 8)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.shr(2)])
    acc = _wrap_int32(A.astype(np.int64) @ B.astype(np.int64))
    acc = _wrap_int32(acc.astype(np.int64) >> 2)
    out_sat, _ = run_program_pallas(prog, saturate=True)
    np.testing.assert_array_equal(out_sat, np.clip(acc, -128, 127))
    out_trunc, _ = run_program_pallas(prog, saturate=False)
    np.testing.assert_array_equal(out_trunc, truncate_int8(acc))
    assert not np.array_equal(out_sat, out_trunc)


def test_run_program_batch_pallas_matches_batched():
    """The batched entry point with per-row INP variation: pallas rows ==
    batched-simulator rows, including the OUT bytes."""
    rng = np.random.default_rng(814)
    A = rng.integers(-64, 64, (24, 20)).astype(np.int8)
    B = rng.integers(-64, 64, (20, 17)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu(), AluImmOp.shr(1)])
    base = prog.dram_image()
    stack = np.broadcast_to(base, (4, base.size)).copy()
    region = prog.regions["inp"]
    start = region.phys_addr - prog.allocator.offset
    for r in range(1, 4):
        stack[r, start:start + region.nbytes] = rng.integers(
            0, 256, region.nbytes, dtype=np.uint8)
    out_b, _ = run_program_batch(prog, dram_stack=stack.copy())
    out_p, rep = run_program_batch(prog, dram_stack=stack.copy(),
                                   backend="pallas")
    np.testing.assert_array_equal(out_p, out_b)
    assert rep.gemm_loops == 4 * prog.gemm_loops()


def test_gemm_backend_xla_leg_equality():
    """``gemm_backend="xla"`` routes the GEMM through the lowered
    reference — same OUT bytes (the kernel and the reference share
    semantics, so the backend choice is a deployment knob)."""
    rng = np.random.default_rng(815)
    A = rng.integers(-128, 128, (19, 23)).astype(np.int8)
    B = rng.integers(-128, 128, (23, 31)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu(), AluImmOp.shr(3)])
    out_k, _ = run_program_pallas(prog, gemm_backend="pallas")
    out_x, _ = run_program_pallas(prog, gemm_backend="xla")
    np.testing.assert_array_equal(out_k, out_x)


# ---------------------------------------------------------------------------
# Dispatch + typed error contracts (satellite: stable constraint ids)
# ---------------------------------------------------------------------------

def test_make_simulator_dispatch():
    assert "pallas" in BACKENDS
    cfg = vta_default()
    sim = make_simulator(cfg, np.zeros(1024, np.uint8), backend="pallas")
    assert isinstance(sim, PallasSimulator) and not sim.is_batch
    bsim = make_simulator(cfg, np.zeros((2, 1024), np.uint8),
                          backend="pallas")
    assert isinstance(bsim, BatchPallasSimulator) and bsim.is_batch


def test_kernel_constraint_ids():
    a = jnp.zeros((16, 16), jnp.int8)
    b_bad = jnp.zeros((8, 16), jnp.int8)
    with pytest.raises(CompileError) as exc:
        kernel_ops.vta_matmul(a, b_bad)
    assert exc.value.constraint == "kernel-gemm-shape"
    from repro.kernels.vta_gemm import vta_gemm
    with pytest.raises(CompileError) as exc:
        vta_gemm(a, jnp.zeros((16, 16), jnp.int8), block_m=256)
    assert exc.value.constraint == "kernel-block-divisibility"
    with pytest.raises(ValueError, match="kernel backend"):
        kernel_ops.vta_matmul(a, jnp.zeros((16, 16), jnp.int8),
                              backend="cuda")
    assert issubclass(CompileError, ValueError)   # catchable either way


def test_non_compiler_program_raises_typed_error():
    """Hand-written streams carry no compiler metadata — the backend must
    refuse with the stable constraint id, not misexecute."""
    cfg = vta_default()
    prog = VTAProgram(config=cfg, allocator=DramAllocator())
    sim = PallasSimulator(cfg, np.zeros(1024, np.uint8))
    with pytest.raises(CompileError) as exc:
        sim.run_program(prog)
    assert exc.value.constraint == "pallas-program-metadata"
    with pytest.raises(CompileError) as exc:
        sim.run([isa.FinishInsn()])
    assert exc.value.constraint == "pallas-program-metadata"


def test_unsupported_observability_raises():
    """Per-instruction observability (trace, overflow counters, fault
    hooks) has no meaning on a fused kernel call — loud errors, not
    silent no-ops."""
    cfg = vta_default()
    rng = np.random.default_rng(816)
    A = rng.integers(-8, 8, (4, 4)).astype(np.int8)
    prog = compile_matmul(A, A, cfg=cfg)
    with pytest.raises(ValueError, match="trace"):
        PallasSimulator(cfg, prog.dram_image(), trace=True)
    with pytest.raises(ValueError, match="trace"):
        PallasSimulator(cfg, prog.dram_image(), count_overflows=True)
    sim = PallasSimulator(cfg, prog.dram_image())
    with pytest.raises(ValueError, match="fault_hook"):
        sim.run_program(prog, fault_hook=lambda s, i: None)


# ---------------------------------------------------------------------------
# Network-level end-to-end (the tentpole's acceptance)
# ---------------------------------------------------------------------------

def _compiled_lenet():
    from repro.models.lenet import (calibrate_shifts, lenet5_random_weights,
                                    lenet5_specs)
    from repro.core.network_compiler import compile_network
    weights = lenet5_random_weights(seed=0)
    rng = np.random.default_rng(7)
    cal = [rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
           for _ in range(4)]
    shifts = calibrate_shifts(weights, cal)
    return compile_network(lenet5_specs(weights, shifts),
                           np.zeros((1, 1, 32, 32), np.int8))


def test_lenet5_serving_bit_identical():
    net = _compiled_lenet()
    rng = np.random.default_rng(817)
    img = rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
    np.testing.assert_array_equal(net.serve_one(img, backend="pallas"),
                                  net.serve_one(img, backend="fast"))
    out_f, _ = net.run_functional(backend="fast")
    out_p, _ = net.run_functional(backend="pallas")
    np.testing.assert_array_equal(out_p, out_f)
    batch = np.stack([rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
                      for _ in range(3)])
    out_b, _ = net.serve(batch)
    out_pb, reps = net.serve(batch, backend="pallas")
    np.testing.assert_array_equal(out_pb, out_b)
    assert len(reps) == len(net.layers)


def test_serve_rejects_bad_backend_and_guarded_pallas():
    net = _compiled_lenet()
    batch = np.zeros((2, 1, 1, 32, 32), np.int8)
    with pytest.raises(ValueError, match="backend"):
        net.serve(batch, backend="fast")
    class _Policy:          # shape-only stand-in; rejected before use
        pass
    with pytest.raises(ValueError, match="guarded"):
        net.serve(batch, backend="pallas", guard=_Policy())


def test_resnet8_serving_bit_identical():
    """Residual joins, stride-2 chunks and the GAP pair tree all ride the
    general epilogue path end to end."""
    from repro.models.resnet8 import compile_resnet8, synthetic_image
    net, _ = compile_resnet8()
    img = synthetic_image(5)
    np.testing.assert_array_equal(net.serve_one(img, backend="pallas"),
                                  net.serve_one(img, backend="fast"))
