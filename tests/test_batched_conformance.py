"""Differential conformance suite: the batched runtime vs the per-image
oracle (DESIGN.md §Batching).

The contract under test: executing one compiled :class:`InstructionPlan`
over a ``(batch, nbytes)`` DRAM stack (:func:`repro.core.fast_simulator.
run_batch` / :class:`BatchFastSimulator`) is **bit-identical** to looping
the single-image oracle interpreter over the stack's rows — on the full
DRAM image, every SRAM buffer's end state, the instruction trace, and the
report counters (batch totals = sums of the per-image oracle reports).

Coverage: random ``compile_matmul`` programs with random batch sizes
(1–16), multi-chunk plans, LOAD_UOP wave streaming, padded-conv/max-pool
layer programs, stride-2 downsampling convs and global-avg-pool tree
reductions (DESIGN.md §Strided-lowering), and handcrafted streams whose
UOP/WGT DRAM regions differ *per batch row* (driving the non-uniform
general paths the serving workload never hits).

Every drawn workload is additionally recompiled with
``schedule="pipelined"`` (DESIGN.md §Pipeline): the double-buffered
stream must pass the full validator (dep-token dry run + concurrent
hazard check), stay batch == oracle-loop bit-identical, and produce the
serialized program's OUT bytes on all three backends.

The seeded fuzz below is hypothesis-free (tier-1 floor); an equivalent
hypothesis property runs when the optional dependency is installed.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.core.fast_simulator import (BatchFastSimulator, FastSimulator,
                                       plan_for, run_batch)
from repro.core.gemm_compiler import (AluImmOp, AluIndexedImmOp, AluPairOp,
                                      compile_matmul)
from repro.core.hwconfig import VTAConfig, vta_default
from repro.core.layer_compiler import LayerSpec, compile_layer
from repro.core.pallas_backend import (HAS_PALLAS, BatchPallasSimulator,
                                       PallasSimulator)
from repro.core.simulator import FunctionalSimulator
from repro.harden.guards import validate_program

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # optional dev dependency
    HAS_HYPOTHESIS = False

_SUM_FIELDS = ("gemm_loops", "gemm_reset_loops", "alu_loops",
               "dram_bytes_read", "dram_bytes_written")


# ---------------------------------------------------------------------------
# The conformance oracle
# ---------------------------------------------------------------------------

def varied_stack(prog, rng, batch, vary=("inp", "acc")):
    """Per-request DRAM stack: row 0 keeps the compiled image, rows 1..
    get random bytes in the ``vary`` regions (INP/ACC vary per request in
    serving; varying WGT/UOP drives the non-uniform batch paths)."""
    base = prog.dram_image()
    stack = np.broadcast_to(base, (batch, base.size)).copy()
    for b in range(1, batch):
        for name in vary:
            if name not in prog.regions:
                continue
            region = prog.regions[name]
            start = region.phys_addr - prog.allocator.offset
            stack[b, start:start + region.nbytes] = rng.integers(
                0, 256, region.nbytes, dtype=np.uint8)
    return stack


def _out_region_rows(prog, dram) -> np.ndarray:
    region = prog.regions["out"]
    start = region.phys_addr - prog.allocator.offset
    return np.atleast_2d(dram)[:, start:start + region.nbytes]


def assert_pallas_leg(prog, stack, ref_dram) -> None:
    """The pallas-backend conformance leg: execute the same compiled
    program over the same varied DRAM stack on the kernel backend and
    require its OUT bytes to equal the (already oracle-verified)
    reference rows bit-for-bit.  No-op when jax is unavailable — the
    simulator legs above still run everywhere."""
    if not HAS_PALLAS:
        return
    psim = BatchPallasSimulator(prog.config, stack)   # defensive copy
    psim.run_program(prog)
    np.testing.assert_array_equal(
        _out_region_rows(prog, psim.dram), _out_region_rows(prog, ref_dram),
        err_msg="pallas backend OUT bytes diverged from the oracle")


def assert_batch_matches_oracle_loop(cfg, instructions, stack, *,
                                     plan=None, prog=None):
    """Run the batch engine once and the oracle per row; every observable
    must match bit-for-bit.  When ``prog`` (the compiled program) is
    given, the pallas backend runs the same stack as a third leg
    (OUT-bytes equality).  Returns the batched report."""
    bsim = BatchFastSimulator(cfg, stack, trace=True)
    rep_b = bsim.run(instructions, plan=plan)
    totals = {f: 0 for f in _SUM_FIELDS}
    for b in range(stack.shape[0]):
        osim = FunctionalSimulator(cfg, stack[b], trace=True)
        rep_o = osim.run(instructions)
        np.testing.assert_array_equal(
            bsim.dram[b], osim.dram, err_msg=f"DRAM row {b} diverged")
        np.testing.assert_array_equal(bsim.uop_buf[b], osim.uop_buf)
        np.testing.assert_array_equal(bsim.inp_buf[b], osim.inp_buf)
        np.testing.assert_array_equal(bsim.wgt_buf[b], osim.wgt_buf)
        np.testing.assert_array_equal(bsim.acc_buf[b], osim.acc_buf)
        np.testing.assert_array_equal(bsim.out_buf[b], osim.out_buf)
        assert rep_o.insn_executed == rep_b.insn_executed
        assert rep_o.insn_trace == rep_b.insn_trace
        for f in _SUM_FIELDS:
            totals[f] += getattr(rep_o, f)
    for f in _SUM_FIELDS:            # batch totals == oracle-loop sums
        assert getattr(rep_b, f) == totals[f], f
    if prog is not None:
        assert_pallas_leg(prog, stack, bsim.dram)
    return rep_b


# ---------------------------------------------------------------------------
# Pipelined-schedule conformance (DESIGN.md §Pipeline)
# ---------------------------------------------------------------------------

def _out_bytes_after(prog, backend):
    """Execute ``prog`` on one backend; return its OUT region bytes (the
    decoded-result source, layout-independent of the chunk plan)."""
    if backend == "batched":
        sim = BatchFastSimulator(prog.config, prog.dram_image()[None].copy())
        sim.run(prog.instructions, plan=plan_for(prog))
        dram = sim.dram[0]
    elif backend == "pallas":
        sim = PallasSimulator(prog.config, prog.dram_image(),
                              copy_dram=False)
        sim.run_program(prog)
        dram = sim.dram
    else:
        cls = FunctionalSimulator if backend == "oracle" else FastSimulator
        sim = cls(prog.config, prog.dram_image())
        sim.run(prog.instructions)
        dram = sim.dram
    region = prog.regions["out"]
    start = region.phys_addr - prog.allocator.offset
    return dram[start:start + region.nbytes].copy()


def assert_pipelined_variant_conforms(prog_s, prog_p, rng, batch=3):
    """The §Pipeline contract for one drawn workload: the pipelined
    stream passes the full validator (including the concurrent-hazard
    check), stays batch == oracle-loop bit-identical on a varied stack,
    and matches the serialized OUT bytes on every backend."""
    assert prog_p.schedule == "pipelined", "expected a pipelined stream"
    validate_program(prog_p)
    stack = varied_stack(prog_p, rng, batch)
    assert_batch_matches_oracle_loop(prog_p.config, prog_p.instructions,
                                     stack, plan=plan_for(prog_p))
    ref = _out_bytes_after(prog_s, "oracle")
    backends = ("oracle", "fast", "batched") + \
        (("pallas",) if HAS_PALLAS else ())
    for backend in backends:
        np.testing.assert_array_equal(
            _out_bytes_after(prog_p, backend), ref,
            err_msg=f"pipelined {backend} diverged from serialized")


def _random_alu_ops(rng):
    ops = []
    if rng.random() < 0.5:
        ops.append(AluImmOp.relu())
    if rng.random() < 0.5:
        ops.append(AluImmOp(isa.AluOp.ADD, int(rng.integers(-200, 200))))
    if rng.random() < 0.4:
        ops.append(AluImmOp(isa.AluOp.MIN, int(rng.integers(0, 128))))
    if rng.random() < 0.5:
        ops.append(AluImmOp.shr(int(rng.integers(1, 8))))
    return ops


# ---------------------------------------------------------------------------
# Seeded differential fuzz (hypothesis-free tier-1 floor)
# ---------------------------------------------------------------------------

def test_fuzz_random_programs_random_batch_sizes():
    """Random shapes / ALU post-ops / X preloads × batch sizes 1–16."""
    rng = np.random.default_rng(303)
    for case in range(8):
        m, k, n = (int(rng.integers(1, 50)) for _ in range(3))
        A = rng.integers(-128, 128, (m, k)).astype(np.int8)
        B = rng.integers(-128, 128, (k, n)).astype(np.int8)
        X = None
        if rng.random() < 0.4:
            X = rng.integers(-10**6, 10**6, (m, n)).astype(np.int32)
        ops = _random_alu_ops(rng)
        prog = compile_matmul(A, B, X=X, alu_ops=ops)
        batch = int(rng.integers(1, 17))
        stack = varied_stack(prog, rng, batch)
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_matmul(A, B, X=X, alu_ops=ops,
                                schedule="pipelined")
        assert_pipelined_variant_conforms(prog, prog_p, rng)


def test_fuzz_varied_weights_drive_nonuniform_gemm():
    """Rows with *different* WGT bytes: the uniform-weights latch must
    drop and the per-image weight gather must still match the oracle."""
    rng = np.random.default_rng(304)
    for case in range(4):
        m, k, n = (int(rng.integers(4, 40)) for _ in range(3))
        A = rng.integers(-128, 128, (m, k)).astype(np.int8)
        B = rng.integers(-128, 128, (k, n)).astype(np.int8)
        ops = _random_alu_ops(rng)
        prog = compile_matmul(A, B, alu_ops=ops)
        stack = varied_stack(prog, rng, int(rng.integers(2, 9)),
                             vary=("inp", "acc", "wgt"))
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_matmul(A, B, alu_ops=ops, schedule="pipelined")
        assert_pipelined_variant_conforms(prog, prog_p, rng)


_SMALL_CFG = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                       acc_buff_vectors=64, out_buff_vectors=64,
                       uop_buff_entries=32)


def test_fuzz_multi_chunk_programs_batched():
    """Tiny SRAM forces §3.3 multi-chunk plans; batched == oracle loop."""
    rng = np.random.default_rng(305)
    for case in range(3):
        m = int(rng.integers(30, 80))
        k = int(rng.integers(20, 60))
        n = int(rng.integers(17, 50))
        A = rng.integers(-64, 64, (m, k)).astype(np.int8)
        B = rng.integers(-64, 64, (k, n)).astype(np.int8)
        ops = _random_alu_ops(rng)
        prog = compile_matmul(A, B, alu_ops=ops, cfg=_SMALL_CFG)
        assert prog.chunk_plan.n_chunks > 1
        stack = varied_stack(prog, rng, int(rng.integers(2, 7)))
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_matmul(A, B, alu_ops=ops, cfg=_SMALL_CFG,
                                schedule="pipelined")
        assert_pipelined_variant_conforms(prog, prog_p, rng)


def test_fuzz_uop_wave_streaming_batched():
    """Programs streaming LOAD_UOP waves mid-execution: the cached plan
    must observe the refilled slots identically on every batch row."""
    rng = np.random.default_rng(306)
    for uop_entries in (8, 16):
        cfg = VTAConfig(inp_buff_vectors=64, wgt_buff_matrices=4,
                        acc_buff_vectors=64, out_buff_vectors=64,
                        uop_buff_entries=uop_entries)
        m = int(rng.integers(34, 70))
        k = int(rng.integers(20, 50))
        n = int(rng.integers(10, 34))
        A = rng.integers(-64, 64, (m, k)).astype(np.int8)
        B = rng.integers(-64, 64, (k, n)).astype(np.int8)
        rh = 16
        n_vec = -(-m // rh) * -(-n // rh) * rh
        idx = tuple(int(v) for v in rng.choice(n_vec, size=n_vec // 2,
                                               replace=False))
        ops = [AluImmOp.relu(), AluIndexedImmOp(isa.AluOp.ADD, 3, idx)]
        prog = compile_matmul(A, B, cfg=cfg, alu_ops=ops)
        n_uop_loads = sum(1 for i in prog.instructions
                          if isinstance(i, isa.MemInsn)
                          and i.memory_type == isa.MemId.UOP)
        assert n_uop_loads > 1, "expected multi-wave streaming"
        stack = varied_stack(prog, rng, int(rng.integers(2, 7)))
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_matmul(A, B, cfg=cfg, alu_ops=ops,
                                schedule="pipelined")
        assert_pipelined_variant_conforms(prog, prog_p, rng)


def test_padded_conv_and_pool_pairs_batched():
    """Same-padded conv + 2×2 max/avg pooling layers (multi-chunk): the
    pair/indexed ALU programs must be bit-exact across the batch."""
    rng = np.random.default_rng(307)
    cfg = VTAConfig(inp_buff_vectors=256, wgt_buff_matrices=64,
                    acc_buff_vectors=128, out_buff_vectors=128,
                    uop_buff_entries=256)
    for pool in ("max2x2", "avg2x2"):
        spec = LayerSpec(
            name=f"c_{pool}", kind="conv",
            weights=rng.integers(-8, 8, (8, 3, 3, 3)).astype(np.int8),
            bias=rng.integers(-100, 100, (8,)).astype(np.int32),
            padding=1, relu=True, pool=pool)
        inp = rng.integers(-32, 64, (1, 3, 12, 12)).astype(np.int8)
        layer = compile_layer(spec, inp, cfg=cfg)
        assert layer.n_chunks > 1
        prog = layer.program
        stack = varied_stack(prog, rng, 5)
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_layer(spec, inp, cfg=cfg,
                               schedule="pipelined").program
        assert_pipelined_variant_conforms(prog, prog_p, rng)


def test_fuzz_strided_conv_programs_batched():
    """Stride-2 downsampling convs (k3/s2/p1 halving and k2/s2 projection
    geometry, DESIGN.md §Strided-lowering) drawn at random: the batched
    runtime must match the per-image oracle bit for bit."""
    rng = np.random.default_rng(308)
    for case in range(6):
        c = int(rng.integers(1, 5))
        f = int(rng.integers(1, 9))
        hw = int(rng.choice([8, 12, 16]))
        k, pad = (3, 1) if rng.random() < 0.5 else (2, 0)
        spec = LayerSpec(
            f"s2_{case}", "conv",
            rng.integers(-8, 8, (f, c, k, k)).astype(np.int8),
            rng.integers(-100, 100, (f,)).astype(np.int32),
            stride=2, padding=pad, relu=bool(rng.integers(2)))
        inp = rng.integers(-32, 64, (1, c, hw, hw)).astype(np.int8)
        layer = compile_layer(spec, inp)
        assert (layer.out_h, layer.out_w) == (hw // 2, hw // 2)
        prog = layer.program
        stack = varied_stack(prog, rng, int(rng.integers(2, 7)))
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_layer(spec, inp, schedule="pipelined").program
        assert_pipelined_variant_conforms(prog, prog_p, rng)


def test_fuzz_gap_reduction_programs_batched():
    """Global-avg-pool tree reductions: log2(H·W) ADD-pair rounds + one
    SHR over the surviving row, including a β-chunked result (the tree
    pins α into one chunk; the block columns still tile) and a program
    small enough that its pair uops stream in LOAD_UOP waves."""
    rng = np.random.default_rng(309)
    cfgs = (vta_default(),
            VTAConfig(inp_buff_vectors=256, wgt_buff_matrices=64,
                      acc_buff_vectors=64, out_buff_vectors=64,
                      uop_buff_entries=32))
    for case in range(6):
        cfg = cfgs[case % 2]
        c = int(rng.integers(1, 5))
        if case % 2 == 0:
            f, hw = int(rng.integers(1, 9)), int(rng.choice([4, 8]))
        else:                                  # β-chunked under the tiny ACC
            f, hw = int(rng.integers(60, 90)), 4
        spec = LayerSpec(
            f"gap_{case}", "conv",
            rng.integers(-6, 7, (f, c, 1, 1)).astype(np.int8),
            rng.integers(-50, 50, (f,)).astype(np.int32),
            relu=bool(rng.integers(2)), pool="gap")
        inp = rng.integers(-32, 64, (1, c, hw, hw)).astype(np.int8)
        layer = compile_layer(spec, inp, cfg=cfg)
        assert layer.keep_rows == (0,)
        if case % 2 == 1:
            assert layer.n_chunks > 1          # β tiles, α stays whole
        prog = layer.program
        stack = varied_stack(prog, rng, int(rng.integers(2, 7)))
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_layer(spec, inp, cfg=cfg,
                               schedule="pipelined").program
        assert_pipelined_variant_conforms(prog, prog_p, rng)


# ---------------------------------------------------------------------------
# Handcrafted per-row UOP/WGT divergence (non-uniform general paths)
# ---------------------------------------------------------------------------

def _uop_word(acc, inp, wgt):
    return acc | (inp << 11) | (wgt << 22)


def _handcrafted_stream(nu):
    """LOAD UOP/INP/WGT/ACC → GEMM reset → GEMM → ALU imm → ALU pair →
    STORE OUT.  All dep flags zero (single-stream execution).  Logical
    DRAM bases are in per-kind struct units over one 16 KiB image."""
    return [
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.UOP, sram_base=0,
                    dram_base=0, y_size=1, x_size=nu, x_stride=nu),
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.INP, sram_base=0,
                    dram_base=64, y_size=2, x_size=4, x_stride=6,
                    x_pad_0=1, y_pad_1=1),
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.WGT, sram_base=0,
                    dram_base=8, y_size=1, x_size=2, x_stride=2),
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.ACC, sram_base=0,
                    dram_base=64, y_size=2, x_size=8, x_stride=20),
        isa.GemInsn(reset=1, uop_bgn=0, uop_end=nu, iter_out=1, iter_in=2,
                    acc_factor_in=4),
        isa.GemInsn(uop_bgn=0, uop_end=nu, iter_out=2, iter_in=2,
                    acc_factor_out=8, acc_factor_in=4,
                    inp_factor_out=2, inp_factor_in=1,
                    wgt_factor_out=1),
        isa.AluInsn(alu_opcode=isa.AluOp.ADD, uop_bgn=0, uop_end=nu,
                    iter_out=2, iter_in=1, dst_factor_out=8,
                    use_imm=1, imm=5),
        # dst from uop[0] (0..15), src from uop[1] (0..7): overlapping →
        # the sequential (oracle-order) fallback on every backend
        isa.AluInsn(alu_opcode=isa.AluOp.ADD, uop_bgn=0, uop_end=nu,
                    iter_out=1, iter_in=1),
        isa.MemInsn(isa.Opcode.STORE, isa.MemId.OUT, sram_base=0,
                    dram_base=512, y_size=1, x_size=16, x_stride=16),
        isa.FinishInsn(),
    ]


def _handcrafted_stack(rng, batch, nu, *, vary_uops, vary_wgt):
    cfg = vta_default()
    stack = np.zeros((batch, 16384), dtype=np.uint8)
    for b in range(batch):
        salt = b if vary_uops else 0
        words = np.array([_uop_word((k + salt) % 16,
                                    (k * 3 + salt) % 8,
                                    (k + salt) % 2)
                          for k in range(nu)], dtype="<u4")
        stack[b, :nu * 4] = words.view(np.uint8)
        wsalt = rng.integers(0, 256, 2 * 256, dtype=np.uint8)
        stack[b, 2048:2048 + 2 * 256] = wsalt if vary_wgt else 0
        stack[b, 1024:1024 + 16 * 16] = rng.integers(
            0, 256, 256, dtype=np.uint8)          # INP always per-row
        stack[b, 4096:4096 + 28 * 64] = rng.integers(
            0, 256, 28 * 64, dtype=np.uint8)      # ACC always per-row
    if not vary_wgt:
        stack[:, 2048:2048 + 2 * 256] = rng.integers(
            0, 256, 2 * 256, dtype=np.uint8)[None]
    return cfg, stack


@pytest.mark.parametrize("vary_uops,vary_wgt", [
    (True, True),       # fully divergent rows: general paths everywhere
    (False, True),      # shared lattice, per-row weight gather
    (False, False),     # uniform: shared fast paths
])
def test_handcrafted_per_row_uop_wgt_divergence(vary_uops, vary_wgt):
    rng = np.random.default_rng(99)
    nu = 24
    cfg, stack = _handcrafted_stack(rng, batch=6, nu=nu,
                                    vary_uops=vary_uops, vary_wgt=vary_wgt)
    insns = _handcrafted_stream(nu)
    rep = assert_batch_matches_oracle_loop(cfg, insns, stack)
    assert rep.gemm_loops == 6 * 2 * 2 * nu      # batch × iter lattice


def test_uniformity_latch_observed():
    """The latch must be True for identical rows and drop when a load
    reads per-row bytes."""
    rng = np.random.default_rng(7)
    nu = 8
    cfg, stack = _handcrafted_stack(rng, batch=4, nu=nu,
                                    vary_uops=True, vary_wgt=True)
    sim = BatchFastSimulator(cfg, stack)
    sim.run(_handcrafted_stream(nu))
    assert not sim._uniform["uop"] and not sim._uniform["wgt"]
    cfg, stack = _handcrafted_stack(rng, batch=4, nu=nu,
                                    vary_uops=False, vary_wgt=False)
    sim = BatchFastSimulator(cfg, stack)
    sim.run(_handcrafted_stream(nu))
    assert sim._uniform["uop"] and sim._uniform["wgt"]


def test_extreme_values_at_f32_exactness_boundary():
    """Worst-case int8 magnitudes ((-128)·(-128) products) with contraction
    lengths at and just past the float32-exactness limit: the fused BLAS
    path runs at its bound and the fallback takes over beyond it, both
    bit-identical to the oracle.  Regression for the 127·128 vs 128·128
    product-bound error."""
    rng = np.random.default_rng(404)
    for k in (1024, 1040):            # c·bs == 1024 (limit), 1040 (beyond)
        A = np.full((16, k), -128, dtype=np.int8)
        B = np.full((k, 16), -128, dtype=np.int8)
        A[0, :7] = 127                # mix in the positive extreme
        B[:5, 3] = 127
        prog = compile_matmul(A, B)
        stack = varied_stack(prog, rng, 3)
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)


# ---------------------------------------------------------------------------
# run_batch API
# ---------------------------------------------------------------------------

def test_run_batch_returns_stack_and_batch_totals():
    rng = np.random.default_rng(11)
    A = rng.integers(-64, 64, (24, 24)).astype(np.int8)
    B = rng.integers(-64, 64, (24, 24)).astype(np.int8)
    prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()])
    batch = 3
    stack = varied_stack(prog, rng, batch)
    out_stack, rep = run_batch(prog.config, stack, prog.instructions,
                               plan=plan_for(prog))
    assert out_stack.shape == stack.shape
    assert rep.gemm_loops == batch * prog.gemm_loops()
    # batch of one over the unmodified image == the single-image program
    one, rep1 = run_batch(prog.config, prog.dram_image()[None],
                          prog.instructions)
    single = FunctionalSimulator(prog.config, prog.dram_image())
    single.run(prog.instructions)
    np.testing.assert_array_equal(one[0], single.dram)
    assert rep1.gemm_loops == prog.gemm_loops()


def test_batched_rejects_bad_stacks():
    cfg = vta_default()
    with pytest.raises(ValueError):
        BatchFastSimulator(cfg, np.zeros(64, dtype=np.uint8))
    with pytest.raises(TypeError):
        BatchFastSimulator(cfg, np.zeros((2, 64), dtype=np.int8))


# ---------------------------------------------------------------------------
# Hypothesis property (skips cleanly when the dependency is absent)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(m=st.integers(1, 40), k=st.integers(1, 40), n=st.integers(1, 40),
           batch=st.integers(1, 16), seed=st.integers(0, 2**31 - 1),
           relu=st.booleans(), shr=st.integers(0, 6))
    def test_hypothesis_run_batch_bit_identical(m, k, n, batch, seed,
                                                relu, shr):
        rng = np.random.default_rng(seed)
        A = rng.integers(-128, 128, (m, k)).astype(np.int8)
        B = rng.integers(-128, 128, (k, n)).astype(np.int8)
        ops = ([AluImmOp.relu()] if relu else []) + \
            ([AluImmOp.shr(shr)] if shr else [])
        prog = compile_matmul(A, B, alu_ops=ops)
        stack = varied_stack(prog, rng, batch)
        assert_batch_matches_oracle_loop(prog.config, prog.instructions,
                                         stack, plan=plan_for(prog),
                                         prog=prog)
        prog_p = compile_matmul(A, B, alu_ops=ops, schedule="pipelined")
        assert_pipelined_variant_conforms(prog, prog_p, rng)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_run_batch_bit_identical():
        pass
