"""resnet8 end-to-end tests — ResNet-scale CNNs on the VTA.

The acceptance contract of the strided lowering (DESIGN.md
§Strided-lowering): resnet8 — 3 stages, two stride-2 stage transitions
(k3/s2/p1 main path + k2/s2 projection shortcut each), three on-VTA
residual joins, a global-average-pool head fused with a 1×1 mixing conv
— compiles through the graph pipeline and serves **bit-identical across
the oracle, fast and batched backends at batch 8**, with the GAP tree
reduction visible as ALU ADD-pair instructions in the compiled head.

Hypothesis-free: part of the tier-1 floor.
"""

import numpy as np
import pytest

from repro.core import isa
from repro.models.resnet8 import (compile_resnet8, reference_forward_int8,
                                  synthetic_image)


@pytest.fixture(scope="module")
def resnet8():
    return compile_resnet8()


def test_topology_strided_transitions_and_gap_head(resnet8):
    net, _ = resnet8
    names = [l.spec.name for l in net.layers]
    assert names == ["stem", "b1a", "b1b", "t2a", "t2p", "t2b",
                     "t3a", "t3p", "t3b", "head", "fc"]
    # two stride-2 stage transitions, each a k3 main conv + k2 projection
    strided = {l.spec.name: l.spec.weights.shape[2:]
               for l in net.layers if l.spec.stride == 2}
    assert strided == {"t2a": (3, 3), "t2p": (2, 2),
                       "t3a": (3, 3), "t3p": (2, 2)}
    # resolutions actually halve at each transition: 32 → 16 → 8 → GAP 1
    dims = {l.spec.name: (l.out_h, l.out_w) for l in net.layers
            if l.spec.kind == "conv"}
    assert dims["b1b"] == (32, 32)
    assert dims["t2a"] == dims["t2p"] == dims["t2b"] == (16, 16)
    assert dims["t3a"] == dims["t3p"] == dims["t3b"] == (8, 8)
    assert dims["head"] == (1, 1)                      # post-GAP
    # three joins close on the VTA, each downsample join on its projection
    assert net.residual_sources == [None, None, 0, None, None, 4,
                                    None, None, 7, None, None]
    # the stage-1 block is multi-chunk by construction (1024×144 matrices)
    b1b = net.layers[2]
    assert b1b.n_chunks > 1 and b1b.program.chunk_plan.acc_copies == 2


def test_gap_head_is_a_tree_reduction_on_the_vta(resnet8):
    """The GAP must execute as log2(H·W) ALU ADD-pair rounds + one SHR
    over the surviving row — on the TensorAlu, not host numpy."""
    net, _ = resnet8
    head = [l for l in net.layers if l.spec.pool == "gap"][0]
    assert head.keep_rows == (0,)
    assert (head.out_h, head.out_w) == (1, 1)
    # 8×8 map → 6 tree rounds; each round is one vector-vector ADD insn
    adds = [i for i in head.program.instructions
            if isinstance(i, isa.AluInsn)
            and i.alu_opcode == isa.AluOp.ADD and not i.use_imm]
    assert len(adds) == 6
    # the ÷64 and the requant fold into one SHR over the surviving row
    shrs = [i for i in head.program.instructions
            if isinstance(i, isa.AluInsn) and i.alu_opcode == isa.AluOp.SHR]
    assert len(shrs) == 1 and shrs[0].imm >= 6
    # non-head layers carry no pool program
    for l in net.layers:
        if l.spec.pool is None:
            assert l.keep_rows is None


def test_residual_joins_execute_on_the_vta(resnet8):
    """All three joins — identity and both projection joins — are ALU
    vector-vector ADDs against an ACC-loaded skip operand."""
    net, _ = resnet8
    for layer in net.layers:
        prog = layer.program
        res_loads = [i for i in prog.instructions
                     if isinstance(i, isa.MemInsn)
                     and i.opcode == isa.Opcode.LOAD
                     and i.memory_type == isa.MemId.ACC and i.sram_base > 0]
        if layer.spec.residual_add:
            assert len(res_loads) == layer.n_chunks
            assert "res" in prog.regions
        else:
            assert not res_loads and "res" not in prog.regions
    # at least one join needs a genuine on-device pre-shift (the t3
    # branch keeps an octave of gain, so the projection arrives coarser)
    assert any(l.spec.residual_pre_shift > 0 for l in net.layers
               if l.spec.residual_add)


def test_bit_identical_across_backends_at_batch_8(resnet8):
    """Acceptance: one compiled plan, three execution paths, one answer —
    at batch 8, against the graph's integer reference."""
    net, graph = resnet8
    out_fast, reps_fast = net.verify(backend="fast")
    out_oracle, reps_oracle = net.verify(backend="oracle")
    np.testing.assert_array_equal(out_oracle, out_fast)
    assert [r.gemm_loops for r in reps_oracle] == \
        [r.gemm_loops for r in reps_fast]
    imgs = [synthetic_image(100 + r) for r in range(8)]
    outs, reports = net.serve(imgs)
    assert outs.shape[0] == 8 and len(reports) == len(net.layers)
    for img, out in zip(imgs, outs):
        np.testing.assert_array_equal(out, net.serve_one(img,
                                                         backend="fast"))
        np.testing.assert_array_equal(out, reference_forward_int8(graph,
                                                                  img))
    # spot-check one request on the (slow) oracle serving path too
    np.testing.assert_array_equal(
        outs[0], net.serve_one(imgs[0], backend="oracle"))


def test_logits_vary_across_inputs(resnet8):
    """The requant plan must leave signal: different images produce
    different logits (the network did not calibrate itself to zero)."""
    net, graph = resnet8
    a = reference_forward_int8(graph, synthetic_image(100))
    b = reference_forward_int8(graph, synthetic_image(101))
    assert a.any() and b.any()
    assert not np.array_equal(a, b)


def test_gemm_loop_budget_is_stable(resnet8):
    """The §5.1 metric for the new workload, pinned (53252 ≈ 18× the
    LeNet-5 2942) so instruction-schedule regressions surface here."""
    net, _ = resnet8
    assert net.gemm_loops() == 53252
    assert net.chunks_per_layer() == [1, 5, 5, 2, 1, 3, 1, 1, 2, 1, 1]
