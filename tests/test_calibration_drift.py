"""Calibration/execution semantic drift — the differential regression
suite for the `np.clip`-vs-`truncate_int8` bug (DESIGN.md §Quantization).

`calibrate_network` advances its calibration images layer by layer; the
device requants through the wrapping ACC→OUT truncation.  The pre-fix
code (a) advanced with saturating ``np.clip`` and (b) ignored pinned
``spec.requant_shift`` values — so the moment a pinned shift lets a
calibration activation leave int8, calibration computed downstream
ranges for a machine that does not exist.  The tests here prove, bit
for bit, that the fixed calibration trace equals what ``serve`` /
``serve_one`` produce for the same images, and that the legacy clip
semantics (still reachable via ``saturate=True``) produces a *different*
trace on the same network — i.e. this suite fails on the pre-fix path.
"""

import dataclasses

import numpy as np

from repro.core.layer_compiler import LayerSpec
from repro.core.layout import requant_int8, truncate_int8
from repro.core.network_compiler import (calibrate_network,
                                         calibrate_network_shifts,
                                         compile_network)
from repro.models.lenet import lenet5_random_weights, lenet5_specs, \
    synthetic_digit


def test_requant_int8_wrap_vs_saturate_semantics():
    v = np.array([200, -300, 127, -128, 0], dtype=np.int64)
    assert np.array_equal(requant_int8(v), truncate_int8(v))
    assert requant_int8(np.array([200]))[0] == -56          # wraps
    assert requant_int8(np.array([200]), saturate=True)[0] == 127
    assert requant_int8(np.array([-300]), saturate=True)[0] == -128
    # in-range values are identical under both semantics
    inr = np.arange(-128, 128, dtype=np.int64)
    assert np.array_equal(requant_int8(inr),
                          requant_int8(inr, saturate=True))


def _wrapping_pinned_specs():
    """A 2-layer fc chain whose pinned layer-1 shift wraps on the
    calibration images (but not on the all-zeros compile input)."""
    w1 = (2 * np.eye(4)).astype(np.int8)
    w2 = np.array([[1, 1, -1], [1, -1, 1], [-1, 1, 1], [1, 1, 1]],
                  dtype=np.int8)
    specs = [
        LayerSpec("a", "fc", w1, requant_shift=0),     # pinned: acc ±200
        LayerSpec("b", "fc", w2),                      # unpinned
    ]
    images = [np.array([[100, -100, 50, -50]], dtype=np.int8),
              np.array([[90, 80, -90, -80]], dtype=np.int8)]
    return specs, images


def test_calibration_honours_pinned_shifts():
    specs, images = _wrapping_pinned_specs()
    shifts, _ = calibrate_network(specs, images)
    assert shifts[0] == 0                       # pinned value, not rechosen
    assert calibrate_network_shifts(specs, images)[0] == 0


def test_calibration_trace_bit_identical_to_serve_on_wrap():
    """THE regression test: with a pinned shift that wraps on the
    calibration set, the calibration trace must still equal device
    execution exactly — the pre-fix np.clip path diverges here."""
    specs, images = _wrapping_pinned_specs()
    shifts, traces = calibrate_network(specs, images)
    pinned = [dataclasses.replace(s, requant_shift=sh)
              for s, sh in zip(specs, shifts)]
    net = compile_network(pinned, np.zeros((1, 4), dtype=np.int8))
    for i, img in enumerate(images):
        for backend in ("oracle", "fast"):
            out = net.serve_one(img, backend=backend)
            np.testing.assert_array_equal(
                out, traces[-1][i],
                err_msg=f"calibration trace != {backend} execution for "
                        f"image {i}")
    outs, _ = net.serve(list(images))
    np.testing.assert_array_equal(outs, np.stack(traces[-1]))
    # the wrap genuinely happened: layer-1 activations left [-128, 127]
    # pre-truncation, so clip and wrap disagree on this network ...
    _, clip_traces = calibrate_network(specs, images, saturate=True)
    assert not all(np.array_equal(a, b) for a, b in
                   zip(traces[-1], clip_traces[-1])), \
        "test network no longer exercises the wrap path"
    # ... and the clip-advanced (pre-fix) trace does NOT match the device
    assert not all(
        np.array_equal(net.serve_one(img, backend="fast"), clip_traces[-1][i])
        for i, img in enumerate(images))


def test_saturate_trace_matches_clip_semantics():
    """The saturate=True leg follows the documented clip semantics."""
    specs, images = _wrapping_pinned_specs()
    _, clip_traces = calibrate_network(specs, images, saturate=True)
    acc0 = images[0].astype(np.int64) @ specs[0].weights.astype(np.int64)
    np.testing.assert_array_equal(
        clip_traces[0][0],
        np.clip(acc0 >> 0, -128, 127).astype(np.int8))


def test_unpinned_calibration_trace_matches_serve_lenet5():
    """General differential check on the real model: for unpinned
    LeNet-5, calibration-chosen shifts keep every activation in range,
    and the per-layer trace is bit-identical to batched serving."""
    weights = lenet5_random_weights(seed=7)
    images = [synthetic_digit(s) for s in range(1, 5)]
    shifts, traces = calibrate_network(lenet5_specs(weights), images)
    net = compile_network(lenet5_specs(weights, shifts), images[0])
    outs, _ = net.serve(list(images))
    np.testing.assert_array_equal(outs, np.stack(traces[-1]))
    # no-wrap invariant: the clip- and wrap-advanced traces agree at
    # *every* layer when shifts were chosen by calibration itself
    _, clip_traces = calibrate_network(lenet5_specs(weights), images,
                                       saturate=True)
    for k, (layer_t, layer_c) in enumerate(zip(traces, clip_traces)):
        for a, b in zip(layer_t, layer_c):
            np.testing.assert_array_equal(
                a, b, err_msg=f"layer {k} wrapped on the calibration set")
