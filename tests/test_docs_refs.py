"""Docs-consistency floor: every ``*.md`` document cited from ``src/``
must exist at the repo root.

A dozen module docstrings cite DESIGN.md / EXPERIMENTS.md sections (the
hardware/software co-design discipline of the VTA blueprint paper); this
test is what keeps those cross-references from dangling again.  CI runs it
as a dedicated docs-consistency step.

Hypothesis-free: part of the tier-1 floor.
"""

import pathlib
import re

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]
SRC = REPO_ROOT / "src"
# §-section citations also live in tests/, benchmarks/ and examples/.
SCAN_DIRS = (SRC, REPO_ROOT / "tests", REPO_ROOT / "benchmarks",
             REPO_ROOT / "examples")

# Upper-case markdown citations like DESIGN.md, EXPERIMENTS.md, ROADMAP.md.
_MD_REF = re.compile(r"\b([A-Z][A-Z0-9_]*\.md)\b")
# Section citations like "DESIGN.md §3" / "EXPERIMENTS.md §Perf".
_SECTION_REF = re.compile(r"([A-Z][A-Z0-9_]*\.md)\s*§([A-Za-z0-9-]+)")

_THIS_FILE = pathlib.Path(__file__).resolve()


def _scan_files():
    for base in SCAN_DIRS:
        for py in sorted(base.rglob("*.py")):
            if py.resolve() == _THIS_FILE:
                continue
            yield py, py.read_text(encoding="utf-8")


def cited_docs():
    refs = {}          # doc name -> first citing file
    for py, text in _scan_files():
        for m in _MD_REF.finditer(text):
            refs.setdefault(m.group(1), py.relative_to(REPO_ROOT))
    return refs


def cited_sections():
    refs = {}          # (doc, section) -> first citing file
    for py, text in _scan_files():
        for m in _SECTION_REF.finditer(text):
            refs.setdefault((m.group(1), m.group(2)),
                            py.relative_to(REPO_ROOT))
    return refs


def test_every_cited_markdown_doc_exists():
    refs = cited_docs()
    assert refs, "expected src/ to cite at least one markdown doc"
    missing = {doc: str(src) for doc, src in refs.items()
               if not (REPO_ROOT / doc).exists()}
    assert not missing, (
        f"docstrings cite markdown files that do not exist: {missing}")


def test_every_cited_section_resolves():
    """Every ``<DOC>.md §<section>`` citation in the codebase must appear
    in that document — scanned, not hardcoded, so a future citation of a
    section that does not exist fails here instead of dangling."""
    refs = cited_sections()
    assert refs, "expected at least one '<DOC>.md §<section>' citation"
    doc_text = {}
    missing = {}
    for (doc, section), src in refs.items():
        if doc not in doc_text:
            path = REPO_ROOT / doc
            doc_text[doc] = (path.read_text(encoding="utf-8")
                             if path.exists() else "")
        if f"§{section}" not in doc_text[doc]:
            missing[f"{doc} §{section}"] = str(src)
    assert not missing, f"cited sections not found in their docs: {missing}"
