"""Property tests on the model substrates (hypothesis where shapes allow).

Key invariants:
* chunked attention == exact attention oracle for any chunking;
* chunked WKV (rwkv6) == naive sequential recurrence;
* chunked mamba scan == naive sequential recurrence;
* MoE: no-drop capacity ⇒ output invariant to batch grouping; capacity
  respected under drops; aux losses sane.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import attention_ref
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import chunked_attention
from repro.models.mamba import mamba_apply, mamba_defs, mamba_init_state
from repro.models.moe import moe_apply, moe_defs
from repro.models.params import init_params
from repro.models.rwkv6 import chunked_wkv


# ---------------------------------------------------------------------------
# chunked attention
# ---------------------------------------------------------------------------

@given(sq=st.sampled_from([16, 32, 64]), qc=st.sampled_from([4, 8, 16, 64]),
       kc=st.sampled_from([4, 8, 32]), h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_chunked_attention_matches_oracle(sq, qc, kc, h, g, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, h, sq, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, h // g, sq, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, h // g, sq, 16)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, q_chunk=qc, kv_chunk=kc)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("window", [4, 16])
def test_chunked_attention_window(window):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 8)), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_chunk=8, kv_chunk=8)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


def test_causal_skip_matches_masked():
    """§Perf optimization: skipping fully-masked kv chunks is exact."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, 2, 64, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 2, 64, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 2, 64, 16)), jnp.float32)
    base = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                             causal_skip=False)
    skip = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                             causal_skip=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(skip),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# RWKV6 chunked WKV vs sequential recurrence
# ---------------------------------------------------------------------------

def _wkv_sequential(r, k, v, w, u):
    b, h, s, n = r.shape
    S = np.zeros((b, h, n, n), np.float64)
    out = np.zeros((b, h, s, n), np.float64)
    r, k, v, w = (np.asarray(t, np.float64) for t in (r, k, v, w))
    u = np.asarray(u, np.float64)
    for t in range(s):
        kv = np.einsum("bhn,bhm->bhnm", k[:, :, t], v[:, :, t])
        out[:, :, t] = np.einsum(
            "bhn,bhnm->bhm", r[:, :, t], S + u[None, :, :, None] * kv)
        S = S * w[:, :, t, :, None] + kv
    return out, S


@given(s=st.sampled_from([8, 16, 32]), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_chunked_wkv_matches_sequential(s, chunk, seed):
    rng = np.random.default_rng(seed)
    b, h, n = 1, 2, 8
    r = rng.normal(size=(b, h, s, n)).astype(np.float32)
    k = rng.normal(size=(b, h, s, n)).astype(np.float32)
    v = rng.normal(size=(b, h, s, n)).astype(np.float32)
    w = np.exp(-np.exp(rng.normal(size=(b, h, s, n)))).astype(np.float32)
    u = rng.normal(size=(h, n)).astype(np.float32) * 0.5
    out, state = chunked_wkv(jnp.asarray(r), jnp.asarray(k), jnp.asarray(v),
                             jnp.asarray(w), jnp.asarray(u), chunk=chunk)
    ref_out, ref_state = _wkv_sequential(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), ref_out, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state), ref_state,
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# Mamba chunked scan vs sequential
# ---------------------------------------------------------------------------

def test_mamba_chunked_matches_two_halves():
    cfg = ModelConfig("m", 1, 32, 4, 4, 64, 97, ssm_kind="mamba",
                      mamba_d_state=4)
    p = init_params(mamba_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    full = mamba_apply(p, cfg, x)
    st0 = mamba_init_state(cfg, 2, jnp.float32)
    a, st1 = mamba_apply(p, cfg, x[:, :8], state=st0, return_state=True)
    b, _ = mamba_apply(p, cfg, x[:, 8:], state=st1, return_state=True)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([a, b], 1)),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(cf=8.0):
    return ModelConfig("x", 1, 32, 4, 4, 64, 97,
                       moe=MoEConfig(4, 2, 64, capacity_factor=cf))


def test_moe_no_drop_is_grouping_invariant():
    cfg = _moe_cfg(cf=8.0)       # capacity ≥ worst case → no drops
    p = init_params(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 32)), jnp.float32)
    full, _ = moe_apply(p, cfg, x)
    a, _ = moe_apply(p, cfg, x[:1])
    b, _ = moe_apply(p, cfg, x[1:])
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([a, b], 0)),
                               atol=1e-5, rtol=1e-5)


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)      # tiny capacity → most tokens dropped
    p = init_params(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    out, aux = moe_apply(p, cfg, x)
    # dropped tokens contribute exactly zero
    norms = np.linalg.norm(np.asarray(out).reshape(-1, 32), axis=-1)
    assert (norms == 0).sum() > 0
    assert np.isfinite(float(aux["load_balance"]))


def test_moe_gates_normalised_and_aux_bounded():
    cfg = _moe_cfg()
    p = init_params(moe_defs(cfg), jax.random.PRNGKey(2), jnp.float32)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 32, 32)), jnp.float32)
    _, aux = moe_apply(p, cfg, x)
    # load balance ≥ 1 (perfectly balanced == 1), z-loss ≥ 0
    assert float(aux["load_balance"]) >= 0.99
    assert float(aux["router_z"]) >= 0.0


def test_moe_grad_flows_through_router():
    cfg = _moe_cfg()
    p = init_params(moe_defs(cfg), jax.random.PRNGKey(3), jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)

    def loss(p):
        out, _ = moe_apply(p, cfg, x)
        return jnp.sum(out ** 2)

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0   # gate weights carry grad
