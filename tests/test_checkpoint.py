"""Checkpointing: atomicity, keep-K GC, async overlap, elastic restore."""

import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer

# Seed-legacy LM-stack suite: fails on the container's jax/orbax versions;
# excluded from the blocking VTA-core run (pytest.ini 'legacy' marker).
pytestmark = pytest.mark.legacy


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(16,)), jnp.float32)},
        "step": jnp.int32(7),
        "nested": [jnp.arange(4), jnp.ones((2, 2), jnp.bfloat16)],
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = _tree()
    ck.save(3, tree)
    assert ck.latest_step() == 3
    out = ck.restore(3, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_wait(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = _tree()
    ck.save_async(1, tree)
    ck.wait()
    assert ck.latest_step() == 1


def test_keep_k_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    assert ck.complete_steps() == [3, 4]


def test_atomicity_partial_write_ignored(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(5, _tree())
    # a crashed mid-write leaves a .tmp dir: must be invisible + GC'd
    crash = tmp_path / "step_0000000009.tmp"
    crash.mkdir()
    (crash / "leaf_00000.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 5
    ck.save(6, _tree())
    assert not crash.exists()


def test_corrupt_manifest_is_not_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=3)
    ck.save(5, _tree())
    broken = tmp_path / "step_0000000007"
    broken.mkdir()                      # no manifest inside
    assert ck.latest_step() == 5


def test_restore_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    ck.save(1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        ck.restore(1, {"w": jnp.zeros((5, 4))})


def test_elastic_restore_across_device_counts(tmp_path):
    """Save under one sharding, restore under another (1-device CPU here;
    the mechanism — full-array leaves + caller-provided shardings — is
    device-count independent)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ck = Checkpointer(tmp_path, keep=1)
    with jax.set_mesh(mesh1):
        ck.save(1, tree)
    # "new cluster": different mesh shape (1×1 is all CPU offers, but the
    # sharding object is re-derived, which is the elastic code path)
    mesh2 = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh2, P("data", None))}
    out = ck.restore(1, tree, shardings)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["w"].sharding == shardings["w"]
