"""Data-definition tests (paper §3.2): padding / splitting / binarisation."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core.layout import (binarize_blocks, debinarize_blocks,
                               matrix_padding, matrix_splitting,
                               matrix_to_binary, matrix_unsplit,
                               remove_padding, should_pad_height)


def test_paper_3x3_example():
    """§3.2 worked example: a 3×3 matrix, block_size=2 → 4×4 padded →
    four 2×2 blocks (A0..A3) ordered by row."""
    m = np.arange(9, dtype=np.int8).reshape(3, 3)
    padded = matrix_padding(m, 2)
    assert padded.shape == (4, 4)
    np.testing.assert_array_equal(padded[:3, :3], m)
    assert padded[3].sum() == 0 and padded[:, 3].sum() == 0
    split = matrix_splitting(padded, 2)
    assert (split.block_rows, split.block_cols) == (2, 2)
    np.testing.assert_array_equal(split.block(0, 0), m[:2, :2])
    # binarisation order: left→right, top→bottom
    raw = binarize_blocks(split, np.int8)
    assert raw[:4] == bytes([0, 1, 3, 4])   # A0 row-major


def test_wgt_blocks_transposed_order_unchanged():
    m = np.arange(16, dtype=np.int8).reshape(4, 4)
    split = matrix_splitting(m, 2)
    raw = binarize_blocks(split, np.int8, transpose=True)
    # first block transposed: [[0,1],[4,5]]ᵀ = [[0,4],[1,5]]
    assert raw[:4] == bytes([0, 4, 1, 5])
    rt = debinarize_blocks(raw, np.int8, 2, 2, 2, 2, transpose=True)
    np.testing.assert_array_equal(matrix_unsplit(rt), m)


@given(h=st.integers(1, 70), w=st.integers(1, 70), bs=st.sampled_from([2, 8, 16]))
@settings(max_examples=100)
def test_pad_split_binarise_roundtrip(h, w, bs):
    rng = np.random.default_rng(h * 1000 + w * 10 + bs)
    m = rng.integers(-128, 128, (h, w), dtype=np.int64).astype(np.int8)
    raw, split = matrix_to_binary(m, bs, np.int8)
    # widths always padded to block multiples; heights per the §3.2 rule
    assert split.padded_shape[1] % bs == 0
    if h > 1:
        assert split.padded_shape[0] % bs == 0
    else:
        assert split.row_height == 1
    rt = debinarize_blocks(raw, np.int8, split.block_rows, split.block_cols,
                           split.row_height, bs)
    recovered = remove_padding(matrix_unsplit(rt), (h, w))
    np.testing.assert_array_equal(recovered, m)


@given(h=st.integers(1, 40), w=st.integers(1, 40))
@settings(max_examples=50)
def test_padding_preserves_values_and_zero_fills(h, w):
    rng = np.random.default_rng(h * 100 + w)
    m = rng.integers(-128, 128, (h, w), dtype=np.int64).astype(np.int8)
    p = matrix_padding(m, 16, pad_height=True)
    np.testing.assert_array_equal(p[:h, :w], m)
    assert p[h:].sum() == 0 and p[:, w:].sum() == 0
    assert p.shape[0] % 16 == 0 and p.shape[1] % 16 == 0


def test_height_padding_rule():
    """The '(generally)' rule of §3.2 that reproduces the paper's §5.1 loop
    counts: multi-row matrices are height-padded, single-row are not."""
    assert should_pad_height(np.zeros((784, 25), dtype=np.int8))
    assert not should_pad_height(np.zeros((1, 400), dtype=np.int8))


def test_int32_acc_binarisation():
    m = np.array([[2**30, -2**30]], dtype=np.int32)
    raw, split = matrix_to_binary(m, 2, np.int32, pad_height=False)
    rt = debinarize_blocks(raw, np.int32, split.block_rows, split.block_cols,
                           split.row_height, 2)
    np.testing.assert_array_equal(matrix_unsplit(rt)[:1, :2], m)
