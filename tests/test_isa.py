"""Bit-level ISA round-trip tests (paper §2.3, Fig. 3/4)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dependency
from hypothesis import given, settings, strategies as st

from repro.core import isa


def test_insn_width():
    assert isa.INSN_BYTES == 16    # 128-bit instructions
    assert isa.UOP_BYTES == 4      # 32-bit UOPs
    for insn in (isa.GemInsn(), isa.AluInsn(), isa.FinishInsn(),
                 isa.MemInsn(isa.Opcode.LOAD, isa.MemId.INP, 0, 0, 1, 1, 1)):
        assert len(insn.encode()) == 16


def test_gemm_field_widths_match_fig3():
    # Fig. 3: 3-bit opcode, 4 dep flags, 13-bit UOP_BGN, 14-bit UOP_END,
    # 14-bit LP_OUT/LP_IN, 2×11-bit ACC factors, 2×11-bit INP, 2×10-bit WGT.
    assert isa.GemInsn.W0 == [3, 1, 1, 1, 1, 1, 13, 14, 14, 14]
    assert isa.GemInsn.W1 == [11, 11, 11, 11, 10, 10]
    assert isa.Uop.W == [11, 11, 10]


@given(uop_bgn=st.integers(0, 2**13 - 1), uop_end=st.integers(0, 2**14 - 1),
       iter_out=st.integers(0, 2**14 - 1), iter_in=st.integers(0, 2**14 - 1),
       f=st.tuples(*[st.integers(0, 2**11 - 1)] * 4),
       w=st.tuples(*[st.integers(0, 2**10 - 1)] * 2),
       reset=st.integers(0, 1),
       dep=st.tuples(*[st.integers(0, 1)] * 4))
@settings(max_examples=200)
def test_gemm_roundtrip(uop_bgn, uop_end, iter_out, iter_in, f, w, reset, dep):
    g = isa.GemInsn(reset=reset, uop_bgn=uop_bgn, uop_end=uop_end,
                    iter_out=iter_out, iter_in=iter_in,
                    acc_factor_out=f[0], acc_factor_in=f[1],
                    inp_factor_out=f[2], inp_factor_in=f[3],
                    wgt_factor_out=w[0], wgt_factor_in=w[1],
                    dep=isa.DepFlags(*dep))
    assert isa.GemInsn.decode(g.encode()) == g


@given(op=st.sampled_from(list(isa.AluOp)), imm=st.integers(-2**15, 2**15 - 1),
       use_imm=st.integers(0, 1), uop_bgn=st.integers(0, 2**13 - 1),
       iters=st.tuples(st.integers(0, 2**14 - 1), st.integers(0, 2**14 - 1)))
@settings(max_examples=200)
def test_alu_roundtrip(op, imm, use_imm, uop_bgn, iters):
    a = isa.AluInsn(alu_opcode=op, imm=imm, use_imm=use_imm, uop_bgn=uop_bgn,
                    iter_out=iters[0], iter_in=iters[1])
    assert isa.AluInsn.decode(a.encode()) == a


@given(opcode=st.sampled_from([isa.Opcode.LOAD, isa.Opcode.STORE]),
       mem=st.sampled_from(list(isa.MemId)),
       sram=st.integers(0, 2**16 - 1), dram=st.integers(0, 2**32 - 1),
       y=st.integers(0, 2**16 - 1), x=st.integers(0, 2**16 - 1),
       stride=st.integers(0, 2**16 - 1),
       pads=st.tuples(*[st.integers(0, 15)] * 4))
@settings(max_examples=200)
def test_mem_roundtrip(opcode, mem, sram, dram, y, x, stride, pads):
    m = isa.MemInsn(opcode, mem, sram, dram, y, x, stride, *pads)
    assert isa.MemInsn.decode(m.encode()) == m


@given(acc=st.integers(0, 2**11 - 1), inp=st.integers(0, 2**11 - 1),
       wgt=st.integers(0, 2**10 - 1))
@settings(max_examples=100)
def test_uop_roundtrip(acc, inp, wgt):
    u = isa.Uop(acc, inp, wgt)
    assert isa.Uop.decode(u.encode()) == u


def test_stream_roundtrip():
    insns = [
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.UOP, 0, 0x1000, 1, 4, 4),
        isa.GemInsn(reset=1, uop_bgn=0, uop_end=1),
        isa.GemInsn(uop_bgn=1, uop_end=2, iter_out=1, iter_in=16),
        isa.AluInsn(alu_opcode=isa.AluOp.MAX, use_imm=1, imm=0,
                    iter_out=1, iter_in=16),
        isa.MemInsn(isa.Opcode.STORE, isa.MemId.OUT, 0, 0x300, 1, 16, 16),
        isa.FinishInsn(),
    ]
    raw = isa.encode_stream(insns)
    assert len(raw) == 16 * len(insns)
    decoded = isa.decode_stream(raw)
    assert isa.encode_stream(decoded) == raw
    assert [type(i) for i in decoded] == [type(i) for i in insns]


def test_loop_count_is_section51_metric():
    g = isa.GemInsn(uop_bgn=1, uop_end=2, iter_out=1, iter_in=16)
    assert g.loop_count == 16      # §3.4: one 16×16 matmul = 16 GeMM loops


def test_field_overflow_raises():
    with pytest.raises(ValueError):
        isa.GemInsn(uop_bgn=2**13).encode()
    with pytest.raises(ValueError):
        isa.MemInsn(isa.Opcode.LOAD, isa.MemId.INP, 0, 2**32, 1, 1, 1).encode()
