"""End-to-end batched serving tests (DESIGN.md §Batching).

``NetworkProgram.serve`` must produce, for every request in the batch,
exactly the bytes the per-image paths produce: the compiler's reference
(``verify``), the per-image ``serve_one`` on both simulator backends, and
the integer model reference.  Serving twice must reuse the cached
instruction plans — compile-once/serve-many asserted via plan identity.

Hypothesis-free: tier-1 floor.
"""

import numpy as np
import pytest

from repro.core.fast_simulator import plan_for
from repro.core.network_compiler import compile_network
from repro.core.simulator import (decode_out_region, decode_out_region_batch,
                                  make_simulator, run_program,
                                  run_program_batch)
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                reference_forward_int8, synthetic_digit)

BATCH = 8


@pytest.fixture(scope="module")
def lenet():
    weights = lenet5_random_weights(seed=0)
    net = compile_network(lenet5_specs(weights), synthetic_digit(0))
    return weights, net


@pytest.fixture(scope="module")
def cifar():
    from repro.models.cifar_cnn import (calibrate_shifts,
                                        cifar_cnn_random_weights,
                                        cifar_cnn_specs,
                                        synthetic_cifar_image)
    weights = cifar_cnn_random_weights(seed=0)
    shifts = calibrate_shifts(
        weights, [synthetic_cifar_image(s) for s in range(1, 3)])
    net = compile_network(cifar_cnn_specs(weights, shifts),
                          synthetic_cifar_image(0))
    return weights, net


def _digits(n, seed=42):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# LeNet-5
# ---------------------------------------------------------------------------

def test_lenet_serve_matches_per_image_exactly(lenet):
    weights, net = lenet
    imgs = _digits(BATCH)
    outs, reports = net.serve(imgs)
    assert outs.shape[0] == BATCH
    assert len(reports) == len(net.layers)
    shifts = [l.requant_shift for l in net.layers]
    for b, img in enumerate(imgs):
        np.testing.assert_array_equal(
            outs[b], net.serve_one(img, backend="fast"),
            err_msg=f"request {b}: batched != looped fast")
        ref, _ = reference_forward_int8(weights, img, shifts)
        np.testing.assert_array_equal(outs[b], ref)
    # one request cross-checked against the per-struct oracle interpreter
    np.testing.assert_array_equal(outs[0],
                                  net.serve_one(imgs[0], backend="oracle"))


def test_lenet_serve_matches_verify_on_reference_input(lenet):
    """Serving the compile-time input must reproduce ``verify()``'s
    output (the compiler's own reference path)."""
    _, net = lenet
    expected, _ = net.verify(backend="fast")
    outs, _ = net.serve([net.input_tensor] * BATCH)
    for b in range(BATCH):
        np.testing.assert_array_equal(outs[b], expected)


def test_lenet_serve_reuses_cached_plans(lenet):
    """Compile-once/serve-many: the per-layer instruction plans must be
    the *same objects* across serve calls (no recompilation)."""
    _, net = lenet
    imgs = _digits(4, seed=3)
    net.serve(imgs)
    plans_first = net.plans()
    net.serve(imgs)
    plans_second = net.plans()
    assert all(a is b for a, b in zip(plans_first, plans_second))
    assert len(plans_first) == len(net.layers)
    # the plan the batched engine used is the one cached on the program
    assert all(plan_for(l.program) is p
               for l, p in zip(net.layers, plans_first))


def test_lenet_serve_report_totals(lenet):
    """Batched reports carry batch totals: loop counts are batch × the
    single-image program counts."""
    _, net = lenet
    _, reports = net.serve(_digits(BATCH, seed=5))
    for layer, rep in zip(net.layers, reports):
        assert rep.gemm_loops == BATCH * layer.program.gemm_loops()
        assert rep.insn_executed == len(layer.program.instructions)
    assert sum(r.gemm_loops for r in reports) == BATCH * 2942   # §5.1


def test_lenet_serve_accepts_stacked_array(lenet):
    _, net = lenet
    imgs = _digits(6, seed=9)
    outs_list, _ = net.serve(imgs)
    outs_arr, _ = net.serve(np.concatenate(imgs, axis=0))   # (6, 1, 32, 32)
    np.testing.assert_array_equal(outs_list, outs_arr)
    with pytest.raises(ValueError):
        net.serve([])
    with pytest.raises(ValueError):
        net.serve(np.zeros((4, 3, 5), dtype=np.int8))
    # wrong channel count: staged bytes don't fit the compiled INP region
    with pytest.raises(ValueError):
        net.serve([np.zeros((1, 3, 32, 32), dtype=np.int8)])


# ---------------------------------------------------------------------------
# CIFAR CNN (multi-chunk, padded conv, max pool, uop waves)
# ---------------------------------------------------------------------------

def test_cifar_serve_matches_per_image_exactly(cifar):
    from repro.models.cifar_cnn import reference_forward_int8 as cifar_ref
    weights, net = cifar
    assert max(net.chunks_per_layer()) > 1      # the multi-chunk workload
    rng = np.random.default_rng(21)
    imgs = [rng.integers(-64, 64, (1, 3, 32, 32)).astype(np.int8)
            for _ in range(BATCH)]
    outs, reports = net.serve(imgs)
    shifts = [l.requant_shift for l in net.layers]
    for b, img in enumerate(imgs):
        np.testing.assert_array_equal(
            outs[b], net.serve_one(img, backend="fast"),
            err_msg=f"request {b}: batched != looped fast")
        ref, _ = cifar_ref(weights, img, shifts)
        np.testing.assert_array_equal(outs[b], ref)
    for layer, rep in zip(net.layers, reports):
        assert rep.gemm_loops == BATCH * layer.program.gemm_loops()


def test_cifar_serve_reuses_cached_plans(cifar):
    _, net = cifar
    rng = np.random.default_rng(23)
    imgs = [rng.integers(-64, 64, (1, 3, 32, 32)).astype(np.int8)
            for _ in range(2)]
    net.serve(imgs)
    first = net.plans()
    net.serve(imgs)
    assert all(a is b for a, b in zip(first, net.plans()))


# ---------------------------------------------------------------------------
# Program-level batched dispatch (simulator.py)
# ---------------------------------------------------------------------------

def test_run_program_batch_replicates_single_image(lenet):
    _, net = lenet
    prog = net.layers[0].program
    out_single, _ = run_program(prog, backend="fast")
    outs, rep = run_program_batch(prog, batch=3)
    assert outs.shape == (3,) + out_single.shape
    for b in range(3):
        np.testing.assert_array_equal(outs[b], out_single)
    assert rep.gemm_loops == 3 * prog.gemm_loops()
    # uniform dispatch: backend="batched" on the single-image entry point
    out_b, _ = run_program(prog, backend="batched")
    np.testing.assert_array_equal(out_b, out_single)
    with pytest.raises(ValueError):
        run_program_batch(prog)          # neither batch nor stack
    with pytest.raises(ValueError):
        run_program_batch(prog, batch=2,
                          dram_stack=np.zeros((3, 8), dtype=np.uint8))


def test_decode_out_region_batch_matches_single(lenet):
    _, net = lenet
    prog = net.layers[0].program
    image = prog.dram_image()
    sim = make_simulator(prog.config, image, backend="fast")
    sim.run(prog.instructions)
    single = decode_out_region(prog, sim.dram)
    stacked = decode_out_region_batch(prog, np.stack([sim.dram, sim.dram]))
    np.testing.assert_array_equal(stacked[0], single)
    np.testing.assert_array_equal(stacked[1], single)


def test_make_simulator_batched_backend_selection():
    from repro.core.fast_simulator import BatchFastSimulator
    from repro.core.hwconfig import vta_default
    cfg = vta_default()
    sim = make_simulator(cfg, np.zeros((2, 64), dtype=np.uint8),
                         backend="batched")
    assert isinstance(sim, BatchFastSimulator)
