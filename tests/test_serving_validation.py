"""Serving-layer validation fixes riding the quantization PR:

* ``pad_ladder(max_batch <= 0)`` used to return the degenerate ``(0,)``
  ladder (an engine that pads every request to batch zero); it now
  raises a typed :class:`CompileError` with the stable constraint id
  ``ladder-max-batch`` — at ladder construction, at policy construction
  AND at ``NetworkProgram.padded_batch_sizes``.
* ``nearest_rank`` truncated ``int(q * n)`` before the ceiling
  division, so p99.9 of 1000 samples read rank 999 instead of 1000; it
  now computes ``ceil(q · n / 100)`` exactly via ``fractions.Fraction``.
"""

import math
from fractions import Fraction

import numpy as np
import pytest

from repro.core.errors import CompileError
from repro.core.layer_compiler import LayerSpec
from repro.core.network_compiler import compile_network
from repro.serving.vta.metrics import nearest_rank
from repro.serving.vta.policy import BatchPolicy, pad_ladder

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # optional dev dependency
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Ladder construction rejects non-positive max_batch at every layer
# ---------------------------------------------------------------------------

class TestLadderValidation:
    @pytest.mark.parametrize("bad", [0, -3])
    def test_pad_ladder_rejects(self, bad):
        with pytest.raises(CompileError) as ei:
            pad_ladder(bad)
        assert ei.value.constraint == "ladder-max-batch"

    def test_pad_ladder_still_powers_of_two(self):
        assert pad_ladder(1) == (1,)
        assert pad_ladder(8) == (1, 2, 4, 8)
        assert pad_ladder(6) == (1, 2, 4, 6)

    @pytest.mark.parametrize("bad", [0, -1])
    def test_batch_policy_rejects(self, bad):
        with pytest.raises(CompileError) as ei:
            BatchPolicy(max_batch=bad)
        assert ei.value.constraint == "policy-max-batch"

    def test_padded_batch_sizes_rejects(self):
        spec = LayerSpec("fc", "fc", np.eye(4, dtype=np.int8),
                         requant_shift=0)
        net = compile_network([spec], np.zeros((1, 4), np.int8))
        assert net.padded_batch_sizes(4) == (1, 2, 4)
        with pytest.raises(CompileError) as ei:
            net.padded_batch_sizes(0)
        assert ei.value.constraint == "ladder-max-batch"

    def test_compile_error_is_value_error(self):
        # pre-existing catchers used ValueError; the typed error must
        # keep matching them
        with pytest.raises(ValueError):
            pad_ladder(0)


# ---------------------------------------------------------------------------
# nearest_rank: exact ceil(q·n/100)
# ---------------------------------------------------------------------------

class TestNearestRank:
    def test_p999_of_1000_is_max(self):
        # the old int(q*n) truncation read rank 999 here
        vals = [float(i) for i in range(1, 1001)]
        assert nearest_rank(vals, 99.9) == 1000.0

    def test_documented_examples(self):
        vals = [float(i) for i in range(1, 11)]
        assert nearest_rank(vals, 50) == 5.0
        assert nearest_rank(vals, 95) == 10.0
        assert nearest_rank(vals, 0) == 1.0
        assert nearest_rank(vals, 100) == 10.0

    def test_no_float_rounding_at_boundaries(self):
        # q·n/100 landing exactly on an integer must not pick up a
        # stray ulp: p30 of 10 values is rank 3 exactly
        vals = [float(i) for i in range(1, 11)]
        assert nearest_rank(vals, 30) == 3.0
        assert nearest_rank(vals, 30.0000001) == 4.0

    def test_validation(self):
        with pytest.raises(ValueError):
            nearest_rank([], 50)
        with pytest.raises(ValueError):
            nearest_rank([1.0], 101)
        with pytest.raises(ValueError):
            nearest_rank([1.0], -1)


if HAS_HYPOTHESIS:
    @given(st.integers(1, 400), st.floats(0, 100), st.floats(0, 100))
    @settings(max_examples=100, deadline=None)
    def test_hypothesis_nearest_rank_spec(n, q1, q2):
        vals = [float(i) for i in range(1, n + 1)]
        # agrees with the documented definition, computed independently
        want = max(1, math.ceil(Fraction(q1) * n / 100))
        assert nearest_rank(vals, q1) == float(min(want, n))
        # monotone in q; q=100 -> max
        lo, hi = sorted((q1, q2))
        assert nearest_rank(vals, lo) <= nearest_rank(vals, hi)
        assert nearest_rank(vals, 100) == float(n)
else:                                   # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_nearest_rank_spec():
        pass
