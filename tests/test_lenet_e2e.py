"""LeNet-5 end-to-end reproduction tests (paper §4.3 / §5).

These are the paper's headline claims, asserted verbatim:

* layer-1 lowering shapes (§4.3);
* 2942 GeMM loops total (§5.1) with the per-layer breakdown;
* 2972 TensorGemm cycles and the 47552-cycle SIMD-CPU comparison (§5.2);
* bit-accurate execution of the full 5-layer chain on the functional
  simulator, including the host-side reshaping of Fig. 12.
"""

import numpy as np
import pytest

from repro.core.cycle_model import FPGA_CLOCK_HZ, analyze_programs
from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                reference_forward_float,
                                reference_forward_int8, synthetic_digit)


@pytest.fixture(scope="module")
def lenet():
    weights = lenet5_random_weights(seed=0)
    net = compile_network(lenet5_specs(weights), synthetic_digit(0))
    return weights, net


def test_layer1_lowering_shapes(lenet):
    _, net = lenet
    l1 = net.layers[0]
    assert l1.input_matrix.shape == (784, 25)       # §4.3 verbatim
    a_split_rows = 784 // 16                        # α = 49
    assert a_split_rows == 49
    assert l1.weight_matrix.shape == (25, 6)        # λ·bs=32 → λ=2 after pad
    assert l1.keep_rows is not None and len(l1.keep_rows) == 196
    assert (l1.out_h, l1.out_w) == (14, 14)         # (1,6,14,14)


def test_gemm_loops_2942(lenet):
    """§5.1: 'the execution requires 2942 GeMM loops'."""
    _, net = lenet
    assert net.gemm_loops_per_layer() == [1568, 1120, 200, 48, 6]
    assert net.gemm_loops() == 2942


def test_cycle_model_matches_paper(lenet):
    """§5.2: 2972 TensorGemm cycles; 47552 SIMD-CPU cycles; ≈10 GHz CPU."""
    _, net = lenet
    cr = net.cycle_report()
    assert cr.gemm_insns == 5                   # one GeMM per layer
    assert cr.tensor_gemm_cycles == 2972
    assert cr.simd_cpu_cycles(16) == 47552
    assert 9e9 < cr.equivalent_cpu_clock_hz() < 11e9
    # our leaner ALU schedule: total below the paper's 6358 (EXPERIMENTS.md)
    assert cr.total_compute_cycles <= 6358
    assert cr.execution_time_s(FPGA_CLOCK_HZ) < 9.9e-6


def test_chained_execution_bit_accurate(lenet):
    """Fig. 12 chain on the functional simulator == integer reference."""
    weights, net = lenet
    out, reports = net.verify()
    shifts = [l.requant_shift for l in net.layers]
    logits, _ = reference_forward_int8(weights, synthetic_digit(0), shifts)
    np.testing.assert_array_equal(out, logits)
    # 5 VTA executions, each terminated by FINISH
    assert len(reports) == 5
    assert sum(r.gemm_loops for r in reports) == 2942


def test_classification_agrees_with_float_reference(lenet):
    """The paper validates against a (PyTorch) float model; ours is JAX."""
    weights, net = lenet
    out, _ = net.run_functional()
    fl = reference_forward_float(weights, synthetic_digit(0))
    assert int(np.argmax(out)) == int(np.argmax(fl))


def test_multiple_images_bit_accurate():
    """Robustness: different inputs and weight seeds stay bit-accurate."""
    for seed in (1, 2):
        weights = lenet5_random_weights(seed=seed)
        img = synthetic_digit(seed + 10)
        net = compile_network(lenet5_specs(weights), img)
        out, _ = net.verify()
        shifts = [l.requant_shift for l in net.layers]
        logits, _ = reference_forward_int8(weights, img, shifts)
        np.testing.assert_array_equal(out, logits)
        assert net.gemm_loops() == 2942   # loop count is input-independent


def test_dram_traffic_reported(lenet):
    """§5.1: the functional simulator reports DRAM exchange volume."""
    _, net = lenet
    _, reports = net.run_functional()
    assert all(r.dram_bytes_read > 0 for r in reports)
    assert all(r.dram_bytes_written > 0 for r in reports)
