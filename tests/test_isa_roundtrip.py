"""ISA round-trip property suite (paper §2.3 / Fig. 3-4).

The wire format is what the paper's certification argument rests on: a
VTA program is *bytes*, and every analysis (simulators, cycle model,
conformance suites) reasons about the decoded form.  Two layers of
guard:

* **Round-trip property** — ``decode(encode(insn)) == insn`` for every
  instruction type and every bit field at its min/max/random values
  (and ``decode_insn`` dispatching by opcode).  A deterministic
  boundary sweep runs as the hypothesis-free tier-1 floor; the
  hypothesis property (200+ examples per instruction type) runs when
  the optional dependency is installed.
* **Golden bytes** — the exact 16-byte encodings of one instruction of
  each kind (and one 4-byte UOP) are pinned as hex.  Any change to a
  field width, field order, or word endianness fails here even if it
  round-trips, because it silently breaks compatibility with the VTA
  hardware's fixed layout.
"""

import zlib

import numpy as np
import pytest

from repro.core import isa

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # optional dev dependency
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Field universes: (name, min, max) per instruction type, from the bit
# widths of the VTA hw_spec layout (W0/W1 class vars).
# ---------------------------------------------------------------------------

DEP_FIELDS = [("pop_prev", 0, 1), ("pop_next", 0, 1),
              ("push_prev", 0, 1), ("push_next", 0, 1)]

MEM_FIELDS = [("sram_base", 0, 2**16 - 1), ("dram_base", 0, 2**32 - 1),
              ("y_size", 0, 2**16 - 1), ("x_size", 0, 2**16 - 1),
              ("x_stride", 0, 2**16 - 1),
              ("y_pad_0", 0, 15), ("y_pad_1", 0, 15),
              ("x_pad_0", 0, 15), ("x_pad_1", 0, 15)]

GEM_FIELDS = [("reset", 0, 1), ("uop_bgn", 0, 2**13 - 1),
              ("uop_end", 0, 2**14 - 1), ("iter_out", 0, 2**14 - 1),
              ("iter_in", 0, 2**14 - 1),
              ("acc_factor_out", 0, 2**11 - 1), ("acc_factor_in", 0, 2**11 - 1),
              ("inp_factor_out", 0, 2**11 - 1), ("inp_factor_in", 0, 2**11 - 1),
              ("wgt_factor_out", 0, 2**10 - 1), ("wgt_factor_in", 0, 2**10 - 1)]

ALU_FIELDS = [("reset", 0, 1), ("uop_bgn", 0, 2**13 - 1),
              ("uop_end", 0, 2**14 - 1), ("iter_out", 0, 2**14 - 1),
              ("iter_in", 0, 2**14 - 1),
              ("dst_factor_out", 0, 2**11 - 1), ("dst_factor_in", 0, 2**11 - 1),
              ("src_factor_out", 0, 2**11 - 1), ("src_factor_in", 0, 2**11 - 1),
              ("use_imm", 0, 1), ("imm", -2**15, 2**15 - 1)]

UOP_FIELDS = [("acc_idx", 0, 2**11 - 1), ("inp_idx", 0, 2**11 - 1),
              ("wgt_idx", 0, 2**10 - 1)]


def _mem(**kw):
    kw.setdefault("opcode", isa.Opcode.LOAD)
    kw.setdefault("memory_type", isa.MemId.INP)
    base = dict(sram_base=0, dram_base=0, y_size=1, x_size=1, x_stride=1)
    base.update(kw)
    return isa.MemInsn(**base)


def _dep_from_bits(bits):
    return isa.DepFlags(**{n: int(b)
                           for (n, _, _), b in zip(DEP_FIELDS, bits)})


MAKERS = {
    isa.MemInsn: (MEM_FIELDS, _mem),
    isa.GemInsn: (GEM_FIELDS, lambda **kw: isa.GemInsn(**kw)),
    isa.AluInsn: (ALU_FIELDS, lambda **kw: isa.AluInsn(**kw)),
}


def _roundtrip(insn):
    raw = insn.encode()
    assert len(raw) == isa.INSN_BYTES
    dec = isa.decode_insn(raw)            # dispatch by opcode, then decode
    assert type(dec) is type(insn)
    assert dec == insn
    assert dec.encode() == raw            # encode∘decode is the identity too


# ---------------------------------------------------------------------------
# Deterministic boundary sweep (hypothesis-free tier-1 floor)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [isa.MemInsn, isa.GemInsn, isa.AluInsn])
def test_every_field_roundtrips_at_min_and_max(cls):
    """Each bit field at its extreme values, others random — every
    combination must survive decode(encode(·)) bit-exactly."""
    fields, make = MAKERS[cls]
    # zlib.crc32, not hash(): string hashing is randomized per process,
    # and this sweep must be reproducible
    rng = np.random.default_rng(zlib.crc32(cls.__name__.encode()))
    for name, lo, hi in fields:
        for value in (lo, hi):
            kw = {n: int(rng.integers(l, h + 1)) for n, l, h in fields}
            kw[name] = value
            dep_bits = [int(rng.integers(0, 2)) for _ in DEP_FIELDS]
            insn = make(dep=_dep_from_bits(dep_bits), **kw)
            _roundtrip(insn)
    # all-min and all-max corners
    for pick in (0, 1):
        kw = {n: (l, h)[pick] for n, l, h in fields}
        insn = make(dep=_dep_from_bits([pick] * 4), **kw)
        _roundtrip(insn)


def test_mem_opcode_and_memory_type_combinations():
    for opcode in (isa.Opcode.LOAD, isa.Opcode.STORE):
        for mem in isa.MemId:
            _roundtrip(_mem(opcode=opcode, memory_type=mem,
                            sram_base=3, dram_base=77, y_size=2, x_size=5,
                            x_stride=9))


def test_alu_opcode_and_signed_imm_roundtrip():
    for op in isa.AluOp:
        for imm in (-2**15, -1, 0, 1, 2**15 - 1):
            _roundtrip(isa.AluInsn(alu_opcode=op, use_imm=1, imm=imm,
                                   uop_bgn=0, uop_end=1))


def test_finish_roundtrips_with_every_dep_combination():
    for bits in range(16):
        dep = _dep_from_bits([(bits >> i) & 1 for i in range(4)])
        _roundtrip(isa.FinishInsn(dep=dep))


def test_uop_roundtrips_at_boundaries():
    rng = np.random.default_rng(5)
    for name, lo, hi in UOP_FIELDS:
        for value in (lo, hi):
            kw = {n: int(rng.integers(l, h + 1)) for n, l, h in UOP_FIELDS}
            kw[name] = value
            u = isa.Uop(**kw)
            raw = u.encode()
            assert len(raw) == isa.UOP_BYTES
            assert isa.Uop.decode(raw) == u


def test_seeded_random_sweep_all_types():
    """1000 random instructions across the four types + uops — the
    deterministic bulk of the round-trip floor."""
    rng = np.random.default_rng(42)
    for _ in range(250):
        for cls in (isa.MemInsn, isa.GemInsn, isa.AluInsn):
            fields, make = MAKERS[cls]
            kw = {n: int(rng.integers(l, h + 1)) for n, l, h in fields}
            insn = make(dep=_dep_from_bits(rng.integers(0, 2, 4)), **kw)
            _roundtrip(insn)
        _roundtrip(isa.FinishInsn(dep=_dep_from_bits(rng.integers(0, 2, 4))))
        kw = {n: int(rng.integers(l, h + 1)) for n, l, h in UOP_FIELDS}
        u = isa.Uop(**kw)
        assert isa.Uop.decode(u.encode()) == u


def test_stream_roundtrip_and_length_guard():
    rng = np.random.default_rng(7)
    insns = [_mem(sram_base=1, dram_base=2, y_size=3, x_size=4, x_stride=5),
             isa.GemInsn(uop_bgn=1, uop_end=4, iter_out=2, iter_in=16),
             isa.AluInsn(alu_opcode=isa.AluOp.MAX, use_imm=1, imm=0),
             isa.FinishInsn()]
    raw = isa.encode_stream(insns)
    assert len(raw) == len(insns) * isa.INSN_BYTES
    assert isa.decode_stream(raw) == insns
    with pytest.raises(ValueError):
        isa.decode_stream(raw[:-1])
    uops = [isa.Uop(int(rng.integers(0, 2**11)), int(rng.integers(0, 2**11)),
                    int(rng.integers(0, 2**10))) for _ in range(9)]
    assert isa.decode_uops(isa.encode_uops(uops)) == uops
    with pytest.raises(ValueError):
        isa.decode_uops(isa.encode_uops(uops)[:-2])


def test_out_of_range_fields_are_rejected_at_encode():
    """A field that does not fit its bit width must raise, not wrap —
    wrapping would be silent wire corruption."""
    with pytest.raises(ValueError):
        _mem(sram_base=2**16).encode()
    with pytest.raises(ValueError):
        isa.GemInsn(uop_bgn=2**13).encode()
    with pytest.raises(ValueError):
        isa.AluInsn(dst_factor_out=2**11).encode()
    with pytest.raises(ValueError):
        isa.Uop(wgt_idx=2**10).encode()


# ---------------------------------------------------------------------------
# Golden bytes: the exact wire layout, pinned
# ---------------------------------------------------------------------------

GOLDEN = {
    "load": (lambda: isa.MemInsn(
        isa.Opcode.LOAD, isa.MemId.ACC, sram_base=0x1234,
        dram_base=0xDEADBEEF, y_size=7, x_size=640, x_stride=896,
        y_pad_0=1, y_pad_1=2, x_pad_0=3, x_pad_1=4,
        dep=isa.DepFlags(pop_prev=1, push_next=1)),
        "c8d148bcfbb67a030700800280032143"),
    "store": (lambda: isa.MemInsn(
        isa.Opcode.STORE, isa.MemId.OUT, sram_base=5, dram_base=4096,
        y_size=2, x_size=32, x_stride=64,
        dep=isa.DepFlags(pop_prev=1, push_prev=1)),
        "29160000400000000200200040000000"),
    "gemm": (lambda: isa.GemInsn(
        reset=0, uop_bgn=37, uop_end=101, iter_out=9, iter_in=16,
        acc_factor_out=0, acc_factor_in=1, inp_factor_out=16,
        inp_factor_in=1, wgt_factor_out=6, wgt_factor_in=0,
        dep=isa.DepFlags(pop_prev=1, push_prev=1, pop_next=1)),
        "3a25a00c480020000008000402600000"),
    "alu": (lambda: isa.AluInsn(
        alu_opcode=isa.AluOp.SHR, uop_bgn=1, uop_end=2, iter_out=24,
        iter_in=16, dst_factor_out=16, dst_factor_in=1, src_factor_out=16,
        src_factor_in=1, use_imm=1, imm=-6,
        dep=isa.DepFlags(push_next=1)),
        "44014000c0002000100800040270fd7f"),
    "finish": (lambda: isa.FinishInsn(dep=isa.DepFlags(pop_next=1)),
               "13000000000000000000000000000000"),
}
GOLDEN_UOP = (lambda: isa.Uop(acc_idx=0x5A5, inp_idx=0x3C3, wgt_idx=0x2A2),
              "a51d9ea8")


@pytest.mark.parametrize("kind", sorted(GOLDEN))
def test_golden_bytes_regression(kind):
    """The pinned 16-byte little-endian encodings — a format change that
    still round-trips (e.g. swapped field order) fails here."""
    make, hexbytes = GOLDEN[kind]
    insn = make()
    assert insn.encode().hex() == hexbytes
    assert isa.decode_insn(bytes.fromhex(hexbytes)) == insn


def test_golden_uop_bytes_regression():
    make, hexbytes = GOLDEN_UOP
    uop = make()
    assert uop.encode().hex() == hexbytes
    assert isa.Uop.decode(bytes.fromhex(hexbytes)) == uop


# ---------------------------------------------------------------------------
# Hypothesis property (200+ examples per instruction type; skips cleanly
# when the optional dependency is absent)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    def _dep_strategy():
        return st.builds(isa.DepFlags, *[st.integers(0, 1)] * 4)

    def _fields_strategy(fields):
        return {n: st.integers(lo, hi) for n, lo, hi in fields}

    @settings(max_examples=200, deadline=None)
    @given(opcode=st.sampled_from([isa.Opcode.LOAD, isa.Opcode.STORE]),
           memory_type=st.sampled_from(list(isa.MemId)),
           dep=_dep_strategy(), **_fields_strategy(MEM_FIELDS))
    def test_hypothesis_mem_roundtrip(opcode, memory_type, dep, **kw):
        _roundtrip(isa.MemInsn(opcode=opcode, memory_type=memory_type,
                               dep=dep, **kw))

    @settings(max_examples=200, deadline=None)
    @given(dep=_dep_strategy(), **_fields_strategy(GEM_FIELDS))
    def test_hypothesis_gemm_roundtrip(dep, **kw):
        _roundtrip(isa.GemInsn(dep=dep, **kw))

    @settings(max_examples=200, deadline=None)
    @given(alu_opcode=st.sampled_from(list(isa.AluOp)), dep=_dep_strategy(),
           **_fields_strategy(ALU_FIELDS))
    def test_hypothesis_alu_roundtrip(alu_opcode, dep, **kw):
        _roundtrip(isa.AluInsn(alu_opcode=alu_opcode, dep=dep, **kw))

    @settings(max_examples=200, deadline=None)
    @given(dep=_dep_strategy())
    def test_hypothesis_finish_roundtrip(dep):
        _roundtrip(isa.FinishInsn(dep=dep))

    @settings(max_examples=200, deadline=None)
    @given(**_fields_strategy(UOP_FIELDS))
    def test_hypothesis_uop_roundtrip(**kw):
        u = isa.Uop(**kw)
        assert isa.Uop.decode(u.encode()) == u
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_roundtrip():
        pass
