"""Virtual-clock serving simulation tests (DESIGN.md §Serving,
EXPERIMENTS.md §Serving-latency).

The determinism contracts behind the ``servelat/*`` benchmark rows: the
discrete-event simulation of the engine's own batching policy replays
bit-identically for a given seed (trace + histogram + summary), seeded
load generators are pure functions of their seed, the closed-loop source
bounds concurrency by construction, padding follows the compiled-shape
ladder, and the metrics audit catches the accounting violations it
claims to (exercised both positively and negatively).

Hypothesis-free: tier-1 floor.
"""

import numpy as np
import pytest

from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)
from repro.serving.vta import (BatchPolicy, ClosedLoopSource, PoissonSource,
                               RequestRecord, ServiceModel, ServingMetrics,
                               VirtualClock, calibrate_service_model,
                               nearest_rank, pad_ladder, padded_size,
                               poisson_arrival_times, ready_count,
                               request_images, simulate)

MODEL = ServiceModel(base_s=0.004, per_image_s=0.001)


@pytest.fixture(scope="module")
def lenet():
    return compile_network(lenet5_specs(lenet5_random_weights(0)),
                           synthetic_digit(0))


# ---------------------------------------------------------------------------
# Clock + policy primitives
# ---------------------------------------------------------------------------

def test_virtual_clock_is_monotonic():
    clock = VirtualClock()
    assert clock.now() == 0.0
    clock.advance_to(1.5)
    clock.advance_to(1.5)                       # no-op advance is fine
    assert clock.now() == 1.5
    with pytest.raises(ValueError, match="backward"):
        clock.advance_to(1.0)


def test_pad_ladder_and_padded_size():
    assert pad_ladder(8) == (1, 2, 4, 8)
    assert pad_ladder(1) == (1,)
    ladder = pad_ladder(6)                      # non-pow2 cap joins ladder
    assert ladder == (1, 2, 4, 6)
    assert padded_size(3, ladder) == 4
    assert padded_size(5, ladder) == 6
    assert padded_size(1, ladder) == 1
    with pytest.raises(ValueError):
        padded_size(7, ladder)


def test_ready_count_policy_matrix():
    policy = BatchPolicy(max_batch=4, max_wait_s=0.01)
    # a full batch dispatches regardless of age
    assert ready_count(9, 5.0, 5.0, policy) == 4
    # young + under-full: wait
    assert ready_count(2, 5.0, 5.005, policy) == 0
    # aged past max_wait (float-exact boundary): dispatch what's there
    assert ready_count(2, 5.0, 5.0 + policy.max_wait_s, policy) == 2
    # closed drain flushes immediately
    assert ready_count(2, 5.0, 5.0, policy, closed=True) == 2
    assert ready_count(0, 0.0, 0.0, policy, closed=True) == 0
    # max_wait=0 dispatches every arrival at once
    eager = BatchPolicy(max_batch=4, max_wait_s=0.0)
    assert ready_count(1, 7.0, 7.0, eager) == 1


def test_batch_policy_validation():
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=4, max_wait_s=-1.0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=4, max_depth=0)


# ---------------------------------------------------------------------------
# Seeded load generation
# ---------------------------------------------------------------------------

def test_poisson_arrivals_are_seed_deterministic():
    a = poisson_arrival_times(200.0, 50, seed=7)
    b = poisson_arrival_times(200.0, 50, seed=7)
    assert a == b
    assert a != poisson_arrival_times(200.0, 50, seed=8)
    assert all(t1 < t2 for t1, t2 in zip(a, a[1:]))
    with pytest.raises(ValueError):
        poisson_arrival_times(0.0, 10, seed=0)


def test_closed_loop_source_issues_exactly_n():
    src = ClosedLoopSource(3, 10, think_s=0.01)
    arrivals = src.initial_arrivals()
    assert len(arrivals) == 3                   # one in flight per client
    fired = {rid for _, rid in arrivals}
    t = 0.0
    while len(fired) < 10:
        t += 0.01
        for _, rid in src.on_complete(min(fired), t):
            assert rid not in fired
            fired.add(rid)
    assert src.on_complete(9, t + 1.0) == []    # budget exhausted
    assert fired == set(range(10))


def test_closed_loop_source_rejects_zero_retry():
    with pytest.raises(ValueError, match="retry_s"):
        ClosedLoopSource(2, 4, retry_s=0.0)


# ---------------------------------------------------------------------------
# Discrete-event simulation determinism
# ---------------------------------------------------------------------------

def _run(seed, **kw):
    policy = kw.pop("policy", BatchPolicy(max_batch=4, max_wait_s=0.01,
                                          max_depth=16))
    return simulate(PoissonSource(kw.pop("rate", 600.0),
                                  kw.pop("n", 80), seed=seed),
                    policy, MODEL, slo_s=kw.pop("slo_s", 0.05), **kw)


def test_same_seed_replays_bit_identically():
    a, b = _run(42, workers=2), _run(42, workers=2)
    assert a.trace() == b.trace()
    assert a.metrics.latency_histogram() == b.metrics.latency_histogram()
    assert a.metrics.summary() == b.metrics.summary()
    assert a.metrics.audit() == [] and b.metrics.audit() == []


def test_different_seed_diverges():
    assert _run(42).trace() != _run(43).trace()


def test_simulated_execution_matches_direct_serve(lenet):
    """DES with net attached really executes batches: outputs must be
    bit-identical to a direct NetworkProgram.serve of the same images."""
    images = request_images(lenet, 10, seed=3)
    result = simulate(PoissonSource(500.0, 10, seed=5, images=images),
                      BatchPolicy(max_batch=4, max_wait_s=0.01),
                      MODEL, workers=2, net=lenet)
    direct, _ = lenet.serve(images)
    assert sorted(result.outputs) == list(range(10))
    for rid, out in result.outputs.items():
        np.testing.assert_array_equal(out, direct[rid])
    assert result.metrics.audit() == []


def test_overload_sheds_with_backpressure_accounting():
    """Offered load far above capacity: rejections occur and the counters
    conserve (submitted == completed + rejected)."""
    result = _run(1, rate=5000.0, n=200,
                  policy=BatchPolicy(max_batch=2, max_wait_s=0.001,
                                     max_depth=4))
    s = result.metrics.summary()
    assert s["rejected"] > 0
    assert s["submitted"] == s["completed"] + s["rejected"]
    assert result.metrics.drained()
    assert result.metrics.audit() == []


def test_heavy_backlog_fills_batches():
    """Under sustained overload every non-tail batch forms at max_batch."""
    result = _run(2, rate=5000.0, n=120)
    sizes = [r.batch_size for r in result.records]
    assert max(sizes) == 4
    full = sum(1 for n in sizes if n == 4)
    assert full >= 0.8 * len(sizes)


def test_sim_respects_padding_ladder():
    result = _run(3, rate=900.0, n=60,
                  policy=BatchPolicy(max_batch=8, max_wait_s=0.004,
                                     max_depth=64))
    ladder = pad_ladder(8)
    for r in result.records:
        assert r.padded_size in ladder
        assert r.padded_size == padded_size(r.batch_size, ladder)


def test_max_wait_zero_sim_never_batches_waiting_requests():
    """max_wait=0 with a free worker dispatches each arrival alone."""
    result = simulate(PoissonSource(10.0, 20, seed=9),
                      BatchPolicy(max_batch=8, max_wait_s=0.0),
                      ServiceModel(base_s=1e-4, per_image_s=1e-5),
                      workers=4)
    assert all(r.batch_size == 1 for r in result.records)


def test_closed_loop_bounds_concurrency():
    """At most ``clients`` requests are ever in flight: count overlapping
    enqueue→complete intervals."""
    clients = 3
    result = simulate(ClosedLoopSource(clients, 30, think_s=0.001),
                      BatchPolicy(max_batch=4, max_wait_s=0.002),
                      MODEL, workers=2)
    assert len(result.records) == 30
    events = []
    for r in result.records:
        events.append((r.enqueue_t, 1))
        events.append((r.complete_t, -1))
    in_flight = peak = 0
    for _, delta in sorted(events, key=lambda e: (e[0], e[1])):
        in_flight += delta
        peak = max(peak, in_flight)
    assert peak <= clients
    assert result.metrics.audit() == []


def test_slo_counter_matches_recount():
    result = _run(4, slo_s=1e-6)                # impossible SLO
    s = result.metrics.summary()
    assert s["slo_violations"] == s["completed"] > 0
    assert result.metrics.audit() == []         # recount agrees


def test_service_model_calibration_is_usable(lenet):
    model = calibrate_service_model(lenet, batch=4, repeats=1)
    assert model.base_s > 0
    assert model.per_image_s >= 0
    assert model.service_s(4) >= model.service_s(1)


# ---------------------------------------------------------------------------
# Metrics: percentiles + audit negative coverage
# ---------------------------------------------------------------------------

def test_nearest_rank_percentiles():
    vals = [float(i) for i in range(1, 11)]     # 1..10
    assert nearest_rank(vals, 50) == 5.0
    assert nearest_rank(vals, 95) == 10.0
    assert nearest_rank(vals, 99) == 10.0
    assert nearest_rank(vals, 0) == 1.0
    assert nearest_rank([3.0], 99) == 3.0
    with pytest.raises(ValueError):
        nearest_rank([], 50)


def _record(rid=0, enq=0.0, disp=0.1, comp=0.2, batch=1, padded=1):
    return RequestRecord(rid=rid, enqueue_t=enq, dispatch_t=disp,
                         complete_t=comp, batch_size=batch,
                         padded_size=padded, backend="batched", worker=0)


def test_audit_flags_violations():
    m = ServingMetrics(slo_s=0.05)
    m.on_submit()
    m.observe(_record(rid=1, disp=0.2, comp=0.1))     # non-monotonic
    errs = m.audit()
    assert any("non-monotonic" in e for e in errs)
    # the SLO counter itself agrees with the recount — no such error
    assert not any("slo_violations" in e for e in errs)

    m2 = ServingMetrics()
    m2.on_submit()
    m2.observe(_record(rid=2))
    m2.observe(_record(rid=2))                        # duplicate + over-count
    errs2 = m2.audit()
    assert any("twice" in e for e in errs2)
    assert any("over-accounted" in e for e in errs2)

    m3 = ServingMetrics()
    m3.on_submit()
    m3.observe(_record(rid=3, batch=4, padded=2))     # batch > padded
    assert any("padded" in e for e in m3.audit())


def test_metrics_summary_and_drained():
    m = ServingMetrics(slo_s=0.15)
    for i in range(4):
        m.on_submit()
    m.on_reject()
    for i in range(3):
        m.observe(_record(rid=i, enq=float(i), disp=i + 0.05,
                          comp=i + 0.1 * (i + 1), batch=3, padded=4))
    assert m.drained()
    s = m.summary()
    assert s["completed"] == 3 and s["rejected"] == 1
    assert s["slo_violations"] == 2                   # 0.2s and 0.3s > 0.15s
    assert s["mean_batch_occupancy"] == 3.0
    assert s["mean_padded_size"] == 4.0
    assert m.audit() == []
