"""CIFAR-10-scale CNN end-to-end: the first workload past LeNet-5.

This is the scaling demonstration of DESIGN.md §3: same-padded
convolutions, max pooling, and layer matrices that no longer fit one SRAM
residency.  Layer 1 (conv 3→64 k5, same padding) lowers to a 1024×75
input matrix — 5120 INP vectors against a 2048-vector buffer — so its
program is multi-chunk *by construction*, with the pool/requant ALU uops
re-indexed against each chunk's local ACC window.

  1. calibrate static requant shifts over a held-out image set (§4.2);
  2. compile all 5 layers into one shared DRAM allocation (Fig. 12) and
     report the per-layer chunk/uop/wave statistics;
  3. verify the chain bit-exactly on the fast backend — and, unless
     ``--skip-oracle``, on the oracle too, asserting both backends agree
     byte-for-byte;
  4. serve a batch of classification requests against the integer
     reference.

    PYTHONPATH=src python examples/cifar10_cnn_e2e.py [--requests 4]
                                                      [--batch 4]
                                                      [--backend fast|oracle]
                                                      [--skip-oracle]

``--batch N`` serves the requests through the batched runtime (one
compiled plan per layer over the whole group, DESIGN.md §Batching)
instead of one VTA chain per image.
"""

import argparse
import time

import numpy as np

from repro.core import isa
from repro.core.cycle_model import FPGA_CLOCK_HZ
from repro.core.network_compiler import compile_network
from repro.models.cifar_cnn import (calibrate_shifts,
                                    cifar_cnn_random_weights,
                                    cifar_cnn_specs, reference_forward_int8,
                                    synthetic_cifar_image)


def layer_stats(net) -> None:
    print("layer      chunks  gemm_loops  uops   uop_waves")
    for layer in net.layers:
        prog = layer.program
        waves = sum(1 for i in prog.instructions
                    if isinstance(i, isa.MemInsn)
                    and i.memory_type == isa.MemId.UOP) - 1
        print(f"  {layer.spec.name:<9}{layer.n_chunks:>5}"
              f"{prog.gemm_loops():>12}{len(prog.uops):>7}{waves:>10}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=1,
                    help="requests per batched VTA execution; 1 = serve "
                         "per-image (default: 1)")
    ap.add_argument("--backend", choices=("fast", "oracle"), default="fast",
                    help="backend for the per-image serving loop")
    ap.add_argument("--skip-oracle", action="store_true",
                    help="skip the oracle cross-check (CI smoke mode)")
    args = ap.parse_args()
    if args.batch > 1 and args.backend != "fast":
        ap.error("--batch > 1 runs the batched engine; "
                 "--backend oracle is per-image only (use --batch 1)")

    weights = cifar_cnn_random_weights(seed=0)
    print("calibrating static requant shifts (§4.2)...")
    cal = [synthetic_cifar_image(s) for s in range(1, 9)]
    shifts = calibrate_shifts(weights, cal)

    print("compiling the CIFAR-10 CNN through the VTA pipeline...")
    t0 = time.perf_counter()
    net = compile_network(cifar_cnn_specs(weights, shifts),
                          synthetic_cifar_image(0))
    print(f"  compiled in {time.perf_counter() - t0:.3f}s; "
          f"total GeMM loops = {net.gemm_loops()} "
          f"(LeNet-5 was 2942 — ~{net.gemm_loops() / 2942:.0f}x larger)")
    layer_stats(net)
    assert max(net.chunks_per_layer()) > 1, "expected a multi-chunk layer"
    cr = net.cycle_report()
    print(f"  compute cycles = {cr.total_compute_cycles} "
          f"(+{cr.compute_load_cycles} UOP/ACC-load) → "
          f"{cr.execution_time_s(include_loads=True) * 1e6:.1f} µs @650 MHz")

    print("verifying the chain (fast backend)...")
    out_fast, _ = net.verify(backend="fast")
    if not args.skip_oracle:
        print("verifying the chain (oracle backend)...")
        out_oracle, _ = net.verify(backend="oracle")
        np.testing.assert_array_equal(out_oracle, out_fast)
        print("  oracle and fast backends agree bit-for-bit")

    rng = np.random.default_rng(42)
    images = [rng.integers(-64, 64, (1, 3, 32, 32)).astype(np.int8)
              for _ in range(args.requests)]
    serve_s = 0.0
    logits_all = []
    if args.batch > 1:
        mode = f"batched (batch {args.batch})"
        for lo in range(0, len(images), args.batch):
            t0 = time.perf_counter()
            outs, _ = net.serve(images[lo:lo + args.batch])
            serve_s += time.perf_counter() - t0
            logits_all.extend(outs)
    else:
        mode = f"per-image ({args.backend})"
        for img in images:
            t0 = time.perf_counter()
            logits_all.append(net.serve_one(img, backend=args.backend))
            serve_s += time.perf_counter() - t0
    shifts = [l.requant_shift for l in net.layers]
    for r, (img, logits) in enumerate(zip(images, logits_all)):
        ref_logits, _ = reference_forward_int8(weights, img, shifts)
        assert np.array_equal(logits, ref_logits), f"request {r}: mismatch!"
    if args.requests:
        print(f"\nserved {args.requests} requests in {serve_s:.2f}s "
              f"({args.requests / serve_s:.1f} img/s, {mode}); "
              f"bit-exact vs integer reference: "
              f"{args.requests}/{args.requests}")


if __name__ == "__main__":
    main()
