"""Serve an LM with batched requests through the production serving engine
(prefill + KV-cache decode + continuous batching).

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x22b]
                                               [--requests 6]

Any of the 10 assigned architectures works (reduced smoke config on CPU);
the same engine lowers the full configs in the multi-pod dry-run.
"""

import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    import sys
    serve_main()
