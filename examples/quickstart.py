"""Quickstart — the paper's §3.4 worked example, end to end.

Compiles C = ReLU(A·B) for 16×16 int8 matrices down to VTA binaries,
prints the instruction stream, runs the functional simulator, and checks
the result bit-for-bit.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import isa
from repro.core.gemm_compiler import AluImmOp, compile_matmul
from repro.core.simulator import run_program

rng = np.random.default_rng(0)
A = rng.integers(-128, 128, (16, 16), dtype=np.int64).astype(np.int8)
B = rng.integers(-128, 128, (16, 16), dtype=np.int64).astype(np.int8)

prog = compile_matmul(A, B, alu_ops=[AluImmOp.relu()], name="quickstart")

print("== DRAM allocation (§2.2) ==")
for region in prog.allocator.regions:
    print(f"  {region.name:<18} phys @{region.phys_addr:#06x}  "
          f"logical @{region.logical_addr(0):#06x}  "
          f"{region.count} × {region.struct_bytes}B")

print("\n== instruction stream (§3.3) ==")
for i, insn in enumerate(prog.instructions):
    if isinstance(insn, isa.MemInsn):
        print(f"  [{i}] {insn.opcode.name} {insn.memory_type.name} "
              f"sram@{insn.sram_base:#x} dram@{insn.dram_base:#x} "
              f"y={insn.y_size} x={insn.x_size}")
    elif isinstance(insn, isa.GemInsn):
        print(f"  [{i}] GEMM{' (reset)' if insn.reset else ''} "
              f"uop[{insn.uop_bgn}:{insn.uop_end}] "
              f"LP_OUT={insn.iter_out} LP_IN={insn.iter_in}")
    elif isinstance(insn, isa.AluInsn):
        print(f"  [{i}] ALU {insn.alu_opcode.name} imm={insn.imm}")
    else:
        print(f"  [{i}] FINISH")

print(f"\nUOPs: {[(u.acc_idx, u.inp_idx, u.wgt_idx) for u in prog.uops]}")

out, report = run_program(prog)
expect = np.maximum(A.astype(np.int64) @ B.astype(np.int64), 0)
expect = (expect & 0xFF).astype(np.uint8).view(np.int8)
assert np.array_equal(out, expect), "simulator mismatch!"
print(f"\nGeMM loops: {report.gemm_loops} (§3.4: one 16-loop instruction)")
print(f"DRAM traffic: {report.dram_bytes_total} bytes")
print("bit-exact ✓")

# binary artifacts (Fig. 5)
import tempfile
with tempfile.TemporaryDirectory() as d:
    files = prog.write_binaries(d)
    print("\nFig. 5 binaries:", sorted(p.name for p in files.values()))
