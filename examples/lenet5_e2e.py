"""End-to-end driver (deliverable (b)): LeNet-5 served through the VTA
compiler pipeline with batched requests — the paper's own workload (§4.3).

  1. compile all 5 layers into one shared DRAM allocation (Fig. 12);
  2. serve a batch of digit-classification requests: per request, the host
     re-binarises the input, launches the 5 chained VTA executions on the
     functional simulator, and reads back the logits;
  3. verify every answer bit-exactly against the integer reference and
     report agreement with the float (JAX) model + the §5 tables.

    PYTHONPATH=src python examples/lenet5_e2e.py [--requests 16]
                                                 [--backend fast|oracle]

``--backend fast`` (the default) serves on the vectorised plan-compiling
simulator; ``--backend oracle`` uses the per-struct reference interpreter.
Both are bit-exact — the fast path just gets there ~10× sooner.
"""

import argparse
import time

import numpy as np

from repro.core.cycle_model import FPGA_CLOCK_HZ
from repro.core.layout import matrix_to_binary
from repro.core.network_compiler import compile_network
from repro.core.simulator import (decode_out_region, make_simulator,
                                  run_instructions)
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                reference_forward_float,
                                reference_forward_int8)


def serve_request(net, image: np.ndarray, *,
                  backend: str = "fast") -> np.ndarray:
    """One inference: rewrite the layer-1 INP region for this image, then
    run the 5 chained VTA executions (Fig. 12)."""
    from repro.core.layer_compiler import layer_matrices
    image = image.astype(np.int8)
    first = net.layers[0]
    A, _, _ = layer_matrices(first.spec, image)
    inp_bin, _ = matrix_to_binary(A, net.config.block_size,
                                  net.config.inp_dtype)
    image_mem = net.dram_image()
    region = first.program.regions["inp"]
    start = region.phys_addr - net.allocator.offset
    image_mem[start:start + len(inp_bin)] = np.frombuffer(inp_bin, np.uint8)

    out = None
    for k, layer in enumerate(net.layers):
        sim = make_simulator(net.config, image_mem, backend=backend)
        run_instructions(sim, layer.program.instructions,
                         program=layer.program)
        image_mem = sim.dram
        out_mat = decode_out_region(layer.program, image_mem)
        from repro.core.layer_compiler import decode_layer_output
        semantic = decode_layer_output(layer, out_mat)
        if k + 1 < len(net.layers):
            nxt = net.layers[k + 1]
            A, _, _ = layer_matrices(nxt.spec, semantic)
            nxt_bin, _ = matrix_to_binary(A, net.config.block_size,
                                          net.config.inp_dtype)
            r = nxt.program.regions["inp"]
            s = r.phys_addr - net.allocator.offset
            image_mem[s:s + len(nxt_bin)] = np.frombuffer(nxt_bin, np.uint8)
        out = semantic
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--backend", choices=("fast", "oracle"), default="fast",
                    help="functional-simulator backend (default: fast)")
    args = ap.parse_args()

    weights = lenet5_random_weights(seed=0)
    print("compiling LeNet-5 through the VTA pipeline...")
    t0 = time.perf_counter()
    # static requant shifts calibrated over a held-out image set (§4.2:
    # everything is fixed at compile time — predictable execution)
    from repro.models.lenet import calibrate_shifts
    cal_rng = np.random.default_rng(7)
    cal = [cal_rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
           for _ in range(8)]
    shifts = calibrate_shifts(weights, cal)
    net = compile_network(lenet5_specs(weights, shifts),
                          np.zeros((1, 1, 32, 32), np.int8))
    print(f"  compiled in {time.perf_counter() - t0:.3f}s; "
          f"total GeMM loops = {net.gemm_loops()} (paper: 2942)")
    cr = net.cycle_report()
    print(f"  TensorGemm cycles = {cr.tensor_gemm_cycles} (paper: 2972); "
          f"exec = {cr.execution_time_s(FPGA_CLOCK_HZ) * 1e6:.2f} µs "
          f"@650 MHz (paper: 9.8 µs, leaner ALU schedule)")
    shifts = [l.requant_shift for l in net.layers]

    rng = np.random.default_rng(42)
    agree_float = 0
    serve_s = 0.0
    for r in range(args.requests):
        img = rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
        t0 = time.perf_counter()
        logits = serve_request(net, img, backend=args.backend)
        serve_s += time.perf_counter() - t0
        ref_logits, _ = reference_forward_int8(weights, img, shifts)
        assert np.array_equal(logits, ref_logits), f"request {r}: mismatch!"
        fl = reference_forward_float(weights, img)
        agree_float += int(np.argmax(logits) == np.argmax(fl))
    print(f"\nserved {args.requests} requests in {serve_s:.2f}s "
          f"({args.requests / serve_s:.1f} req/s on the {args.backend} "
          f"functional simulator; verification excluded)")
    print(f"bit-exact vs integer reference: {args.requests}/{args.requests}")
    print(f"argmax agreement with float model: "
          f"{agree_float}/{args.requests}")


if __name__ == "__main__":
    main()
