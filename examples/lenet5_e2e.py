"""End-to-end driver (deliverable (b)): LeNet-5 served through the VTA
compiler pipeline with batched requests — the paper's own workload (§4.3).

  1. compile all 5 layers into one shared DRAM allocation (Fig. 12);
  2. serve a batch of digit-classification requests — per-image
     (``--batch 1``: host re-binarises the input, launches the 5 chained
     VTA executions, reads back the logits) or truly batched
     (``--batch N``: one compiled plan per layer executes over the whole
     request batch at once, DESIGN.md §Batching);
  3. verify every answer bit-exactly against the integer reference and
     report agreement with the float (JAX) model + the §5 tables.

    PYTHONPATH=src python examples/lenet5_e2e.py [--requests 16]
                                                 [--batch 8]
                                                 [--backend fast|oracle|pallas]

``--backend fast`` (the default) serves on the vectorised plan-compiling
simulator; ``--backend oracle`` uses the per-struct reference interpreter
(per-image serving only); ``--backend pallas`` lowers each layer to the
``vta_gemm`` MXU kernel (``interpret=True`` off-TPU, and batched serving
via ``--batch``).  All paths are bit-exact — batching just gets there
sooner (EXPERIMENTS.md §Serving).
"""

import argparse
import time

import numpy as np

from repro.core.cycle_model import FPGA_CLOCK_HZ
from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                reference_forward_float,
                                reference_forward_int8)


def serve_request(net, image: np.ndarray, *,
                  backend: str = "fast") -> np.ndarray:
    """One inference: rewrite the layer-1 INP region for this image, then
    run the 5 chained VTA executions (Fig. 12).  Thin wrapper kept for
    compatibility — the logic lives in ``NetworkProgram.serve_one``."""
    return net.serve_one(image.astype(np.int8), backend=backend)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=1,
                    help="requests per batched VTA execution; 1 = serve "
                         "per-image (default: 1)")
    ap.add_argument("--backend", choices=("fast", "oracle", "pallas"),
                    default="fast",
                    help="execution backend: fast/oracle simulators, or "
                         "the vta_gemm Pallas kernel (default: fast)")
    args = ap.parse_args()
    if args.batch > 1 and args.backend == "oracle":
        ap.error("--batch > 1 runs the batched engine; "
                 "--backend oracle is per-image only (use --batch 1)")

    weights = lenet5_random_weights(seed=0)
    print("compiling LeNet-5 through the VTA pipeline...")
    t0 = time.perf_counter()
    # static requant shifts calibrated over a held-out image set (§4.2:
    # everything is fixed at compile time — predictable execution)
    from repro.models.lenet import calibrate_shifts
    cal_rng = np.random.default_rng(7)
    cal = [cal_rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
           for _ in range(8)]
    shifts = calibrate_shifts(weights, cal)
    net = compile_network(lenet5_specs(weights, shifts),
                          np.zeros((1, 1, 32, 32), np.int8))
    print(f"  compiled in {time.perf_counter() - t0:.3f}s; "
          f"total GeMM loops = {net.gemm_loops()} (paper: 2942)")
    cr = net.cycle_report()
    print(f"  TensorGemm cycles = {cr.tensor_gemm_cycles} (paper: 2972); "
          f"exec = {cr.execution_time_s(FPGA_CLOCK_HZ) * 1e6:.2f} µs "
          f"@650 MHz (paper: 9.8 µs, leaner ALU schedule)")
    shifts = [l.requant_shift for l in net.layers]

    rng = np.random.default_rng(42)
    images = [rng.integers(0, 128, (1, 1, 32, 32)).astype(np.int8)
              for _ in range(args.requests)]
    logits_all = []
    serve_s = 0.0
    if args.batch > 1:
        batch_backend = "pallas" if args.backend == "pallas" else "batched"
        mode = f"batched (batch {args.batch}, {batch_backend})"
        for lo in range(0, len(images), args.batch):
            group = images[lo:lo + args.batch]
            t0 = time.perf_counter()
            outs, _ = net.serve(group, backend=batch_backend)
            serve_s += time.perf_counter() - t0
            logits_all.extend(outs)
    else:
        mode = f"per-image ({args.backend})"
        for img in images:
            t0 = time.perf_counter()
            logits_all.append(serve_request(net, img,
                                            backend=args.backend))
            serve_s += time.perf_counter() - t0

    agree_float = 0
    for r, (img, logits) in enumerate(zip(images, logits_all)):
        ref_logits, _ = reference_forward_int8(weights, img, shifts)
        assert np.array_equal(logits, ref_logits), f"request {r}: mismatch!"
        fl = reference_forward_float(weights, img)
        agree_float += int(np.argmax(logits) == np.argmax(fl))
    if args.requests:
        print(f"\nserved {args.requests} requests in {serve_s:.2f}s "
              f"({args.requests / serve_s:.1f} img/s, {mode} on the "
              f"functional simulator; verification excluded)")
        print(f"bit-exact vs integer reference: "
              f"{args.requests}/{args.requests}")
        print(f"argmax agreement with float model: "
              f"{agree_float}/{args.requests}")


if __name__ == "__main__":
    main()
