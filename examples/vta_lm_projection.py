"""The bridge between the paper and the LM framework: one transformer
projection layer executed three ways, bit-identically —

  1. the paper-faithful VTA path: the W8A8 projection is compiled by the
     standalone compiler (pad → split → binarise → GeMM instructions) and
     executed on the bit-accurate functional simulator;
  2. the TPU-native path: the fused Pallas ``vta_gemm`` kernel
     (interpret mode on CPU) — DESIGN.md §2's 128×128 MXU re-expression;
  3. the XLA reference (`ref.vta_gemm_ref`) the LM stack uses off-TPU.

All three must produce the same int8 activations: the paper's lowering
discipline IS the framework's quantised projection path.

    PYTHONPATH=src python examples/vta_lm_projection.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core.gemm_compiler import AluImmOp, compile_matmul
from repro.core.simulator import run_program
from repro.kernels import ops, ref

# a GQA projection: 64 tokens × d_model 96 → kv heads 2 × head_dim 32
rng = np.random.default_rng(7)
x_int8 = rng.integers(-64, 64, (64, 96), dtype=np.int64).astype(np.int8)
w_int8 = rng.integers(-64, 64, (96, 64), dtype=np.int64).astype(np.int8)
bias = rng.integers(-2000, 2000, (64,), dtype=np.int64).astype(np.int32)
SHIFT = 6

# -- 1. the paper's pipeline + functional simulator ----------------------
prog = compile_matmul(x_int8, w_int8, bias=bias,
                      alu_ops=[AluImmOp.relu(), AluImmOp.shr(SHIFT)],
                      name="kv_proj")
vta_out, report = run_program(prog)
print(f"VTA path: {report.gemm_loops} GeMM loops, "
      f"{report.insn_executed} instructions, "
      f"{report.dram_bytes_total} DRAM bytes")

# -- 2. the Pallas kernel (TensorGemm+TensorAlu fused, truncating mode) --
kern_out = ops.vta_matmul_pallas(
    jnp.asarray(x_int8), jnp.asarray(w_int8), jnp.asarray(bias),
    relu=True, shift=SHIFT, saturate=False)

# -- 3. the XLA reference the LM stack runs off-TPU ----------------------
xla_out = ref.vta_gemm_ref(
    jnp.asarray(x_int8), jnp.asarray(w_int8), jnp.asarray(bias),
    relu=True, shift=SHIFT, saturate=False)

assert np.array_equal(vta_out, np.asarray(kern_out)), "VTA != Pallas"
assert np.array_equal(vta_out, np.asarray(xla_out)), "VTA != XLA"
print("VTA simulator == Pallas vta_gemm == XLA reference ✓ (bit-exact)")
print(f"output sample: {vta_out[0, :8]}")
