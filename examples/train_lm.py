"""Train an LM with the full production substrate — microbatched AdamW,
checkpoint/restart, deterministic data, fault injection.

Default: a CPU-sized run of the lm100m family (reduced width) that learns
the synthetic Markov stream in ~60s.  ``--full`` trains the real ~100M
config (use on TPU; a few hundred steps per the deliverable).

    PYTHONPATH=src python examples/train_lm.py [--steps 60] [--full]
    PYTHONPATH=src python examples/train_lm.py --inject-failure
"""

import argparse

from repro.configs import get_config, get_smoke
from repro.launch.mesh import make_smoke_mesh
from repro.launch.train import train
from repro.optim import adamw
from repro.train.train_step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--full", action="store_true",
                    help="the real ~100M config (TPU-sized)")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", action="store_true",
                    help="kill the step halfway — the loop restarts from "
                         "the last checkpoint and converges identically")
    args = ap.parse_args()

    cfg = get_config("lm100m") if args.full else get_smoke("lm100m")
    tc = TrainConfig(
        microbatches=args.microbatches,
        opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=max(1, args.steps // 10),
                              total_steps=args.steps))
    fail_at = [args.steps // 2] if args.inject_failure else None

    report = train(cfg, steps=args.steps, global_batch=args.global_batch,
                   seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                   ckpt_every=max(1, args.steps // 5),
                   mesh=make_smoke_mesh(), train_cfg=tc, fail_at=fail_at)

    hist = report.metrics_history
    first = next((m["loss"] for m in hist if "loss" in m), float("nan"))
    last = hist[-1]["loss"] if hist else float("nan")
    print(f"\nloss {first:.3f} → {last:.3f} over {report.final_step} steps "
          f"({report.restarts} restarts, "
          f"{report.straggler.slow_steps} straggler steps)")
    assert last < first, "training did not reduce the loss"
    print("OK")


if __name__ == "__main__":
    main()
