"""Quantization front door end-to-end: float training → PTQ → accuracy.

The one-command version of EXPERIMENTS.md §Accuracy:

  1. train (or load from ``--checkpoint``) a float model on the
     procedural digit dataset — hermetic, seeded, no network access;
  2. post-training-quantize it with :func:`repro.quantize.
     quantize_network` (power-of-2 weight scales, biases at accumulator
     scale, the §4.2 activation-range scan under the device's requant
     semantics);
  3. serve the held-out test split through the batched VTA runtime and
     report int8 vs float top-1 — exiting non-zero if int8 drifts more
     than 2 points from float (the accuracy gate CI enforces).

    PYTHONPATH=src python examples/quantize_eval.py [--net lenet5|resnet8|both]
                                                    [--train-n N] [--eval-n N]
                                                    [--calib-n N] [--epochs N]
                                                    [--batch N] [--seed N]
                                                    [--checkpoint PATH.npz]

Sizes default from the ``ACCURACY_*`` env vars (falling back to the
full-scale 4000-train / 2000-eval run), so the CI smoke step can shrink
the split without a separate code path.  ``--checkpoint`` loads an
existing ``.npz`` float checkpoint if present (the import path for real
MNIST/ONNX-exported weights) and saves the trained one otherwise; with
``--net both`` it is used as a per-net suffix template.
"""

import argparse
import os
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))

from repro.quantize import evaluate_net
from benchmarks.accuracy_tables import GATE_POINTS


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="float front door -> PTQ -> dataset-scale accuracy")
    ap.add_argument("--net", choices=("lenet5", "resnet8", "both"),
                    default="both")
    ap.add_argument("--train-n", type=int,
                    default=_env_int("ACCURACY_TRAIN_N", 4000))
    ap.add_argument("--eval-n", type=int,
                    default=_env_int("ACCURACY_EVAL_N", 2000))
    ap.add_argument("--calib-n", type=int,
                    default=_env_int("ACCURACY_CALIB_N", 64))
    ap.add_argument("--epochs", type=int,
                    default=_env_int("ACCURACY_EPOCHS", 6))
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None,
                    help=".npz float checkpoint to load if present / "
                         "save after training")
    args = ap.parse_args(argv)

    nets = ("lenet5", "resnet8") if args.net == "both" else (args.net,)
    print(f"net       float%   int8%    delta    pallas  "
          f"(train={args.train_n} eval={args.eval_n} "
          f"calib={args.calib_n} epochs={args.epochs})")
    failed = False
    for net in nets:
        ckpt = args.checkpoint
        if ckpt is not None and args.net == "both":
            root, ext = os.path.splitext(ckpt)
            ckpt = f"{root}.{net}{ext or '.npz'}"
        rec = evaluate_net(net, train_n=args.train_n, eval_n=args.eval_n,
                           calib_n=args.calib_n, epochs=args.epochs,
                           seed=args.seed, batch=args.batch,
                           checkpoint=ckpt)
        # gate the published (2-decimal) delta — a raw-float boundary
        # like 2.0000000000000018 must read as exactly 2.00 points
        gate = round(rec["delta_points"], 2) <= GATE_POINTS
        failed |= not gate
        print(f"{net:<10}{rec['float_top1'] * 100:6.2f}  "
              f"{rec['int8_top1'] * 100:6.2f}  "
              f"{rec['delta_points']:+6.2f}{'' if gate else ' *FAIL*'}  "
              f"{'bit-identical' if rec['pallas_spotcheck_bit_identical'] else 'MISMATCH'}")
        if not rec["pallas_spotcheck_bit_identical"]:
            failed = True
    if failed:
        print(f"accuracy gate FAILED (int8 must stay within "
              f"{GATE_POINTS} points of float)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
