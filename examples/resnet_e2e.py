"""resnet_tiny end-to-end: branching CNNs through the graph compiler.

The first workload the flat `List[LayerSpec]` front end could not
express (DESIGN.md §Graph): a CIFAR-10-scale ResNet with two residual
joins, compiled through the DAG IR + pass pipeline (`repro.graph`) and
executed with the skip adds *on the VTA* — each join is an ALU
vector-vector ADD against an ACC-loaded second operand, visible in the
instruction stream below, not a host-side numpy merge.

  1. calibrate weight scales + static requant shifts (two-phase §4.2);
  2. compile the DAG into 7 VTA layer programs sharing one DRAM
     allocation; print the per-layer schedule — input/residual sources,
     chunk counts, ALU ADD instructions;
  3. verify the network bit-exactly on the fast backend — and, unless
     ``--skip-oracle``, on the oracle too;
  4. serve a batch of requests (batched runtime for ``--batch > 1``)
     against the graph's integer reference.

    PYTHONPATH=src python examples/resnet_e2e.py [--requests 4]
                                                 [--batch 4]
                                                 [--backend fast|oracle]
                                                 [--skip-oracle]
"""

import argparse
import time

import numpy as np

from repro.core import isa
from repro.models.resnet_tiny import (compile_resnet_tiny,
                                      reference_forward_int8,
                                      synthetic_image)


def schedule_stats(net) -> None:
    srcs, rsrcs = net._sources(), net._res_sources()
    print("layer   in<-  res<-  chunks  gemm_loops  alu_add_insns")
    for k, layer in enumerate(net.layers):
        adds = sum(1 for i in layer.program.instructions
                   if isinstance(i, isa.AluInsn)
                   and i.alu_opcode == isa.AluOp.ADD and not i.use_imm)
        src = "img" if srcs[k] < 0 else net.layers[srcs[k]].spec.name
        res = ("-" if rsrcs[k] is None
               else net.layers[rsrcs[k]].spec.name)
        print(f"  {layer.spec.name:<6}{src:>5}{res:>7}"
              f"{layer.n_chunks:>7}{layer.program.gemm_loops():>12}"
              f"{adds:>10}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=1,
                    help="requests per batched VTA execution; 1 = serve "
                         "per-image (default: 1)")
    ap.add_argument("--backend", choices=("fast", "oracle"), default="fast",
                    help="backend for the per-image serving loop")
    ap.add_argument("--skip-oracle", action="store_true",
                    help="skip the oracle cross-check (CI smoke mode)")
    args = ap.parse_args()
    if args.batch > 1 and args.backend != "fast":
        ap.error("--batch > 1 runs the batched engine; "
                 "--backend oracle is per-image only (use --batch 1)")

    print("calibrating weight scales + requant shifts, compiling the "
          "resnet_tiny DAG...")
    t0 = time.perf_counter()
    net, graph = compile_resnet_tiny()
    print(f"  compiled in {time.perf_counter() - t0:.3f}s; "
          f"{len(net.layers)} VTA layers, "
          f"total GeMM loops = {net.gemm_loops()}")
    schedule_stats(net)
    res_layers = [l for l in net.layers if l.spec.residual_add]
    assert len(res_layers) == 2, "expected two residual joins"
    assert max(l.n_chunks for l in res_layers) > 1, \
        "expected a multi-chunk residual layer"
    for l in res_layers:
        print(f"  join @{l.spec.name}: on-VTA ADD, skip pre-shift "
              f"{l.spec.residual_pre_shift}, post-add requant "
              f"{l.residual_shift}")

    print("verifying the network (fast backend)...")
    out_fast, _ = net.verify(backend="fast")
    if not args.skip_oracle:
        print("verifying the network (oracle backend)...")
        out_oracle, _ = net.verify(backend="oracle")
        np.testing.assert_array_equal(out_oracle, out_fast)
        print("  oracle and fast backends agree bit-for-bit")

    images = [synthetic_image(100 + r) for r in range(args.requests)]
    serve_s = 0.0
    logits_all = []
    if args.batch > 1:
        mode = f"batched (batch {args.batch})"
        for lo in range(0, len(images), args.batch):
            t0 = time.perf_counter()
            outs, _ = net.serve(images[lo:lo + args.batch])
            serve_s += time.perf_counter() - t0
            logits_all.extend(outs)
    else:
        mode = f"per-image ({args.backend})"
        for img in images:
            t0 = time.perf_counter()
            logits_all.append(net.serve_one(img, backend=args.backend))
            serve_s += time.perf_counter() - t0
    for r, (img, logits) in enumerate(zip(images, logits_all)):
        ref = reference_forward_int8(graph, img)
        assert np.array_equal(logits, ref), f"request {r}: mismatch!"
    if args.requests:
        print(f"\nserved {args.requests} requests in {serve_s:.2f}s "
              f"({args.requests / serve_s:.1f} img/s, {mode}); "
              f"bit-exact vs graph integer reference: "
              f"{args.requests}/{args.requests}")


if __name__ == "__main__":
    main()
