"""Async serving demo: a seeded request stream through the VTA serving
engine (DESIGN.md §Serving).

  1. compile LeNet-5 through the VTA pipeline (compile-once);
  2. start the async engine — bounded request queue, max-batch/max-wait
     dynamic batch former, a worker pool draining formed batches on the
     batched (and optionally pallas) backend;
  3. replay a seeded Poisson arrival trace against it in real time;
  4. assert the serving contracts: every result bit-identical to a
     direct ``NetworkProgram.serve`` of the same image, and zero SLO
     accounting errors (``metrics.audit()`` empty);
  5. print the latency/throughput summary (p50/p95/p99, occupancy,
     SLO violations).

    PYTHONPATH=src python examples/serve_vta.py [--requests 16]
        [--rate 200] [--max-batch 4] [--max-wait 0.005]
        [--backends batched,batched] [--slo 0.5] [--guard]

Used by CI as the serving smoke: it exits non-zero on any contract
violation.  The hermetic latency-curve campaign lives in
``benchmarks/serving_latency_tables.py`` (EXPERIMENTS.md
§Serving-latency).
"""

import argparse
import sys

import numpy as np

from repro.core.network_compiler import compile_network
from repro.models.lenet import (lenet5_random_weights, lenet5_specs,
                                synthetic_digit)
from repro.serving.vta import (BatchPolicy, QueueFull, VTAServingEngine,
                               WallClock, poisson_arrival_times,
                               request_images)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="offered load in requests/second (Poisson)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-wait", type=float, default=0.005)
    ap.add_argument("--backends", default="batched,batched",
                    help="comma-separated worker backends "
                         "(batched|pallas), one worker per entry")
    ap.add_argument("--slo", type=float, default=0.5,
                    help="per-request latency SLO in seconds")
    ap.add_argument("--guard", action="store_true",
                    help="serve through the PR 6 integrity guards "
                         "(batched workers only)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("compiling LeNet-5 through the VTA pipeline...")
    net = compile_network(lenet5_specs(lenet5_random_weights(0)),
                          synthetic_digit(0))
    print(f"  plan shapes: {[s['inp_nbytes'] for s in net.plan_shapes()]} "
          f"INP bytes/layer; padded batch ladder = "
          f"{net.padded_batch_sizes(args.max_batch)}")

    guard = None
    if args.guard:
        from repro.harden import GuardPolicy
        guard = GuardPolicy()

    backends = tuple(args.backends.split(","))
    policy = BatchPolicy(max_batch=args.max_batch,
                         max_wait_s=args.max_wait,
                         max_depth=max(64, 4 * args.requests))
    engine = VTAServingEngine(net, policy=policy, backends=backends,
                              guard=guard, slo_s=args.slo)

    images = request_images(net, args.requests, seed=args.seed + 1)
    arrivals = poisson_arrival_times(args.rate, args.requests,
                                     seed=args.seed)
    clock = WallClock()
    tickets = []
    with engine:                       # start; drain + shutdown on exit
        t0 = clock.now()
        for img, t_rel in zip(images, arrivals):
            clock.sleep_until(t0 + t_rel)     # replay the seeded trace
            try:
                tickets.append(engine.submit(img))
            except QueueFull as exc:
                print(f"  backpressure: {exc}", file=sys.stderr)
                raise
        outs = [t.result(timeout=120.0) for t in tickets]

    # contract 1: bit-identity vs the direct compile-once serve path
    direct, _ = net.serve(images)
    mismatches = sum(1 for got, want in zip(outs, direct)
                     if not np.array_equal(got, want))
    # contract 2: zero SLO accounting errors after drain
    audit = engine.metrics.audit()
    summary = engine.metrics.summary()

    print(f"\nserved {summary['completed']:.0f}/{args.requests} requests "
          f"on {backends} (guarded={bool(guard)})")
    print(f"  p50/p95/p99 latency = {summary['p50_ms']:.2f}/"
          f"{summary['p95_ms']:.2f}/{summary['p99_ms']:.2f} ms; "
          f"throughput = {summary['throughput_rps']:.1f} rps")
    print(f"  mean batch occupancy = {summary['mean_batch_occupancy']:.2f}"
          f" (padded {summary['mean_padded_size']:.2f}); "
          f"SLO({args.slo * 1e3:.0f}ms) violations = "
          f"{summary['slo_violations']:.0f}")
    print(f"  bit-identical to direct serve: "
          f"{args.requests - mismatches}/{args.requests}")
    print(f"  accounting audit: "
          f"{'clean' if not audit else audit}")
    if args.guard:
        outcomes = [t.guard_report.outcome for t in tickets]
        print(f"  guard outcomes: "
              f"{ {o: outcomes.count(o) for o in set(outcomes)} }")

    if mismatches or audit or summary["completed"] != args.requests:
        print("SERVING CONTRACT VIOLATION", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
