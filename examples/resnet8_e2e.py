"""resnet8 end-to-end: ResNet-scale CNNs through the strided lowering.

The first workload with real stage transitions (DESIGN.md
§Strided-lowering): a 3-stage CIFAR-10-scale ResNet-8 whose
downsampling runs as stride-2 convolutions (k3/s2/p1 main path +
k2/s2 projection shortcut per transition, joins on the VTA) and whose
classification head is a global-average-pool tree reduction fused with
a 1×1 mixing conv — ADD-pair rounds + one SHR, all on the TensorAlu.

  1. calibrate weight scales + static requant shifts (two-phase §4.2);
  2. compile the DAG into 11 VTA layer programs sharing one DRAM
     allocation; print the per-layer schedule — input/residual sources,
     strides, chunk counts, ALU ADD instructions;
  3. verify the network bit-exactly on the fast backend — and, unless
     ``--skip-oracle``, on the oracle too;
  4. serve a batch of requests (batched runtime for ``--batch > 1``)
     against the graph's integer reference.

    PYTHONPATH=src python examples/resnet8_e2e.py [--requests 8]
                                                  [--batch 8]
                                                  [--backend fast|oracle|pallas]
                                                  [--skip-oracle]

``--backend pallas`` runs every layer through the ``vta_gemm`` MXU kernel
(``interpret=True`` off-TPU) — residual joins, strided chunks and the GAP
head all execute bit-identically to the simulators.
"""

import argparse
import time

import numpy as np

from repro.core import isa
from repro.models.resnet8 import (compile_resnet8, reference_forward_int8,
                                  synthetic_image)


def schedule_stats(net) -> None:
    srcs, rsrcs = net._sources(), net._res_sources()
    print("layer   in<-   res<-  stride  pool  chunks  gemm_loops  alu_adds")
    for k, layer in enumerate(net.layers):
        adds = sum(1 for i in layer.program.instructions
                   if isinstance(i, isa.AluInsn)
                   and i.alu_opcode == isa.AluOp.ADD and not i.use_imm)
        src = "img" if srcs[k] < 0 else net.layers[srcs[k]].spec.name
        res = ("-" if rsrcs[k] is None
               else net.layers[rsrcs[k]].spec.name)
        pool = layer.spec.pool or "-"
        print(f"  {layer.spec.name:<6}{src:>5}{res:>8}"
              f"{layer.spec.stride:>7}{pool:>7}"
              f"{layer.n_chunks:>7}{layer.program.gemm_loops():>12}"
              f"{adds:>9}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=1,
                    help="requests per batched VTA execution; 1 = serve "
                         "per-image (default: 1)")
    ap.add_argument("--backend", choices=("fast", "oracle", "pallas"),
                    default="fast",
                    help="backend for the per-image serving loop")
    ap.add_argument("--skip-oracle", action="store_true",
                    help="skip the oracle cross-check (CI smoke mode)")
    args = ap.parse_args()
    if args.batch > 1 and args.backend == "oracle":
        ap.error("--batch > 1 runs the batched engine; "
                 "--backend oracle is per-image only (use --batch 1)")

    print("calibrating weight scales + requant shifts, compiling the "
          "resnet8 DAG...")
    t0 = time.perf_counter()
    net, graph = compile_resnet8()
    print(f"  compiled in {time.perf_counter() - t0:.3f}s; "
          f"{len(net.layers)} VTA layers, "
          f"total GeMM loops = {net.gemm_loops()}")
    schedule_stats(net)
    strided = [l for l in net.layers if l.spec.stride == 2]
    assert len(strided) == 4, "expected 4 stride-2 convs (2 per transition)"
    res_layers = [l for l in net.layers if l.spec.residual_add]
    assert len(res_layers) == 3, "expected three residual joins"
    gap_layers = [l for l in net.layers if l.spec.pool == "gap"]
    assert len(gap_layers) == 1, "expected a fused GAP head"
    print(f"  GAP head @{gap_layers[0].spec.name}: "
          f"{len(gap_layers[0].keep_rows)} surviving row, tree reduction "
          f"on-device")

    print("verifying the network (fast backend)...")
    out_fast, _ = net.verify(backend="fast")
    if not args.skip_oracle:
        print("verifying the network (oracle backend)...")
        out_oracle, _ = net.verify(backend="oracle")
        np.testing.assert_array_equal(out_oracle, out_fast)
        print("  oracle and fast backends agree bit-for-bit")

    images = [synthetic_image(100 + r) for r in range(args.requests)]
    serve_s = 0.0
    logits_all = []
    if args.batch > 1:
        batch_backend = "pallas" if args.backend == "pallas" else "batched"
        mode = f"batched (batch {args.batch}, {batch_backend})"
        for lo in range(0, len(images), args.batch):
            t0 = time.perf_counter()
            outs, _ = net.serve(images[lo:lo + args.batch],
                                backend=batch_backend)
            serve_s += time.perf_counter() - t0
            logits_all.extend(outs)
    else:
        mode = f"per-image ({args.backend})"
        for img in images:
            t0 = time.perf_counter()
            logits_all.append(net.serve_one(img, backend=args.backend))
            serve_s += time.perf_counter() - t0
    for r, (img, logits) in enumerate(zip(images, logits_all)):
        ref = reference_forward_int8(graph, img)
        assert np.array_equal(logits, ref), f"request {r}: mismatch!"
    if args.requests:
        print(f"\nserved {args.requests} requests in {serve_s:.2f}s "
              f"({args.requests / serve_s:.1f} img/s, {mode}); "
              f"bit-exact vs graph integer reference: "
              f"{args.requests}/{args.requests}")


if __name__ == "__main__":
    main()
