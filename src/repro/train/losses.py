"""Losses.  Chunked cross-entropy: the (B, S, vocab) logits tensor is never
materialised — the sequence axis is scanned in chunks and the vocab axis
stays TP-sharded, so peak live memory is (B, chunk, vocab/tp)."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import unembed_logits
from repro.models.layers import constrain


def chunked_softmax_xent(params, cfg: ModelConfig, h: jax.Array,
                         labels: jax.Array, *, chunk: int = 512,
                         mask: Optional[jax.Array] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """h (B, S, d), labels (B, S) → (mean nll, mean accuracy)."""
    b, s, _ = h.shape
    chunk = min(chunk, s)
    while s % chunk:            # largest divisor of s ≤ chunk (VLM: 3840)
        chunk -= 1
    nc = s // chunk
    hc = h.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = (jnp.ones((nc, b, chunk), bool) if mask is None
          else mask.reshape(b, nc, chunk).transpose(1, 0, 2))

    def body(carry, inp):
        nll_sum, correct, count = carry
        hh, ll, mm = inp
        logits = unembed_logits(params, cfg, hh).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (lse - tgt) * mm
        pred = logits.argmax(-1)
        return (nll_sum + nll.sum(),
                correct + ((pred == ll) & mm).sum(),
                count + mm.sum()), None

    (nll_sum, correct, count), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.int32(0), jnp.int32(0)), (hc, lc, mc))
    count = jnp.maximum(count, 1)
    return nll_sum / count, correct / count
