"""Distributed-optimization tricks beyond plain GSPMD.

``compressed_pod_allreduce`` — int8-compressed gradient all-reduce over the
``pod`` axis (the slow inter-pod DCI links).  The mesh's in-pod axes keep
their full-precision GSPMD reduce-scatter; only the pure-DP pod replica sum
is compressed:

  1. shared scale: pmax of the per-pod absmax (one f32 scalar per tensor);
  2. quantise to ±63 (so an int8 wire sum of ≤2 pods cannot wrap; for
     ``n_pods`` pods the clip is ±127/n_pods);
  3. psum the int8 payload — 4× less inter-pod traffic than f32;
  4. dequantise with the shared scale.

Because GSPMD would otherwise reduce over ``pod`` implicitly, callers must
arrange per-pod partial gradients — ``train_step`` does this by declaring
the batch sharded over pod while the compression runs inside shard_map with
the pod axis manual and every other axis auto.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _compress_body(n_pods: int, g: jax.Array) -> jax.Array:
    limit = max(1, 127 // n_pods)
    scale = jax.lax.pmax(jnp.max(jnp.abs(g.astype(jnp.float32))), "pod")
    scale = jnp.maximum(scale, 1e-12) / limit
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale),
                 -limit, limit).astype(jnp.int8)
    s = jax.lax.psum(q, "pod")
    return (s.astype(jnp.float32) * scale / n_pods).astype(g.dtype)


def compressed_pod_allreduce(grads: Any) -> Any:
    """Mean-reduce gradients over the pod axis with int8 wire format.

    No-op when the mesh has no pod axis.  Inputs are per-pod partials
    (pod-sharded batch ⇒ vma-unreduced grads); output is the pod mean.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or "pod" not in mesh.axis_names:
        return grads
    n_pods = mesh.shape["pod"]
    auto = frozenset(n for n in mesh.axis_names if n != "pod")

    fn = jax.shard_map(
        lambda g: jax.tree.map(
            functools.partial(_compress_body, n_pods), g),
        mesh=mesh, in_specs=P("pod"), out_specs=P(),
        check_vma=False, axis_names={"pod"})
    return fn(grads)
