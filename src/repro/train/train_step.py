"""Training step factory: microbatched gradient accumulation + AdamW.

The global batch is split into ``microbatches`` along the batch axis and
scanned; gradients accumulate in ``grad_accum_dtype`` (f32 by default,
bf16 for the ≥300B configs where the f32 accumulator wouldn't fit).
Collectives amortise: GSPMD reduce-scatters the accumulated gradient once
per step, not per microbatch.  The optional int8-compressed inter-pod
gradient all-reduce lives in train/distributed.py.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import encode, forward
from repro.optim import adamw
from .losses import chunked_softmax_xent


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    loss_chunk: int = 512
    moe_aux_weight: float = 1e-2
    grad_accum_dtype: Any = jnp.float32
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)
    grad_compression: Optional[str] = None    # None | "int8_pod"


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            train_cfg: TrainConfig) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, cfg, batch["frames"])
    prefix = batch.get("prefix_embed")
    h, aux = forward(params, cfg, batch["tokens"], enc_out=enc_out,
                     prefix_embed=prefix)
    if prefix is not None:
        h = h[:, prefix.shape[1]:]        # loss over token positions only
    nll, acc = chunked_softmax_xent(params, cfg, h, batch["labels"],
                                    chunk=train_cfg.loss_chunk)
    loss = nll + train_cfg.moe_aux_weight * aux
    return loss, {"nll": nll, "accuracy": acc, "moe_aux": aux}


def make_train_step(cfg: ModelConfig, train_cfg: TrainConfig):
    """Returns ``train_step(params, opt_state, batch) → (params, opt_state,
    metrics)`` — jit it with the param/batch shardings (launch/train.py)."""

    if cfg.causal_skip:
        # the fori_loop chunk-skip has dynamic trip counts — not reverse-
        # differentiable; training always uses the masked scan
        cfg = dataclasses.replace(cfg, causal_skip=False)

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, train_cfg), has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        m = train_cfg.microbatches

        def reshape(x):
            return x.reshape((m, x.shape[0] // m) + x.shape[1:])

        micro = jax.tree.map(reshape, batch)
        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, train_cfg.grad_accum_dtype), params)

        def body(carry, mb):
            g_acc, loss_acc, met_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(train_cfg.grad_accum_dtype),
                g_acc, grads)
            met_acc = jax.tree.map(lambda a, x: a + x, met_acc, metrics)
            return (g_acc, loss_acc + loss, met_acc), None

        met0 = {"nll": jnp.float32(0), "accuracy": jnp.float32(0),
                "moe_aux": jnp.float32(0)}
        (g_acc, loss, metrics), _ = jax.lax.scan(
            body, (g0, jnp.float32(0), met0), micro)
        inv = 1.0 / m
        return loss * inv, jax.tree.map(lambda x: x * inv, metrics), \
            jax.tree.map(lambda g: g * inv, g_acc)

    def train_step(params, opt_state, batch):
        if train_cfg.microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        if train_cfg.grad_compression == "int8_pod":
            from .distributed import compressed_pod_allreduce
            grads = compressed_pod_allreduce(grads)
        params, opt_state, opt_metrics = adamw.apply_updates(
            train_cfg.opt, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
