"""Post-training quantization: float checkpoint → VTA-ready int8 model
(DESIGN.md §Quantization).

The PTQ scheme is the paper's §4.2 discipline generalised to *trained
float* weights:

* **Weight scales** — per linear layer, the largest power-of-2 exponent
  ``e_w`` with ``round(max|W| · 2^e_w) <= 127``: the int8 weight tensor
  represents ``W_float · 2^e_w``, using as much of the int8 range as a
  power-of-2 scale can.
* **Bias at accumulator scale** — biases add to the int32 accumulator,
  which sits at ``2^(e_in + e_w)`` above the real-valued feature, so
  ``b_int32 = round(b_float · 2^(e_in + e_w))``.
* **Activation-range scan** — requant shifts are chosen over a
  calibration batch under the *device's* truncate/saturate semantics:
  the chain path drives :func:`repro.core.network_compiler.
  calibrate_network` layer by layer (interleaved with the exponent
  bookkeeping above), the graph path rides
  :func:`repro.graph.plan_requant`'s ``on_linear`` hook so weights are
  quantised in place at exactly the moment the planner knows their
  input's scale (the planner *raises* on any int8 overfeed rather than
  wrapping, so the graph path is drift-free by construction).

:func:`quantize_network` is the single model-agnostic entry point: it
accepts either a flat :class:`FloatLayer` chain (LeNet-5 shape) or a
float-weighted :class:`~repro.graph.Graph` (resnet8 shape) and returns a
:class:`QuantizedModel` ready to ``compile()`` into a
:class:`~repro.core.network_compiler.NetworkProgram`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.errors import CompileError
from repro.core.layer_compiler import LayerSpec
from repro.core.network_compiler import (NetworkProgram, calibrate_network,
                                         compile_network)
from repro.graph import Graph, compile_graph, plan_requant

# Float images live in [0, 1]; the device input is int8, so the front
# door maps pixel p → round(p · 2^7) clipped to int8 — input scale 2^7.
INPUT_EXP = 7

# Weight-scale search bound (|exponent|): 2^12 resolves weights down to
# ~2.4e-4 of the int8 range, far below PTQ noise for these nets.
WEIGHT_EXP_MAX = 12


@dataclasses.dataclass(frozen=True)
class FloatLayer:
    """One float layer of a sequential chain — the PTQ-side mirror of
    :class:`~repro.core.layer_compiler.LayerSpec` (same fields, float
    ``weights``/``bias``, no shift: PTQ chooses ``requant_shift``)."""

    name: str
    kind: str                      # "conv" | "fc"
    weights: np.ndarray            # float (F, C, kh, kw) | (D, F)
    bias: Optional[np.ndarray] = None
    stride: int = 1
    padding: int = 0
    relu: bool = False
    pool: Optional[str] = None


def choose_weight_exp(weights: np.ndarray, *,
                      max_exp: int = WEIGHT_EXP_MAX) -> int:
    """Largest exponent ``e`` with ``round(max|W| · 2^e) <= 127``."""
    m = float(np.abs(np.asarray(weights, np.float64)).max(initial=0.0))
    if m == 0.0:
        return max_exp
    e = 0
    while e < max_exp and round(m * 2.0 ** (e + 1)) <= 127:
        e += 1
    while round(m * 2.0 ** e) > 127 and e > -max_exp:
        e -= 1
    return e


def quantize_weights(weights: np.ndarray, exp: int) -> np.ndarray:
    """``round(W · 2^exp)`` as int8 (clipped to ±127, symmetric)."""
    q = np.round(np.asarray(weights, np.float64) * 2.0 ** exp)
    return np.clip(q, -127, 127).astype(np.int8)


def quantize_bias(bias: np.ndarray, exp: int) -> np.ndarray:
    """``round(b · 2^exp)`` as int32 — ``exp`` is the accumulator scale
    ``e_in + e_w`` of the layer the bias adds into."""
    q = np.round(np.asarray(bias, np.float64) * 2.0 ** exp)
    lim = np.iinfo(np.int32).max
    return np.clip(q, -lim - 1, lim).astype(np.int32)


def quantize_images(images: np.ndarray, *,
                    input_exp: int = INPUT_EXP) -> np.ndarray:
    """Float [0, 1] images → device int8 at scale ``2^input_exp``."""
    q = np.round(np.asarray(images, np.float64) * 2.0 ** input_exp)
    return np.clip(q, -128, 127).astype(np.int8)


@dataclasses.dataclass
class QuantizedModel:
    """What PTQ decided, plus everything needed to compile and serve.

    ``weight_exps``/``shifts`` are observability (the invariant tests
    assert against them); ``calib_int`` is the quantised calibration set
    — its first image doubles as the compile-time reference input.
    """

    kind: str                           # "chain" | "graph"
    input_exp: int
    weight_exps: Dict[str, int]
    shifts: Dict[str, int]
    calib_int: List[np.ndarray]
    specs: Optional[List[LayerSpec]] = None
    graph: Optional[Graph] = None
    margin: int = 1

    def compile(self, *, cfg=None, dram_offset: int = 0,
                schedule: str = "serialized") -> NetworkProgram:
        if self.kind == "chain":
            return compile_network(self.specs, self.calib_int[0], cfg=cfg,
                                   dram_offset=dram_offset,
                                   schedule=schedule)
        return compile_graph(self.graph, self.calib_int[0],
                             calib=self.calib_int, margin=self.margin,
                             cfg=cfg, dram_offset=dram_offset,
                             schedule=schedule)

    def quantize_images(self, images: np.ndarray) -> np.ndarray:
        return quantize_images(images, input_exp=self.input_exp)


def quantize_network(model: Union[Sequence[FloatLayer], Graph],
                     calib_images: np.ndarray, *, margin: int = 1,
                     saturate: bool = False,
                     input_exp: int = INPUT_EXP) -> QuantizedModel:
    """PTQ front door: float model + float calibration images → int8
    :class:`QuantizedModel`.

    ``model`` is either a sequence of :class:`FloatLayer` (sequential
    chain) or a float-weighted :class:`~repro.graph.Graph` with
    unplanned requants.  ``calib_images`` is a float ``(N, C, H, W)``
    batch in [0, 1] (N >= 1).  ``saturate`` selects the device requant
    mode the chain calibration advances under (must match how the
    compiled network will be executed).
    """
    calib = np.asarray(calib_images, np.float64)
    if calib.ndim != 4 or calib.shape[0] < 1:
        raise CompileError(
            f"calibration images must be a (N, C, H, W) float batch, "
            f"got shape {calib.shape}", constraint="calibration")
    calib_int = [quantize_images(img[None], input_exp=input_exp)
                 for img in calib]
    if isinstance(model, Graph):
        return _quantize_graph(model, calib_int, margin=margin,
                               input_exp=input_exp)
    return _quantize_chain(list(model), calib_int, margin=margin,
                           saturate=saturate, input_exp=input_exp)


def _quantize_chain(layers: List[FloatLayer],
                    calib_int: List[np.ndarray], *, margin: int,
                    saturate: bool, input_exp: int) -> QuantizedModel:
    """Sequential PTQ: weight-exp choice, bias at accumulator scale, and
    the §4.2 activation scan interleave layer by layer, because layer
    k+1's accumulator scale ``e_in + e_w`` depends on shift k."""
    e_act = input_exp
    cur = calib_int
    specs: List[LayerSpec] = []
    weight_exps: Dict[str, int] = {}
    shifts: Dict[str, int] = {}
    for fl in layers:
        if fl.kind not in ("conv", "fc"):
            raise CompileError(f"FloatLayer kind must be conv|fc, got "
                               f"{fl.kind!r}", layer=fl.name,
                               constraint="node-kind")
        e_w = choose_weight_exp(fl.weights)
        w_int = quantize_weights(fl.weights, e_w)
        b_int = (quantize_bias(fl.bias, e_act + e_w)
                 if fl.bias is not None else None)
        spec = LayerSpec(fl.name, fl.kind, w_int, b_int, stride=fl.stride,
                         padding=fl.padding, relu=fl.relu, pool=fl.pool)
        # one step of the shared device-semantics scan (shift + advance)
        (shift,), traces = calibrate_network([spec], cur, margin=margin,
                                             saturate=saturate)
        specs.append(dataclasses.replace(spec, requant_shift=shift))
        cur = traces[0]
        weight_exps[fl.name] = e_w
        shifts[fl.name] = shift
        # pool divisions cancel against their exponent gain, so the
        # activation scale steps by e_w - shift regardless of pooling
        e_act = e_act + e_w - shift
    return QuantizedModel("chain", input_exp, weight_exps, shifts,
                          list(calib_int), specs=specs, margin=margin)


def _quantize_graph(graph: Graph, calib_int: List[np.ndarray], *,
                    margin: int, input_exp: int) -> QuantizedModel:
    """Graph PTQ: ride the requant planner's topo walk — the
    ``on_linear`` hook quantises each conv/fc node in place the moment
    the planner knows its input's scale exponent (mutates ``graph``,
    exactly as :func:`plan_requant` already mutates shifts)."""
    weight_exps: Dict[str, int] = {}

    def on_linear(node, rel_exp: int) -> None:
        if not np.issubdtype(np.asarray(node.weights).dtype, np.floating):
            raise CompileError(
                f"graph PTQ expects float weights, node {node.name!r} "
                f"has dtype {node.weights.dtype}", layer=node.name,
                constraint="ptq-float-weights")
        e_w = choose_weight_exp(node.weights)
        if node.bias is not None:
            # planner exponents are relative to the graph input; the
            # absolute accumulator scale adds the input's own 2^input_exp
            node.bias = quantize_bias(node.bias, input_exp + rel_exp + e_w)
        node.weights = quantize_weights(node.weights, e_w)
        node.weight_exp = e_w
        weight_exps[node.name] = e_w

    plan = plan_requant(graph, calib_int, margin=margin,
                        on_linear=on_linear)
    return QuantizedModel("graph", input_exp, weight_exps,
                          dict(plan.shifts), list(calib_int), graph=graph,
                          margin=margin)


def calibrate_integer_weight_exps(build_probe, calib: Sequence[np.ndarray],
                                  linear_nodes: Sequence[str], *,
                                  margin: int = 1,
                                  octave_keep: Sequence[str] = ()
                                  ) -> Dict[str, int]:
    """Two-phase §4.2 weight-scale calibration for *integer-weight*
    graph models — the model-agnostic generalisation of the two
    model-private ``calibrate_weight_exps`` copies that used to live in
    ``models/resnet_tiny.py`` and ``models/resnet8.py``.

    Random int8 weights amplify (a k3 conv over 16 channels gains ~2^5),
    so with ``weight_exp = 0`` the raw-integer skip of a residual block
    sits many octaves above its branch.  Real quantised CNNs absorb that
    gain into the *weight scale*: each linear node's ``weight_exp`` is
    set to its planned requant shift over a throwaway probe graph
    (``build_probe()`` → unplanned graph with ``weight_exp = 0``), which
    normalises every post-requant activation to scale ≈ 0 — the
    trained-network situation.  Nodes in ``octave_keep`` then keep one
    octave of gain (``- 1``) so their join operands land scales apart
    and the planner must equalise with a genuine on-device pre-shift.
    """
    probe = build_probe()
    plan = plan_requant(probe, list(calib), margin=margin)
    exps = {name: plan.shifts[f"{name}_q"] for name in linear_nodes}
    for name in octave_keep:
        exps[name] -= 1
    return exps
