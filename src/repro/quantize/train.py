"""Hermetic float front door: seeded JAX training + checkpoint import
(DESIGN.md §Quantization).

Two float reference models mirror the repo's two int8 topologies exactly
— LeNet-5 (the flat :func:`repro.models.lenet.lenet5_specs` chain) and
resnet8 (the :func:`repro.models.resnet8.build_resnet8` graph) — trained
on the procedural digit dataset (:mod:`repro.quantize.digits`) with a
hand-rolled Adam (the container has no optax; the paper's reference
models were PyTorch, recorded in DESIGN.md).  Everything is seeded and
CPU-scale, so the float checkpoints are reproducible bit streams, and
``save_checkpoint``/``load_checkpoint`` round-trip them as plain ``.npz``
parameter dicts — the import path real MNIST/ONNX-exported weights drop
into later.

Params are flat ``{name: float32 array}`` dicts whose keys equal the
weight-field names of :class:`~repro.models.lenet.LeNetWeights` /
:class:`~repro.models.resnet8.Resnet8Weights`, so the PTQ mapping is a
field-for-field walk with no renaming layer.
"""

from __future__ import annotations

import pathlib
from typing import Dict, Optional

import numpy as np

NETS = ("lenet5", "resnet8")
NET_CHANNELS = {"lenet5": 1, "resnet8": 3}

# (name, kind, shape) per net — shapes match the int8 models exactly.
_LENET_SHAPES = (
    ("conv1_w", (6, 1, 5, 5)), ("conv1_b", (6,)),
    ("conv2_w", (16, 6, 5, 5)), ("conv2_b", (16,)),
    ("conv3_w", (120, 16, 5, 5)), ("conv3_b", (120,)),
    ("fc4_w", (120, 84)), ("fc4_b", (84,)),
    ("fc5_w", (84, 10)), ("fc5_b", (10,)),
)
_RESNET8_SHAPES = (
    ("stem_w", (16, 3, 3, 3)), ("stem_b", (16,)),
    ("b1a_w", (16, 16, 3, 3)), ("b1a_b", (16,)),
    ("b1b_w", (16, 16, 3, 3)), ("b1b_b", (16,)),
    ("t2a_w", (32, 16, 3, 3)), ("t2a_b", (32,)),
    ("t2p_w", (32, 16, 2, 2)), ("t2p_b", (32,)),
    ("t2b_w", (32, 32, 3, 3)), ("t2b_b", (32,)),
    ("t3a_w", (64, 32, 3, 3)), ("t3a_b", (64,)),
    ("t3p_w", (64, 32, 2, 2)), ("t3p_b", (64,)),
    ("t3b_w", (64, 64, 3, 3)), ("t3b_b", (64,)),
    ("head_w", (64, 64, 1, 1)), ("head_b", (64,)),
    ("fc_w", (64, 10)), ("fc_b", (10,)),
)
_NET_SHAPES = {"lenet5": _LENET_SHAPES, "resnet8": _RESNET8_SHAPES}


def _check_net(net: str) -> None:
    if net not in NETS:
        raise ValueError(f"net must be one of {NETS}, got {net!r}")


def init_params(net: str, seed: int = 0) -> Dict[str, np.ndarray]:
    """He-initialised float32 parameters (numpy, deterministic)."""
    _check_net(net)
    rng = np.random.default_rng(seed)
    params: Dict[str, np.ndarray] = {}
    for name, shape in _NET_SHAPES[net]:
        if name.endswith("_b"):
            params[name] = np.zeros(shape, dtype=np.float32)
        else:
            fan_in = int(np.prod(shape[1:])) if len(shape) == 4 \
                else shape[0]
            std = np.sqrt(2.0 / fan_in)
            params[name] = rng.normal(0.0, std, shape).astype(np.float32)
    return params


# ---------------------------------------------------------------------------
# Float forwards (batched; mirror the int8 topologies node for node)
# ---------------------------------------------------------------------------

def _jx():
    import jax
    import jax.numpy as jnp
    from jax import lax
    return jax, jnp, lax


def lenet5_apply(params, x):
    """Float logits ``(B, 10)`` for ``(B, 1, 32, 32)`` images — the
    float twin of :func:`repro.models.lenet.lenet5_specs`."""
    _, jnp, lax = _jx()
    x = jnp.asarray(x, jnp.float32)

    def conv(x, w, b, pool):
        y = lax.conv_general_dilated(
            x, jnp.asarray(w), (1, 1), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        y = jnp.maximum(y + jnp.asarray(b)[None, :, None, None], 0)
        if pool:
            y = (y[:, :, 0::2, 0::2] + y[:, :, 0::2, 1::2]
                 + y[:, :, 1::2, 0::2] + y[:, :, 1::2, 1::2]) / 4.0
        return y

    x = conv(x, params["conv1_w"], params["conv1_b"], True)
    x = conv(x, params["conv2_w"], params["conv2_b"], True)
    x = conv(x, params["conv3_w"], params["conv3_b"], False)
    v = x.reshape(x.shape[0], -1)
    v = jnp.maximum(v @ params["fc4_w"] + params["fc4_b"], 0)
    return v @ params["fc5_w"] + params["fc5_b"]


def resnet8_apply(params, x):
    """Float logits ``(B, 10)`` for ``(B, 3, 32, 32)`` images — the
    float twin of :func:`repro.models.resnet8.build_resnet8` (same
    joins, stride-2 transitions, k2/s2 projections, GAP head)."""
    _, jnp, lax = _jx()
    x = jnp.asarray(x, jnp.float32)

    def conv(name, x, stride=1, padding=0, relu=True):
        y = lax.conv_general_dilated(
            x, jnp.asarray(params[f"{name}_w"]), (stride, stride),
            [(padding, padding), (padding, padding)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        y = y + jnp.asarray(params[f"{name}_b"])[None, :, None, None]
        return jnp.maximum(y, 0) if relu else y

    v = conv("stem", x, padding=1)
    a = conv("b1a", v, padding=1)
    b = conv("b1b", a, padding=1, relu=False)
    v = jnp.maximum(b + v, 0)
    a = conv("t2a", v, stride=2, padding=1)
    p = conv("t2p", v, stride=2, relu=False)
    b = conv("t2b", a, padding=1, relu=False)
    v = jnp.maximum(b + p, 0)
    a = conv("t3a", v, stride=2, padding=1)
    p = conv("t3p", v, stride=2, relu=False)
    b = conv("t3b", a, padding=1, relu=False)
    v = jnp.maximum(b + p, 0)
    h = conv("head", v)
    g = h.mean(axis=(2, 3))
    return g @ params["fc_w"] + params["fc_b"]


APPLY_FNS = {"lenet5": lenet5_apply, "resnet8": resnet8_apply}


# ---------------------------------------------------------------------------
# Training (hand-rolled Adam; no optax in the container)
# ---------------------------------------------------------------------------

def train_float(net: str, images: np.ndarray, labels: np.ndarray, *,
                epochs: int = 6, batch: int = 64, lr: float = 1e-3,
                seed: int = 0) -> Dict[str, np.ndarray]:
    """Train the float model with seeded shuffling + Adam; returns the
    trained parameter dict (numpy float32)."""
    jax, jnp, _ = _jx()
    _check_net(net)
    apply_fn = APPLY_FNS[net]
    params = {k: jnp.asarray(v) for k, v in init_params(net, seed).items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in params.items()}
    b1, b2, eps = 0.9, 0.999, 1e-8

    def loss_fn(p, x, y):
        logits = apply_fn(p, x)
        logz = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logz, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, m, v, t, x, y):
        grads = jax.grad(loss_fn)(p, x, y)
        m = {k: b1 * m[k] + (1 - b1) * grads[k] for k in p}
        v = {k: b2 * v[k] + (1 - b2) * grads[k] ** 2 for k in p}
        mc = 1.0 - b1 ** t
        vc = 1.0 - b2 ** t
        p = {k: p[k] - lr * (m[k] / mc) / (jnp.sqrt(v[k] / vc) + eps)
             for k in p}
        return p, m, v

    images = np.asarray(images, np.float32)
    labels = np.asarray(labels, np.int32)
    rng = np.random.default_rng(seed + 1)
    t = 0
    for _ in range(epochs):
        order = rng.permutation(len(images))
        for lo in range(0, len(images) - batch + 1, batch):
            idx = order[lo:lo + batch]
            t += 1
            params, m, v = step(params, m, v, float(t),
                                jnp.asarray(images[idx]),
                                jnp.asarray(labels[idx]))
    return {k: np.asarray(p, np.float32) for k, p in params.items()}


def float_top1(net: str, params: Dict[str, np.ndarray],
               images: np.ndarray, labels: np.ndarray, *,
               batch: int = 256) -> float:
    """Float top-1 accuracy (batched forward, no training state)."""
    apply_fn = APPLY_FNS[net]
    correct = 0
    for lo in range(0, len(images), batch):
        logits = np.asarray(apply_fn(params, images[lo:lo + batch]))
        correct += int((logits.argmax(axis=1)
                        == labels[lo:lo + batch]).sum())
    return correct / len(images)


# ---------------------------------------------------------------------------
# Checkpoint import path (plain .npz — ONNX/MNIST exports drop in here)
# ---------------------------------------------------------------------------

def save_checkpoint(path, params: Dict[str, np.ndarray]) -> None:
    np.savez(path, **{k: np.asarray(v, np.float32)
                      for k, v in params.items()})


def load_checkpoint(path,
                    net: Optional[str] = None) -> Dict[str, np.ndarray]:
    """Load a ``.npz`` float checkpoint; with ``net`` given, validate
    the parameter names and shapes against the topology."""
    with np.load(path) as z:
        params = {k: np.asarray(z[k], np.float32) for k in z.files}
    if net is not None:
        _check_net(net)
        want = {name: shape for name, shape in _NET_SHAPES[net]}
        if set(params) != set(want):
            raise ValueError(
                f"checkpoint params {sorted(params)} != {net} topology "
                f"params {sorted(want)}")
        for name, shape in want.items():
            if params[name].shape != shape:
                raise ValueError(
                    f"checkpoint param {name!r} has shape "
                    f"{params[name].shape}, {net} expects {shape}")
    return params


def train_or_load(net: str, *, checkpoint=None, train_n: int = 4000,
                  epochs: int = 6, batch: int = 64, lr: float = 1e-3,
                  seed: int = 0) -> Dict[str, np.ndarray]:
    """The front door: load ``checkpoint`` if it exists, else train on
    the procedural digit dataset (and save to ``checkpoint`` when a path
    is given) — hermetic either way."""
    from .digits import digit_dataset
    _check_net(net)
    if checkpoint is not None and pathlib.Path(checkpoint).exists():
        return load_checkpoint(checkpoint, net)
    images, labels = digit_dataset(train_n, seed=seed, split="train",
                                   channels=NET_CHANNELS[net])
    params = train_float(net, images, labels, epochs=epochs, batch=batch,
                         lr=lr, seed=seed)
    if checkpoint is not None:
        save_checkpoint(checkpoint, params)
    return params
