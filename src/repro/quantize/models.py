"""Float-parameter → PTQ-model bridges for the repo's two topologies.

Parameter dicts (from :mod:`repro.quantize.train` or an imported ``.npz``
checkpoint) become the float inputs :func:`repro.quantize.ptq.
quantize_network` accepts: LeNet-5 as a flat :class:`FloatLayer` chain
mirroring :func:`repro.models.lenet.lenet5_specs`, resnet8 as a
float-weighted graph built by the *same*
:func:`repro.models.resnet8.build_resnet8` the int8 model uses (the IR
carries dtype-agnostic arrays; PTQ quantises the nodes in place).
"""

from __future__ import annotations

from typing import Dict, List, Union

import numpy as np

from repro.graph import Graph

from .ptq import FloatLayer

CHANNELS = {"lenet5": 1, "resnet8": 3}


def lenet5_float_layers(params: Dict[str, np.ndarray]) -> List[FloatLayer]:
    """The five float layers of §4.3, field-for-field against
    :func:`repro.models.lenet.lenet5_specs`."""
    return [
        FloatLayer("l1_conv", "conv", params["conv1_w"], params["conv1_b"],
                   relu=True, pool="avg2x2"),
        FloatLayer("l2_conv", "conv", params["conv2_w"], params["conv2_b"],
                   relu=True, pool="avg2x2"),
        FloatLayer("l3_conv", "conv", params["conv3_w"], params["conv3_b"],
                   relu=True),
        FloatLayer("l4_fc", "fc", params["fc4_w"], params["fc4_b"],
                   relu=True),
        FloatLayer("l5_fc", "fc", params["fc5_w"], params["fc5_b"]),
    ]


def resnet8_float_graph(params: Dict[str, np.ndarray]) -> Graph:
    """The resnet8 DAG carrying float weights (unplanned requants,
    ``weight_exp=0`` placeholders) — graph PTQ rewrites the linear nodes
    in place during planning."""
    from repro.models.resnet8 import Resnet8Weights, build_resnet8
    weights = Resnet8Weights(**{k: np.asarray(v, np.float32)
                                for k, v in params.items()})
    return build_resnet8(weights)


def float_model(net: str, params: Dict[str, np.ndarray]
                ) -> Union[List[FloatLayer], Graph]:
    """The :func:`quantize_network`-ready float model for ``net``."""
    if net == "lenet5":
        return lenet5_float_layers(params)
    if net == "resnet8":
        return resnet8_float_graph(params)
    raise ValueError(f"net must be lenet5|resnet8, got {net!r}")
