"""Post-training quantization + accuracy validation (DESIGN.md
§Quantization, EXPERIMENTS.md §Accuracy).

The subsystem that turns "compiles bit-identically" into "serves correct
answers at scale", in three stages:

* :mod:`repro.quantize.digits` — a deterministic procedurally-generated
  MNIST-like digit dataset (hermetic: every image is a pure function of
  ``(seed, split, index)``);
* :mod:`repro.quantize.train`  — the float front door: seeded JAX
  training of float LeNet-5/resnet8 twins + the ``.npz`` checkpoint
  import path;
* :mod:`repro.quantize.ptq` / :mod:`repro.quantize.evaluate` — the
  model-agnostic :func:`quantize_network` PTQ pipeline (weight-exp
  scales, biases at accumulator scale, the §4.2 activation scan under
  device requant semantics) and the dataset-scale serving harness.
"""

from .digits import digit_dataset, digit_image                  # noqa: F401
from .evaluate import (backend_agreement, evaluate_net,          # noqa: F401
                       int8_top1)
from .models import float_model, lenet5_float_layers, \
    resnet8_float_graph                                          # noqa: F401
from .ptq import (INPUT_EXP, FloatLayer, QuantizedModel,         # noqa: F401
                  calibrate_integer_weight_exps, choose_weight_exp,
                  quantize_bias, quantize_images, quantize_network,
                  quantize_weights)
from .train import (NETS, float_top1, init_params,               # noqa: F401
                    load_checkpoint, save_checkpoint, train_float,
                    train_or_load)
