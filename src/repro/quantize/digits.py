"""Deterministic procedurally-generated digit dataset (DESIGN.md
§Quantization).

The accuracy-validation story needs thousands of labelled images without
network access, so the dataset is *generated*, MNIST-style: 5×7 digit
glyphs randomly scaled (×3/×4 per axis), sheared, placed on a 32×32
canvas, intensity-jittered and noised.  Every image is a pure function
of ``(seed, split, index)`` — a Philox stream keyed on that tuple — so

* train/test splits are disjoint by construction (different ``split``
  keys, not different slices of one stream);
* the dataset is identical across machines, runs and dataset sizes
  (image ``i`` does not depend on how many images were requested);
* labels are exactly balanced (``label = index % 10``).

Images are float32 in [0, 1], shaped ``(n, 1, 32, 32)`` (or
``(n, 3, 32, 32)`` with ``channels=3``, where a per-image random colour
tints the glyph — shape, not colour, carries the class).  This is the
float front door's input; :func:`repro.quantize.ptq.quantize_images`
maps it onto the device's int8 input scale.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

CANVAS = 32

# 5×7 glyph bitmaps, one per digit class.
_GLYPH_ROWS = {
    0: ("01110", "10001", "10011", "10101", "11001", "10001", "01110"),
    1: ("00100", "01100", "00100", "00100", "00100", "00100", "01110"),
    2: ("01110", "10001", "00001", "00110", "01000", "10000", "11111"),
    3: ("11110", "00001", "00001", "01110", "00001", "00001", "11110"),
    4: ("00010", "00110", "01010", "10010", "11111", "00010", "00010"),
    5: ("11111", "10000", "11110", "00001", "00001", "10001", "01110"),
    6: ("00110", "01000", "10000", "11110", "10001", "10001", "01110"),
    7: ("11111", "00001", "00010", "00100", "01000", "01000", "01000"),
    8: ("01110", "10001", "10001", "01110", "10001", "10001", "01110"),
    9: ("01110", "10001", "10001", "01111", "00001", "00010", "01100"),
}

GLYPHS = {d: np.array([[int(c) for c in row] for row in rows],
                      dtype=np.float32)
          for d, rows in _GLYPH_ROWS.items()}

_SPLIT_KEYS = {"train": 0, "test": 1, "calib": 2}


def digit_image(seed: int, split: str, index: int, *,
                channels: int = 1) -> Tuple[np.ndarray, int]:
    """One ``(image, label)`` pair — a pure function of its arguments."""
    if split not in _SPLIT_KEYS:
        raise ValueError(f"split must be one of {sorted(_SPLIT_KEYS)}, "
                         f"got {split!r}")
    if channels not in (1, 3):
        raise ValueError(f"channels must be 1 or 3, got {channels}")
    label = index % 10
    rng = np.random.default_rng((seed, _SPLIT_KEYS[split], index))
    fy = int(rng.integers(3, 5))
    fx = int(rng.integers(3, 5))
    glyph = np.kron(GLYPHS[label], np.ones((fy, fx), dtype=np.float32))
    h, w = glyph.shape
    slant = int(rng.integers(-2, 3))            # horizontal shear, ±2 px
    ws = w + abs(slant)
    sheared = np.zeros((h, ws), dtype=np.float32)
    for r in range(h):
        off = round(slant * r / max(h - 1, 1))
        off = off - min(0, slant)               # keep offsets non-negative
        sheared[r, off:off + w] = glyph[r]
    top = int(rng.integers(0, CANVAS - h + 1))
    left = int(rng.integers(0, CANVAS - ws + 1))
    intensity = float(rng.uniform(0.55, 1.0))
    canvas = rng.uniform(0.0, 0.12, (CANVAS, CANVAS)).astype(np.float32)
    canvas[top:top + h, left:left + ws] += intensity * sheared
    canvas += rng.normal(0.0, 0.03, (CANVAS, CANVAS)).astype(np.float32)
    gray = np.clip(canvas, 0.0, 1.0).astype(np.float32)
    if channels == 1:
        return gray[None, :, :], label
    tint = rng.uniform(0.5, 1.0, (3,)).astype(np.float32)
    img = np.clip(gray[None, :, :] * tint[:, None, None], 0.0, 1.0)
    return img.astype(np.float32), label


def digit_dataset(n: int, *, seed: int = 0, split: str = "train",
                  channels: int = 1) -> Tuple[np.ndarray, np.ndarray]:
    """``(images (n, C, 32, 32) float32 in [0,1], labels (n,) int64)``."""
    if n < 1:
        raise ValueError(f"dataset size must be >= 1, got {n}")
    pairs = [digit_image(seed, split, i, channels=channels)
             for i in range(n)]
    images = np.stack([p[0] for p in pairs])
    labels = np.array([p[1] for p in pairs], dtype=np.int64)
    return images, labels
