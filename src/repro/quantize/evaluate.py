"""Dataset-scale accuracy harness (EXPERIMENTS.md §Accuracy).

Runs a held-out digit split through ``NetworkProgram.serve`` on the
batched backend (with a pallas spot-check on a subset — the conformance
contract makes the backends interchangeable, so spot-checking is a
cross-check, not a coverage gap) and reports int8-vs-float top-1 deltas.
``evaluate_net`` is the one-call pipeline the accuracy benchmark
(:mod:`benchmarks.accuracy_tables`) and the example front door
(``examples/quantize_eval.py``) both drive: train-or-load float weights
→ PTQ → compile → serve the test split → accuracy table.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from .digits import digit_dataset
from .models import CHANNELS, float_model
from .ptq import INPUT_EXP, quantize_images, quantize_network
from .train import float_top1, train_or_load


def int8_top1(net_prog, images: np.ndarray, labels: np.ndarray, *,
              input_exp: int = INPUT_EXP, batch: int = 64,
              backend: str = "batched") -> float:
    """Top-1 accuracy of a compiled network over float images, served
    through the batch engine in ``batch``-sized stacks."""
    ints = quantize_images(images, input_exp=input_exp)
    correct = 0
    for lo in range(0, len(ints), batch):
        chunk = ints[lo:lo + batch]
        outs, _ = net_prog.serve(chunk, backend=backend)
        preds = outs.reshape(len(chunk), -1).argmax(axis=1)
        correct += int((preds == labels[lo:lo + len(chunk)]).sum())
    return correct / len(ints)


def backend_agreement(net_prog, images: np.ndarray, *,
                      input_exp: int = INPUT_EXP,
                      backends: Sequence[str] = ("batched", "pallas")
                      ) -> bool:
    """Bit-identity spot-check: every backend serves the same stack to
    the same bytes (the conformance contract, checked live on real
    quantised-from-float weights)."""
    ints = quantize_images(images, input_exp=input_exp)
    ref, _ = net_prog.serve(ints, backend=backends[0])
    for be in backends[1:]:
        outs, _ = net_prog.serve(ints, backend=be)
        if not np.array_equal(ref, outs):
            return False
    return True


def evaluate_net(net: str, *, train_n: int = 4000, eval_n: int = 2000,
                 calib_n: int = 64, epochs: int = 6, seed: int = 0,
                 batch: int = 64, margin: int = 0,
                 checkpoint: Optional[str] = None,
                 spotcheck_n: int = 8) -> Dict[str, object]:
    """Float front door → PTQ → dataset-scale serve, one call.

    Returns the accuracy record the benchmark publishes: float and int8
    top-1 on the ``eval_n``-image held-out split, the delta in points,
    and the pallas spot-check verdict.

    ``margin=0`` by default: the §4.2 scan already sizes each shift so
    the full calibration-set accumulator range fits int8 exactly, and an
    extra guard octave costs real accuracy (one bit of logit resolution
    per layer — measured ~4 points of top-1 on LeNet-5 digits).
    """
    channels = CHANNELS[net]
    params = train_or_load(net, checkpoint=checkpoint, train_n=train_n,
                           epochs=epochs, seed=seed)
    test_x, test_y = digit_dataset(eval_n, seed=seed, split="test",
                                   channels=channels)
    calib_x, _ = digit_dataset(calib_n, seed=seed, split="calib",
                               channels=channels)
    facc = float_top1(net, params, test_x, test_y)
    qm = quantize_network(float_model(net, params), calib_x, margin=margin)
    prog = qm.compile()
    iacc = int8_top1(prog, test_x, test_y, input_exp=qm.input_exp,
                     batch=batch)
    agree = backend_agreement(prog, test_x[:spotcheck_n],
                              input_exp=qm.input_exp)
    return {
        "net": net,
        "n_train": train_n,
        "n_eval": eval_n,
        "n_calib": calib_n,
        "float_top1": facc,
        "int8_top1": iacc,
        "delta_points": (facc - iacc) * 100.0,
        "pallas_spotcheck_bit_identical": bool(agree),
        "weight_exps": {k: int(v) for k, v in qm.weight_exps.items()},
        "shifts": {k: int(v) for k, v in qm.shifts.items()},
    }
