"""Loop-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every while body ONCE — useless for
scanned layer stacks (a 36-layer scan under-reports 36×, nested microbatch
and attention-chunk scans compound to ~10⁵×).  XLA's optimized HLO carries
``backend_config={"known_trip_count":{"n":…}}`` on every while, so this
module walks the module text and accumulates, with trip multiplication:

* FLOPs       — dot (2·|out|·|contract|), convolution, elementwise/reduce;
* HBM bytes   — at *fusion granularity* (a fusion's internals stay in
  registers/VMEM: bytes = its operands + outputs; parameters/GTE/bitcast/
  tuple are free; dynamic-update-slice is in-place: update bytes only);
* collective wire bytes — per op kind, with ring-transfer factors and the
  participant-group size parsed from ``replica_groups``; groups spanning
  device blocks of 256 are classified inter-pod (DCI) vs intra-pod (ICI).

Shapes in the post-SPMD module are PER-PARTITION, so every number is
per-device — exactly what the roofline terms want.

Validated in tests/test_hlo_cost.py against analytically-known programs
(matmul under lax.scan, etc.).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*(\(?[^,()]*(?:\([^)]*\))?[^,]*)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"(\d+)"')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_DIMS_RE = re.compile(r"(lhs|rhs)_(contracting|batch)_dims=\{([0-9,]*)\}")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")
_DIMLABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# elementwise-ish opcodes whose flops ≈ output numel
_EW1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "logistic", "floor", "ceil", "round-nearest-even", "sign", "cosine",
    "sine", "expm1", "log1p", "atan2", "remainder", "compare", "select",
    "and", "or", "xor", "not", "clamp", "shift-left",
    "shift-right-arithmetic", "shift-right-logical",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "reshape", "after-all", "partition-id", "replica-id",
    "opt-barrier", "custom-call",
}


def shape_numel_bytes(shape_str: str) -> Tuple[int, int]:
    """Total (numel, bytes) over every array in a (possibly tuple) shape."""
    numel = 0
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        numel += n
        total += n * _DTYPE_BYTES[dtype]
    return numel, total


def shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class OpLine:
    name: str
    shape: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[OpLine]
    shapes: Dict[str, str]          # %name -> shape string


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    # TPU-fusion HBM model: only dot/conv operands+outputs and collective
    # payloads touch HBM; elementwise/reduce chains are VMEM-fused into
    # their producers (which is how XLA:TPU — and our Pallas kernels with
    # VMEM scratch — actually execute).  ``bytes`` (raw) upper-bounds,
    # ``bytes_fused`` approximates the TPU target.
    bytes_fused: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_wire: float = 0.0          # ring-factored wire bytes per device
    coll_wire_interpod: float = 0.0
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_fused += other.bytes_fused * mult
        for k in COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
        self.coll_wire += other.coll_wire * mult
        self.coll_wire_interpod += other.coll_wire_interpod * mult
        self.coll_count += other.coll_count * mult


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):            # computation header
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry_name = cur.name
                for pname, pshape in _PARAM_RE.findall(m.group(3)):
                    cur.shapes[pname] = pshape.strip()
                continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            _, name, shape, opcode, rest = m.groups()
            cur.ops.append(OpLine(name, shape, opcode, rest))
            cur.shapes[name] = shape
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _group_geometry(rest: str, n_devices: int) -> Tuple[int, bool]:
    """(participants per group, spans multiple 256-device pods?)."""
    m = _GROUPS_RE.search(rest)
    if m:
        n_groups, g_size, total = (int(m.group(1)), int(m.group(2)),
                                   int(m.group(3)))
        # iota groups [G,S]<=[N]: group members are id, id+G, id+2G, ...
        # stride G; spans pods iff (S-1)*G >= 256 boundary crossing
        spans = (g_size - 1) * n_groups >= 256 and total > 256
        return g_size, spans
    m = _GROUPS_LIST_RE.search(rest)
    if m and m.group(1).strip():
        groups = [g for g in re.findall(r"\{([0-9, ]+)\}", "{" + m.group(1) + "}")]
        sizes = []
        spans = False
        for g in groups:
            ids = [int(x) for x in g.replace(" ", "").split(",") if x]
            sizes.append(len(ids))
            if ids and (max(ids) // 256) != (min(ids) // 256):
                spans = True
        return (max(sizes) if sizes else 1), spans
    return n_devices, n_devices > 256


def _dot_flops(op: OpLine, shapes: Dict[str, str]) -> float:
    out = shape_dims(op.shape)
    contract = 1
    # The lhs operand: newer XLA dumps type every operand inline
    # ("dot(f32[128,256]{1,0} %Arg_0.1, ...)"), so the first token of
    # ``rest`` is a shape, not a %name — search for the first %name and
    # fall back to the inline operand shape when the name isn't resolvable.
    m = re.search(r"%([\w.\-]+)", op.rest)
    dims_attrs = {f"{a}_{b}": v for a, b, v in _DIMS_RE.findall(op.rest)}
    lhs_c = dims_attrs.get("lhs_contracting", "")
    if lhs_c:
        lhs_dims: List[int] = []
        if m and m.group(1) in shapes:
            lhs_dims = shape_dims(shapes[m.group(1)])
        if not lhs_dims:
            inline = _SHAPE_RE.search(op.rest)
            if inline:
                lhs_dims = shape_dims(inline.group(0))
        for idx in lhs_c.split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    numel = 1
    for d in out:
        numel *= d
    return 2.0 * numel * contract


def _conv_flops(op: OpLine, shapes: Dict[str, str]) -> float:
    out_numel, _ = shape_numel_bytes(op.shape)
    window = 1
    m = _WINDOW_RE.search(op.rest)
    if m:
        for s in m.group(1).split("x"):
            window *= int(s)
    fgc = 1
    m = _FGC_RE.search(op.rest)
    if m:
        fgc = int(m.group(1))
    in_feat = 1
    ml = _DIMLABELS_RE.search(op.rest)
    operands = re.findall(r"%([\w.\-]+)", op.rest)
    if ml and len(operands) >= 2 and operands[1] in shapes:
        rhs_labels = ml.group(2)
        rhs_dims = shape_dims(shapes[operands[1]])
        if "i" in rhs_labels:
            i_idx = rhs_labels.index("i")
            if i_idx < len(rhs_dims):
                in_feat = rhs_dims[i_idx]
    return 2.0 * out_numel * window * in_feat


# op_name substrings whose f32 is *by design* (explicit casts in the model
# code — they stay f32 on the TPU target too)
_F32_BY_DESIGN = ("softmax_xent", "logsumexp", "adamw", "apply_updates")


class CostWalker:
    """``dtype_correction``: XLA:CPU legalizes bf16 dots by upcasting both
    operands to f32, so on this container every dot — and every collective
    fed by one — carries f32 payloads that are bf16 on the TPU target.
    With the flag on (default), f32 dot traffic and f32 collective payloads
    are counted at 2 bytes/element unless the op is in an intentionally-f32
    region (loss, optimizer).  FLOP counts are dtype-independent either
    way.  Both corrected and uncorrected totals are reported."""

    def __init__(self, comps: Dict[str, Computation], n_devices: int,
                 dtype_correction: bool = True):
        self.comps = comps
        self.n_devices = n_devices
        self.dtype_correction = dtype_correction
        self._memo: Dict[str, Cost] = {}
        self.unknown_trip_whiles = 0

    def _dtype_factor(self, op: OpLine) -> float:
        if not self.dtype_correction:
            return 1.0
        if "f32[" not in op.shape:
            return 1.0
        meta = re.search(r'op_name="([^"]+)"', op.rest)
        if meta and any(tag in meta.group(1) for tag in _F32_BY_DESIGN):
            return 1.0
        return 0.5

    def computation_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        cost = Cost()
        if comp is None:
            return cost
        self._memo[name] = cost            # cycle guard (shouldn't happen)
        for op in comp.ops:
            cost.add(self.op_cost(op, comp))
        return cost

    # ------------------------------------------------------------------
    def op_cost(self, op: OpLine, comp: Computation) -> Cost:
        c = Cost()
        opcode = op.opcode
        if opcode in _FREE:
            # custom-calls in our modules are metadata (Sharding, etc.)
            return c
        _, out_bytes = shape_numel_bytes(op.shape)
        out_numel, _ = shape_numel_bytes(op.shape)

        if opcode == "while":
            trip = 1
            m = _TRIP_RE.search(op.rest)
            if m:
                trip = int(m.group(1))
            else:
                self.unknown_trip_whiles += 1
            body = _CALLS_RE.search(op.rest)
            cond = _COND_RE.search(op.rest)
            if body:
                c.add(self.computation_cost(body.group(1)), trip)
            if cond:
                c.add(self.computation_cost(cond.group(1)), trip)
            return c

        if opcode in ("fusion", "call", "map"):
            m = _CALLS_RE.search(op.rest)
            inner = None
            if m:
                inner = self.computation_cost(m.group(1))
                c.flops += inner.flops
                c.bytes_fused += inner.bytes_fused
                for k in COLLECTIVES:
                    c.coll_bytes[k] += inner.coll_bytes[k]
                c.coll_wire += inner.coll_wire
                c.coll_wire_interpod += inner.coll_wire_interpod
                c.coll_count += inner.coll_count
            # HBM traffic at fusion boundary: operands + outputs
            c.bytes += out_bytes + self._operand_bytes(op, comp)
            return c

        if opcode == "conditional":
            # count the worst branch
            branches = re.findall(r"branch_computations=\{([^}]*)\}", op.rest)
            best = Cost()
            if branches:
                for b in branches[0].split(","):
                    bc = self.computation_cost(b.strip().lstrip("%"))
                    if bc.flops + bc.bytes > best.flops + best.bytes:
                        best = bc
            c.add(best)
            c.bytes += out_bytes
            return c

        base = opcode.split("-start")[0]
        if base in COLLECTIVES:
            _, payload = shape_numel_bytes(op.shape)
            payload *= self._dtype_factor(op)
            g, spans = _group_geometry(op.rest, self.n_devices)
            ring = (g - 1) / g if g > 1 else 0.0
            if base == "all-reduce":
                wire = 2.0 * payload * ring
            elif base == "reduce-scatter":
                # output is per-partition (= input/g): wire ≈ in·(g-1)/g
                wire = payload * (g - 1)
            elif base == "all-gather":
                wire = payload * ring
            elif base == "all-to-all":
                wire = payload * ring
            else:                               # collective-permute
                wire = payload
            c.coll_bytes[base] += payload
            c.coll_wire += wire
            if spans:
                c.coll_wire_interpod += wire
            c.coll_count += 1
            c.bytes += payload + self._operand_bytes(op, comp)
            c.bytes_fused += payload + self._operand_bytes(op, comp)
            return c
        if opcode.endswith("-done") or opcode in ("copy-start", "copy-done",
                                                  "send", "recv",
                                                  "send-done", "recv-done"):
            return c

        if opcode == "dot":
            f = self._dtype_factor(op)
            c.flops += _dot_flops(op, comp.shapes)
            c.bytes += (out_bytes + self._operand_bytes(op, comp)) * f
            c.bytes_fused += (out_bytes + self._operand_bytes(op, comp)) * f
            return c
        if opcode == "convolution":
            f = self._dtype_factor(op)
            c.flops += _conv_flops(op, comp.shapes)
            c.bytes += (out_bytes + self._operand_bytes(op, comp)) * f
            c.bytes_fused += (out_bytes + self._operand_bytes(op, comp)) * f
            return c
        if opcode in ("reduce", "reduce-window"):
            c.flops += self._operand_numel(op, comp)
            c.bytes += out_bytes + self._operand_bytes(op, comp)
            return c
        if opcode == "dynamic-update-slice":
            # in-place: traffic = the update operand (2nd arg) + indices
            ops_ = re.findall(r"%([\w.\-]+)", op.rest)
            upd = 0
            if len(ops_) >= 2 and ops_[1] in comp.shapes:
                _, upd = shape_numel_bytes(comp.shapes[ops_[1]])
            c.bytes += 2 * upd
            return c
        if opcode in _EW1:
            c.flops += out_numel
        elif opcode in ("sort",):
            dims = shape_dims(op.shape)
            n = dims[-1] if dims else 1
            import math
            c.flops += out_numel * max(1, math.log2(max(2, n)))
        # default data movement
        c.bytes += out_bytes + self._operand_bytes(op, comp)
        return c

    # ------------------------------------------------------------------
    def _operand_bytes(self, op: OpLine, comp: Computation) -> int:
        total = 0
        # operands are the %names before any attribute (rest up to "),")
        arglist = op.rest.split("), ")[0]
        for name in re.findall(r"%([\w.\-]+)", arglist):
            if name in comp.shapes:
                _, b = shape_numel_bytes(comp.shapes[name])
                total += b
        return total

    def _operand_numel(self, op: OpLine, comp: Computation) -> int:
        total = 0
        arglist = op.rest.split("), ")[0]
        for name in re.findall(r"%([\w.\-]+)", arglist):
            if name in comp.shapes:
                n, _ = shape_numel_bytes(comp.shapes[name])
                total += n
        return total


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalise ``compiled.cost_analysis()`` across jax versions.

    Older jax returns a per-device list of dicts, newer returns the dict
    directly; either way the caller wants one flat ``{"flops": …}`` dict
    (device 0 — post-SPMD modules are identical per device).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def analyze_hlo(text: str, n_devices: int,
                dtype_correction: bool = True) -> Dict[str, float]:
    """Per-device loop-scaled cost of an optimized (post-SPMD) HLO module.

    With ``dtype_correction`` (default) f32 dot/collective traffic is
    counted at bf16 width (the TPU-target dtype; XLA:CPU upcasts — see
    CostWalker); the uncorrected totals are reported alongside."""
    comps = parse_module(text)
    walker = CostWalker(comps, n_devices, dtype_correction)
    cost = walker.computation_cost("__entry__")
    out = {
        "flops_per_device": cost.flops,
        "bytes_per_device": cost.bytes,
        "bytes_fused_per_device": cost.bytes_fused,
        "collective_bytes": dict(cost.coll_bytes),
        "collective_wire_per_device": cost.coll_wire,
        "collective_wire_interpod": cost.coll_wire_interpod,
        "collective_count": cost.coll_count,
        "unknown_trip_whiles": walker.unknown_trip_whiles,
    }
    if dtype_correction:
        raw = CostWalker(comps, n_devices, False).computation_cost(
            "__entry__")
        out["uncorrected"] = {
            "bytes_fused_per_device": raw.bytes_fused,
            "collective_wire_per_device": raw.coll_wire,
        }
    return out
