"""Pure-jnp oracles for every Pallas kernel (the kernel test contracts)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def vta_gemm_ref(a: jax.Array, b: jax.Array,
                 bias: Optional[jax.Array] = None, *,
                 relu: bool = False, shift: int = 0, saturate: bool = True,
                 out_dtype=jnp.int8) -> jax.Array:
    """Oracle for kernels.vta_gemm: int32 accumulate + TensorAlu epilogue."""
    acc = jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    if bias is not None:
        acc = acc + bias.astype(jnp.int32)[None, :]
    if relu:
        acc = jnp.maximum(acc, 0)
    if shift:
        acc = jax.lax.shift_right_arithmetic(acc, jnp.int32(shift))
    if out_dtype == jnp.int8:
        if saturate:
            acc = jnp.clip(acc, -128, 127)
        else:
            acc = jax.lax.shift_right_arithmetic(
                jax.lax.shift_left(acc, 24), jnp.int32(24))
    return acc.astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, sm_scale: Optional[float] = None,
                  window: Optional[int] = None,
                  q_offset: int = 0) -> jax.Array:
    """Oracle for kernels.flash_attention (float32 softmax, GQA-aware)."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = h // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with every position masked: softmax gives uniform; zero them
    any_valid = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)
