"""Jitted public wrappers around the Pallas kernels.

Handles shape padding to block multiples, backend selection (real Pallas on
TPU, ``interpret=True`` elsewhere — this container is CPU-only so every test
runs the kernel bodies in interpret mode), and the pure-JAX fallbacks used
by the dry-run path (XLA lowers those for the roofline analysis; see
DESIGN.md §2).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import CompileError

from . import ref as _ref
from .flash_attention import flash_attention as _flash
from .vta_gemm import vta_gemm as _vta_gemm

_BACKENDS = ("auto", "pallas", "xla")


def _check_backend(backend: str) -> None:
    if backend not in _BACKENDS:
        raise ValueError(
            f"kernel backend must be one of {_BACKENDS}, got {backend!r}")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def vta_matmul(a: jax.Array, b: jax.Array,
               bias: Optional[jax.Array] = None, *,
               relu: bool = False, shift: int = 0, saturate: bool = True,
               out_dtype=jnp.int8,
               block_m: int = 256, block_n: int = 256, block_k: int = 256,
               backend: str = "auto") -> jax.Array:
    """Fused W8A8 GEMM (the paper's datapath as a TPU feature).

    backend: "pallas" | "xla" | "auto" (pallas on TPU, interpret elsewhere
    only if explicitly requested — interpret mode is for tests; "auto" off
    TPU uses the XLA reference, which is semantically identical).
    """
    _check_backend(backend)
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise CompileError(
            f"incompatible GEMM operand shapes {tuple(a.shape)} @ "
            f"{tuple(b.shape)}", constraint="kernel-gemm-shape")
    if backend == "xla" or (backend == "auto" and not _on_tpu()):
        return _ref.vta_gemm_ref(a, b, bias, relu=relu, shift=shift,
                                 saturate=saturate, out_dtype=out_dtype)
    interpret = not _on_tpu()
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 128))
    bk = min(block_k, _round_up(k, 128))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    bias_p = (jnp.pad(bias, (0, np_ - n)) if bias is not None else None)
    out = _vta_gemm(a_p, b_p, bias_p, relu=relu, shift=shift,
                    saturate=saturate, out_dtype=out_dtype,
                    block_m=bm, block_n=bn, block_k=bk, interpret=interpret)
    return out[:m, :n]


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, sm_scale: Optional[float] = None,
              window: Optional[int] = None, q_offset: int = 0,
              block_q: int = 128, block_k: int = 128,
              backend: str = "auto") -> jax.Array:
    """Flash attention with GQA; pads sequence dims to block multiples."""
    _check_backend(backend)
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if backend == "xla" or (backend == "auto" and not _on_tpu()):
        return _ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale,
                                  window=window, q_offset=q_offset)
    interpret = not _on_tpu()
    bq = min(block_q, _round_up(sq, 8))
    bk = min(block_k, _round_up(skv, 8))
    sq_p, skv_p = _round_up(sq, bq), _round_up(skv, bk)
    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    out = _flash(q_p, k_p, v_p, causal=causal, sm_scale=sm_scale,
                 window=window, q_offset=q_offset,
                 block_q=bq, block_k=bk, interpret=interpret)
    return out[:, :, :sq, :]


def vta_matmul_pallas(a, b, bias=None, **kw):
    """Force the Pallas path (interpret off-TPU) — used by kernel tests."""
    kw.setdefault("backend", "pallas")
    m, k = a.shape
    _, n = b.shape
    bm = min(kw.pop("block_m", 256), _round_up(m, 8))
    bn = min(kw.pop("block_n", 256), _round_up(n, 128))
    bk = min(kw.pop("block_k", 256), _round_up(k, 128))
    kw.pop("backend")
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    bias_p = (jnp.pad(bias, (0, np_ - n)) if bias is not None else None)
    out = _vta_gemm(a_p, b_p, bias_p, block_m=bm, block_n=bn, block_k=bk,
                    interpret=not _on_tpu(), **kw)
    return out[:m, :n]


def attention_pallas(q, k, v, **kw):
    """Force the Pallas path (interpret off-TPU) — used by kernel tests."""
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    bq = min(kw.pop("block_q", 128), _round_up(sq, 8))
    bk = min(kw.pop("block_k", 128), _round_up(skv, 8))
    sq_p, skv_p = _round_up(sq, bq), _round_up(skv, bk)
    q_p = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    k_p = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    out = _flash(q_p, k_p, v_p, block_q=bq, block_k=bk,
                 interpret=not _on_tpu(), **kw)
    return out[:, :, :sq, :]
