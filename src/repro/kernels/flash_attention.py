"""Pallas TPU kernel: causal flash attention with native GQA (bf16/f32).

The LM-side hot path of the framework (DESIGN.md §4).  Online-softmax over
KV blocks with running (m, l, o) carried in VMEM scratch; GQA is handled in
the BlockSpec index maps (query head h reads KV head ``h // group``), so
K/V are never materialised per-query-head.

Grid = (batch, q_heads, Sq/bq, Skv/bk); the KV axis is ``arbitrary`` (the
scratch carries across it), everything else parallel.  Causal masking is
applied in-kernel from absolute positions; fully-masked KV blocks are
numerically inert (contribute exp(-inf)=0), and the `block_causal` fast
path skips them via the grid truncation in ops.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.errors import CompileError

# jax 0.4.x exposes this as TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, sm_scale: float, causal: bool,
                  block_q: int, block_k: int, q_offset: int,
                  window: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    if window is not None:
        # sliding-window attention (Mixtral-style SWA)
        mask = jnp.logical_and(mask, q_pos - k_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                               # (bq, bk)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot_general(
                        p, v_ref[0, 0].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_cur

    @pl.when(ki == n_kv - 1)
    def _finish():
        # fully-masked rows (l == 0) return 0, not NaN
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sm_scale", "block_q", "block_k", "window",
                     "q_offset", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, sm_scale: Optional[float] = None,
                    window: Optional[int] = None,
                    q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """``q`` (B, H, Sq, D); ``k``/``v`` (B, Hkv, Skv, D) with H % Hkv == 0.

    Sq/Skv must be multiples of the block sizes (ops.py pads).  ``q_offset``
    is the absolute position of q[…, 0, :] — used for chunked prefill where
    queries start mid-sequence.
    """
    b, h, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if h % hkv:
        raise CompileError(
            f"{h} query heads do not group over {hkv} KV heads",
            constraint="kernel-gqa-heads")
    group = h // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    if sq % block_q or skv % block_k:
        raise CompileError(
            f"sequence lengths {(sq, skv)} not multiples of the attention "
            f"blocks {(block_q, block_k)}; call through ops.attention, "
            f"which pads", constraint="kernel-block-divisibility")
    n_q = sq // block_q
    n_kv = skv // block_k
    grid = (b, h, n_q, n_kv)

    kernel = functools.partial(
        _flash_kernel, n_kv=n_kv, sm_scale=float(sm_scale), causal=causal,
        block_q=block_q, block_k=block_k, q_offset=q_offset, window=window)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki, g=group: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
