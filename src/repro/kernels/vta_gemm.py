"""Pallas TPU kernel: the VTA datapath as one fused kernel (DESIGN.md §2).

``vta_gemm`` is the TPU-native re-expression of the paper's execution model:

* TensorGemm — int8 × int8 → int32 blocked matmul on the MXU
  (``preferred_element_type=int32``; the FPGA's 16×16 MAC array becomes the
  128×128 systolic array);
* ACC preload — the optional bias is the paper's ``C = A·B + X`` form;
* TensorAlu — the element-wise epilogue (ReLU, arithmetic-shift-right
  requant, int8 saturation) fused into the same kernel, replacing the VTA's
  separate ALU instruction stream;
* LOAD/STORE overlap — the ``(i, j, k)`` grid with an ``arbitrary`` K axis
  gives Pallas's automatic HBM→VMEM double buffering, playing the role of
  the VTA's dependency-flag-driven module overlap.

Block shapes are the kernel's VMEM claim: with the default 256×256×256
int8/int32 tiles the working set is A(64 KiB) + B(64 KiB) + acc(256 KiB) +
out(64 KiB) ≈ 0.45 MiB — comfortably double-bufferable in 16 MiB VMEM, and
every matmul dimension is a multiple of the 128-wide MXU.

One deliberate semantic upgrade over the FPGA: the epilogue *saturates* to
int8 instead of truncating (the paper's OUT path truncates ACC).  Truncation
is reproduced bit-exactly by the core/ simulator; saturation is what a
quantised LM inference path needs.  ``ops.vta_matmul(..., saturate=False)``
selects faithful truncation.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.errors import CompileError

# jax 0.4.x exposes this as TPUCompilerParams; newer releases renamed it.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _gemm_kernel(a_ref, b_ref, bias_ref, out_ref, acc_ref, *,
                 n_k: int, relu: bool, shift: int, saturate: bool,
                 out_dtype):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (arbitrary) axis so
    ``acc_ref`` persists across K steps for a fixed (i, j) tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: int8 × int8 → int32 (the TensorGemm step).
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        acc = acc_ref[...]
        if bias_ref is not None:
            acc = acc + bias_ref[...].astype(jnp.int32)   # ACC preload (X)
        if relu:
            acc = jnp.maximum(acc, 0)                     # TensorAlu MAX
        if shift:
            acc = jax.lax.shift_right_arithmetic(         # TensorAlu SHR
                acc, jnp.int32(shift))
        if out_dtype == jnp.int8:
            if saturate:
                acc = jnp.clip(acc, -128, 127)
            else:
                # faithful VTA truncation: low 8 bits, two's complement
                acc = jax.lax.shift_right_arithmetic(
                    jax.lax.shift_left(acc, 24), jnp.int32(24))
        out_ref[...] = acc.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("relu", "shift", "saturate", "out_dtype",
                     "block_m", "block_n", "block_k", "interpret"))
def vta_gemm(a: jax.Array, b: jax.Array,
             bias: Optional[jax.Array] = None, *,
             relu: bool = False, shift: int = 0, saturate: bool = True,
             out_dtype=jnp.int8,
             block_m: int = 256, block_n: int = 256, block_k: int = 256,
             interpret: bool = False) -> jax.Array:
    """Fused quantised GEMM: ``epilogue(A @ B + bias)``.

    ``a`` int8 (M, K), ``b`` int8 (K, N), ``bias`` int32 (N,) or None.
    M/N/K must be multiples of the block sizes (``ops.vta_matmul`` pads).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise CompileError(
            f"incompatible GEMM operand shapes {tuple(a.shape)} @ "
            f"{tuple(b.shape)}", constraint="kernel-gemm-shape")
    if m % block_m or n % block_n or k % block_k:
        raise CompileError(
            f"GEMM shape {(m, k, n)} not a multiple of the kernel blocks "
            f"{(block_m, block_k, block_n)}; call through ops.vta_matmul, "
            f"which pads", constraint="kernel-block-divisibility")
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
    ]
    args = [a, b]
    if bias is not None:
        # bias broadcasts over rows: keep a (1, block_n) VMEM tile
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        args.append(bias.reshape(1, n).astype(jnp.int32))
        kernel = functools.partial(_gemm_kernel, n_k=n_k, relu=relu,
                                   shift=shift, saturate=saturate,
                                   out_dtype=out_dtype)
    else:
        def kernel(a_ref, b_ref, out_ref, acc_ref):
            _gemm_kernel(a_ref, b_ref, None, out_ref, acc_ref, n_k=n_k,
                         relu=relu, shift=shift, saturate=saturate,
                         out_dtype=out_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
