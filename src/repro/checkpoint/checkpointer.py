"""Async, atomic, elastic checkpointing.

* **Atomic**: a checkpoint is written to ``step_N.tmp/`` and renamed to
  ``step_N/`` only when complete — a crash mid-write can never corrupt the
  restore set (the ``.tmp`` is ignored and GC'd).
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread, overlapping the next training steps;
  ``wait()`` joins before the next save or at shutdown.
* **Elastic**: leaves are stored whole (gathered), with the tree structure
  and dtypes in ``manifest.json``.  ``restore`` re-places them under *any*
  mesh via the shardings the caller provides — restoring a 4-way run onto
  8 devices (or 1) is just a different sharding argument
  (tests/test_checkpoint.py exercises device-count changes).
* **Keep-K GC**: older complete checkpoints beyond ``keep`` are removed
  after a successful save.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_SEP = "\x1e"


def _flatten(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(jax.tree_util.keystr((k,), simple=True))
                        for k in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host and write in the background."""
        self.wait()
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}

        def write():
            try:
                self._write(step, host)
            except BaseException as e:       # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()

    def save(self, step: int, tree) -> None:
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        self._write(step, host)

    # ------------------------------------------------------------------
    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        final = self.dir / f"step_{step:010d}"
        tmp = self.dir / f"step_{step:010d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(host.items())):
            fname = f"leaf_{i:05d}.npy"
            store = arr
            if arr.dtype.kind not in "fiub?" or str(arr.dtype) == "bfloat16":
                # ml_dtypes (bf16/fp8, numpy kind 'V') don't np.load back
                # cleanly — store as f32 (lossless for these widths)
                store = arr.astype(np.float32)
            np.save(tmp / fname, store)
            manifest["leaves"][key] = {
                "file": fname, "dtype": str(arr.dtype),
                "shape": list(arr.shape)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)                      # atomic commit
        self._gc()

    def _gc(self) -> None:
        done = self.complete_steps()
        for s in done[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)
        for tmp in self.dir.glob("*.tmp"):
            shutil.rmtree(tmp, ignore_errors=True)

    # ------------------------------------------------------------------
    def complete_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.complete_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching tree of Shardings (or
        None → replicated default device placement)."""
        path = self.dir / f"step_{step:010d}"
        manifest = json.loads((path / "manifest.json").read_text())
        flat_like = _flatten(like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        out: Dict[str, Any] = {}
        for key, meta in manifest["leaves"].items():
            if key not in flat_like:
                continue                      # dropped leaf (fwd compat)
            arr = np.load(path / meta["file"])
            want = flat_like[key]
            if tuple(arr.shape) != tuple(want.shape):
                raise ValueError(
                    f"leaf {key!r}: checkpoint shape {arr.shape} != "
                    f"expected {want.shape}")
            cast = jax.numpy.asarray(arr).astype(want.dtype)
            sh = flat_shard.get(key)
            out[key] = jax.device_put(cast, sh) if sh is not None else cast
        missing = set(flat_like) - set(out)
        if missing:
            raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
        # unflatten by matching the like-tree's flatten order
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for pth, _ in flat:
            key = _SEP.join(str(jax.tree_util.keystr((k,), simple=True))
                            for k in pth)
            leaves.append(out[key])
        return jax.tree_util.tree_unflatten(treedef, leaves)
