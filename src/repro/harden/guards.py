"""Runtime integrity guards for VTA serving (DESIGN.md §Hardening).

Three independent detection layers, composed by :func:`guarded_serve` /
:func:`guarded_serve_one` under a :class:`GuardPolicy`:

1. **Segment CRCs** — ``VTAProgram.finalize()`` records a CRC32 per
   segment; :func:`capture_golden` snapshots the immutable segments
   (``wgt``/``uop``/``acc``/``insn`` — ``inp``/``res`` are re-staged per
   request and ``out`` is device-written) and :func:`verify_network`
   re-checks them before and after every serve.  Any single-bit DRAM
   upset in a covered segment is detected deterministically.
2. **Instruction-stream validation** — :func:`validate_program` re-encodes
   the decoded stream and compares it against the segment bytes (catching
   field-level corruption the CRC cannot see), then statically checks
   every SRAM/DRAM access, the loop-lattice footprint, the STORE target,
   the FINISH terminator and the §2.3 dependency tokens, rejecting with
   typed :class:`~repro.core.errors.CompileError`\\ s.
3. **Execution checks** — typed :class:`~repro.core.simulator.VTABoundsError`
   raising before state mutation, a per-serve :class:`Watchdog` deadline
   (the seed ``runtime/fault_tolerance.py`` pattern), optional ACC
   overflow/saturation counters, and opt-in dual execution (a second
   clean run whose output must match bit-for-bit — the only layer that
   catches transient SRAM upsets that corrupt data in flight).

Recovery: on any detection the guards re-stage the corrupted layers from
the golden snapshot (bytes objects captured at snapshot time — immutable,
so the snapshot cannot rot), re-decode the instruction stream from the
golden bytes, and retry the serve up to ``GuardPolicy.max_retries`` times.
A request never returns silently-wrong data: it returns a clean output or
``None`` with ``GuardReport.outcome == "failed"``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import isa, pipeline_schedule
from repro.core.errors import CompileError
from repro.core.fast_simulator import invalidate_plan
from repro.core.simulator import TokenQueues, VTAHazardError

#: Segments that must not change between serves.  ``inp``/``res`` are
#: re-staged per request; ``out`` is written by the device.
IMMUTABLE_SEGMENTS = ("wgt", "uop", "acc", "insn")

#: Static per-instruction work ceiling (lattice points / moved structs).
#: Far above any real compiled program (LeNet-5's largest instruction is
#: ~3k loops) and far below geometries that would exhaust memory.
MAX_INSN_FOOTPRINT = 1 << 22


class WatchdogTimeout(RuntimeError):
    """A guarded serve exceeded its deadline (hung-queue fault model)."""


class Watchdog:
    """Per-serve deadline enforcement in a daemon thread — the seed
    ``runtime/fault_tolerance.py`` watchdog pattern: ``arm`` before the
    step, ``check`` at every instruction boundary (via the fault-hook
    wrapper), ``stop`` when the serve path is done."""

    def __init__(self, deadline_s: float):
        self.deadline = deadline_s
        self._armed_at: Optional[float] = None
        self._tripped = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(min(0.05, self.deadline / 4)):
            armed = self._armed_at
            if armed is not None and time.monotonic() - armed > self.deadline:
                self._tripped.set()

    def arm(self) -> None:
        self._tripped.clear()
        self._armed_at = time.monotonic()

    def disarm(self) -> None:
        self._armed_at = None

    def check(self) -> None:
        if self._tripped.is_set():
            raise WatchdogTimeout("serve exceeded watchdog deadline")

    def stop(self) -> None:
        self._stop.set()
        self._thread.join()


# ---------------------------------------------------------------------------
# Policies and reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GuardPolicy:
    """What the guarded serve path checks and how it recovers."""

    verify_crc: bool = True            # pre/post segment CRC verification
    validate_instructions: bool = True  # pre-execution stream validation
    dual_execute: bool = False         # second clean run, bit-compare
    dual_backend: str = "fast"         # backend of the shadow run
    deadline_s: Optional[float] = None  # per-serve watchdog deadline
    max_retries: int = 1               # restore-and-retry budget
    count_overflows: bool = False      # ACC overflow/saturation counters


@dataclasses.dataclass
class GuardReport:
    """What the guards saw for one request (or one batched serve)."""

    outcome: str = "clean"             # clean | recovered | failed
    retries: int = 0
    crc_failures: List[str] = dataclasses.field(default_factory=list)
    validation_errors: List[str] = dataclasses.field(default_factory=list)
    runtime_errors: List[str] = dataclasses.field(default_factory=list)
    dual_mismatches: int = 0
    watchdog_tripped: bool = False
    restored_layers: int = 0
    acc_overflow_lanes: int = 0
    acc_saturation_lanes: int = 0

    @property
    def ok(self) -> bool:
        return self.outcome != "failed"

    @property
    def detections(self) -> int:
        return (len(self.crc_failures) + len(self.validation_errors)
                + len(self.runtime_errors) + self.dual_mismatches
                + int(self.watchdog_tripped))


@dataclasses.dataclass
class GoldenImage:
    """Immutable-segment snapshot of a compiled network.

    Segment values are the ``bytes`` objects themselves — immutable, so
    holding references *is* the snapshot; an SEU model that replaces a
    program's segment cannot reach these."""

    segments: List[Dict[str, bytes]]    # per layer
    crcs: List[Dict[str, int]]


def capture_golden(net) -> GoldenImage:
    """Snapshot the immutable segments of every layer.

    Must be called on a known-good network (normally right after
    compilation); the finalize-time CRCs are cross-checked against the
    bytes so corruption that happened *before* the capture is refused
    rather than baked in."""
    segments: List[Dict[str, bytes]] = []
    crcs: List[Dict[str, int]] = []
    for layer in net.layers:
        prog = layer.program
        segs = {name: prog.segments[name] for name in IMMUTABLE_SEGMENTS
                if name in prog.segments}
        layer_crcs = {}
        for name, data in segs.items():
            crc = zlib.crc32(data)
            ref = prog.segment_crcs.get(name)
            if ref is not None and ref != crc:
                raise ValueError(
                    f"layer {prog.name!r} segment {name!r} does not match "
                    f"its finalize()-time CRC — refusing to snapshot a "
                    f"corrupted program")
            layer_crcs[name] = crc
        segments.append(segs)
        crcs.append(layer_crcs)
    return GoldenImage(segments=segments, crcs=crcs)


def golden_of(net) -> GoldenImage:
    """The network's cached golden snapshot (captured on first use)."""
    golden = getattr(net, "_harden_golden", None)
    if golden is None:
        golden = capture_golden(net)
        net._harden_golden = golden
    return golden


def verify_network(net, golden: GoldenImage) -> List[str]:
    """CRC-check every immutable segment; returns ``layer:segment``
    labels of the mismatches (empty = clean)."""
    bad: List[str] = []
    for k, layer in enumerate(net.layers):
        prog = layer.program
        for name, crc in golden.crcs[k].items():
            data = prog.segments.get(name)
            if data is None or zlib.crc32(data) != crc:
                bad.append(f"{prog.name}:{name}")
    return bad


def restore_network(net, golden: GoldenImage,
                    layers: Optional[List[int]] = None) -> int:
    """Re-stage immutable segments from the golden snapshot and re-decode
    each restored layer's instruction stream from the golden ``insn``
    bytes (field-level corruption lives in the decoded objects, so the
    bytes alone are not enough).  Returns the number of layers touched."""
    touched = 0
    ks = range(len(net.layers)) if layers is None else layers
    for k in ks:
        prog = net.layers[k].program
        for name, data in golden.segments[k].items():
            prog.segments[name] = data
            prog.segment_crcs[name] = golden.crcs[k][name]
        if "insn" in golden.segments[k]:
            prog.instructions = isa.decode_stream(golden.segments[k]["insn"])
            invalidate_plan(prog)
        touched += 1
    return touched


# ---------------------------------------------------------------------------
# Instruction-stream validation
# ---------------------------------------------------------------------------

def _reject(prog, constraint: str, msg: str) -> None:
    raise CompileError(msg, layer=prog.name, constraint=constraint)


def _regions_by_kind(prog) -> Dict[str, List[Tuple[int, int]]]:
    """kind -> [(start_byte, end_byte)] in image coordinates."""
    by_kind: Dict[str, List[Tuple[int, int]]] = {}
    off = prog.allocator.offset
    for region in prog.regions.values():
        start = region.phys_addr - off
        by_kind.setdefault(region.kind, []).append(
            (start, start + region.nbytes))
    return by_kind


def _contained(spans: List[Tuple[int, int]], start: int, end: int) -> bool:
    return any(start >= lo and end <= hi for lo, hi in spans)


def _decode_uop_words(raw: bytes) -> np.ndarray:
    words = np.frombuffer(raw, dtype="<u4").astype(np.int64)
    return np.stack([words & 0x7FF, (words >> 11) & 0x7FF,
                     (words >> 22) & 0x3FF], axis=1)


def _check_mem(prog, cfg, idx: int, m: isa.MemInsn, image_size: int,
               by_kind: Dict[str, List[Tuple[int, int]]],
               uop_model: np.ndarray) -> None:
    kind = {isa.MemId.UOP: "uop", isa.MemId.INP: "inp", isa.MemId.WGT: "wgt",
            isa.MemId.ACC: "acc", isa.MemId.OUT: "out"}[m.memory_type]
    is_load = m.opcode == isa.Opcode.LOAD
    verb = "load" if is_load else "store"
    if not is_load and m.memory_type != isa.MemId.OUT:
        _reject(prog, "store-memtype",
                f"insn {idx}: STORE {kind.upper()} — only STORE OUT is a "
                f"valid VTA instruction")
    cap = cfg.buffer_capacity(kind)
    if is_load:
        row_w = m.x_pad_0 + m.x_size + m.x_pad_1
        span = (m.y_pad_0 + m.y_size + m.y_pad_1) * row_w
    else:
        span = m.y_size * m.x_size
    if span and m.sram_base + span > cap:
        _reject(prog, f"{verb}-sram-bounds",
                f"insn {idx}: {verb.upper()} {kind.upper()} SRAM span "
                f"[{m.sram_base}, {m.sram_base + span}) exceeds capacity "
                f"{cap}")
    if span > MAX_INSN_FOOTPRINT:
        _reject(prog, "lattice-footprint",
                f"insn {idx}: {verb.upper()} moves {span} structures")
    if m.y_size and m.x_size:
        nbytes = cfg.elem_bytes(kind)
        start = m.dram_base * nbytes
        end = (m.dram_base + (m.y_size - 1) * m.x_stride + m.x_size) * nbytes
        if end > image_size or start < 0:
            _reject(prog, f"{verb}-dram-bounds",
                    f"insn {idx}: {verb.upper()} {kind.upper()} DRAM span "
                    f"[{start}, {end}) exceeds image of {image_size} bytes")
        if not _contained(by_kind.get(kind, []), start, end):
            _reject(prog, f"{verb}-region-containment",
                    f"insn {idx}: {verb.upper()} {kind.upper()} DRAM span "
                    f"[{start}, {end}) strays outside the program's "
                    f"{kind.upper()} regions")
        if is_load and m.memory_type == isa.MemId.UOP:
            # advance the symbolic UOP-buffer model from the segment bytes
            raw = prog.segments.get("uop", b"")
            region = prog.regions["uop"]
            base = (region.phys_addr - prog.allocator.offset) // nbytes
            row_w_l = m.x_pad_0 + m.x_size + m.x_pad_1
            for y in range(m.y_size):
                lo = (m.dram_base + y * m.x_stride - base) * nbytes
                rows = _decode_uop_words(raw[lo:lo + m.x_size * nbytes])
                dst = (m.sram_base + (m.y_pad_0 + y) * row_w_l + m.x_pad_0)
                uop_model[dst:dst + len(rows)] = rows


def _check_tensor(prog, cfg, idx: int, t, uop_model: np.ndarray) -> None:
    is_alu = isinstance(t, isa.AluInsn)
    what = "ALU" if is_alu else "GEMM"
    if t.uop_end > uop_model.shape[0]:
        _reject(prog, "uop-range",
                f"insn {idx}: {what} uop range [{t.uop_bgn}, {t.uop_end}) "
                f"exceeds UOP buffer capacity {uop_model.shape[0]}")
    n_uop = max(0, t.uop_end - t.uop_bgn)
    lattice = t.iter_out * t.iter_in * n_uop
    if lattice > MAX_INSN_FOOTPRINT:
        _reject(prog, "lattice-footprint",
                f"insn {idx}: {what} lattice of {lattice} points exceeds "
                f"the static ceiling {MAX_INSN_FOOTPRINT}")
    if n_uop == 0 or t.iter_out <= 0 or t.iter_in <= 0:
        return
    uops = uop_model[t.uop_bgn:t.uop_end]
    acc_cap = cfg.acc_buff_vectors

    def _max_idx(f_out: int, f_in: int, col: int) -> int:
        return ((t.iter_out - 1) * f_out + (t.iter_in - 1) * f_in
                + int(uops[:, col].max()))

    if is_alu:
        hi = _max_idx(t.dst_factor_out, t.dst_factor_in, 0)
        if hi >= acc_cap:
            _reject(prog, "alu-acc-dst-bounds",
                    f"insn {idx}: ALU ACC dst index {hi} >= capacity "
                    f"{acc_cap}")
        if not t.use_imm:
            hi = _max_idx(t.src_factor_out, t.src_factor_in, 1)
            if hi >= acc_cap:
                _reject(prog, "alu-acc-src-bounds",
                        f"insn {idx}: ALU ACC src index {hi} >= capacity "
                        f"{acc_cap}")
        return
    hi = _max_idx(t.acc_factor_out, t.acc_factor_in, 0)
    if hi >= acc_cap:
        _reject(prog, "gemm-acc-bounds",
                f"insn {idx}: GEMM ACC index {hi} >= capacity {acc_cap}")
    if not t.reset:
        hi = _max_idx(t.inp_factor_out, t.inp_factor_in, 1)
        if hi >= cfg.inp_buff_vectors:
            _reject(prog, "gemm-inp-bounds",
                    f"insn {idx}: GEMM INP index {hi} >= capacity "
                    f"{cfg.inp_buff_vectors}")
        hi = _max_idx(t.wgt_factor_out, t.wgt_factor_in, 2)
        if hi >= cfg.wgt_buff_matrices:
            _reject(prog, "gemm-wgt-bounds",
                    f"insn {idx}: GEMM WGT index {hi} >= capacity "
                    f"{cfg.wgt_buff_matrices}")


def validate_program(prog) -> None:
    """Pre-execution instruction-stream validation.

    Raises a typed :class:`CompileError` (machine-greppable ``constraint``
    ids) on the first violation; returning means the stream round-trips
    to its segment bytes, stays inside every SRAM/DRAM bound of the
    :class:`VTAConfig`, keeps its loop footprint under the static
    ceiling, terminates with FINISH, and balances its §2.3 dependency
    tokens."""
    cfg = prog.config
    insns = prog.instructions
    # 1. decode→re-encode round-trip against the fetched bytes: catches
    #    any field-level divergence between host objects and device bytes.
    #    This check always runs — it is the only detector for mutations
    #    of the decoded objects themselves.
    seg = prog.segments.get("insn")
    if seg is not None:
        try:
            encoded = isa.encode_stream(insns)
        except (ValueError, TypeError) as e:
            _reject(prog, "insn-roundtrip",
                    f"instruction stream does not re-encode: {e}")
        if encoded != seg:
            _reject(prog, "insn-roundtrip",
                    "re-encoded instruction stream differs from the insn "
                    "segment bytes")
        # The static checks below depend only on the insn/uop byte content,
        # and the round-trip just proved the stream matches ``seg`` — both
        # are immutable bytes objects that restore_network re-installs *by
        # reference*.  Identity-match means the checks would repeat
        # verbatim: skip them (the round-trip above still ran).
        cached = getattr(prog, "_harden_validated_segs", None)
        if (cached is not None and cached[0] is seg
                and cached[1] is prog.segments.get("uop")):
            return
    # 2. termination
    if not insns or not isinstance(insns[-1], isa.FinishInsn):
        _reject(prog, "finish-missing",
                "instruction stream does not end with FINISH")
    # 3. per-instruction static checks with a symbolic UOP-buffer model
    image_size = prog.allocator.image_size()
    by_kind = _regions_by_kind(prog)
    uop_model = np.zeros((cfg.uop_buff_entries, 3), dtype=np.int64)
    for idx, insn in enumerate(insns):
        if isinstance(insn, isa.MemInsn):
            _check_mem(prog, cfg, idx, insn, image_size, by_kind, uop_model)
        elif isinstance(insn, (isa.GemInsn, isa.AluInsn)):
            _check_tensor(prog, cfg, idx, insn, uop_model)
    # 4. §2.3 dependency-token balance (a corrupted dep flag deadlocks
    #    real hardware; here the static queue simulation catches it)
    tokens = TokenQueues()
    try:
        for insn in insns:
            tokens.pre(insn)
            tokens.post(insn)
            if isinstance(insn, isa.FinishInsn):
                break
    except VTAHazardError as e:
        _reject(prog, "dep-token-hazard", str(e))
    # 5. concurrent-hazard check (DESIGN.md §Pipeline): on the real
    #    three-module machine a *relaxed* token stream may be perfectly
    #    balanced yet leave two modules racing on an SRAM range — verify
    #    every conflicting access pair is ordered by the happens-before
    #    relation the tokens imply.
    try:
        pipeline_schedule.check_program_hazards(prog)
    except VTAHazardError as e:
        _reject(prog, "dep-token-hazard", str(e))
    if seg is not None:
        prog._harden_validated_segs = (seg, prog.segments.get("uop"))


def validate_network(net) -> List[str]:
    """Validate every layer; returns the error strings (empty = clean)."""
    errors: List[str] = []
    for layer in net.layers:
        try:
            validate_program(layer.program)
        except CompileError as e:
            errors.append(str(e))
    return errors


# ---------------------------------------------------------------------------
# Guarded serving
# ---------------------------------------------------------------------------

def _wrap_hook(fault_hook: Optional[Callable],
               watchdog: Optional[Watchdog]) -> Optional[Callable]:
    """Compose the user/injection hook with the watchdog deadline check —
    one hook slot serves both (checked at every instruction boundary)."""
    if watchdog is None:
        return fault_hook

    def hook(sim, layer_idx: int, insn_idx: int) -> None:
        watchdog.check()
        if fault_hook is not None:
            fault_hook(sim, layer_idx, insn_idx)

    return hook


_SERVE_FAULTS = (VTAHazardError, CompileError, WatchdogTimeout,
                 ValueError, IndexError)


def _precheck(net, golden: GoldenImage, policy: GuardPolicy,
              report: GuardReport) -> bool:
    """Pre-serve CRC + validation with restore on detection.  Returns
    False when the network could not be brought to a valid state."""
    if policy.verify_crc:
        bad = verify_network(net, golden)
        if bad:
            report.crc_failures.extend(bad)
            report.restored_layers += restore_network(net, golden)
    if policy.validate_instructions:
        errors = validate_network(net)
        if errors:
            report.validation_errors.extend(errors)
            report.restored_layers += restore_network(net, golden)
            if validate_network(net):
                return False       # golden image itself does not validate
    return True


def _finish(report: GuardReport, sim_reports=None) -> None:
    if sim_reports:
        report.acc_overflow_lanes = sum(r.acc_overflow_lanes
                                        for r in sim_reports)
        report.acc_saturation_lanes = sum(r.acc_saturation_lanes
                                          for r in sim_reports)
    report.outcome = "clean" if report.detections == 0 else "recovered"


def guarded_serve_one(net, image, policy: GuardPolicy, *,
                      backend: str = "fast", fault_hook=None
                      ) -> Tuple[Optional[np.ndarray], GuardReport]:
    """One request through the full guard stack; returns
    ``(output, GuardReport)`` with ``output=None`` on unrecoverable
    corruption — never a silently wrong result."""
    golden = golden_of(net)
    report = GuardReport()
    watchdog = Watchdog(policy.deadline_s) if policy.deadline_s else None
    try:
        for attempt in range(policy.max_retries + 1):
            report.retries = attempt
            if not _precheck(net, golden, policy, report):
                break
            hook = _wrap_hook(fault_hook, watchdog)
            try:
                if watchdog:
                    watchdog.arm()
                out = net.serve_one(image, backend=backend, fault_hook=hook,
                                    count_overflows=policy.count_overflows)
            except WatchdogTimeout as e:
                report.watchdog_tripped = True
                report.runtime_errors.append(str(e))
                report.restored_layers += restore_network(net, golden)
                continue
            except _SERVE_FAULTS as e:
                report.runtime_errors.append(f"{type(e).__name__}: {e}")
                report.restored_layers += restore_network(net, golden)
                continue
            finally:
                if watchdog:
                    watchdog.disarm()
            if policy.verify_crc:
                bad = verify_network(net, golden)
                if bad:
                    report.crc_failures.extend(bad)
                    report.restored_layers += restore_network(net, golden)
                    continue
            if policy.dual_execute:
                # clean shadow run (no injection hook): a transient that
                # corrupted the primary in flight cannot repeat, so any
                # bitwise divergence is a detection
                shadow = net.serve_one(image, backend=policy.dual_backend)
                if not np.array_equal(out, shadow):
                    report.dual_mismatches += 1
                    report.restored_layers += restore_network(net, golden)
                    continue
            _finish(report)
            return out, report
        report.outcome = "failed"
        return None, report
    finally:
        if watchdog:
            watchdog.stop()


def guarded_serve(net, images, policy: GuardPolicy, *, fault_hook=None):
    """Batched guarded serving: ``(outputs, sim_reports, guard_reports)``
    with one :class:`GuardReport` per request.  CRC/validation detections
    are batch-level (one program image serves every request); the
    dual-execution bit-compare is per request."""
    golden = golden_of(net)
    batch_report = GuardReport()
    watchdog = Watchdog(policy.deadline_s) if policy.deadline_s else None
    try:
        for attempt in range(policy.max_retries + 1):
            batch_report.retries = attempt
            if not _precheck(net, golden, policy, batch_report):
                break
            hook = _wrap_hook(fault_hook, watchdog)
            try:
                if watchdog:
                    watchdog.arm()
                outs, sim_reports = net.serve(
                    images, fault_hook=hook,
                    count_overflows=policy.count_overflows)
            except WatchdogTimeout as e:
                batch_report.watchdog_tripped = True
                batch_report.runtime_errors.append(str(e))
                batch_report.restored_layers += restore_network(net, golden)
                continue
            except _SERVE_FAULTS as e:
                batch_report.runtime_errors.append(
                    f"{type(e).__name__}: {e}")
                batch_report.restored_layers += restore_network(net, golden)
                continue
            finally:
                if watchdog:
                    watchdog.disarm()
            if policy.verify_crc:
                bad = verify_network(net, golden)
                if bad:
                    batch_report.crc_failures.extend(bad)
                    batch_report.restored_layers += restore_network(net,
                                                                    golden)
                    continue
            mism: List[int] = []
            if policy.dual_execute:
                shadow, _ = net.serve(images)
                mism = [i for i in range(len(outs))
                        if not np.array_equal(outs[i], shadow[i])]
                if mism:
                    batch_report.dual_mismatches += len(mism)
                    batch_report.restored_layers += restore_network(net,
                                                                    golden)
                    continue
            _finish(batch_report, sim_reports)
            reports = [dataclasses.replace(batch_report) for _ in outs]
            return outs, sim_reports, reports
        batch_report.outcome = "failed"
        n = len(net._as_image_list(images))
        return None, [], [dataclasses.replace(batch_report)
                          for _ in range(n)]
    finally:
        if watchdog:
            watchdog.stop()


__all__ = ["IMMUTABLE_SEGMENTS", "MAX_INSN_FOOTPRINT", "GoldenImage",
           "GuardPolicy", "GuardReport", "Watchdog", "WatchdogTimeout",
           "capture_golden", "golden_of", "guarded_serve",
           "guarded_serve_one", "restore_network", "validate_network",
           "validate_program", "verify_network"]
