"""Fault injection + runtime integrity guards (DESIGN.md §Hardening).

The source paper deploys the VTA in safety-critical aeronautics under
certification constraints; this subsystem supplies the robustness layer
such a deployment demands:

* :mod:`repro.harden.faults` — a seeded, deterministic
  :class:`FaultInjector` that corrupts DRAM segments, SRAM scratchpads
  mid-run and encoded instruction words, through the ``fault_hook``
  injection points threaded into every simulator backend.
* :mod:`repro.harden.guards` — CRC32 verification of immutable DRAM
  segments against the reference captured at ``VTAProgram.finalize()``,
  a pre-execution instruction-stream validator (decode→re-encode
  round-trip + static bounds/hazard checks), a per-serve watchdog
  deadline, and the :class:`GuardPolicy`-driven restore-and-retry
  recovery used by ``NetworkProgram.serve``/``serve_one``.

``benchmarks/fault_campaign.py`` runs the seeded campaign that measures
detection coverage (detected / masked / silent-data-corruption) per fault
class; EXPERIMENTS.md §Faults holds the results.
"""

from .faults import FAULT_CLASSES, FaultInjector, FaultSpec
from .guards import (GoldenImage, GuardPolicy, GuardReport, Watchdog,
                     WatchdogTimeout, capture_golden, guarded_serve,
                     guarded_serve_one, restore_network, validate_network,
                     validate_program, verify_network)

__all__ = [
    "FAULT_CLASSES", "FaultInjector", "FaultSpec",
    "GoldenImage", "GuardPolicy", "GuardReport", "Watchdog",
    "WatchdogTimeout", "capture_golden", "guarded_serve",
    "guarded_serve_one", "restore_network", "validate_network",
    "validate_program", "verify_network",
]
