"""Seeded, deterministic fault injection for the VTA stack.

Models single-event upsets (SEUs) at the three places the hardware holds
state (DESIGN.md §Hardening):

* **DRAM segments** (``dram-wgt`` / ``dram-uop`` / ``dram-bias``) — one bit
  flipped in a program's immutable weight/uop/bias(ACC) segment bytes.
  The flip bypasses ``VTAProgram.set_segment`` on purpose: ``set_segment``
  models an *authorised* host write (and refreshes the finalize-time CRC),
  whereas an SEU corrupts the bytes underneath the host's reference.
* **Instruction words** (``insn-bits`` / ``insn-field``) — ``insn-bits``
  flips a bit of the encoded 128-bit stream (what the device fetches);
  :meth:`FaultInjector.materialize` then re-decodes the corrupted bytes
  into the executable stream the simulators run, which may itself raise
  (an undecodable opcode is a loud fault).  ``insn-field`` mutates a field
  of an already-decoded instruction object — the segment bytes stay
  intact, so CRC passes and only the guards' decode→re-encode round-trip
  can catch it.
* **SRAM scratchpads** (``sram``) — a transient one-shot bit flip in a
  live simulator buffer at a chosen (layer, instruction) point, delivered
  through the ``fault_hook(sim, layer_idx, insn_idx)`` injection points of
  ``NetworkProgram.serve``/``serve_one``.  Because the hook fires once,
  a guarded retry models the transient correctly: the re-execution is
  clean.

Everything is driven by one ``numpy`` Generator seeded at construction,
so a campaign (benchmarks/fault_campaign.py) is exactly reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core import isa
from repro.core.fast_simulator import invalidate_plan

#: fault class -> corrupted DRAM segment (canonical key)
DRAM_CLASSES = {"dram-wgt": "wgt", "dram-uop": "uop", "dram-bias": "acc"}

FAULT_CLASSES = ("dram-wgt", "dram-uop", "dram-bias",
                 "insn-bits", "insn-field", "sram")

#: SRAM buffers a transient flip can land in
SRAM_BUFFERS = ("uop", "inp", "wgt", "acc", "out")

# Mutable integer fields per instruction kind, with their encoded widths
# (isa.py W0/W1 layouts) — the universe the ``insn-field`` class samples.
_INT_FIELDS = {
    isa.MemInsn: [("sram_base", 16), ("dram_base", 32), ("y_size", 16),
                  ("x_size", 16), ("x_stride", 16), ("y_pad_0", 4),
                  ("y_pad_1", 4), ("x_pad_0", 4), ("x_pad_1", 4)],
    isa.GemInsn: [("reset", 1), ("uop_bgn", 13), ("uop_end", 14),
                  ("iter_out", 14), ("iter_in", 14),
                  ("acc_factor_out", 11), ("acc_factor_in", 11),
                  ("inp_factor_out", 11), ("inp_factor_in", 11),
                  ("wgt_factor_out", 10), ("wgt_factor_in", 10)],
    isa.AluInsn: [("reset", 1), ("uop_bgn", 13), ("uop_end", 14),
                  ("iter_out", 14), ("iter_in", 14),
                  ("dst_factor_out", 11), ("dst_factor_in", 11),
                  ("src_factor_out", 11), ("src_factor_in", 11),
                  ("use_imm", 1), ("imm", 16)],
    isa.FinishInsn: [],
}

_DEP_FIELDS = ("pop_prev", "pop_next", "push_prev", "push_next")


@dataclasses.dataclass
class FaultSpec:
    """One planned injection — enough to apply it and to log the campaign.

    ``layer`` indexes ``net.layers``; the remaining fields are class-
    specific: ``target`` is a segment name (dram-*), SRAM buffer name
    (sram) or field name (insn-field); ``offset`` a byte/element offset;
    ``bit`` the flipped bit; ``insn_idx`` the instruction (insn-field);
    ``at_insn`` the firing point of a transient sram hook; ``value`` the
    mutated field value (insn-field)."""

    fault_class: str
    layer: int
    target: str = ""
    offset: int = 0
    bit: int = 0
    insn_idx: int = 0
    at_insn: int = 0
    value: int = 0

    def describe(self) -> str:
        if self.fault_class in DRAM_CLASSES:
            return (f"{self.fault_class}: layer {self.layer} segment "
                    f"{self.target!r} byte {self.offset} bit {self.bit}")
        if self.fault_class == "insn-bits":
            return (f"insn-bits: layer {self.layer} insn byte "
                    f"{self.offset} bit {self.bit}")
        if self.fault_class == "insn-field":
            return (f"insn-field: layer {self.layer} insn "
                    f"{self.insn_idx} field {self.target}={self.value}")
        return (f"sram: layer {self.layer} buf {self.target!r} elem "
                f"{self.offset} bit {self.bit} at insn {self.at_insn}")


def _flip_sram(sim, buffer: str, offset: int, bit: int) -> None:
    """Flip one bit of an SRAM buffer element, batched or not.

    UOP entries live as unpacked (acc, inp, wgt) triples in the simulator
    but are a packed 32-bit word in hardware, so the flip is applied to
    the packed form and unpacked back — a flip can therefore carry a
    field across its boundary exactly as on the device."""
    buf = getattr(sim, f"{buffer}_buf")
    if buffer == "uop":
        flat = buf.reshape(-1, 3)
        row = flat[offset % flat.shape[0]]
        word = (int(row[0]) | (int(row[1]) << 11) | (int(row[2]) << 22))
        word ^= 1 << (bit % 32)
        row[0] = word & 0x7FF
        row[1] = (word >> 11) & 0x7FF
        row[2] = (word >> 22) & 0x3FF
        return
    flat = buf.reshape(-1)
    i = offset % flat.size
    width = flat.dtype.itemsize * 8
    mask = np.int64(1) << np.int64(bit % width)
    flat[i] = (np.int64(flat[i]) ^ mask).astype(flat.dtype)


class FaultInjector:
    """Plans and applies seeded faults against a ``NetworkProgram``."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    # ------------------------------------------------------------ plan --
    def _pick_layer(self, net, *, needs_segment: Optional[str] = None) -> int:
        candidates = [k for k, layer in enumerate(net.layers)
                      if needs_segment is None
                      or len(layer.program.segments.get(needs_segment, b""))]
        if not candidates:
            raise ValueError(f"no layer has segment {needs_segment!r}")
        return int(candidates[self.rng.integers(len(candidates))])

    def plan(self, net, fault_class: str) -> FaultSpec:
        """Draw one deterministic injection for ``fault_class``."""
        rng = self.rng
        if fault_class in DRAM_CLASSES:
            seg = DRAM_CLASSES[fault_class]
            k = self._pick_layer(net, needs_segment=seg)
            data = net.layers[k].program.segments[seg]
            return FaultSpec(fault_class=fault_class, layer=k, target=seg,
                             offset=int(rng.integers(len(data))),
                             bit=int(rng.integers(8)))
        if fault_class == "insn-bits":
            k = self._pick_layer(net, needs_segment="insn")
            data = net.layers[k].program.segments["insn"]
            return FaultSpec(fault_class="insn-bits", layer=k, target="insn",
                             offset=int(rng.integers(len(data))),
                             bit=int(rng.integers(8)))
        if fault_class == "insn-field":
            k = self._pick_layer(net)
            insns = net.layers[k].program.instructions
            # sample an instruction that has at least one mutable field
            for _ in range(64):
                idx = int(rng.integers(len(insns)))
                insn = insns[idx]
                fields = _INT_FIELDS[type(insn)]
                pool = [(name, width) for name, width in fields]
                pool += [(f"dep.{d}", 1) for d in _DEP_FIELDS]
                name, width = pool[int(rng.integers(len(pool)))]
                old = self._get_field(insn, name)
                value = self._mutate_value(rng, old, width,
                                           signed=(name == "imm"))
                if value != old:
                    return FaultSpec(fault_class="insn-field", layer=k,
                                     target=name, insn_idx=idx, value=value)
            raise RuntimeError("could not draw a field mutation")
        if fault_class == "sram":
            k = self._pick_layer(net)
            prog = net.layers[k].program
            buffer = SRAM_BUFFERS[int(rng.integers(len(SRAM_BUFFERS)))]
            # flip within the layer's *live* SRAM footprint — the default
            # buffers are far larger than what one layer touches, so a
            # uniform draw over full capacity would land in dead SRAM
            # nearly every time and measure nothing
            size = self._live_extent(prog, net.config).get(buffer, 0)
            if size == 0:       # layer never touches this scratchpad
                size = 1        # flip element 0: still a valid (dead) upset
            width = 32 if buffer in ("uop", "acc") else 8
            return FaultSpec(fault_class="sram", layer=k, target=buffer,
                             offset=int(rng.integers(size)),
                             bit=int(rng.integers(width)),
                             at_insn=int(rng.integers(
                                 len(prog.instructions))))
        raise ValueError(f"unknown fault class {fault_class!r}; "
                         f"expected one of {FAULT_CLASSES}")

    @staticmethod
    def _live_extent(prog, cfg) -> dict:
        """Max flip-unit index each scratchpad reaches in this layer
        (uop: entries; acc: int32 lanes; inp/wgt/out: bytes) — the live
        footprint a transient upset can actually perturb."""
        mul = {"uop": 1, "inp": cfg.block_size,
               "wgt": cfg.block_size ** 2, "acc": cfg.block_size,
               "out": cfg.block_size}
        names = {isa.MemId.UOP: "uop", isa.MemId.INP: "inp",
                 isa.MemId.WGT: "wgt", isa.MemId.ACC: "acc",
                 isa.MemId.OUT: "out"}
        extent: dict = {}
        for insn in prog.instructions:
            if not isinstance(insn, isa.MemInsn):
                continue
            name = names[insn.memory_type]
            if insn.opcode == isa.Opcode.LOAD:
                span = ((insn.y_pad_0 + insn.y_size + insn.y_pad_1)
                        * (insn.x_pad_0 + insn.x_size + insn.x_pad_1))
            else:
                span = insn.y_size * insn.x_size
            end = (insn.sram_base + span) * mul[name]
            extent[name] = max(extent.get(name, 0), end)
        # GEMM/ALU write ACC/OUT banks the MemInsns may not cover (e.g.
        # a store reads only part of what the lattice produced); the ACC
        # load extent is the dominant bound in every compiled program,
        # so the MemInsn scan is a sound, simple proxy.
        return extent

    @staticmethod
    def _get_field(insn, name: str) -> int:
        if name.startswith("dep."):
            return int(getattr(insn.dep, name[4:]))
        return int(getattr(insn, name))

    @staticmethod
    def _set_field(insn, name: str, value: int) -> None:
        if name.startswith("dep."):
            setattr(insn.dep, name[4:], value)
        else:
            setattr(insn, name, value)

    @staticmethod
    def _mutate_value(rng, old: int, width: int, *,
                      signed: bool = False) -> int:
        if width == 1:
            return 1 - old
        # flip one encoded bit of the field — a minimal, in-width upset
        value = (old & ((1 << width) - 1)) ^ (1 << int(rng.integers(width)))
        if signed and value >= 1 << (width - 1):
            value -= 1 << width       # AluInsn.imm is signed 16-bit
        return value

    # ----------------------------------------------------------- apply --
    def apply(self, net, spec: FaultSpec) -> None:
        """Mutate program state per ``spec`` (sram specs use
        :meth:`hook_for` instead — they fire mid-run)."""
        prog = net.layers[spec.layer].program
        if spec.fault_class in DRAM_CLASSES or spec.fault_class == "insn-bits":
            seg = spec.target
            data = bytearray(prog.segments[seg])
            data[spec.offset] ^= 1 << spec.bit
            prog.segments[seg] = bytes(data)   # SEU: bypasses set_segment
        elif spec.fault_class == "insn-field":
            self._set_field(prog.instructions[spec.insn_idx], spec.target,
                            spec.value)
            invalidate_plan(prog)
        elif spec.fault_class == "sram":
            pass                               # delivered via hook_for
        else:
            raise ValueError(spec.fault_class)

    def materialize(self, net, spec: FaultSpec) -> None:
        """Model the device *fetching* a corrupted instruction segment:
        re-decode the (possibly flipped) bytes into the executable stream.
        Raises ``ValueError`` when the corrupted bytes are undecodable —
        a loud fault on its own."""
        if spec.fault_class != "insn-bits":
            return
        prog = net.layers[spec.layer].program
        prog.instructions = isa.decode_stream(prog.segments["insn"])
        invalidate_plan(prog)

    def hook_for(self, spec: FaultSpec) -> Optional[Callable]:
        """A one-shot network-level ``hook(sim, layer_idx, insn_idx)``
        delivering a transient SRAM flip; None for non-sram classes."""
        if spec.fault_class != "sram":
            return None
        state = {"fired": False}

        def hook(sim, layer_idx: int, insn_idx: int) -> None:
            if (state["fired"] or layer_idx != spec.layer
                    or insn_idx != spec.at_insn):
                return
            state["fired"] = True
            _flip_sram(sim, spec.target, spec.offset, spec.bit)

        return hook

    def inject(self, net, fault_class: str
               ) -> Tuple[FaultSpec, Optional[Callable]]:
        """Plan + apply in one call; returns ``(spec, hook)`` where the
        hook is non-None only for the transient ``sram`` class."""
        spec = self.plan(net, fault_class)
        self.apply(net, spec)
        return spec, self.hook_for(spec)


def estimate_footprint(instructions) -> int:
    """Worst-case per-instruction work estimate (lattice points / moved
    elements) from the *fields alone* — no allocation.  The unguarded
    campaign arm uses it to classify corrupted programs whose geometry
    explodes (a 2^28-point lattice) as hangs/resource exhaustion instead
    of executing them; the guards reject the same programs statically
    (constraint ``lattice-footprint``)."""
    worst = 0
    for insn in instructions:
        if isinstance(insn, isa.MemInsn):
            rows = insn.y_pad_0 + insn.y_size + insn.y_pad_1
            row_w = insn.x_pad_0 + insn.x_size + insn.x_pad_1
            worst = max(worst, rows * row_w)
        elif isinstance(insn, (isa.GemInsn, isa.AluInsn)):
            n_uop = max(0, insn.uop_end - insn.uop_bgn)
            worst = max(worst, insn.iter_out * insn.iter_in * n_uop)
    return worst


__all__ = ["DRAM_CLASSES", "FAULT_CLASSES", "SRAM_BUFFERS", "FaultInjector",
           "FaultSpec", "estimate_footprint"]
