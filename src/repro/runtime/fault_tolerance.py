"""Fault-tolerant training runtime.

``resilient_train_loop`` is the driver a cluster scheduler would invoke on
every (re)start of a job:

  1. restore the latest complete checkpoint (possibly onto a *different*
     device count — elastic re-mesh: shardings are re-derived from the
     logical spec tree against whatever mesh exists now);
  2. run steps, checkpointing every ``ckpt_every``;
  3. on a step failure (device loss manifests as an exception), retry from
     the last checkpoint up to ``max_restarts`` times — the deterministic
     data pipeline regenerates the exact same batches;
  4. a watchdog thread enforces a per-step deadline: a hung collective
     (the classic multi-pod failure mode) trips it and the loop restarts
     rather than hanging the job forever.

``FailureInjector`` deterministically raises at chosen steps — the tests
use it to prove loss trajectories are bit-identical with and without
failures (checkpoint → restart → replay is exact).

Straggler mitigation: per-step wall times feed an EWMA; steps slower than
``straggler_factor ×`` the EWMA are counted and reported so an external
scheduler can rotate the slow host out.  (In-process we can only observe;
the *mitigation* — preemptive re-scheduling — is the scheduler's move, and
our restart path is what makes that move cheap.)
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from repro.checkpoint.checkpointer import Checkpointer


class StepTimeout(RuntimeError):
    pass


class FailureInjector:
    """Raises RuntimeError at the given global steps.  Repeating a step in
    ``fail_at`` fails it that many times (a deterministic 'hard' failure
    that exhausts the restart budget)."""

    def __init__(self, fail_at: List[int]):
        from collections import Counter
        self.pending = Counter(fail_at)

    def check(self, step: int) -> None:
        if self.pending.get(step, 0) > 0:
            self.pending[step] -= 1
            raise RuntimeError(f"injected failure at step {step}")


class Watchdog:
    """Per-step deadline enforcement in a daemon thread."""

    def __init__(self, deadline_s: float):
        self.deadline = deadline_s
        self._armed_at: Optional[float] = None
        self._tripped = threading.Event()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self) -> None:
        while not self._stop.wait(min(0.05, self.deadline / 4)):
            armed = self._armed_at
            if armed is not None and time.monotonic() - armed > self.deadline:
                self._tripped.set()

    def arm(self) -> None:
        self._tripped.clear()
        self._armed_at = time.monotonic()

    def disarm(self) -> None:
        self._armed_at = None

    def check(self) -> None:
        if self._tripped.is_set():
            raise StepTimeout("step exceeded watchdog deadline")

    def stop(self) -> None:
        self._stop.set()
        self._thread.join()


@dataclasses.dataclass
class StragglerStats:
    ewma_s: float = 0.0
    slow_steps: int = 0
    total_steps: int = 0

    def update(self, dt: float, factor: float = 3.0) -> bool:
        self.total_steps += 1
        if self.ewma_s == 0.0:
            self.ewma_s = dt
            return False
        slow = dt > factor * self.ewma_s
        if slow:
            self.slow_steps += 1
        # slow steps pollute the EWMA less
        alpha = 0.05 if slow else 0.2
        self.ewma_s = (1 - alpha) * self.ewma_s + alpha * dt
        return slow


@dataclasses.dataclass
class LoopReport:
    final_step: int
    restarts: int
    metrics_history: List[Dict[str, float]]
    straggler: StragglerStats


def resilient_train_loop(
    *, state: Any,
    step_fn: Callable[[Any, int], Any],
    save_tree_fn: Callable[[Any], Any],
    restore_fn: Callable[[Checkpointer, int, Any], Any],
    checkpointer: Checkpointer,
    total_steps: int,
    ckpt_every: int = 50,
    max_restarts: int = 5,
    watchdog_deadline_s: Optional[float] = None,
    failure_injector: Optional[FailureInjector] = None,
    metrics_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
) -> LoopReport:
    """Run ``step_fn(state, step) → state`` with checkpoint/restart.

    ``save_tree_fn(state)`` extracts the checkpointable pytree;
    ``restore_fn(ckptr, step, state)`` rebuilds state from a checkpoint
    (this is where elastic re-meshing happens — the caller re-derives
    shardings for the current mesh)."""
    restarts = 0
    history: List[Dict[str, float]] = []
    straggler = StragglerStats()
    watchdog = Watchdog(watchdog_deadline_s) if watchdog_deadline_s else None

    start = checkpointer.latest_step()
    step = 0
    if start is not None:
        state = restore_fn(checkpointer, start, state)
        step = start

    try:
        while step < total_steps:
            try:
                t0 = time.monotonic()
                if watchdog:
                    watchdog.arm()
                if failure_injector:
                    failure_injector.check(step)
                state = step_fn(state, step)
                if watchdog:
                    watchdog.check()
                    watchdog.disarm()
                straggler.update(time.monotonic() - t0)
                step += 1
                if metrics_fn:
                    history.append(dict(metrics_fn(state), step=step))
                if step % ckpt_every == 0 or step == total_steps:
                    checkpointer.save_async(step, save_tree_fn(state))
            except (RuntimeError, StepTimeout) as e:
                restarts += 1
                if restarts > max_restarts:
                    raise RuntimeError(
                        f"exceeded {max_restarts} restarts") from e
                checkpointer.wait()
                last = checkpointer.latest_step()
                if last is None:
                    step = 0           # restart from scratch
                else:
                    state = restore_fn(checkpointer, last, state)
                    step = last
        checkpointer.wait()
    finally:
        if watchdog:
            watchdog.stop()
    return LoopReport(final_step=step, restarts=restarts,
                      metrics_history=history, straggler=straggler)
