"""Abstract inputs + shardings for every (arch × shape × mesh) cell.

Everything here is allocation-free: parameters, optimizer state, batches
and KV caches materialise as ``ShapeDtypeStruct`` trees, and the step
functions lower against them (``launch/dryrun.py``).  The same builders
feed the real training/serving drivers with concrete arrays.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import BIG_ARCHS, SHAPES, ShapeSpec, get_config
from repro.models.config import ModelConfig
from repro.models.params import abstract_params, logical_tree
from repro.models.transformer import model_defs
from repro.optim import adamw
from repro.parallel.sharding import logical_to_spec, spec_tree
from repro.serving.cache import CacheTree, cache_logical_tree, init_cache
from repro.train.train_step import TrainConfig, make_train_step


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, n: int) -> Tuple[str, ...]:
    """Largest prefix of (pod, data) that evenly divides n."""
    out = []
    size = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and n % (size * mesh.shape[a]) == 0:
            out.append(a)
            size *= mesh.shape[a]
    return tuple(out)


def batch_spec(mesh: Mesh, n: int, extra_dims: int = 1) -> P:
    axes = batch_axes(mesh, n)
    lead = axes if len(axes) > 1 else (axes[0] if axes else None)
    return P(lead, *([None] * extra_dims)) if extra_dims else P(lead)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_named(mesh: Mesh, specs) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# parameters + optimizer
# ---------------------------------------------------------------------------

def param_pack(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16):
    defs = model_defs(cfg)
    abstract = abstract_params(defs, dtype)
    specs = spec_tree(logical_tree(defs), mesh)
    return defs, abstract, specs


def _moment_abstract(p: jax.ShapeDtypeStruct, eightbit: bool):
    if not eightbit:
        return jax.ShapeDtypeStruct(p.shape, jnp.float32)
    nb = adamw.scale_blocks(p.shape[-1])
    return adamw.Moment8(
        jax.ShapeDtypeStruct(p.shape, jnp.int8),
        jax.ShapeDtypeStruct(p.shape[:-1] + (nb,), jnp.float32))


def _sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop axes that no longer divide (scale tensors' shrunken last dim)."""
    fixed = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                          - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep, size = [], 1
        for a in axes:
            if dim % (size * mesh.shape[a]) == 0:
                keep.append(a)
                size *= mesh.shape[a]
        fixed.append(tuple(keep) if len(keep) > 1
                     else (keep[0] if keep else None))
    return P(*fixed)


def _moment_spec(param_spec: P, p: jax.ShapeDtypeStruct, eightbit: bool,
                 mesh: Mesh):
    """int8 moments are parameter-shaped → they inherit the parameter's
    sharding verbatim (zero resharding in the optimizer step; the earlier
    flat layout cost ~300 s/step of resharding collectives on the 340B
    config — EXPERIMENTS.md §Perf)."""
    if not eightbit:
        return param_spec
    nb = adamw.scale_blocks(p.shape[-1])
    return adamw.Moment8(
        param_spec, _sanitize_spec(param_spec, p.shape[:-1] + (nb,), mesh))


def opt_pack(abstract_p, param_specs, mesh: Mesh, eightbit: bool):
    mu = jax.tree.map(lambda p: _moment_abstract(p, eightbit), abstract_p)
    mu_s = jax.tree.map(
        lambda s, p: _moment_spec(s, p, eightbit, mesh),
        param_specs, abstract_p,
        is_leaf=lambda x: isinstance(x, P))
    state = adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32), mu=mu, nu=mu)
    specs = adamw.AdamWState(step=P(), mu=mu_s, nu=mu_s)
    return state, specs


# ---------------------------------------------------------------------------
# input_specs — the assignment's entry point
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, P]]:
    """Training-batch ShapeDtypeStructs + PartitionSpecs for one shape."""
    b, s = shape.global_batch, shape.seq_len
    s_tok = s - cfg.frontend_prefix
    batch: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((b, s_tok), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s_tok), jnp.int32),
    }
    specs: Dict[str, P] = {
        "tokens": batch_spec(mesh, b, 1),
        "labels": batch_spec(mesh, b, 1),
    }
    if cfg.frontend_prefix:
        batch["prefix_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_prefix, cfg.d_model), jnp.bfloat16)
        specs["prefix_embed"] = batch_spec(mesh, b, 2)
    if cfg.encoder_layers:
        batch["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        specs["frames"] = batch_spec(mesh, b, 2)
    return batch, specs


def default_train_config(arch_id: str, shape: ShapeSpec) -> TrainConfig:
    big = arch_id in BIG_ARCHS
    # micro4 over micro8: fewer per-µb weight all-gathers (§Perf iter 3);
    # SP-sharded residual carries keep the activation memory in budget
    micro = 4 if shape.global_batch >= 64 else 1
    return TrainConfig(
        microbatches=micro,
        grad_accum_dtype=jnp.bfloat16 if big else jnp.float32,
        opt=adamw.AdamWConfig(eightbit=big),
    )


# ---------------------------------------------------------------------------
# cache specs (decode / prefill)
# ---------------------------------------------------------------------------

def cache_pack(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int,
               dtype=jnp.bfloat16, *, seq_all: bool = False):
    """Abstract CacheTree + PartitionSpec tree.

    ``seq_all`` (long-context, batch=1): dense-KV sequence shards over
    *both* (data, model) — 512k tokens / 256 chips."""
    abstract = jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_seq, dtype))

    is_lg = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)

    def resolve(lg):
        spec = []
        for ax in lg:
            if ax == "batch":
                axes = batch_axes(mesh, batch)
                spec.append(axes if len(axes) > 1 else
                            (axes[0] if axes else None))
            elif ax == "seq":
                if seq_all:
                    axes = tuple(a for a in ("data", "model")
                                 if a in mesh.axis_names)
                    spec.append(axes if len(axes) > 1 else
                                (axes[0] if axes else None))
                else:
                    spec.append("model" if "model" in mesh.axis_names
                                else None)
            elif ax == "tp":
                spec.append("model" if "model" in mesh.axis_names else None)
            elif ax is None:
                spec.append(None)
            else:
                spec.append(None)
        return P(*spec)

    logical = cache_logical_tree(cfg)
    specs = jax.tree.map(resolve, logical, is_leaf=is_lg)

    # drop non-dividing axes (e.g. batch=1) leaf by leaf
    def sanitize(spec, leaf):
        fixed = []
        for dim, entry in zip(leaf.shape,
                              tuple(spec) + (None,) * (len(leaf.shape)
                                                       - len(spec))):
            if entry is None:
                fixed.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            keep, size = [], 1
            for a in axes:
                if dim % (size * mesh.shape[a]) == 0:
                    keep.append(a)
                    size *= mesh.shape[a]
            fixed.append(tuple(keep) if len(keep) > 1
                         else (keep[0] if keep else None))
        return P(*fixed)

    specs = jax.tree.map(sanitize, specs, abstract,
                         is_leaf=lambda x: isinstance(x, P))
    return abstract, specs


# ---------------------------------------------------------------------------
# lowerable step builders
# ---------------------------------------------------------------------------

def sharded_arg_bytes(abstract_tree, spec_tree_, mesh: Mesh) -> int:
    """Exact per-device bytes of the (sharded) arguments — authoritative
    where the CPU backend's memory_analysis is not."""
    total = 0
    specs = jax.tree.leaves(spec_tree_, is_leaf=lambda x: isinstance(x, P))
    leaves = jax.tree.leaves(abstract_tree)
    assert len(specs) == len(leaves), (len(specs), len(leaves))
    for leaf, spec in zip(leaves, specs):
        nbytes = int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
        div = 1
        for entry in tuple(spec):
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                div *= mesh.shape[a]
        total += -(-nbytes // max(1, div))
    return total


@dataclasses.dataclass
class Lowerable:
    """A jit'd step + the abstract args to lower it with."""
    fn: Any
    args: Tuple[Any, ...]
    arg_bytes_per_device: Optional[int] = None

    def lower(self):
        return self.fn.lower(*self.args)


def build_train(arch_id: str, shape_name: str, mesh: Mesh,
                cfg: Optional[ModelConfig] = None,
                train_cfg: Optional[TrainConfig] = None) -> Lowerable:
    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    train_cfg = train_cfg or default_train_config(arch_id, shape)
    _, abs_p, p_specs = param_pack(cfg, mesh)
    abs_opt, opt_specs = opt_pack(abs_p, p_specs, mesh, train_cfg.opt.eightbit)
    abs_batch, b_specs = input_specs(cfg, shape, mesh)

    step = make_train_step(cfg, train_cfg)
    fn = jax.jit(
        step,
        in_shardings=(tree_named(mesh, p_specs), tree_named(mesh, opt_specs),
                      tree_named(mesh, b_specs)),
        out_shardings=(tree_named(mesh, p_specs),
                       tree_named(mesh, opt_specs), None),
        donate_argnums=(0, 1))
    ab = sharded_arg_bytes((abs_p, abs_opt, abs_batch),
                           (p_specs, opt_specs, b_specs), mesh)
    return Lowerable(fn, (abs_p, abs_opt, abs_batch), ab)


def build_prefill(arch_id: str, shape_name: str, mesh: Mesh,
                  cfg: Optional[ModelConfig] = None) -> Lowerable:
    from repro.serving.engine import prefill
    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    s_tok = s - cfg.frontend_prefix
    seq_all = b == 1
    abs_cache, c_specs = cache_pack(cfg, mesh, b, s, seq_all=seq_all)
    _, abs_p, p_specs = param_pack(cfg, mesh)

    tokens = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
    t_spec = batch_spec(mesh, b, 1)
    kwargs_abs = {}
    kwargs_specs = {}
    if cfg.frontend_prefix:
        kwargs_abs["prefix_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_prefix, cfg.d_model), jnp.bfloat16)
        kwargs_specs["prefix_embed"] = batch_spec(mesh, b, 2)
    if cfg.encoder_layers:
        kwargs_abs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        kwargs_specs["frames"] = batch_spec(mesh, b, 2)

    def step(params, tokens, cache, kw):
        return prefill(params, cfg, tokens, cache, **kw)

    fn = jax.jit(
        step,
        in_shardings=(tree_named(mesh, p_specs), named(mesh, t_spec),
                      tree_named(mesh, c_specs),
                      tree_named(mesh, kwargs_specs)),
        donate_argnums=(2,))
    ab = sharded_arg_bytes((abs_p, tokens, abs_cache, kwargs_abs),
                           (p_specs, t_spec, c_specs, kwargs_specs), mesh)
    return Lowerable(fn, (abs_p, tokens, abs_cache, kwargs_abs), ab)


def build_decode(arch_id: str, shape_name: str, mesh: Mesh,
                 cfg: Optional[ModelConfig] = None) -> Lowerable:
    from repro.serving.engine import decode_step
    cfg = cfg or get_config(arch_id)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    seq_all = b == 1
    abs_cache, c_specs = cache_pack(cfg, mesh, b, s, seq_all=seq_all)
    _, abs_p, p_specs = param_pack(cfg, mesh)
    tokens = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.encoder_layers:
        # enc-dec decode attends over the (precomputed) encoder output
        enc_abs = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        enc_spec = batch_spec(mesh, b, 2)

        def step(params, cache, tokens, pos, enc_out):
            return decode_step(params, cfg, cache, tokens, pos,
                               enc_out=enc_out)

        fn = jax.jit(
            step,
            in_shardings=(tree_named(mesh, p_specs),
                          tree_named(mesh, c_specs),
                          named(mesh, batch_spec(mesh, b, 0)),
                          named(mesh, P()), named(mesh, enc_spec)),
            donate_argnums=(1,))
        ab = sharded_arg_bytes(
            (abs_p, abs_cache, tokens, pos, enc_abs),
            (p_specs, c_specs, batch_spec(mesh, b, 0), P(), enc_spec), mesh)
        return Lowerable(fn, (abs_p, abs_cache, tokens, pos, enc_abs), ab)

    def step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    fn = jax.jit(
        step,
        in_shardings=(tree_named(mesh, p_specs), tree_named(mesh, c_specs),
                      named(mesh, batch_spec(mesh, b, 0)), named(mesh, P())),
        donate_argnums=(1,))
    ab = sharded_arg_bytes(
        (abs_p, abs_cache, tokens, pos),
        (p_specs, c_specs, batch_spec(mesh, b, 0), P()), mesh)
    return Lowerable(fn, (abs_p, abs_cache, tokens, pos), ab)


def build_cell(arch_id: str, shape_name: str, mesh: Mesh, **kw) -> Lowerable:
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return build_train(arch_id, shape_name, mesh, **kw)
    if kind == "prefill":
        return build_prefill(arch_id, shape_name, mesh, **kw)
    return build_decode(arch_id, shape_name, mesh, **kw)
