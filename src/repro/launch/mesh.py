"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` before any jax initialisation and
only then builds meshes.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) for the
    512-chip two-pod deployment."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
