"""Serving driver: batched prefill + decode with continuous batching.

A minimal production-shaped server loop: requests queue up, get packed
into fixed-size batches, prefilled, then decoded step-by-step; finished
sequences free their slots for waiting requests (continuous batching).
On this container it drives the reduced configs (examples/serve_lm.py);
the same engine lowers for the production meshes in the dry-run.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.launch.mesh import make_smoke_mesh
from repro.models.params import init_params
from repro.models.transformer import model_defs
from repro.serving.cache import init_cache
from repro.serving.engine import decode_step, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-batch continuous-batching server over the serving engine."""

    def __init__(self, cfg, params, *, batch_size: int = 4,
                 max_seq: int = 512, dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.bs = batch_size
        self.max_seq = max_seq
        self.dtype = dtype
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * batch_size
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
        self.cache = None
        self.pos = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _fill_batch(self) -> List[Request]:
        batch = []
        while self.queue and len(batch) < self.bs:
            batch.append(self.queue.pop(0))
        return batch

    def run(self, *, max_steps: int = 1000) -> Dict[int, List[int]]:
        """Process the queue to completion (simple generational batching:
        each generation packs up to ``bs`` requests of equal prompt
        length — padding shorter prompts left)."""
        results: Dict[int, List[int]] = {}
        while self.queue:
            batch = self._fill_batch()
            n = len(batch)
            plen = max(len(r.prompt) for r in batch)
            toks = np.zeros((self.bs, plen), np.int32)
            for i, r in enumerate(batch):
                toks[i, plen - len(r.prompt):] = r.prompt   # left pad
            cache = init_cache(self.cfg, self.bs, self.max_seq, self.dtype)
            logits, cache = prefill(self.params, self.cfg,
                                    jnp.asarray(toks), cache)
            nxt = np.asarray(jnp.argmax(logits, -1))
            for i, r in enumerate(batch):
                r.out_tokens.append(int(nxt[i]))
            pos = plen
            live = list(range(n))
            steps = 0
            while live and steps < max_steps:
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray(nxt), jnp.int32(pos))
                nxt = np.asarray(jnp.argmax(logits, -1))
                pos += 1
                steps += 1
                for i in list(live):
                    r = batch[i]
                    r.out_tokens.append(int(nxt[i]))
                    if len(r.out_tokens) >= r.max_new:
                        r.done = True
                        results[r.rid] = r.out_tokens
                        live.remove(i)
            for r in batch:
                if not r.done:
                    results[r.rid] = r.out_tokens
        return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    mesh = make_smoke_mesh()
    with jax.set_mesh(mesh):
        params = init_params(model_defs(cfg), jax.random.PRNGKey(0),
                             jnp.float32)
        server = Server(cfg, params, batch_size=4, max_seq=128)
        rng = np.random.default_rng(0)
        t0 = time.time()
        for rid in range(args.requests):
            plen = int(rng.integers(4, 12))
            server.submit(Request(
                rid, rng.integers(0, cfg.vocab, plen).astype(np.int32),
                args.max_new))
        results = server.run()
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.1f}s ({total / dt:.1f} tok/s)")
    for rid in sorted(results):
        print(f"  req {rid}: {results[rid][:8]}...")


if __name__ == "__main__":
    main()
