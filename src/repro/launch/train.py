"""Training driver: config-driven, fault-tolerant, checkpointed.

Usage (real cluster: one process per host, same command everywhere):

  PYTHONPATH=src python -m repro.launch.train --arch lm100m --steps 300 \\
      --global-batch 32 --seq-len 256 --ckpt-dir /tmp/ckpt

On this CPU container it runs the reduced configs end-to-end (the
examples/ wrap it); on TPU the same driver scales to the production mesh
(--mesh pod|multipod).
"""

from __future__ import annotations

import argparse
import dataclasses
import functools
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import SHAPES, get_config, get_smoke
from repro.data.pipeline import DataConfig, make_global_batch
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.launch.specs import (batch_spec, default_train_config, opt_pack,
                                param_pack, tree_named)
from repro.models.params import init_params
from repro.optim import adamw
from repro.runtime.fault_tolerance import (FailureInjector, LoopReport,
                                           resilient_train_loop)
from repro.train.train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    metrics: Dict[str, float]


def build_trainer(cfg, mesh, train_cfg: TrainConfig, data_cfg: DataConfig,
                  seed: int = 0):
    """Returns (init_state_fn, jit_step, shardings) for the driver."""
    defs, abs_p, p_specs = param_pack(cfg, mesh, jnp.float32)
    p_shard = tree_named(mesh, p_specs)
    abs_opt, opt_specs = opt_pack(abs_p, p_specs, mesh,
                                  train_cfg.opt.eightbit)
    o_shard = tree_named(mesh, opt_specs)

    step_fn = make_train_step(cfg, train_cfg)
    jit_step = jax.jit(step_fn,
                       in_shardings=(p_shard, o_shard, None),
                       out_shardings=(p_shard, o_shard, None),
                       donate_argnums=(0, 1))

    def init_state() -> TrainState:
        with jax.set_mesh(mesh):
            params = init_params(defs, jax.random.PRNGKey(seed), jnp.float32)
            params = jax.device_put(params, p_shard)
            opt = adamw.init(train_cfg.opt, params)
        return TrainState(params, opt, {})

    def run_step(state: TrainState, step: int) -> TrainState:
        batch = make_global_batch(data_cfg, step, mesh)
        with jax.set_mesh(mesh):
            params, opt, metrics = jit_step(state.params, state.opt_state,
                                            batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        return TrainState(params, opt, metrics)

    return init_state, run_step, (p_shard, o_shard)


def train(cfg, *, steps: int, global_batch: int, seq_len: int,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
          mesh=None, train_cfg: Optional[TrainConfig] = None,
          fail_at=None, seed: int = 0, log_every: int = 10,
          watchdog_s: Optional[float] = None) -> LoopReport:
    mesh = mesh or make_smoke_mesh()
    train_cfg = train_cfg or TrainConfig(
        opt=adamw.AdamWConfig(total_steps=steps, warmup_steps=max(1, steps // 20)))
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                          global_batch=global_batch, seed=seed,
                          frontend_prefix=cfg.frontend_prefix,
                          d_model=cfg.d_model,
                          encoder_seq=(cfg.encoder_seq
                                       if cfg.encoder_layers else 0))
    init_state, run_step, (p_shard, o_shard) = build_trainer(
        cfg, mesh, train_cfg, data_cfg, seed)
    state = init_state()

    def step_wrap(state, step):
        state = run_step(state, step)
        if log_every and step % log_every == 0:
            m = state.metrics
            print(f"step {step:5d} loss={m.get('loss', float('nan')):.4f} "
                  f"acc={m.get('accuracy', 0):.3f} "
                  f"gnorm={m.get('grad_norm', 0):.2f}", flush=True)
        return state

    ckptr = Checkpointer(ckpt_dir or "/tmp/repro_ckpt", keep=3)

    def save_tree(state: TrainState):
        return {"params": state.params, "opt": state.opt_state}

    def restore(ckptr: Checkpointer, step: int, state: TrainState):
        like = {"params": state.params, "opt": state.opt_state}
        shardings = {"params": p_shard, "opt": o_shard}
        tree = ckptr.restore(step, like, shardings)
        return TrainState(tree["params"], tree["opt"], {})

    return resilient_train_loop(
        state=state, step_fn=step_wrap, save_tree_fn=save_tree,
        restore_fn=restore, checkpointer=ckptr, total_steps=steps,
        ckpt_every=ckpt_every, watchdog_deadline_s=watchdog_s,
        failure_injector=FailureInjector(fail_at or []),
        metrics_fn=lambda s: s.metrics)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["smoke", "pod", "multipod"],
                    default="smoke")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = {"smoke": make_smoke_mesh,
            "pod": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True)}[
        args.mesh]()
    t0 = time.time()
    report = train(cfg, steps=args.steps, global_batch=args.global_batch,
                   seq_len=args.seq_len, ckpt_dir=args.ckpt_dir,
                   ckpt_every=args.ckpt_every, mesh=mesh)
    dt = time.time() - t0
    last = report.metrics_history[-1] if report.metrics_history else {}
    print(f"done: {report.final_step} steps in {dt:.1f}s, "
          f"final loss={last.get('loss')}, restarts={report.restarts}")


if __name__ == "__main__":
    main()
