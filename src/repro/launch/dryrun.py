import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production meshes and extract the roofline terms.

MUST keep the two lines above as the very first statements — jax locks the
device count on first initialisation, and the 512 placeholder host devices
exist only inside this entry point (tests and benches see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun

For each cell the dry-run records: memory_analysis (bytes/device),
cost_analysis (FLOPs, bytes accessed), and the per-collective byte volumes
parsed from the optimized HLO — the inputs to EXPERIMENTS.md §Roofline.
"""

import argparse
import json
import pathlib
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell


COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum *output* shape bytes of every collective op in the optimized HLO
    (per-participant payload — the standard wire-volume proxy)."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        # "  name = bf16[...]{...} all-gather(...)" — op name after '='
        m = re.search(r"=\s+(\(?[a-z0-9,\[\]{}: ()]+?\)?)\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        if (ls.startswith("ROOT") is False and "-done" in ls.split("=")[0]):
            continue  # count the -start, skip the matching -done
        out[op] += _shape_bytes(shape_str)
        out["count"] += 1
    return out


def apply_variant(arch: str, shape: str, variant: str):
    """§Perf hillclimb variants: config/train-config transforms applied on
    top of the current code.  Comma-separated combos compose."""
    import dataclasses
    from repro.launch.specs import default_train_config
    from repro.configs import SHAPES as _SH
    cfg = get_config(arch)
    tcfg = default_train_config(arch, _SH[shape])
    for v in [v for v in variant.split(",") if v and v != "baseline"]:
        if v == "causal_skip":
            cfg = dataclasses.replace(cfg, causal_skip=True)
        elif v == "remat_dots":
            cfg = dataclasses.replace(cfg, remat="dots")
        elif v.startswith("micro"):
            tcfg = dataclasses.replace(tcfg, microbatches=int(v[5:]))
        elif v.startswith("qchunk"):
            n = int(v[6:])
            cfg = dataclasses.replace(cfg, q_chunk=n, kv_chunk=n)
        else:
            raise ValueError(f"unknown variant {v!r}")
    return cfg, tcfg


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             cfg=None, train_cfg=None,
             save_hlo: Optional[pathlib.Path] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        kw = {"cfg": cfg}
        if train_cfg is not None and SHAPES[shape].kind == "train":
            kw["train_cfg"] = train_cfg
        lowerable = build_cell(arch, shape, mesh, **kw)
        arg_bytes = lowerable.arg_bytes_per_device
        lowered = lowerable.lower()
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    # loop-aware per-device cost (EXPERIMENTS.md §Roofline inputs)
    from repro.analysis.hlo_cost import analyze_hlo
    n_dev = 512 if multi_pod else 256
    scaled = analyze_hlo(hlo, n_dev)
    if save_hlo is not None:
        save_hlo.parent.mkdir(parents=True, exist_ok=True)
        save_hlo.write_text(hlo)

    result = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "xla_flops_unscaled": cost.get("flops", 0.0) if cost else None,
        "xla_bytes_unscaled": cost.get("bytes accessed", 0.0) if cost else None,
        "collectives_unscaled": coll,
        "cost": scaled,
        "arg_bytes_per_device": arg_bytes,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        } if mem is not None else None,
    }
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2×16×16 (512 chips) instead of 16×16")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="comma-separated §Perf variants: causal_skip, "
                         "remat_dots, microN, qchunkN")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        todo = [(a, s) for a, s, skip in cells() if skip is None]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required without --all")
        todo = [(args.arch, args.shape)]

    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
            if args.variant != "baseline":
                tag += f"_{args.variant.replace(',', '+')}"
            try:
                vcfg, vtcfg = apply_variant(arch, shape, args.variant)
                res = run_cell(
                    arch, shape, multi_pod=mp, cfg=vcfg, train_cfg=vtcfg,
                    save_hlo=(out_dir / f"{tag}.hlo"
                              if args.save_hlo else None))
                res["variant"] = args.variant
                (out_dir / f"{tag}.json").write_text(json.dumps(res, indent=1))
                mem = res["memory"] or {}
                c = res["cost"]
                print(f"OK  {tag}: flops/dev={c['flops_per_device']:.3e} "
                      f"bytes/dev={c['bytes_per_device']:.3e} "
                      f"wire/dev={c['collective_wire_per_device']:.3e} "
                      f"args/dev={res['arg_bytes_per_device']:.3e} "
                      f"compile={res['compile_s']}s", flush=True)
            except Exception as e:
                failures += 1
                print(f"FAIL {tag}: {e}", flush=True)
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
