"""Graph compiler front end: DAG IR + pass pipeline (DESIGN.md §Graph).

The paper's compiler stops at strictly sequential CNNs; this subpackage
opens branching topologies (residual blocks) with a small, verifiable
stack:

* :mod:`repro.graph.ir`     — the DAG IR (nodes for conv/fc/relu/pool/
  requant/add/flatten, explicit named tensor values, topological
  verification) and its declarative :class:`~repro.graph.ir.GraphBuilder`;
* :mod:`repro.graph.passes` — shape inference, requant-shift planning
  across branch joins, linearization into fused steps — each pass with a
  declared, unit-tested invariant;
* :mod:`repro.graph.lower`  — lowering onto the existing layer/network
  compilers, with residual adds executed *on the VTA* as ALU vector-vector
  ADD instructions.
"""

from .ir import Graph, GraphBuilder, Node                       # noqa: F401
from .passes import (RequantPlan, Step, evaluate_graph,          # noqa: F401
                     infer_shapes, linearize, plan_requant)
from .lower import compile_graph                                 # noqa: F401
