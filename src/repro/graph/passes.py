"""Pass pipeline over the graph IR (DESIGN.md §Graph).

Three passes, each with a declared invariant the unit tests assert
directly (`tests/test_graph_passes.py`):

* :func:`infer_shapes`   — forward shape inference.  Invariant: every
  value has a resolved shape; add operands agree; conv kernels fit.
* :func:`plan_requant`   — static requant-shift planning over a
  calibration set (§4.2 discipline), *including branch joins*: a
  power-of-2 scale exponent is tracked per value, and at every ``add``
  the operand with the larger exponent receives an on-device pre-shift
  equal to the difference.  Invariant: both operands of every join land
  in the same fixed-point scale; every dense-linear input fits int8.
* :func:`linearize`      — schedules the DAG into fused steps (one VTA
  layer each) with named activation buffers.  Invariant: steps are in
  dependency order; every non-input node is covered by exactly one step.

:func:`evaluate_graph` is the shared bit-exact int64 reference semantics
— the planner measures against it, the lowering compiles against it, and
the fuzz tests compare VTA execution to it ("compile or raise — never
wrong bytes").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.conv_lowering import (ConvGeometry, im2row, ker2col,
                                      mat2tensor)
from repro.core.errors import CompileError
from repro.core.layer_compiler import (check_gap_geometry,
                                       check_stride_tiling,
                                       choose_requant_shift)

from .ir import Graph, Node

# Device constraint: the fused avg-pool SHR is ``2 + layer_shift`` with
# ``layer_shift >= 0`` (DESIGN.md §2), so the requant node after an
# avg-pool must shift by at least the pool's ÷4.
AVG_POOL_DIV = 2


# ---------------------------------------------------------------------------
# Pass 1: shape inference
# ---------------------------------------------------------------------------

def infer_shapes(graph: Graph) -> Dict[str, Tuple[int, ...]]:
    """Forward shape inference; returns value name → shape.

    Raises :class:`CompileError` (naming the node) for rank mismatches,
    channel mismatches, kernels that do not fit, odd pooled extents and
    mismatched add operands.
    """
    shapes: Dict[str, Tuple[int, ...]] = {}
    for name in graph.topo_order():
        node = graph.node(name)
        ins = [shapes[ref] for ref in node.inputs]
        shapes[name] = _node_shape(node, ins)
    return shapes


def _node_shape(node: Node, ins: List[Tuple[int, ...]]) -> Tuple[int, ...]:
    if node.kind == "input":
        return tuple(node.shape)
    if node.kind == "conv":
        s = ins[0]
        if len(s) != 4 or s[0] != 1:
            raise CompileError(f"conv input must be (1, C, H, W), got {s}",
                               layer=node.name, constraint="conv-input-rank")
        f, c, kh, kw = node.weights.shape
        if s[1] != c:
            raise CompileError(
                f"channel mismatch: input has {s[1]}, weights expect {c}",
                layer=node.name, constraint="conv-channels")
        geo = ConvGeometry(c, s[2], s[3], kh, kw, node.stride, node.padding)
        if geo.out_h <= 0 or geo.out_w <= 0:
            raise CompileError(
                f"kernel {kh}x{kw} (stride {node.stride}, pad "
                f"{node.padding}) does not fit the {s[2]}x{s[3]} input",
                layer=node.name, constraint="conv-kernel-fit")
        check_stride_tiling(geo, layer=node.name)
        return (1, f, geo.out_h, geo.out_w)
    if node.kind == "fc":
        s = ins[0]
        if len(s) != 2:
            raise CompileError(
                f"fc input must be 2-D (flatten first), got {s}",
                layer=node.name, constraint="fc-input-rank")
        d, f = node.weights.shape
        if s[1] != d:
            raise CompileError(f"fc dimension mismatch: {s} @ {(d, f)}",
                               layer=node.name, constraint="fc-shape")
        return (s[0], f)
    if node.kind in ("relu", "requant"):
        return ins[0]
    if node.kind == "pool":
        s = ins[0]
        if len(s) != 4:
            raise CompileError(f"pool input must be 4-D, got {s}",
                               layer=node.name, constraint="pool-input-rank")
        if s[2] % 2 or s[3] % 2:
            raise CompileError(
                f"2x2 pooling needs even spatial dims, got {s[2]}x{s[3]}",
                layer=node.name, constraint="pool-even-dims")
        return (s[0], s[1], s[2] // 2, s[3] // 2)
    if node.kind == "global_avg_pool":
        s = ins[0]
        if len(s) != 4:
            raise CompileError(f"global_avg_pool input must be 4-D, got {s}",
                               layer=node.name, constraint="pool-input-rank")
        check_gap_geometry(s[2], s[3], layer=node.name)
        return (s[0], s[1], 1, 1)
    if node.kind == "add":
        if ins[0] != ins[1]:
            raise CompileError(
                f"add operands must agree in shape: {ins[0]} vs {ins[1]}",
                layer=node.name, constraint="add-shape")
        return ins[0]
    if node.kind == "flatten":
        s = ins[0]
        if len(s) != 4 or s[0] != 1:
            raise CompileError(f"flatten input must be (1, C, H, W), got {s}",
                               layer=node.name, constraint="flatten-input")
        return (1, s[1] * s[2] * s[3])
    raise CompileError(f"unknown node kind {node.kind!r}", layer=node.name,
                       constraint="node-kind")


# ---------------------------------------------------------------------------
# Reference semantics (shared by planning, lowering and fuzz tests)
# ---------------------------------------------------------------------------

def _check_int8(node: Node, ref: str, v: np.ndarray, what: str) -> None:
    m = int(np.abs(v).max(initial=0))
    if m > 127:
        raise CompileError(
            f"{what} {ref!r} holds values up to {m} — every dense-linear/"
            f"join operand must be a requantised int8 activation",
            layer=node.name, constraint="int8-feed")


def evaluate_graph(graph: Graph, feed: Union[np.ndarray, Dict[str, np.ndarray]]
                   ) -> Dict[str, np.ndarray]:
    """Bit-exact int64 evaluation of the whole graph (the integer
    reference the VTA execution must reproduce).  Every ``requant.shift``
    and ``add.pre_shifts`` must already be set — run :func:`plan_requant`
    first (or pin them in the builder).
    """
    inputs = graph.input_names
    if not isinstance(feed, dict):
        if len(inputs) != 1:
            raise CompileError(
                f"graph has {len(inputs)} inputs; pass a feed dict",
                constraint="graph-feed")
        feed = {inputs[0]: feed}
    vals: Dict[str, np.ndarray] = {}
    for name in graph.topo_order():
        node = graph.node(name)
        vals[name] = _eval_node(node, [vals[r] for r in node.inputs],
                                node.inputs, feed)
    return vals


def _eval_node(node: Node, ins: List[np.ndarray], refs: Tuple[str, ...],
               feed: Dict[str, np.ndarray]) -> np.ndarray:
    if node.kind == "input":
        if node.name not in feed:
            raise CompileError(f"no feed for input {node.name!r}",
                               constraint="graph-feed")
        arr = np.asarray(feed[node.name]).astype(np.int64)
        if arr.shape != tuple(node.shape):
            raise CompileError(
                f"feed shape {arr.shape} != declared {tuple(node.shape)}",
                layer=node.name, constraint="graph-feed")
        return arr
    if node.kind == "conv":
        _check_int8(node, refs[0], ins[0], "conv input")
        x = ins[0].astype(np.int8)
        f, c, kh, kw = node.weights.shape
        A = im2row(x, kh, kw, node.stride, node.padding).astype(np.int64)
        acc = A @ ker2col(node.weights).astype(np.int64)
        if node.bias is not None:
            acc = acc + node.bias.astype(np.int64)[None, :]
        _, _, h, w = ins[0].shape
        geo = ConvGeometry(c, h, w, kh, kw, node.stride, node.padding)
        return mat2tensor(acc, geo.out_h, geo.out_w)
    if node.kind == "fc":
        _check_int8(node, refs[0], ins[0], "fc input")
        acc = ins[0] @ node.weights.astype(np.int64)
        if node.bias is not None:
            acc = acc + node.bias.astype(np.int64)[None, :]
        return acc
    if node.kind == "relu":
        return np.maximum(ins[0], 0)
    if node.kind == "pool":
        t = ins[0]
        q = (t[:, :, 0::2, 0::2], t[:, :, 0::2, 1::2],
             t[:, :, 1::2, 0::2], t[:, :, 1::2, 1::2])
        if node.mode == "max2x2":
            return np.maximum(np.maximum(q[0], q[1]), np.maximum(q[2], q[3]))
        return q[0] + q[1] + q[2] + q[3]          # avg = sum; ÷4 in requant
    if node.kind == "global_avg_pool":
        # spatial *sum*; the ÷(H·W) SHR lives in the following requant
        return ins[0].sum(axis=(2, 3), keepdims=True)
    if node.kind == "requant":
        if node.shift is None:
            raise CompileError("requant shift unplanned — run plan_requant",
                               layer=node.name, constraint="requant-planned")
        return ins[0] >> node.shift
    if node.kind == "add":
        if node.pre_shifts is None:
            raise CompileError("add pre-shifts unplanned — run plan_requant",
                               layer=node.name, constraint="requant-planned")
        pa, pb = node.pre_shifts
        _check_int8(node, refs[0], ins[0], "add operand")
        _check_int8(node, refs[1], ins[1], "add operand")
        return (ins[0] >> pa) + (ins[1] >> pb)
    if node.kind == "flatten":
        _check_int8(node, refs[0], ins[0], "flatten input")
        return ins[0].reshape(1, -1)
    raise CompileError(f"unknown node kind {node.kind!r}", layer=node.name,
                       constraint="node-kind")


# ---------------------------------------------------------------------------
# Pass 2: requant-shift planning across branch joins
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RequantPlan:
    """What the planner decided (observability + invariant tests).

    ``exps[v]`` is the power-of-2 scale exponent of value ``v``: the int
    tensor ``v`` represents the real quantity ``r ≈ v · 2^{-exps[v]}``
    relative to the network input.  The planner's defining invariant:
    at every ``add``, both operands (after their planned pre-shifts)
    carry the same exponent.
    """

    shifts: Dict[str, int]                      # requant node → shift
    pre_shifts: Dict[str, Tuple[int, int]]      # add node → (pa, pb)
    exps: Dict[str, int]                        # value → scale exponent


def plan_requant(graph: Graph, calib: Sequence[np.ndarray], *,
                 margin: int = 1, on_linear=None) -> RequantPlan:
    """Fill every unpinned ``requant.shift`` / ``add.pre_shifts`` from a
    calibration set (mutates the graph nodes; §4.2 discipline: shifts are
    static, the margin bit guards unseen inputs).

    Planning walks the DAG once in topo order, carrying for every value
    (a) its int64 evaluation over all calibration images and (b) its
    scale exponent.  Requant shifts are the smallest that land int8
    (+ margin; ≥ 2 after an avg-pool — the device folds the ÷4 into the
    same SHR).  At each add the larger-exponent operand gets a pre-shift
    equal to the exponent difference, so both residual operands reach the
    TensorAlu ADD in the same fixed-point scale.

    ``on_linear(node, input_exp)`` — optional hook invoked on every
    conv/fc node right before its first evaluation, with the planner's
    scale exponent of the node's activation input.  PTQ
    (:func:`repro.quantize.quantize_network`, DESIGN.md §Quantization)
    uses it to quantise float weights in place at exactly the moment the
    input scale is known: the hook may rewrite ``node.weights`` /
    ``node.bias`` / ``node.weight_exp``, and planning continues over the
    rewritten integer node.
    """
    if not calib:
        raise CompileError("empty calibration set", constraint="calibration")
    inputs = graph.input_names
    if len(inputs) != 1:
        raise CompileError("plan_requant expects a single-input graph",
                           constraint="graph-feed")
    shapes = infer_shapes(graph)                # shape invariant first
    vals: Dict[str, List[np.ndarray]] = {}
    exps: Dict[str, int] = {}
    shifts: Dict[str, int] = {}
    pre_shifts: Dict[str, Tuple[int, int]] = {}

    for name in graph.topo_order():
        node = graph.node(name)
        refs = node.inputs
        if node.kind == "requant":
            if node.shift is None:
                m = max(int(np.abs(v).max(initial=0))
                        for v in vals[refs[0]])
                shift = choose_requant_shift(np.asarray([m])) + margin
                shift = max(shift, _pool_floor(graph, node, shapes))
                node.shift = shift
            shifts[name] = node.shift
            exps[name] = exps[refs[0]] - node.shift
            vals[name] = [v >> node.shift for v in vals[refs[0]]]
            continue
        if node.kind == "add":
            ea, eb = exps[refs[0]], exps[refs[1]]
            if node.pre_shifts is None:
                node.pre_shifts = (max(0, ea - eb), max(0, eb - ea))
            pa, pb = node.pre_shifts
            if ea - pa != eb - pb:
                raise CompileError(
                    f"join operands disagree in scale even after "
                    f"pre-shifts: exponents {ea}-{pa} vs {eb}-{pb}",
                    layer=name, constraint="join-scale")
            pre_shifts[name] = node.pre_shifts
            exps[name] = ea - pa
            for ref in refs:
                for v in vals[ref]:
                    _check_int8(node, ref, v, "add operand")
            vals[name] = [(a >> pa) + (b >> pb)
                          for a, b in zip(vals[refs[0]], vals[refs[1]])]
            continue
        # every other kind evaluates per image with the shared semantics
        if node.kind == "input":
            vals[name] = [np.asarray(img).astype(np.int64) for img in calib]
            exps[name] = 0
        else:
            if node.kind in ("conv", "fc") and on_linear is not None:
                on_linear(node, exps[refs[0]])
            vals[name] = [_eval_node(node, [vals[r][i] for r in refs],
                                     refs, {}) for i in range(len(calib))]
            if node.kind in ("conv", "fc"):
                # int8 weights represent real coefficients W · 2^-weight_exp,
                # so the integer accumulator sits 2^weight_exp above the
                # real-valued feature (standard fixed-point bookkeeping).
                exps[name] = exps[refs[0]] + node.weight_exp
            elif node.kind == "pool" and node.mode == "avg2x2":
                exps[name] = exps[refs[0]] + AVG_POOL_DIV
            elif node.kind == "global_avg_pool":
                exps[name] = exps[refs[0]] + _gap_div(shapes[refs[0]])
            else:
                exps[name] = exps[refs[0]]
    return RequantPlan(shifts=shifts, pre_shifts=pre_shifts, exps=exps)


def _gap_div(in_shape: Tuple[int, ...]) -> int:
    """log2 of a GAP node's spatial position count (the ÷(H·W) SHR)."""
    return (in_shape[2] * in_shape[3]).bit_length() - 1


def _pool_floor(graph: Graph, requant: Node,
                shapes: Dict[str, Tuple[int, ...]]) -> int:
    """Minimum shift of a requant node: the device folds the producing
    pool's division (avg ÷4, GAP ÷(H·W)) into the same SHR."""
    producer = graph.node(requant.inputs[0])
    if producer.kind == "pool" and producer.mode == "avg2x2":
        return AVG_POOL_DIV
    if producer.kind == "global_avg_pool":
        return _gap_div(shapes[producer.inputs[0]])
    return 0


# ---------------------------------------------------------------------------
# Pass 3: linearization into fused steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Step:
    """One fused VTA layer scheduled out of the DAG.

    ``input_value``/``residual_source`` name activation buffers: the
    graph input or an earlier step's ``output_value`` (the lowering turns
    these into :class:`~repro.core.network_compiler.NetworkProgram`
    ``input_sources``/``residual_sources`` indices).
    """

    name: str
    kind: str                        # conv | fc
    node_names: Tuple[str, ...]      # fused IR nodes, execution order
    input_value: str
    output_value: str
    weights: np.ndarray
    bias: Optional[np.ndarray]
    stride: int
    padding: int
    relu: bool
    pool: Optional[str]              # max2x2 | avg2x2 | None
    requant_shift: int               # LayerSpec shift (pool ÷4 excluded)
    residual_source: Optional[str] = None
    residual_pre_shift: int = 0
    residual_shift: Optional[int] = None


def linearize(graph: Graph) -> List[Step]:
    """Schedule the DAG into fused steps with named activation buffers.

    Fusable patterns (single-consumer chains off a dense-linear node):

        conv → [relu] → [pool|global_avg_pool] → requant       (linear)
        fc   → [relu] → requant                                (linear)
        conv|fc → requant → add(·, skip) → [relu] → requant    (residual)

    plus ``flatten`` folded into the fc that consumes it.  Anything else
    raises :class:`CompileError`.  Requant shifts must be planned first.
    """
    shapes = infer_shapes(graph)
    cons = graph.consumers()
    materialized = set(graph.input_names)
    covered = set(graph.input_names)
    steps: List[Step] = []

    def single(name: str, why: str) -> str:
        c = cons[name]
        if len(c) != 1:
            raise CompileError(
                f"{why}: value {name!r} has {len(c)} consumers "
                f"(exactly one required to fuse)", layer=name,
                constraint="fusion-single-consumer")
        return c[0]

    def shift_of(qname: str) -> int:
        q = graph.node(qname)
        if q.shift is None:
            raise CompileError("requant shift unplanned — run plan_requant",
                               layer=qname, constraint="requant-planned")
        return q.shift

    for name in graph.topo_order():
        node = graph.node(name)
        if node.kind not in ("conv", "fc") or name in covered:
            continue
        chain: List[str] = []
        in_value = node.inputs[0]
        if node.kind == "fc" and in_value not in materialized:
            producer = graph.node(in_value)
            if producer.kind == "flatten" and in_value not in covered:
                single(in_value, "flatten must feed exactly one fc")
                chain.append(in_value)
                in_value = producer.inputs[0]
        if in_value not in materialized:
            raise CompileError(
                f"{node.kind} input {in_value!r} is not an activation "
                f"buffer (it is consumed mid-fusion elsewhere, or is an "
                f"unrequantised intermediate)", layer=name,
                constraint="fusion-input-materialized")
        chain.append(name)

        cur = name
        nxt = graph.node(single(cur, f"{node.kind} result must fuse"))
        relu = False
        pool = None
        if nxt.kind == "relu":
            relu = True
            chain.append(nxt.name)
            cur = nxt.name
            nxt = graph.node(single(cur, "relu result must fuse"))
        pool_div = 0
        if nxt.kind in ("pool", "global_avg_pool"):
            if node.kind == "fc":
                raise CompileError("pooling requires a conv layer",
                                   layer=nxt.name,
                                   constraint="pool-needs-conv")
            if nxt.kind == "global_avg_pool":
                pool = "gap"
                pool_div = _gap_div(shapes[nxt.inputs[0]])
            else:
                pool = nxt.mode
                pool_div = AVG_POOL_DIV if pool == "avg2x2" else 0
            chain.append(nxt.name)
            cur = nxt.name
            nxt = graph.node(single(cur, "pool result must fuse"))
        if nxt.kind != "requant":
            raise CompileError(
                f"{node.kind} chain must end in a requant before any other "
                f"consumer (found {nxt.kind} {nxt.name!r})", layer=name,
                constraint="requant-required")
        q = nxt
        chain.append(q.name)
        q_shift = shift_of(q.name)
        if q_shift < pool_div:
            raise CompileError(
                f"requant after a pooled reduction must shift by >= "
                f"{pool_div} (the fused division), got {q_shift}",
                layer=q.name,
                constraint="avg-pool-min-shift" if pool != "gap"
                else "gap-min-shift")

        # ---- residual continuation: requant feeding exactly one add
        # whose other operand is already materialized ----
        step = None
        if not relu and pool is None and len(cons[q.name]) == 1:
            maybe_add = graph.node(cons[q.name][0])
            if maybe_add.kind == "add":
                other = [r for r in maybe_add.inputs if r != q.name]
                if len(other) == 1 and other[0] in materialized:
                    step = _residual_step(graph, cons, node, chain, in_value,
                                          q_shift, maybe_add, other[0],
                                          single, shift_of)
        if step is None:
            step = Step(name=name, kind=node.kind,
                        node_names=tuple(chain), input_value=in_value,
                        output_value=q.name, weights=node.weights,
                        bias=node.bias, stride=node.stride,
                        padding=node.padding, relu=relu, pool=pool,
                        requant_shift=q_shift - pool_div)
        covered.update(step.node_names)
        materialized.add(step.output_value)
        steps.append(step)

    uncovered = [n for n in graph.topo_order() if n not in covered]
    if uncovered:
        raise CompileError(
            f"nodes not reachable by any fusable pattern: {uncovered} "
            f"(each relu/pool/requant/add must extend a conv/fc chain)",
            layer=uncovered[0], constraint="fusion-coverage")
    for out in graph.outputs:
        if out not in materialized:
            raise CompileError(
                f"graph output {out!r} is a fused intermediate, not an "
                f"activation buffer", layer=out,
                constraint="output-materialized")
    return steps


def _residual_step(graph: Graph, cons, linear: Node, chain: List[str],
                   in_value: str, q_shift: int, add: Node, skip: str,
                   single, shift_of) -> Step:
    """Fuse ``linear → requant → add(·, skip) → [relu] → requant``."""
    if add.pre_shifts is None:
        raise CompileError("add pre-shifts unplanned — run plan_requant",
                           layer=add.name, constraint="requant-planned")
    branch_pos = 0 if add.inputs[1] == skip else 1
    branch_pre = add.pre_shifts[branch_pos]
    skip_pre = add.pre_shifts[1 - branch_pos]
    chain = chain + [add.name]
    cur = add.name
    nxt = graph.node(single(cur, "add result must fuse"))
    relu = False
    if nxt.kind == "relu":
        relu = True
        chain.append(nxt.name)
        cur = nxt.name
        nxt = graph.node(single(cur, "relu result must fuse"))
    if nxt.kind != "requant":
        raise CompileError(
            f"residual add must be requantised before any other consumer "
            f"(found {nxt.kind} {nxt.name!r})", layer=add.name,
            constraint="requant-required")
    chain.append(nxt.name)
    return Step(name=linear.name, kind=linear.kind, node_names=tuple(chain),
                input_value=in_value, output_value=nxt.name,
                weights=linear.weights, bias=linear.bias,
                stride=linear.stride, padding=linear.padding, relu=relu,
                pool=None,
                # the branch operand's scale-equalising shift folds into
                # the pre-add requant: (x >> q) >> pre == x >> (q + pre)
                requant_shift=q_shift + branch_pre,
                residual_source=skip, residual_pre_shift=skip_pre,
                residual_shift=shift_of(nxt.name))
