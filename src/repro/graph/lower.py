"""Graph → VTA lowering (DESIGN.md §Graph).

``compile_graph`` drives the whole pipeline: structural verification,
shape inference, requant planning, linearization, then per-step lowering
onto the existing layer compiler — every step against one shared DRAM
allocation (§4.2), residual steps with their skip operand compiled into a
``res`` region and merged on the VTA by an ALU vector-vector ADD.

Traceability: after compiling each step the lowering asserts the layer's
reference output equals the graph evaluation of the step's output value —
a compiler whose fused semantics drift from the IR semantics fails here,
at compile time, not with wrong bytes at run time.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.conv_lowering import mat2tensor
from repro.core.dram import DramAllocator
from repro.core.errors import CompileError
from repro.core.hwconfig import VTAConfig, vta_default
from repro.core.layer_compiler import CompiledLayer, LayerSpec, compile_layer
from repro.core.network_compiler import NetworkProgram

from .ir import Graph
from .passes import Step, evaluate_graph, linearize, plan_requant


def step_to_spec(step: Step) -> LayerSpec:
    """One fused step → the hardware-agnostic :class:`LayerSpec`."""
    return LayerSpec(
        name=step.name, kind=step.kind, weights=step.weights, bias=step.bias,
        stride=step.stride, padding=step.padding, relu=step.relu,
        pool=step.pool, requant_shift=step.requant_shift,
        residual_add=step.residual_source is not None,
        residual_pre_shift=step.residual_pre_shift,
        residual_shift=step.residual_shift)


def compile_graph(graph: Graph, input_tensor: np.ndarray, *,
                  calib: Optional[Sequence[np.ndarray]] = None,
                  margin: int = 1,
                  cfg: Optional[VTAConfig] = None,
                  dram_offset: int = 0,
                  schedule: str = "serialized") -> NetworkProgram:
    """Compile a branching CNN graph into a :class:`NetworkProgram`.

    ``calib`` is the §4.2 calibration set for the requant planner
    (defaults to just ``input_tensor``); pinned shifts on the graph are
    kept.  The returned program runs on every backend of the network
    runtime — ``run_functional``/``verify`` (oracle/fast), ``serve_one``,
    and batched ``serve`` — with residual adds executed on the VTA.
    """
    cfg = cfg or vta_default()
    graph.verify()
    if len(graph.outputs) != 1:
        raise CompileError(
            f"compile_graph expects exactly one output, got "
            f"{len(graph.outputs)}", constraint="single-output")
    plan_requant(graph, list(calib) if calib is not None
                 else [input_tensor], margin=margin)
    steps = linearize(graph)
    # Dead-step elimination: keep only steps whose output transitively
    # reaches the graph output.  With a single output the producing step
    # is then always last (everything live feeds it).
    live = _live_nodes(graph)
    steps = [s for s in steps if s.output_value in live]
    if not steps or steps[-1].output_value != graph.outputs[0]:
        raise CompileError(
            f"graph output {graph.outputs[0]!r} is not produced by the "
            f"final live step", constraint="output-materialized")
    vals = evaluate_graph(graph, np.asarray(input_tensor))

    alloc = DramAllocator(offset=dram_offset, page_bytes=cfg.page_bytes)
    layers: List[CompiledLayer] = []
    input_sources: List[int] = []
    residual_sources: List[Optional[int]] = []
    produced: Dict[str, int] = {}        # activation buffer → layer index
    inputs = set(graph.input_names)

    def source_index(value: str, step: Step) -> int:
        if value in inputs:
            return -1
        if value not in produced:
            raise CompileError(
                f"step consumes {value!r} before it is produced "
                f"(linearization invariant violated)", layer=step.name,
                constraint="step-order")
        return produced[value]

    for step in steps:
        spec = step_to_spec(step)
        src = source_index(step.input_value, step)
        inp = _as_activation(vals[step.input_value], step, "input")
        residual = None
        res_src: Optional[int] = None
        if step.residual_source is not None:
            res_src = source_index(step.residual_source, step)
            residual = _as_activation(vals[step.residual_source], step,
                                      "residual")
        layer = compile_layer(spec, inp, cfg=cfg, allocator=alloc,
                              residual=residual, schedule=schedule)
        _check_step_reference(layer, vals[step.output_value], step)
        produced[step.output_value] = len(layers)
        layers.append(layer)
        input_sources.append(src)
        residual_sources.append(res_src)

    return NetworkProgram(config=cfg, allocator=alloc, layers=layers,
                          input_tensor=np.asarray(input_tensor),
                          input_sources=input_sources,
                          residual_sources=residual_sources)


def _live_nodes(graph: Graph) -> set:
    """Backward closure from the graph outputs over value edges."""
    live = set()
    stack = list(graph.outputs)
    while stack:
        cur = stack.pop()
        if cur in live:
            continue
        live.add(cur)
        stack.extend(graph.node(cur).inputs)
    return live


def _as_activation(value: np.ndarray, step: Step, what: str) -> np.ndarray:
    """Graph values are int64; activation buffers must be int8-exact."""
    if int(np.abs(value).max(initial=0)) > 127:
        raise CompileError(
            f"{what} activation exceeds int8 (planner invariant violated)",
            layer=step.name, constraint="int8-feed")
    return value.astype(np.int8)


def _check_step_reference(layer: CompiledLayer, expected: np.ndarray,
                          step: Step) -> None:
    """The fused layer's compiled reference must equal the IR semantics."""
    ref = layer.ref_output_matrix
    if layer.spec.kind == "conv":
        ref = mat2tensor(ref, layer.out_h, layer.out_w)
    if not np.array_equal(ref.astype(np.int64), expected):
        raise CompileError(
            f"fused layer semantics diverge from the graph reference for "
            f"value {step.output_value!r}", layer=step.name,
            constraint="lowering-reference")
