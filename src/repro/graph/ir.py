"""Graph IR: a small DAG of named tensor values (DESIGN.md §Graph).

Grammar
-------
A graph is a set of single-output *nodes*; a node's name is also the name
of the tensor value it produces (values are explicit — every edge is a
``(producer name → consumer)`` reference, and :meth:`Graph.topo_order`
certifies the whole structure is a DAG before any pass runs):

    input(shape)                 — a graph input (int8 activation)
    conv(x; W, b, stride, pad)   — dense linear (weights (F, C, kh, kw));
                                   stride 2 downsamples (§Strided-lowering)
    fc(x; W, b)                  — dense linear (weights (D, F))
    relu(x)                      — MAX(x, 0)
    pool(x; "max2x2"|"avg2x2")   — 2×2/stride-2 window; avg produces the
                                   window *sum* (÷4 lives in the requant)
    global_avg_pool(x)           — (1,F,H,W) → (1,F,1,1) spatial *sum*
                                   (÷(H·W) lives in the requant; needs a
                                   square power-of-two map)
    requant(x; shift)            — arithmetic right shift (None = planned)
    add(a, b)                    — the residual join (+ planned pre-shifts)
    flatten(x)                   — NCHW → (1, C·H·W)

The IR deliberately mirrors the device semantics the §2 requantisation
discipline fixed: activations *between* fused layers are int8; values
inside a fused layer (conv accumulator, pool sum, pre-requant add) are
int32.  The pass pipeline (:mod:`repro.graph.passes`) checks both.

Verification levels: :class:`GraphBuilder` rejects malformed nodes at
construction (unknown refs, bad arity, bad attributes); :meth:`Graph.verify`
re-checks the assembled structure — it is cheap and re-run by
:func:`repro.graph.lower.compile_graph` before every compile, so a graph
mutated by hand still cannot reach the lowering in a broken state.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import CompileError

# kind -> number of value inputs
NODE_ARITY = {
    "input": 0, "conv": 1, "fc": 1, "relu": 1, "pool": 1,
    "global_avg_pool": 1, "requant": 1, "add": 2, "flatten": 1,
}
POOL_MODES = ("max2x2", "avg2x2")


@dataclasses.dataclass
class Node:
    """One IR node = one named tensor value.

    Only the attributes meaningful for ``kind`` are set; the rest stay at
    their defaults.  ``shift`` (requant) and ``pre_shifts`` (add) may be
    ``None`` at build time — the requant-planning pass fills them.
    """

    name: str
    kind: str
    inputs: Tuple[str, ...] = ()
    # conv / fc
    weights: Optional[np.ndarray] = None
    bias: Optional[np.ndarray] = None
    stride: int = 1
    padding: int = 0
    # Fixed-point scale of the stored int8 weights: they represent real
    # coefficients ``W · 2^-weight_exp`` (standard weight quantisation).
    # Bookkeeping only — it never changes the integer arithmetic, it
    # informs the requant planner's scale-exponent tracking so branch
    # joins equalise against the *real*-valued network (DESIGN.md §Graph).
    weight_exp: int = 0
    # pool
    mode: Optional[str] = None
    # requant
    shift: Optional[int] = None
    # add: per-operand scale-equalising SHR (filled by plan_requant)
    pre_shifts: Optional[Tuple[int, int]] = None
    # input
    shape: Optional[Tuple[int, ...]] = None


@dataclasses.dataclass
class Graph:
    """A verified DAG of :class:`Node`\\ s (insertion-ordered)."""

    name: str
    nodes: Dict[str, Node]
    outputs: Tuple[str, ...]

    @property
    def input_names(self) -> Tuple[str, ...]:
        return tuple(n.name for n in self.nodes.values()
                     if n.kind == "input")

    def node(self, name: str) -> Node:
        return self.nodes[name]

    def consumers(self) -> Dict[str, List[str]]:
        """value name → names of nodes that read it."""
        out: Dict[str, List[str]] = {name: [] for name in self.nodes}
        for node in self.nodes.values():
            for ref in node.inputs:
                out[ref].append(node.name)
        return out

    # ------------------------------------------------------------------
    def topo_order(self) -> List[str]:
        """Kahn's algorithm over the value edges; raises
        :class:`CompileError` on a cycle (the DAG certificate)."""
        indeg = {name: len(node.inputs) for name, node in self.nodes.items()}
        ready = [n for n, d in indeg.items() if d == 0]
        cons = self.consumers()
        order: List[str] = []
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for consumer in cons[cur]:
                indeg[consumer] -= 1
                if indeg[consumer] == 0:
                    ready.append(consumer)
        if len(order) != len(self.nodes):
            cyclic = sorted(n for n, d in indeg.items() if d > 0)
            raise CompileError(f"graph {self.name!r} has a cycle through "
                               f"{cyclic}", constraint="graph-acyclic")
        return order

    def verify(self) -> None:
        """Structural verification: reference resolution, arities,
        per-kind attribute validity, acyclicity, output reachability."""
        if not self.nodes:
            raise CompileError(f"graph {self.name!r} is empty",
                               constraint="graph-nonempty")
        for node in self.nodes.values():
            if node.kind not in NODE_ARITY:
                raise CompileError(f"unknown node kind {node.kind!r}",
                                   layer=node.name, constraint="node-kind")
            if len(node.inputs) != NODE_ARITY[node.kind]:
                raise CompileError(
                    f"{node.kind} takes {NODE_ARITY[node.kind]} input(s), "
                    f"got {len(node.inputs)}", layer=node.name,
                    constraint="node-arity")
            for ref in node.inputs:
                if ref not in self.nodes:
                    raise CompileError(f"references unknown value {ref!r}",
                                       layer=node.name,
                                       constraint="value-resolution")
            _verify_attrs(node)
        if not self.outputs:
            raise CompileError(f"graph {self.name!r} declares no outputs",
                               constraint="graph-outputs")
        for out in self.outputs:
            if out not in self.nodes:
                raise CompileError(f"output {out!r} is not a node",
                                   constraint="value-resolution")
        if not self.input_names:
            raise CompileError(f"graph {self.name!r} has no input node",
                               constraint="graph-inputs")
        self.topo_order()


def _verify_attrs(node: Node) -> None:
    if node.kind == "input":
        if node.shape is None or len(node.shape) not in (2, 4):
            raise CompileError(
                f"input needs a 2-D or 4-D shape, got {node.shape}",
                layer=node.name, constraint="input-shape")
    elif node.kind == "conv":
        if node.weights is None or node.weights.ndim != 4:
            raise CompileError("conv needs (F, C, kh, kw) weights",
                               layer=node.name, constraint="conv-weight-rank")
        if node.stride < 1:
            raise CompileError(f"stride must be >= 1, got {node.stride}",
                               layer=node.name, constraint="conv-stride")
        if node.stride > 2:
            raise CompileError(
                f"stride {node.stride} unsupported — the strided lowering "
                f"covers strides 1 and 2 (DESIGN.md §Strided-lowering)",
                layer=node.name, constraint="conv-stride-max")
        if node.padding < 0:
            raise CompileError(f"padding must be >= 0, got {node.padding}",
                               layer=node.name, constraint="conv-padding")
    elif node.kind == "fc":
        if node.weights is None or node.weights.ndim != 2:
            raise CompileError("fc needs (D, F) weights", layer=node.name,
                               constraint="fc-weight-rank")
    elif node.kind == "pool":
        if node.mode not in POOL_MODES:
            raise CompileError(
                f"pool mode must be one of {POOL_MODES}, got {node.mode!r}",
                layer=node.name, constraint="pool-kind")
    elif node.kind == "requant":
        if node.shift is not None and node.shift < 0:
            raise CompileError(f"shift must be >= 0, got {node.shift}",
                               layer=node.name, constraint="requant-shift")


class GraphBuilder:
    """Declarative builder: each method adds one node and returns its
    value name, so graphs read as straight-line code:

        b = GraphBuilder("net")
        x = b.input("image", shape=(1, 3, 32, 32))
        v = b.requant("s1_q", b.relu("s1_r", b.conv("s1", x, w, bias)))
        v = b.requant("j_q", b.relu("j_r", b.add("j", v, x)))
        b.output(v)
        g = b.build()          # runs Graph.verify()
    """

    def __init__(self, name: str = "graph"):
        self.name = name
        self._nodes: Dict[str, Node] = {}
        self._outputs: List[str] = []

    # ------------------------------------------------------------------
    def _add(self, node: Node) -> str:
        if node.name in self._nodes:
            raise CompileError(f"duplicate node name {node.name!r}",
                               layer=node.name, constraint="node-name-unique")
        for ref in node.inputs:
            if ref not in self._nodes:
                raise CompileError(
                    f"references unknown value {ref!r} (nodes must be "
                    f"added in def-before-use order)", layer=node.name,
                    constraint="value-resolution")
        _verify_attrs(node)
        self._nodes[node.name] = node
        return node.name

    def input(self, name: str, shape: Sequence[int]) -> str:
        return self._add(Node(name, "input", shape=tuple(shape)))

    def conv(self, name: str, x: str, weights: np.ndarray,
             bias: Optional[np.ndarray] = None, *, stride: int = 1,
             padding: int = 0, weight_exp: int = 0) -> str:
        return self._add(Node(name, "conv", (x,), weights=weights, bias=bias,
                              stride=stride, padding=padding,
                              weight_exp=weight_exp))

    def fc(self, name: str, x: str, weights: np.ndarray,
           bias: Optional[np.ndarray] = None, *,
           weight_exp: int = 0) -> str:
        return self._add(Node(name, "fc", (x,), weights=weights, bias=bias,
                              weight_exp=weight_exp))

    def relu(self, name: str, x: str) -> str:
        return self._add(Node(name, "relu", (x,)))

    def pool(self, name: str, x: str, mode: str) -> str:
        return self._add(Node(name, "pool", (x,), mode=mode))

    def global_avg_pool(self, name: str, x: str) -> str:
        return self._add(Node(name, "global_avg_pool", (x,)))

    def requant(self, name: str, x: str,
                shift: Optional[int] = None) -> str:
        return self._add(Node(name, "requant", (x,), shift=shift))

    def add(self, name: str, a: str, b: str) -> str:
        return self._add(Node(name, "add", (a, b)))

    def flatten(self, name: str, x: str) -> str:
        return self._add(Node(name, "flatten", (x,)))

    def output(self, name: str) -> None:
        if name not in self._nodes:
            raise CompileError(f"output {name!r} is not a node",
                               constraint="value-resolution")
        self._outputs.append(name)

    def build(self) -> Graph:
        graph = Graph(name=self.name, nodes=dict(self._nodes),
                      outputs=tuple(self._outputs))
        graph.verify()
        return graph
