"""moonshot-v1-16b-a3b [moe] — Moonlight-16B-A3B-style MoE
(hf:moonshotai/Moonlight-16B-A3B).

48L, d_model=2048, 16H (kv=16 ⇒ MHA), expert d_ff=1408, vocab=163840,
MoE 64 experts top-6 on every layer.  (Moonlight also carries shared
experts; the assignment lists 64e top-6 only, so shared experts stay off —
noted in DESIGN.md.)
"""

from repro.models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=163840, act="swiglu",
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408),
        remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab=512, act="swiglu",
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96),
        q_chunk=16, kv_chunk=16, remat="none",
    )
