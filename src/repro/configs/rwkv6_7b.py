"""rwkv6-7b [ssm] — RWKV-6 "Finch", attention-free, data-dependent decay
(arXiv:2404.05892).

32L, d_model=4096, d_ff=14336, vocab=65536; 64 WKV heads of dim 64.
Attention-free: the paper's GEMM lowering applies to every projection but
NOT to the WKV recurrence (DESIGN.md §Arch-applicability).  ``long_500k``
runs with O(1) state.
"""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", n_layers=32, d_model=4096, n_heads=64,
        n_kv_heads=64, d_ff=14336, vocab=65536, ssm_kind="rwkv6",
        rwkv_head_dim=64, remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=192, vocab=512, ssm_kind="rwkv6",
        rwkv_head_dim=16, q_chunk=16, kv_chunk=16, remat="none",
    )
