"""Architecture registry: the 10 assigned archs + the paper's LeNet-5 +
the ~100M example config.

``get_config(arch_id)`` returns the full assigned configuration;
``get_smoke(arch_id)`` a reduced same-family config for CPU smoke tests.
``SHAPES`` are the assigned input shapes; ``runnable_cells()`` enumerates
the 40 (arch × shape) cells with the documented ``long_500k`` skips for
pure full-attention archs (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

ARCH_IDS = [
    "whisper-base",
    "nemotron-4-340b",
    "qwen2.5-3b",
    "qwen1.5-110b",
    "gemma3-1b",
    "rwkv6-7b",
    "moonshot-v1-16b-a3b",
    "mixtral-8x22b",
    "internvl2-26b",
    "jamba-1.5-large-398b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULES["lenet5"] = "lenet5"
_MODULES["lm100m"] = "lm100m"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs with sub-quadratic paths run long_500k; pure full-attention skip it
LONG_CONTEXT_ARCHS = {"rwkv6-7b", "jamba-1.5-large-398b", "gemma3-1b",
                      "mixtral-8x22b"}

# ≥100B parameters → 8-bit Adam + bf16 grad accumulation (DESIGN.md §4)
BIG_ARCHS = {"nemotron-4-340b", "qwen1.5-110b", "mixtral-8x22b",
             "jamba-1.5-large-398b"}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).full()


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke()


def cells(include_skips: bool = False) -> List[Tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape, skip_reason) cells."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            skip = None
            if s == "long_500k" and a not in LONG_CONTEXT_ARCHS:
                skip = ("pure full-attention architecture: 500k dense KV "
                        "is quadratic — skipped per assignment note")
            if skip is None or include_skips:
                out.append((a, s, skip))
    return out
