"""whisper-base [audio] — enc-dec, conv frontend STUB (arXiv:2212.04356).

6L decoder (and 6L encoder), d_model=512, 8H (kv=8 ⇒ MHA), d_ff=2048,
vocab=51865.  The audio conv frontend is a stub per the assignment:
``input_specs`` feeds precomputed (B, 1500, 512) frame embeddings to the
encoder.  Whisper's learned absolute positions are kept on the encoder;
the decoder uses RoPE (adaptation note in DESIGN.md — shape-identical).
Decoder seq 4k/32k exceeds Whisper's trained 448 positions; shapes are the
assignment's and exercise the lowering, not the pretrained weights.
"""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", n_layers=6, d_model=512, n_heads=8,
        n_kv_heads=8, d_ff=2048, vocab=51865, act="gelu", norm="layernorm",
        encoder_layers=6, encoder_seq=1500, frontend="audio",
        remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, act="gelu", norm="layernorm",
        encoder_layers=2, encoder_seq=24, frontend="audio",
        q_chunk=16, kv_chunk=16, remat="none",
    )
