"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention
(arXiv:2401.04088).

56L, d_model=6144, 48H (kv=8), expert d_ff=16384, vocab=32768; SWA window
4096 per the assignment ⇒ ``long_500k`` runs with O(window) KV.
"""

from repro.models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", n_layers=56, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab=32768, act="swiglu",
        attn_kind="swa", local_window=4096,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=16384),
        remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, act="swiglu",
        attn_kind="swa", local_window=8,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        q_chunk=16, kv_chunk=16, remat="none",
    )
