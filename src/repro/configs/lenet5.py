"""LeNet-5 — the paper's own workload (§4.3), served through the VTA
compiler pipeline rather than the LM stack.  ``full()``/``smoke()`` return
the layer specs + weights bundle used by examples/lenet5_e2e.py."""

import dataclasses
from typing import List

from repro.core.layer_compiler import LayerSpec
from repro.models.lenet import (LeNetWeights, lenet5_random_weights,
                                lenet5_specs)


@dataclasses.dataclass
class LeNetBundle:
    weights: LeNetWeights
    specs: List[LayerSpec]


def full(seed: int = 0) -> LeNetBundle:
    w = lenet5_random_weights(seed=seed)
    return LeNetBundle(weights=w, specs=lenet5_specs(w))


def smoke(seed: int = 0) -> LeNetBundle:
    return full(seed)
