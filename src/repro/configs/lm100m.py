"""~100M-parameter decoder-only LM for the end-to-end training example
(examples/train_lm.py): 14L, d_model=640, 10H (kv=2), d_ff=2560,
vocab=4096 ⇒ ≈ 96M params."""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="lm100m", n_layers=14, d_model=640, n_heads=10, n_kv_heads=2,
        d_ff=2560, vocab=4096, act="swiglu", q_chunk=256, kv_chunk=256,
        remat="dots",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="lm100m-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512, act="swiglu", q_chunk=16, kv_chunk=16,
        remat="none",
    )
