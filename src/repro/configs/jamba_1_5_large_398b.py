"""jamba-1.5-large-398b [hybrid] — Mamba + attention 7:1 interleave, MoE
16e top-2 (arXiv:2403.19887).

72L, d_model=8192, 64H (kv=8), d_ff=24576, vocab=65536.  Every 8-layer
period holds 7 Mamba layers + 1 attention layer; MoE every other layer.
``long_500k`` runs: only the 9 attention layers hold full-length KV
(sequence-sharded), Mamba layers are O(1) state.
"""

from repro.models.config import ModelConfig, MoEConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", n_layers=72, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=24576, vocab=65536, act="swiglu",
        ssm_kind="mamba", ssm_ratio=7, mamba_d_state=16, mamba_expand=2,
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576, period=2),
        remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, act="swiglu",
        ssm_kind="mamba", ssm_ratio=3, mamba_d_state=4, mamba_expand=2,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, period=2),
        q_chunk=16, kv_chunk=16, remat="none",
    )
