"""nemotron-4-340b [dense] — GQA + squared-ReLU (arXiv:2402.16819).

96L, d_model=18432, 96H (kv=8), d_ff=73728, vocab=256000.  head_dim=192.
"""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b", n_layers=96, d_model=18432, n_heads=96,
        n_kv_heads=8, d_ff=73728, vocab=256000, act="sq_relu",
        remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", n_layers=3, d_model=96, n_heads=6,
        n_kv_heads=2, d_ff=384, vocab=512, act="sq_relu",
        q_chunk=16, kv_chunk=16, remat="none",
    )
