"""qwen2.5-3b [dense] — GQA + QKV bias (hf:Qwen/Qwen2.5).

36L, d_model=2048, 16H (kv=2), d_ff=11008, vocab=151936.  The QKV bias is
the paper's ``C = A·B + X`` accumulator-preload form on the VTA side.
"""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", n_layers=36, d_model=2048, n_heads=16,
        n_kv_heads=2, d_ff=11008, vocab=151936, act="swiglu", qkv_bias=True,
        rope_theta=1e6, remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=512, act="swiglu", qkv_bias=True,
        q_chunk=16, kv_chunk=16, remat="none",
    )
