"""gemma3-1b [dense] — 5:1 local:global attention, 128k context
(hf:google/gemma-3-1b-pt).

26L, d_model=1152, 4H (kv=1 ⇒ MQA), d_ff=6912, vocab=262144.
head_dim=256 (decoupled from d_model/n_heads, per the HF config).
Local layers: 512-token sliding window, RoPE θ=10k; global layers every
6th, RoPE θ=1M.  Tied embeddings.  ``long_500k`` runs: only the ~1/6
global layers hold full-length KV (sequence-sharded), local layers are
O(window).
"""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", n_layers=26, d_model=1152, n_heads=4,
        n_kv_heads=1, d_ff=6912, vocab=262144, head_dim=256,
        act="geglu", attn_kind="local_global", local_ratio=5,
        local_window=512, rope_theta=1e4, rope_theta_global=1e6,
        tie_embeddings=True, remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", n_layers=7, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=192, vocab=512, head_dim=32,
        act="geglu", attn_kind="local_global", local_ratio=2,
        local_window=8, rope_theta=1e4, rope_theta_global=1e6,
        tie_embeddings=True, q_chunk=16, kv_chunk=16, remat="none",
    )
