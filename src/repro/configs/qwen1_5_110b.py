"""qwen1.5-110b [dense] — GQA + QKV bias (hf:Qwen/Qwen1.5).

80L, d_model=8192, 64H (kv=8), d_ff=49152, vocab=152064.
"""

from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", n_layers=80, d_model=8192, n_heads=64,
        n_kv_heads=8, d_ff=49152, vocab=152064, act="swiglu", qkv_bias=True,
        rope_theta=1e6, remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", n_layers=3, d_model=64, n_heads=8,
        n_kv_heads=2, d_ff=192, vocab=512, act="swiglu", qkv_bias=True,
        q_chunk=16, kv_chunk=16, remat="none",
    )
