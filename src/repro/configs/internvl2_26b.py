"""internvl2-26b [vlm] — InternViT frontend STUB + InternLM2-20B backbone
(arXiv:2404.16821).

LM backbone: 48L, d_model=6144, 48H (kv=8), d_ff=16384, vocab=92553.
The vision tower is a stub per the assignment: ``input_specs`` provides a
(B, 256, 6144) precomputed patch-embedding prefix; sequence shapes count
the prefix inside seq_len.
"""

from repro.models.config import ModelConfig

VISION_PREFIX = 256


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", n_layers=48, d_model=6144, n_heads=48,
        n_kv_heads=8, d_ff=16384, vocab=92553, act="swiglu",
        frontend="vision", frontend_prefix=VISION_PREFIX, remat="full", causal_skip=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=512, act="swiglu",
        frontend="vision", frontend_prefix=8,
        q_chunk=16, kv_chunk=16, remat="none",
    )
