"""Optimizers: AdamW (f32 moments) and block-wise 8-bit Adam.

8-bit Adam (Dettmers-style, simplified to uniform block quantisation):
moments are stored int8 with one f32 absmax scale per 256-element block —
state is ~2.03 bytes/param instead of 8, which is what lets the ≥100B
assigned configs train on a 256-chip pod (DESIGN.md §4).  Moments are
dequantised, updated, and requantised inside the step; quantisation noise
behaves like a small amount of gradient noise (validated in tests against
f32 AdamW).

Both optimizers are pure pytree transforms (state mirrors the param tree),
so optimizer state inherits the parameters' FSDP×TP sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    eightbit: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ---------------------------------------------------------------------------
# Block-wise int8 moment quantisation
# ---------------------------------------------------------------------------

def scale_blocks(last: int) -> int:
    return -(-last // BLOCK)


def _q8(x: jax.Array, power: int = 2) -> Tuple[jax.Array, jax.Array]:
    """(..., L) → (int8 (..., L), f32 scales (..., L/BLOCK)) — blocks along
    the LAST axis, so the int8 moment keeps the parameter's shape and
    sharding (a flat layout would force a giant resharding collective in
    every optimizer step — measured in EXPERIMENTS.md §Perf).

    ``power`` gives a power-law code (the dynamic-quantisation analogue of
    bitsandbytes): value = sign·(|q|/127)^power·scale.  power=2 for the
    first moment, 4 for the second — linear int8 would zero the small
    entries of v within a block and blow up m/(√v+ε)."""
    last = x.shape[-1]
    nb = scale_blocks(last)
    pad = nb * BLOCK - last
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xp = xp.reshape(x.shape[:-1] + (nb, BLOCK))
    scale = jnp.max(jnp.abs(xp), axis=-1) / (127.0 ** power)
    safe = jnp.where(scale == 0, 1.0, scale)
    mag = (jnp.abs(xp) / safe[..., None]) ** (1.0 / power)
    q = (jnp.sign(xp) * jnp.clip(jnp.round(mag), 0, 127)).astype(jnp.int8)
    q = q.reshape(x.shape[:-1] + (nb * BLOCK,))[..., :last]
    return q, scale


def _dq8(q: jax.Array, scale: jax.Array, power: int = 2) -> jax.Array:
    last = q.shape[-1]
    nb = scale.shape[-1]
    pad = nb * BLOCK - last
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    qp = qp.reshape(q.shape[:-1] + (nb, BLOCK)).astype(jnp.float32)
    out = jnp.sign(qp) * (jnp.abs(qp) ** power) * scale[..., None]
    return out.reshape(q.shape[:-1] + (nb * BLOCK,))[..., :last]


class Moment8(NamedTuple):
    q: jax.Array        # int8, parameter-shaped
    scale: jax.Array    # f32, (..., last/BLOCK)


def _zeros_moment(p: jax.Array, eightbit: bool):
    if not eightbit:
        return jnp.zeros(p.shape, jnp.float32)
    return Moment8(jnp.zeros(p.shape, jnp.int8),
                   jnp.zeros(p.shape[:-1] + (scale_blocks(p.shape[-1]),),
                             jnp.float32))


def _read_moment(m, shape, power: int = 2):
    if isinstance(m, Moment8):
        return _dq8(m.q, m.scale, power)
    return m


def _write_moment(val: jax.Array, eightbit: bool, power: int = 2):
    if not eightbit:
        return val
    q, s = _q8(val, power)
    return Moment8(q, s)


# ---------------------------------------------------------------------------
# The optimizer
# ---------------------------------------------------------------------------

class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(cfg: AdamWConfig, params) -> AdamWState:
    mk = lambda p: _zeros_moment(p, cfg.eightbit)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(mk, params),
                      nu=jax.tree.map(mk, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState
                  ) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def leaf(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _read_moment(mu, g.shape, 2) + (1 - cfg.b1) * g
        v = cfg.b2 * _read_moment(nu, g.shape, 4) + (1 - cfg.b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.
        new_p = (p.astype(jnp.float32) - lr * (update + decay)).astype(p.dtype)
        return new_p, _write_moment(m, cfg.eightbit, 2), \
            _write_moment(v, cfg.eightbit, 4)

    is_m8 = lambda x: isinstance(x, Moment8)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu, is_leaf=is_m8)
    flat_nu = jax.tree.leaves(state.nu, is_leaf=is_m8)
    out = [leaf(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_mu, new_nu), metrics
