"""Functional VTA simulator (paper §5.1) — bit-accurate instruction interpreter.

Replaces the paper's extracted C++ functional simulator with a pure-numpy
interpreter that consumes exactly the artefacts the compiler emits: a DRAM
image (or the per-region segments) plus the instruction stream.  It is the
*oracle* every other execution path is validated against.  The vectorised
fast path lives in :mod:`repro.core.fast_simulator`; select it with
``run_program(prog, backend="fast")`` (or ``make_simulator``).

Semantics implemented:

* LOAD/STORE — 2-D strided DRAM<->SRAM moves with x/y zero-padding
  (``MemInsn``), per buffer (UOP/WGT/INP/ACC/OUT); mid-stream LOAD UOP
  re-fills (the §3.3 uop waves of multi-chunk programs, DESIGN.md §3) are
  ordinary compute-module loads;
* GEMM — Algorithm 1 verbatim, including ``reset``; int8×int8 products
  accumulated into int32 with wrap-around;
* ALU — MIN/MAX/ADD/SHR over ACC vectors, immediate or vector-pair form;
* FINISH — terminates execution;
* dependency flags — the 4 producer/consumer token queues of §2.3 are
  modelled as counters; a pop on an empty queue means the compiler emitted a
  hazard (the real hardware would deadlock), so the simulator raises.

Observability (§5.1): the simulator reports DRAM traffic, GeMM/ALU loop
counts and per-instruction execution order — the metrics the paper uses.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import isa
from .hwconfig import VTAConfig
from .layout import truncate_int8
from .program import VTAProgram


class VTAHazardError(RuntimeError):
    """A dependency-token pop on an empty queue: the instruction stream
    would deadlock the Load/Compute/Store modules on real hardware."""


class VTABoundsError(VTAHazardError, IndexError):
    """An SRAM or DRAM access outside the configured address space.

    Every simulator backend raises this *before* mutating any state, with
    the offending instruction fields in the message (DESIGN.md
    §Hardening).  Historically these paths surfaced as bare numpy
    ``IndexError``/``ValueError`` deep inside a gather — or, for
    padding that ran past an SRAM buffer, as a silent clip on the
    vectorised backends; the subclassing keeps ``IndexError`` callers
    working while making the fault typed and attributable."""


def module_of(insn) -> str:
    """Which VTA module executes ``insn`` (mirrors the VTA runtime):
    LOAD INP/WGT run on Load; LOAD UOP/ACC, GEMM and ALU on Compute;
    STORE OUT on Store."""
    if isinstance(insn, isa.MemInsn):
        if insn.opcode == isa.Opcode.STORE:
            return "store"
        if insn.memory_type in (isa.MemId.INP, isa.MemId.WGT):
            return "load"
        return "compute"
    return "compute"           # GEMM / ALU / FINISH


class TokenQueues:
    """The 4 producer/consumer dependency-token queues of §2.3, modelled as
    counters.  Shared by every simulator backend: a pop on an empty queue
    means the compiler emitted a hazard (real hardware would deadlock)."""

    _PREV = {"load": None, "compute": "load", "store": "compute"}
    _NEXT = {"load": "compute", "compute": "store", "store": None}

    def __init__(self) -> None:
        self.counters: Dict[Tuple[str, str], int] = {
            ("load", "compute"): 0, ("compute", "load"): 0,
            ("compute", "store"): 0, ("store", "compute"): 0,
        }
        # Accounting for SimReport (DESIGN.md §Pipeline): total token
        # traffic and the deepest any queue ever got — the pipelined
        # schedule shows up as high_water 2 on the producer queues.
        self.pops = 0
        self.pushes = 0
        self.high_water = 0

    def _pop(self, src: Optional[str], dst: str) -> None:
        if src is None:
            raise VTAHazardError(f"{dst}: pop from nonexistent neighbour")
        if self.counters[(src, dst)] <= 0:
            raise VTAHazardError(
                f"dependency hazard: {dst} pops empty queue from {src}")
        self.counters[(src, dst)] -= 1
        self.pops += 1

    def _push(self, src: str, dst: Optional[str]) -> None:
        if dst is None:
            raise VTAHazardError(f"{src}: push to nonexistent neighbour")
        self.counters[(src, dst)] += 1
        self.pushes += 1
        if self.counters[(src, dst)] > self.high_water:
            self.high_water = self.counters[(src, dst)]

    def pre(self, insn) -> None:
        mod = module_of(insn)
        if insn.dep.pop_prev:
            self._pop(self._PREV[mod], mod)
        if insn.dep.pop_next:
            self._pop(self._NEXT[mod], mod)

    def post(self, insn) -> None:
        mod = module_of(insn)
        if insn.dep.push_prev:
            self._push(mod, self._PREV[mod])
        if insn.dep.push_next:
            self._push(mod, self._NEXT[mod])

    def account(self, report: "SimReport") -> None:
        """Fold the token traffic into a :class:`SimReport` (additive, so
        multi-layer/network runs accumulate across streams)."""
        report.dep_pops += self.pops
        report.dep_pushes += self.pushes
        report.dep_queue_high_water = max(report.dep_queue_high_water,
                                          self.high_water)
        self.pops = 0
        self.pushes = 0


@dataclasses.dataclass
class SimReport:
    """What the functional simulator can observe (§5.1)."""

    gemm_loops: int = 0            # non-reset GeMM loops (the 2942 metric)
    gemm_reset_loops: int = 0
    alu_loops: int = 0
    dram_bytes_read: int = 0
    dram_bytes_written: int = 0
    insn_executed: int = 0
    insn_trace: List[str] = dataclasses.field(default_factory=list)
    # Integrity counters (DESIGN.md §Hardening) — populated only when the
    # simulator is built with ``count_overflows=True``; the conformance
    # suites compare loop/traffic fields, so these ride along freely.
    acc_overflow_lanes: int = 0    # int32 lanes that wrapped in GEMM/ALU
    acc_saturation_lanes: int = 0  # ACC lanes outside int8 at OUT commit
    # §2.3 dependency-token traffic (DESIGN.md §Pipeline): pops/pushes
    # processed and the deepest any of the four queues ever got —
    # serialized streams stay at 1; the double-buffered schedule reaches 2.
    dep_pops: int = 0
    dep_pushes: int = 0
    dep_queue_high_water: int = 0

    @property
    def dram_bytes_total(self) -> int:
        return self.dram_bytes_read + self.dram_bytes_written


def _wrap32(x: np.ndarray) -> np.ndarray:
    return x.astype(np.int64).astype(np.int32)


class FunctionalSimulator:
    """Bit-accurate VTA functional simulator."""

    def __init__(self, cfg: VTAConfig, dram: np.ndarray, *, trace: bool = False,
                 count_overflows: bool = False):
        if dram.dtype != np.uint8:
            raise TypeError("dram image must be uint8")
        self.cfg = cfg
        self.dram = dram.copy()
        self.trace = trace
        self.count_overflows = count_overflows
        bs = cfg.block_size
        # SRAM buffers, in structure units.
        self.uop_buf = np.zeros((cfg.uop_buff_entries, 3), dtype=np.int64)
        self.inp_buf = np.zeros((cfg.inp_buff_vectors, bs), dtype=np.int8)
        self.wgt_buf = np.zeros((cfg.wgt_buff_matrices, bs, bs), dtype=np.int8)
        self.acc_buf = np.zeros((cfg.acc_buff_vectors, bs), dtype=np.int32)
        self.out_buf = np.zeros((cfg.out_buff_vectors, bs), dtype=np.int8)
        # Dependency-token queues between modules (§2.3).
        self.tokens = TokenQueues()
        self.report = SimReport()

    # ------------------------------------------------------------------
    # Memory instructions
    # ------------------------------------------------------------------
    def _mem_view(self, mem: isa.MemId):
        return {
            isa.MemId.UOP: self.uop_buf,
            isa.MemId.INP: self.inp_buf,
            isa.MemId.WGT: self.wgt_buf,
            isa.MemId.ACC: self.acc_buf,
            isa.MemId.OUT: self.out_buf,
        }[mem]

    _MEM_KIND = {
        isa.MemId.UOP: "uop", isa.MemId.INP: "inp", isa.MemId.WGT: "wgt",
        isa.MemId.ACC: "acc", isa.MemId.OUT: "out",
    }

    def _struct_from_dram(self, kind: str, log_addr: int) -> np.ndarray:
        cfg = self.cfg
        nbytes = cfg.elem_bytes(kind)
        start = log_addr * nbytes
        raw = self.dram[start:start + nbytes]
        if len(raw) < nbytes:
            raise IndexError(
                f"DRAM read out of range: {kind} logical @{log_addr:#x}")
        self.report.dram_bytes_read += nbytes
        bs = cfg.block_size
        if kind == "uop":
            word = int.from_bytes(raw.tobytes(), "little")
            acc, inp, wgt = isa._unpack(word, isa.Uop.W)
            return np.array([acc, inp, wgt], dtype=np.int64)
        if kind == "inp":
            return raw.view(np.int8).reshape(bs)
        if kind == "wgt":
            return raw.view(np.int8).reshape(bs, bs)
        if kind == "acc":
            return raw.view("<i4").reshape(bs).astype(np.int32)
        raise ValueError(kind)

    def _struct_to_dram(self, kind: str, log_addr: int, data: np.ndarray) -> None:
        cfg = self.cfg
        nbytes = cfg.elem_bytes(kind)
        start = log_addr * nbytes
        if start + nbytes > len(self.dram):
            raise IndexError(
                f"DRAM write out of range: {kind} logical @{log_addr:#x}")
        self.dram[start:start + nbytes] = np.frombuffer(
            np.ascontiguousarray(data).tobytes(), dtype=np.uint8)
        self.report.dram_bytes_written += nbytes

    def _check_mem_bounds(self, insn: isa.MemInsn) -> None:
        """Reject out-of-range SRAM/DRAM spans *before* any state mutates.

        Shared bounds model for every backend (DESIGN.md §Hardening):
        LOAD touches ``(pads+y_size) × (pads+x_size)`` consecutive SRAM
        structs from ``sram_base`` (padding writes zeros, so it counts);
        STORE consumes ``y_size × x_size``.  DRAM addresses grow
        monotonically with y, so the last element of the last row bounds
        the transfer."""
        kind = self._MEM_KIND[insn.memory_type]
        cap = self._mem_view(insn.memory_type).shape[0]
        is_load = insn.opcode == isa.Opcode.LOAD
        if is_load:
            row_w = insn.x_pad_0 + insn.x_size + insn.x_pad_1
            span = (insn.y_pad_0 + insn.y_size + insn.y_pad_1) * row_w
        else:
            span = insn.y_size * insn.x_size
        if span and insn.sram_base + span > cap:
            raise VTABoundsError(
                f"{insn.opcode.name} {kind.upper()} SRAM span "
                f"[{insn.sram_base}, {insn.sram_base + span}) exceeds "
                f"buffer capacity {cap} (x_size={insn.x_size} "
                f"y_size={insn.y_size} pads=({insn.x_pad_0},{insn.x_pad_1},"
                f"{insn.y_pad_0},{insn.y_pad_1}))")
        if insn.y_size and insn.x_size:
            nbytes = self.cfg.elem_bytes(kind)
            last = (insn.dram_base + (insn.y_size - 1) * insn.x_stride
                    + insn.x_size - 1)
            end = (last + 1) * nbytes
            if end > self.dram_nbytes():
                raise VTABoundsError(
                    f"{insn.opcode.name} {kind.upper()} DRAM span ends at "
                    f"byte {end} > image size {self.dram_nbytes()} "
                    f"(dram_base={insn.dram_base:#x} x_size={insn.x_size} "
                    f"y_size={insn.y_size} x_stride={insn.x_stride})")

    def dram_nbytes(self) -> int:
        return len(self.dram)

    def _exec_mem(self, insn: isa.MemInsn) -> None:
        kind = self._MEM_KIND[insn.memory_type]
        if (insn.opcode == isa.Opcode.STORE
                and insn.memory_type == isa.MemId.UOP):
            raise ValueError("STORE UOP is not a valid VTA instruction")
        self._check_mem_bounds(insn)
        buf = self._mem_view(insn.memory_type)
        if insn.opcode == isa.Opcode.LOAD:
            sram = insn.sram_base
            for y in range(insn.y_pad_0):
                for _ in range(insn.x_pad_0 + insn.x_size + insn.x_pad_1):
                    buf[sram] = 0
                    sram += 1
            for y in range(insn.y_size):
                for _ in range(insn.x_pad_0):
                    buf[sram] = 0
                    sram += 1
                dram = insn.dram_base + y * insn.x_stride
                for x in range(insn.x_size):
                    buf[sram] = self._struct_from_dram(kind, dram + x)
                    sram += 1
                for _ in range(insn.x_pad_1):
                    buf[sram] = 0
                    sram += 1
            for y in range(insn.y_pad_1):
                for _ in range(insn.x_pad_0 + insn.x_size + insn.x_pad_1):
                    buf[sram] = 0
                    sram += 1
        else:  # STORE (OUT only on real VTA)
            sram = insn.sram_base
            for y in range(insn.y_size):
                dram = insn.dram_base + y * insn.x_stride
                for x in range(insn.x_size):
                    self._struct_to_dram(kind, dram + x, buf[sram])
                    sram += 1

    # ------------------------------------------------------------------
    # GEMM — Algorithm 1, verbatim loop structure.
    # ------------------------------------------------------------------
    def _check_tensor_bounds(self, t, *, is_alu: bool) -> None:
        """Static pre-check of every index a GEMM/ALU lattice will touch.

        The maximum index per operand is ``max_outer_offset + max(uop
        field)`` because iteration offsets and uop entries are both
        non-negative; checking the maximum before the loop keeps the
        per-element body unguarded (and un-mutated on failure)."""
        what = "ALU" if is_alu else "GEMM"
        if t.uop_end > self.uop_buf.shape[0]:
            raise VTABoundsError(
                f"{what} uop range [{t.uop_bgn}, {t.uop_end}) exceeds UOP "
                f"buffer capacity {self.uop_buf.shape[0]}")
        n_uop = max(0, t.uop_end - t.uop_bgn)
        if n_uop == 0 or t.iter_out <= 0 or t.iter_in <= 0:
            return
        uops = self.uop_buf[t.uop_bgn:t.uop_end]
        acc_cap = self.acc_buf.shape[0]
        if is_alu:
            d_off = ((t.iter_out - 1) * t.dst_factor_out
                     + (t.iter_in - 1) * t.dst_factor_in)
            hi = d_off + int(uops[:, 0].max())
            if hi >= acc_cap:
                raise VTABoundsError(
                    f"ALU ACC dst index {hi} >= capacity {acc_cap} "
                    f"(uop range [{t.uop_bgn}, {t.uop_end}))")
            if not t.use_imm:
                s_off = ((t.iter_out - 1) * t.src_factor_out
                         + (t.iter_in - 1) * t.src_factor_in)
                hi = s_off + int(uops[:, 1].max())
                if hi >= acc_cap:
                    raise VTABoundsError(
                        f"ALU ACC src index {hi} >= capacity {acc_cap} "
                        f"(uop range [{t.uop_bgn}, {t.uop_end}))")
            return
        x_off = ((t.iter_out - 1) * t.acc_factor_out
                 + (t.iter_in - 1) * t.acc_factor_in)
        hi = x_off + int(uops[:, 0].max())
        if hi >= acc_cap:
            raise VTABoundsError(
                f"GEMM ACC index {hi} >= capacity {acc_cap} "
                f"(uop range [{t.uop_bgn}, {t.uop_end}))")
        if not t.reset:
            a_off = ((t.iter_out - 1) * t.inp_factor_out
                     + (t.iter_in - 1) * t.inp_factor_in)
            hi = a_off + int(uops[:, 1].max())
            if hi >= self.inp_buf.shape[0]:
                raise VTABoundsError(
                    f"GEMM INP index {hi} >= capacity "
                    f"{self.inp_buf.shape[0]} "
                    f"(uop range [{t.uop_bgn}, {t.uop_end}))")
            w_off = ((t.iter_out - 1) * t.wgt_factor_out
                     + (t.iter_in - 1) * t.wgt_factor_in)
            hi = w_off + int(uops[:, 2].max())
            if hi >= self.wgt_buf.shape[0]:
                raise VTABoundsError(
                    f"GEMM WGT index {hi} >= capacity "
                    f"{self.wgt_buf.shape[0]} "
                    f"(uop range [{t.uop_bgn}, {t.uop_end}))")

    def _exec_gemm(self, g: isa.GemInsn) -> None:
        self._check_tensor_bounds(g, is_alu=False)
        n_uop = max(0, g.uop_end - g.uop_bgn)
        if g.reset:
            for i_out in range(g.iter_out):
                for i_in in range(g.iter_in):
                    for u in range(g.uop_bgn, g.uop_end):
                        acc0, _, _ = self.uop_buf[u]
                        x = (i_out * g.acc_factor_out + i_in * g.acc_factor_in
                             + int(acc0))
                        self.acc_buf[x] = 0
            self.report.gemm_reset_loops += g.iter_out * g.iter_in * n_uop
            return
        for i_out in range(g.iter_out):
            for i_in in range(g.iter_in):
                for u in range(g.uop_bgn, g.uop_end):
                    acc0, inp0, wgt0 = (int(v) for v in self.uop_buf[u])
                    x = i_out * g.acc_factor_out + i_in * g.acc_factor_in + acc0
                    a = i_out * g.inp_factor_out + i_in * g.inp_factor_in + inp0
                    w = i_out * g.wgt_factor_out + i_in * g.wgt_factor_in + wgt0
                    A = self.inp_buf[a].astype(np.int32)
                    W = self.wgt_buf[w].astype(np.int32)
                    # acc[x] += A · Wᵀ  (W stored transposed ⇒ A·B, §2.3)
                    prod = (A[None, :] * W).sum(axis=1, dtype=np.int64)
                    wide = self.acc_buf[x].astype(np.int64) + prod
                    wrapped = _wrap32(wide)
                    if self.count_overflows:
                        self.report.acc_overflow_lanes += int(
                            np.count_nonzero(wide != wrapped))
                    self.acc_buf[x] = wrapped
        self.report.gemm_loops += g.iter_out * g.iter_in * n_uop

    # ------------------------------------------------------------------
    def _exec_alu(self, a: isa.AluInsn) -> None:
        self._check_tensor_bounds(a, is_alu=True)
        n_uop = max(0, a.uop_end - a.uop_bgn)
        for i_out in range(a.iter_out):
            for i_in in range(a.iter_in):
                for u in range(a.uop_bgn, a.uop_end):
                    dst0, src0, _ = (int(v) for v in self.uop_buf[u])
                    d = i_out * a.dst_factor_out + i_in * a.dst_factor_in + dst0
                    s = i_out * a.src_factor_out + i_in * a.src_factor_in + src0
                    x = self.acc_buf[d].astype(np.int64)
                    y = (np.int64(a.imm) if a.use_imm
                         else self.acc_buf[s].astype(np.int64))
                    if a.alu_opcode == isa.AluOp.MIN:
                        r = np.minimum(x, y)
                    elif a.alu_opcode == isa.AluOp.MAX:
                        r = np.maximum(x, y)
                    elif a.alu_opcode == isa.AluOp.ADD:
                        r = x + y
                    elif a.alu_opcode == isa.AluOp.SHR:
                        # y is the immediate or the acc[s] vector; either
                        # way the shift amount is the low 5 bits.
                        r = x >> (y & 31)
                    else:
                        raise ValueError(a.alu_opcode)
                    wrapped = _wrap32(r)
                    if self.count_overflows:
                        self.report.acc_overflow_lanes += int(
                            np.count_nonzero(r != wrapped))
                    self.acc_buf[d] = wrapped
        self.report.alu_loops += a.iter_out * a.iter_in * n_uop

    # ------------------------------------------------------------------
    def _commit_out(self) -> None:
        """ACC → OUT truncation (§2.1: OUT vectors are truncated ACC)."""
        if self.count_overflows:
            self.report.acc_saturation_lanes += int(np.count_nonzero(
                (self.acc_buf < -128) | (self.acc_buf > 127)))
        self.out_buf[:] = truncate_int8(self.acc_buf)

    def run(self, instructions, *, fault_hook=None) -> SimReport:
        """Execute the stream.  ``fault_hook(sim, insn_idx)`` fires before
        each instruction (dependency pops included) — the injection point
        the harden subsystem uses for SRAM/transient faults and watchdog
        deadline checks (DESIGN.md §Hardening)."""
        for i, insn in enumerate(instructions):
            if fault_hook is not None:
                fault_hook(self, i)
            self.tokens.pre(insn)
            if isinstance(insn, isa.MemInsn):
                if insn.opcode == isa.Opcode.STORE:
                    self._commit_out()
                self._exec_mem(insn)
                tag = f"{insn.opcode.name} {insn.memory_type.name}"
            elif isinstance(insn, isa.GemInsn):
                self._exec_gemm(insn)
                tag = f"GEMM{' reset' if insn.reset else ''}"
            elif isinstance(insn, isa.AluInsn):
                self._exec_alu(insn)
                tag = f"ALU {insn.alu_opcode.name}"
            elif isinstance(insn, isa.FinishInsn):
                tag = "FINISH"
            else:
                raise TypeError(insn)
            self.report.insn_executed += 1
            if self.trace:
                self.report.insn_trace.append(tag)
            self.tokens.post(insn)
            if isinstance(insn, isa.FinishInsn):
                break
        self.tokens.account(self.report)
        return self.report


# ---------------------------------------------------------------------------
# Backend selection + program-level drivers
# ---------------------------------------------------------------------------

BACKENDS = ("oracle", "fast", "batched", "pallas")


def make_simulator(cfg: VTAConfig, dram: np.ndarray, *,
                   backend: str = "oracle", trace: bool = False,
                   count_overflows: bool = False):
    """Instantiate a simulator backend over a DRAM image.

    ``"oracle"`` is the per-struct Python interpreter above — the
    correctness anchor.  ``"fast"`` is the vectorised plan-compiling
    interpreter of :mod:`repro.core.fast_simulator`, bit-exact against the
    oracle but executing each instruction as batched numpy ops.
    ``"batched"`` takes a ``(batch, nbytes)`` DRAM *stack* and executes the
    stream once over all images (DESIGN.md §Batching), bit-identical to
    looping ``"oracle"`` over the stack's rows.  ``"pallas"`` executes
    compiled programs as fused MXU kernel calls
    (:mod:`repro.core.pallas_backend`, ``interpret=True`` off-TPU) —
    bit-identical to the oracle on its default truncation path.
    """
    if backend == "oracle":
        return FunctionalSimulator(cfg, dram, trace=trace,
                                   count_overflows=count_overflows)
    if backend == "fast":
        from .fast_simulator import FastSimulator
        return FastSimulator(cfg, dram, trace=trace,
                             count_overflows=count_overflows)
    if backend == "batched":
        from .fast_simulator import BatchFastSimulator
        return BatchFastSimulator(cfg, dram, trace=trace,
                                  count_overflows=count_overflows)
    if backend == "pallas":
        from .pallas_backend import (BatchPallasSimulator, PallasSimulator)
        cls = BatchPallasSimulator if dram.ndim == 2 else PallasSimulator
        return cls(cfg, dram, trace=trace, count_overflows=count_overflows)
    raise ValueError(f"unknown simulator backend {backend!r}; "
                     f"expected one of {BACKENDS}")


def run_instructions(sim, instructions, *, program: Optional[VTAProgram] = None,
                     fault_hook=None) -> SimReport:
    """Run an instruction stream on either backend.

    On the fast backend, passing ``program`` reuses (or populates) the
    instruction plan cached on it, so repeated executions of the same
    program (batch serving) skip plan compilation entirely.  On the pallas
    backend ``program`` is required — the engine lowers the compiled
    program itself, not the instruction stream.
    ``fault_hook(sim, insn_idx)`` is forwarded to the backend's run loop.
    """
    from .fast_simulator import FastSimulator, plan_for
    from .pallas_backend import PallasSimulator
    if isinstance(sim, PallasSimulator):
        if program is None:
            raise ValueError(
                "the pallas backend executes compiled programs; pass "
                "program= to run_instructions (raw instruction streams "
                "need a simulator backend)")
        return sim.run_program(program, fault_hook=fault_hook)
    if isinstance(sim, FastSimulator) and program is not None:
        return sim.run(instructions, plan=plan_for(program),
                       fault_hook=fault_hook)
    return sim.run(instructions, fault_hook=fault_hook)


def run_program(prog: VTAProgram, *, trace: bool = False,
                backend: str = "oracle", fault_hook=None,
                count_overflows: bool = False
                ) -> Tuple[np.ndarray, SimReport]:
    """Execute a compiled program; return (decoded result matrix, report).

    The decoded matrix is the *unpadded* (M, N) int8 result, reconstructed
    from the OUT region exactly as the §4.2 host-side reshaping does.
    ``backend="fast"`` selects the vectorised interpreter with the plan
    cached on ``prog``; ``backend="batched"`` routes through the batch
    engine with a batch of one (uniform dispatch — the real batched entry
    point is :func:`run_program_batch`); ``backend="pallas"`` executes the
    program as a fused MXU kernel call (truncation path — bit-identical to
    the oracle; see :mod:`repro.core.pallas_backend`).
    """
    if backend == "batched":
        outs, report = run_program_batch(prog, batch=1, trace=trace,
                                         fault_hook=fault_hook,
                                         count_overflows=count_overflows)
        return outs[0], report
    sim = make_simulator(prog.config, prog.dram_image(),
                         backend=backend, trace=trace,
                         count_overflows=count_overflows)
    report = run_instructions(sim, prog.instructions, program=prog,
                              fault_hook=fault_hook)
    out = decode_out_region(prog, sim.dram)
    return out, report


def run_program_batch(prog: VTAProgram, *, batch: Optional[int] = None,
                      dram_stack: Optional[np.ndarray] = None,
                      backend: str = "batched",
                      trace: bool = False, fault_hook=None,
                      count_overflows: bool = False
                      ) -> Tuple[np.ndarray, SimReport]:
    """Execute one compiled program over a batch of DRAM images.

    Either pass ``dram_stack`` — a ``(batch, nbytes)`` uint8 stack whose
    rows are per-image DRAM images (typically the program's own image with
    per-request INP regions staged in) — or just ``batch`` to replicate
    ``prog.dram_image()``.  The instruction plan is compiled once and
    cached on ``prog`` (:func:`~repro.core.fast_simulator.plan_for`), so
    repeated calls pay only the array work.  ``backend="pallas"`` executes
    the stack through the fused-kernel engine instead (one stacked MXU
    call when the batch shares weights).  Returns the stacked decoded
    ``(batch, M, N)`` results and the batch-total report.
    """
    if backend not in ("batched", "pallas"):
        raise ValueError(
            f"run_program_batch supports backend='batched' or 'pallas', "
            f"got {backend!r}")
    if dram_stack is None:
        if batch is None:
            raise ValueError("pass either dram_stack or batch")
        image = prog.dram_image()
        dram_stack = np.broadcast_to(image, (batch, image.size)).copy()
    elif batch is not None and batch != dram_stack.shape[0]:
        raise ValueError(
            f"batch={batch} does not match dram_stack rows "
            f"{dram_stack.shape[0]}")
    sim = make_simulator(prog.config, dram_stack, backend=backend,
                         trace=trace, count_overflows=count_overflows)
    report = run_instructions(sim, prog.instructions, program=prog,
                              fault_hook=fault_hook)
    return decode_out_region_batch(prog, sim.dram), report


def decode_out_region(prog: VTAProgram, dram: np.ndarray) -> np.ndarray:
    """§4.2 stage (i): binary-decode OUT, unsplit blocks, remove padding."""
    cfg = prog.config
    meta = prog.output_meta
    if meta is None:
        raise ValueError("program has no output metadata")
    region = prog.regions["out"]
    start = region.phys_addr - prog.allocator.offset
    raw = dram[start:start + region.nbytes].view(np.int8)
    bs = cfg.block_size
    rh = meta.row_height
    vecs = raw.reshape(meta.block_rows * meta.block_cols * rh, bs)
    blocks = vecs.reshape(meta.block_rows, meta.block_cols, rh, bs)
    full = blocks.transpose(0, 2, 1, 3).reshape(meta.block_rows * rh,
                                                meta.block_cols * bs)
    m, n = meta.valid_shape
    return np.ascontiguousarray(full[:m, :n])


def decode_out_region_batch(prog: VTAProgram,
                            dram_stack: np.ndarray) -> np.ndarray:
    """§4.2 stage (i) over a ``(batch, nbytes)`` DRAM stack → (batch, M, N).

    The per-image decode is pure reshape/transpose, so the batch axis rides
    along for free — one call replaces ``batch`` :func:`decode_out_region`
    calls on the serve path."""
    cfg = prog.config
    meta = prog.output_meta
    if meta is None:
        raise ValueError("program has no output metadata")
    region = prog.regions["out"]
    start = region.phys_addr - prog.allocator.offset
    raw = dram_stack[:, start:start + region.nbytes].view(np.int8)
    bs = cfg.block_size
    rh = meta.row_height
    b = dram_stack.shape[0]
    blocks = raw.reshape(b, meta.block_rows, meta.block_cols, rh, bs)
    full = blocks.transpose(0, 1, 3, 2, 4).reshape(
        b, meta.block_rows * rh, meta.block_cols * bs)
    m, n = meta.valid_shape
    return np.ascontiguousarray(full[:, :m, :n])


def verify_program(prog: VTAProgram, *, trace: bool = False,
                   backend: str = "oracle") -> SimReport:
    """Run + assert the simulator output equals the compiler's oracle."""
    out, report = run_program(prog, trace=trace, backend=backend)
    m, n = prog.output_meta.valid_shape
    expected = prog.expected_out[:m, :n]
    np.testing.assert_array_equal(out, expected,
                                  err_msg=f"program {prog.name!r} mismatch")
    return report
