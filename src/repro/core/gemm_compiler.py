"""Operations definition: matrix op → VTA instructions + UOPs (paper §3.3).

``compile_matmul`` lowers ``C = A × B + X`` followed by element-wise ALU
post-ops down to a :class:`~repro.core.program.VTAProgram`:

* data definition (pad → split → binarise) per §3.2;
* DRAM allocation in the TVM reference order (INP, WGT, [ACC], OUT, UOP,
  INSN), each region on a fresh 4 KiB page (§2.2);
* the blocked-GEMM schedule of Fig. 7/8: ``LP_OUT = λ``,
  ``LP_IN = row_height``, one UOP per output block
  ``(ACC_IDX, INP_IDX, WGT_IDX) = ((i·β+j)·rh, (i·λ)·rh, j)``;
* buffer-capacity chunking (§3.3: "If the data do not fit into the buffers,
  steps 2 to 5 must be repeated");
* multi-chunk ALU re-indexing (DESIGN.md §3): indexed-imm and vector-pair
  ALU programs carry *global* result-vector indices; for every SRAM chunk
  the compiler rewrites them against the chunk's local ACC window, and the
  chunk boundaries are aligned so that no (dst, src) pair ever straddles
  two chunks;
* on-VTA residual adds (DESIGN.md §Graph): an :class:`AluResidualOp` in the
  post-op list merges a second int32 operand — ACC-loaded per chunk beside
  the result window, its own ``res`` DRAM region — with one factor-form
  vector-vector ALU ADD (plus an optional scale-equalising SHR), the chunk
  planner halving the ACC budget so both windows fit;
* UOP wave streaming (DESIGN.md §3): when a program needs more micro-ops
  than the UOP buffer holds, the uop stream is split into *waves* — each
  wave is a contiguous DRAM run loaded with a compute-module LOAD_UOP right
  before the first instruction that consumes it (SRAM slot 0 permanently
  holds the reset uop, so resets and simple-immediate ALU ops survive every
  wave switch);
* dependency flags wiring the Load/Compute/Store queues (§2.3), validated by
  the simulator's token checker.

The §5.1 "GeMM loop" metric falls out of the generated ``iter_out × iter_in
× n_uop`` products — LeNet-5 totals 2942 by construction (see
``tests/test_lenet_e2e.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import cycle_model, isa, pipeline_schedule
from .dram import DramAllocator
from .errors import CompileError
from .hwconfig import VTAConfig, vta_default
from .layout import (matrix_padding, matrix_splitting, binarize_blocks,
                     should_pad_height, pad_to_multiple)
from .program import OutputMeta, VTAProgram


# ---------------------------------------------------------------------------
# ALU post-op specification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AluImmOp:
    """Element-wise op with an immediate, applied to every result vector.

    ``relu``  → MAX(x, 0); ``shr`` → arithmetic shift right (requant);
    ``add``/``min``/``max`` with an immediate.
    """

    op: isa.AluOp
    imm: int = 0

    @staticmethod
    def relu() -> "AluImmOp":
        return AluImmOp(isa.AluOp.MAX, 0)

    @staticmethod
    def shr(shift: int) -> "AluImmOp":
        return AluImmOp(isa.AluOp.SHR, shift)


@dataclasses.dataclass(frozen=True)
class AluPairOp:
    """Vector-pair op ``acc[dst] = op(acc[dst], acc[src])`` over an explicit
    (dst, src) list — used for region ops such as average pooling (ADD
    pairs followed by an ``AluIndexedImmOp`` SHR) or max pooling (MAX
    pairs).  Indices are global result-vector indices (block-major); on
    multi-chunk results each pair is re-indexed against the ACC window of
    the chunk that holds it, and the chunk plan keeps both ends of a pair
    inside the same chunk."""

    op: isa.AluOp
    pairs: Tuple[Tuple[int, int], ...]


@dataclasses.dataclass(frozen=True)
class AluIndexedImmOp:
    """Immediate op applied to an explicit list of result-vector indices.
    Indices are global (block-major) and are re-indexed per chunk."""

    op: isa.AluOp
    imm: int
    indices: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class AluResidualOp:
    """Vector-vector op against a *second ACC-resident operand* — the
    on-device residual add of DESIGN.md §Graph.

    The compiler loads the program's ``residual`` matrix (a second int32
    (M, N) operand, e.g. the skip activation of a ResNet block) into the
    ACC SRAM *beside* the chunk's result window (sram offset = chunk
    result size), then emits one factor-form ``AluInsn`` per chunk:
    ``acc[v] = op(acc[v], acc[res_base + v])`` for every result vector
    ``v`` — a true two-operand TensorAlu instruction, not a host-side
    merge.  ``pre_shift > 0`` first applies an SHR immediate to the loaded
    residual window (scale equalisation across a branch join, planned by
    the graph requant pass).  Chunk planning halves the ACC budget when a
    residual operand is present so both windows always fit.
    """

    op: isa.AluOp = isa.AluOp.ADD
    pre_shift: int = 0


AluSpec = (AluImmOp, AluPairOp, AluIndexedImmOp, AluResidualOp)


# ---------------------------------------------------------------------------
# Chunk geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """How the α×λ×β block grid is tiled to fit the SRAM buffers.

    ``alpha_segs``/``beta_segs`` are the actual ``(start, size)`` tilings
    of the α/β axes.  Segments are at most ``alpha_c``/``beta_c`` wide but
    may be smaller: when pair ALU programs are present the boundaries are
    aligned so that no (dst, src) pair straddles two chunks (the
    pool-window alignment of DESIGN.md §3)."""

    alpha: int
    lam: int
    beta: int
    alpha_c: int
    lam_c: int
    beta_c: int
    row_height: int
    alpha_segs: Tuple[Tuple[int, int], ...] = ()
    beta_segs: Tuple[Tuple[int, int], ...] = ()
    # ACC windows resident per chunk: 1 normally, 2 when the program holds
    # a residual operand beside the result (AluResidualOp).
    acc_copies: int = 1
    # Planned against halved buffer budgets so loads/stores can ping-pong
    # between buffer halves (schedule="pipelined", DESIGN.md §Pipeline).
    double_buffer: bool = False

    @property
    def n_chunks(self) -> int:
        if self.alpha_segs and self.beta_segs:
            return len(self.alpha_segs) * len(self.beta_segs)
        ceil = lambda a, b: -(-a // b)
        return ceil(self.alpha, self.alpha_c) * ceil(self.beta, self.beta_c)

    @property
    def single_chunk(self) -> bool:
        return (self.alpha_c, self.lam_c, self.beta_c) == (
            self.alpha, self.lam, self.beta)


def _segment(total: int, chunk: int, groups: Sequence[Tuple[int, int]] = ()
             ) -> Tuple[Tuple[int, int], ...]:
    """Tile ``[0, total)`` into ``(start, size)`` runs of at most ``chunk``.

    ``groups`` are inclusive ``(lo, hi)`` index intervals that must stay
    within one run (pair ALU programs read both ends of a pair from the
    same ACC window).  Boundaries are chosen greedily at the largest
    admissible cut; a group wider than ``chunk`` is a hard error.
    """
    if not groups:
        return tuple((s, min(chunk, total - s))
                     for s in range(0, total, chunk))
    ok = np.ones(total + 1, dtype=bool)
    for lo, hi in groups:
        ok[lo + 1:hi + 1] = False     # a cut at b splits (lo,hi) iff lo<b<=hi
    segs: List[Tuple[int, int]] = []
    cur = 0
    while cur < total:
        nxt = -1
        for b in range(min(total, cur + chunk), cur, -1):
            if ok[b]:
                nxt = b
                break
        if nxt <= cur:
            raise CompileError(
                f"ALU pair group spans more than one SRAM chunk (chunk "
                f"capacity {chunk} at offset {cur} of {total}); shrink the "
                f"pair groups or use a larger accumulator buffer",
                constraint="alu-pair-group-chunk")
        segs.append((cur, nxt - cur))
        cur = nxt
    return tuple(segs)


def plan_chunks(cfg: VTAConfig, alpha: int, lam: int, beta: int,
                row_height: int, *,
                row_groups: Sequence[Tuple[int, int]] = (),
                col_groups: Sequence[Tuple[int, int]] = (),
                acc_copies: int = 1,
                double_buffer: bool = False,
                max_lam_c: Optional[int] = None,
                max_alpha_c: Optional[int] = None) -> ChunkPlan:
    """Greedy deterministic tiling honouring every buffer capacity.

    ``row_groups``/``col_groups`` are inclusive block-row/block-col
    intervals that must not straddle a chunk boundary — derived from pair
    ALU programs (both ends of a pair must share one ACC window).
    ``acc_copies=2`` halves the per-chunk ACC budget so a residual operand
    window (:class:`AluResidualOp`) fits beside the result window.

    ``double_buffer`` halves every buffer budget again (INP/WGT per load
    group, ACC per chunk) and reserves a second pinned UOP slot so the
    pipelined schedule can ping-pong producers and consumers between
    buffer halves (DESIGN.md §Pipeline); the odd-phase store window sits
    at ``acc_buff/2``, shrinking the OUT budget accordingly.
    ``max_lam_c``/``max_alpha_c`` cap the tile sizes below the buffer
    limits — the makespan-driven planner uses them to generate split
    candidates (more load groups / more chunks = more overlap)."""
    div = 2 if double_buffer else 1
    uop_reserve = div
    inp_budget = cfg.inp_buff_vectors // div
    wgt_budget = cfg.wgt_buff_matrices // div
    acc_budget = (cfg.acc_buff_vectors // div) // acc_copies
    out_budget = cfg.out_buff_vectors - (
        cfg.acc_buff_vectors // 2 if double_buffer else 0)
    lam_c = max(1, min(lam, wgt_budget, inp_budget // row_height))
    if max_lam_c is not None:
        lam_c = max(1, min(lam_c, max_lam_c))
    beta_c = max(1, min(beta, wgt_budget // lam_c,
                        acc_budget // row_height,
                        out_budget // row_height,
                        cfg.uop_buff_entries - uop_reserve))
    alpha_c = max(1, min(alpha,
                         inp_budget // (row_height * lam_c),
                         acc_budget // (row_height * beta_c),
                         out_budget // (row_height * beta_c),
                         (cfg.uop_buff_entries - uop_reserve) // beta_c))
    if max_alpha_c is not None:
        alpha_c = max(1, min(alpha_c, max_alpha_c))
    plan = ChunkPlan(alpha, lam, beta, alpha_c, lam_c, beta_c, row_height,
                     alpha_segs=_segment(alpha, alpha_c, row_groups),
                     beta_segs=_segment(beta, beta_c, col_groups),
                     acc_copies=acc_copies, double_buffer=double_buffer)
    _validate_plan(cfg, plan)
    return plan


def _validate_plan(cfg: VTAConfig, p: ChunkPlan) -> None:
    div = 2 if p.double_buffer else 1
    odd_out_base = cfg.acc_buff_vectors // 2 if p.double_buffer else 0
    assert p.alpha_c * p.row_height * p.lam_c <= cfg.inp_buff_vectors // div
    assert p.lam_c * p.beta_c <= cfg.wgt_buff_matrices // div
    assert (p.alpha_c * p.row_height * p.beta_c * p.acc_copies
            <= cfg.acc_buff_vectors // div)
    assert (odd_out_base + p.alpha_c * p.row_height * p.beta_c
            <= cfg.out_buff_vectors)
    assert p.alpha_c * p.beta_c + div <= cfg.uop_buff_entries
    assert all(a <= p.alpha_c for _, a in p.alpha_segs)
    assert all(b <= p.beta_c for _, b in p.beta_segs)


def _ranges(total: int, chunk: int):
    for start in range(0, total, chunk):
        yield start, min(chunk, total - start)


def _chunk_local_index(v: int, i0: int, a_c: int, j0: int, b_c: int,
                       beta: int, row_height: int) -> Optional[int]:
    """Global result-vector index → index into this chunk's ACC window, or
    ``None`` when the vector lives in another chunk (block-major, §3.2)."""
    br, rem = divmod(v, beta * row_height)
    bc, within = divmod(rem, row_height)
    if not (i0 <= br < i0 + a_c and j0 <= bc < j0 + b_c):
        return None
    return ((br - i0) * b_c + (bc - j0)) * row_height + within


def _alu_chunk_groups(alu_ops: Sequence, beta: int, row_height: int
                      ) -> Tuple[List[Tuple[int, int]], List[Tuple[int, int]]]:
    """Block-row / block-col intervals each pair op must keep in one chunk."""
    row_groups: List[Tuple[int, int]] = []
    col_groups: List[Tuple[int, int]] = []
    stride = beta * row_height
    for spec in alu_ops:
        if isinstance(spec, AluPairOp):
            for dst, src in spec.pairs:
                br_d, br_s = dst // stride, src // stride
                bc_d = (dst // row_height) % beta
                bc_s = (src // row_height) % beta
                if br_d != br_s:
                    row_groups.append((min(br_d, br_s), max(br_d, br_s)))
                if bc_d != bc_s:
                    col_groups.append((min(bc_d, bc_s), max(bc_d, bc_s)))
    return row_groups, col_groups


# ---------------------------------------------------------------------------
# Reference semantics (the pure-numpy oracle for expected_out.bin)
# ---------------------------------------------------------------------------

def reference_result(A: np.ndarray, B: np.ndarray, X: Optional[np.ndarray],
                     alu_ops: Sequence, cfg: VTAConfig,
                     row_height: Optional[int] = None,
                     residual: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Bit-accurate reference: returns ``(acc_int32, out_int8)`` on the
    *padded* geometry (block-major semantics are layout-only)."""
    bs = cfg.block_size
    if row_height is None:
        row_height = bs if should_pad_height(A) else 1
    Ap = matrix_padding(A, bs, pad_height=row_height > 1).astype(np.int32)
    Bp = matrix_padding(B, bs, pad_height=True).astype(np.int32)
    acc = Ap @ Bp   # int32 with wraparound handled by numpy int32 ops below
    acc = acc.astype(np.int64)
    if X is not None:
        Xp = np.zeros(acc.shape, dtype=np.int64)
        Xp[:X.shape[0], :X.shape[1]] = X.astype(np.int64)
        acc = acc + Xp
    acc = _wrap_int32(acc)

    beta = Bp.shape[1] // bs
    vec = _matrix_to_vectors(acc, bs, row_height)   # (n_vec, bs) block-major
    res_vec = None
    if residual is not None:
        Rp = np.zeros(acc.shape, dtype=np.int32)
        Rp[:residual.shape[0], :residual.shape[1]] = \
            residual.astype(np.int32)
        res_vec = _matrix_to_vectors(Rp, bs, row_height)
    for spec in alu_ops:
        if isinstance(spec, AluImmOp):
            vec = _alu_apply(vec, spec.op, spec.imm, np.arange(len(vec)))
        elif isinstance(spec, AluIndexedImmOp):
            vec = _alu_apply(vec, spec.op, spec.imm, np.asarray(spec.indices))
        elif isinstance(spec, AluPairOp):
            for dst, src in spec.pairs:
                vec = _alu_pair(vec, spec.op, dst, src)
        elif isinstance(spec, AluResidualOp):
            if res_vec is None:
                raise CompileError(
                    "AluResidualOp requires a residual operand",
                    constraint="residual-operand-missing")
            # Mirror the device: the residual window is ACC-loaded, an
            # optional SHR immediate equalises its scale, then the
            # vector-vector op merges it into every result vector.
            r = res_vec.astype(np.int64)
            if spec.pre_shift:
                r = _wrap_int32(r >> spec.pre_shift).astype(np.int64)
            vec = _alu_residual(vec, spec.op, r)
        else:
            raise TypeError(spec)
    acc = _vectors_to_matrix(vec, acc.shape, bs, row_height)
    out = (acc.astype(np.int64) & 0xFF).astype(np.uint8).view(np.int8) \
        .astype(np.int8)   # truncation (§2.1: OUT = truncated ACC)
    return acc.astype(np.int32), out


def _wrap_int32(x: np.ndarray) -> np.ndarray:
    return ((x.astype(np.int64) + 2**31) % 2**32 - 2**31).astype(np.int32)


def _alu_apply(vec, op, imm, idx):
    vec = vec.copy()
    sel = vec[idx].astype(np.int64)
    if op == isa.AluOp.MIN:
        sel = np.minimum(sel, imm)
    elif op == isa.AluOp.MAX:
        sel = np.maximum(sel, imm)
    elif op == isa.AluOp.ADD:
        sel = sel + imm
    elif op == isa.AluOp.SHR:
        sel = sel >> imm
    vec[idx] = _wrap_int32(sel)
    return vec


def _alu_residual(vec, op, res64):
    """Whole-result vector-vector op against the residual window."""
    a = vec.astype(np.int64)
    if op == isa.AluOp.MIN:
        r = np.minimum(a, res64)
    elif op == isa.AluOp.MAX:
        r = np.maximum(a, res64)
    elif op == isa.AluOp.ADD:
        r = a + res64
    elif op == isa.AluOp.SHR:
        r = a >> (res64 & 31)
    else:
        raise ValueError(op)
    return _wrap_int32(r)


def _alu_pair(vec, op, dst, src):
    vec = vec.copy()
    a = vec[dst].astype(np.int64)
    b = vec[src].astype(np.int64)
    if op == isa.AluOp.MIN:
        r = np.minimum(a, b)
    elif op == isa.AluOp.MAX:
        r = np.maximum(a, b)
    elif op == isa.AluOp.ADD:
        r = a + b
    elif op == isa.AluOp.SHR:
        r = a >> (b & 31)
    vec[dst] = _wrap_int32(r)
    return vec


def _matrix_to_vectors(mat: np.ndarray, bs: int, row_height: int) -> np.ndarray:
    """(H, W) → (n_vec, bs) in block-major vector order (DRAM/SRAM order)."""
    h, w = mat.shape
    br, bc = h // row_height, w // bs
    blocks = mat.reshape(br, row_height, bc, bs).transpose(0, 2, 1, 3)
    return blocks.reshape(br * bc * row_height, bs)


def _vectors_to_matrix(vec: np.ndarray, shape, bs: int, row_height: int) -> np.ndarray:
    h, w = shape
    br, bc = h // row_height, w // bs
    blocks = vec.reshape(br, bc, row_height, bs).transpose(0, 2, 1, 3)
    return blocks.reshape(h, w)


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

def compile_matmul(A: np.ndarray, B: np.ndarray, *,
                   X: Optional[np.ndarray] = None,
                   bias: Optional[np.ndarray] = None,
                   alu_ops: Sequence = (),
                   residual: Optional[np.ndarray] = None,
                   cfg: Optional[VTAConfig] = None,
                   name: str = "matmul",
                   dram_offset: int = 0,
                   allocator: Optional[DramAllocator] = None,
                   schedule: str = pipeline_schedule.SERIALIZED
                   ) -> VTAProgram:
    """Compile ``C = A·B (+X|+bias)`` + element-wise post-ops to a VTA program.

    ``A`` int8 (M,K); ``B`` int8 (K,N); ``X`` int32 (M,N) accumulator preload
    or ``bias`` int32 (N,) broadcast over rows (the paper's C = A×B + X form,
    §2.3).  ``alu_ops`` is an ordered list of AluImmOp / AluPairOp /
    AluIndexedImmOp / AluResidualOp; indexed/pair programs work on
    multi-chunk results (the uops are rewritten against each chunk's local
    ACC window) and may exceed the UOP buffer (the compiler streams them in
    LOAD_UOP waves).

    ``residual`` — a second int32 (M, N) operand merged *on the VTA* by an
    :class:`AluResidualOp` in ``alu_ops`` (the residual-add lowering,
    DESIGN.md §Graph): it is placed in its own ``res`` DRAM region and
    ACC-loaded beside each chunk's result window.

    ``allocator`` — pass a shared :class:`DramAllocator` to place several
    programs (network layers, §4.2) in one DRAM region; region names are
    then prefixed with ``name``.

    ``schedule`` — ``"serialized"`` (default) emits the conservative
    token stream; ``"pipelined"`` double-buffers load groups against GEMM
    execution and overlaps each chunk's store with the next chunk's
    compute, picking among candidate chunk plans by modeled three-module
    makespan (DESIGN.md §Pipeline).  When the buffers are too small to
    double-buffer the compile falls back to the serialized scheme
    (``prog.schedule`` records what was actually emitted).
    """
    cfg = cfg or vta_default()
    bs = cfg.block_size
    if A.ndim != 2 or B.ndim != 2 or A.shape[1] != B.shape[0]:
        raise CompileError(
            f"incompatible GEMM shapes {A.shape} @ {B.shape}",
            layer=name, constraint="gemm-shape")
    A = np.asarray(A, dtype=np.int8)
    B = np.asarray(B, dtype=np.int8)
    if bias is not None and X is not None:
        raise CompileError("pass either X or bias, not both", layer=name,
                           constraint="bias-xor-preload")
    M, K = A.shape
    N = B.shape[1]
    if bias is not None:
        X = np.broadcast_to(np.asarray(bias, dtype=np.int32), (M, N)).copy()

    n_residual_ops = sum(isinstance(s, AluResidualOp) for s in alu_ops)
    if n_residual_ops > 1:
        raise CompileError("at most one AluResidualOp per program",
                           layer=name, constraint="residual-single-op")
    if (residual is not None) != (n_residual_ops == 1):
        raise CompileError(
            "a residual operand and an AluResidualOp must come together",
            layer=name, constraint="residual-operand-op-pairing")
    if residual is not None:
        residual = np.asarray(residual, dtype=np.int32)
        if residual.shape != (M, N):
            raise CompileError(
                f"residual operand shape {residual.shape} != result "
                f"shape {(M, N)}", layer=name, constraint="residual-shape")

    # ---------------- data definition (§3.2) ----------------
    pad_h = should_pad_height(A)
    row_height = bs if pad_h else A.shape[0]
    Ap = matrix_padding(A, bs, pad_height=pad_h)
    Bp = matrix_padding(B, bs, pad_height=True)
    a_split = matrix_splitting(Ap, bs)
    b_split = matrix_splitting(Bp, bs)
    alpha, lam = a_split.block_rows, a_split.block_cols
    beta = b_split.block_cols
    assert b_split.block_rows == lam, "K-padding mismatch"

    inp_bin = binarize_blocks(a_split, cfg.inp_dtype)
    wgt_bin = binarize_blocks(b_split, cfg.wgt_dtype, transpose=True)

    has_x = X is not None
    if has_x:
        Xp = np.zeros((alpha * row_height, beta * bs), dtype=np.int32)
        Xp[:M, :N] = X.astype(np.int32)
        x_split = matrix_splitting(Xp, bs)
        acc_bin = binarize_blocks(x_split, cfg.acc_dtype)

    has_res = residual is not None
    if has_res:
        Rp = np.zeros((alpha * row_height, beta * bs), dtype=np.int32)
        Rp[:M, :N] = residual
        r_split = matrix_splitting(Rp, bs)
        res_bin = binarize_blocks(r_split, cfg.acc_dtype)

    # ---------------- chunk plan ----------------
    n_result_vec = alpha * beta * row_height
    for spec in alu_ops:
        if isinstance(spec, AluIndexedImmOp):
            idxs = spec.indices
        elif isinstance(spec, AluPairOp):
            idxs = tuple(i for p in spec.pairs for i in p)
        else:
            idxs = ()
        for v in idxs:
            if not 0 <= v < n_result_vec:
                raise CompileError(
                    f"ALU index {v} outside the {n_result_vec}-vector result",
                    layer=name, constraint="alu-index-range")

    row_groups, col_groups = _alu_chunk_groups(alu_ops, beta, row_height)
    acc_copies = 2 if residual is not None else 1

    # ---------------- schedule ----------------
    if schedule not in pipeline_schedule.SCHEDULES:
        raise CompileError(
            f"unknown schedule {schedule!r}; expected one of "
            f"{pipeline_schedule.SCHEDULES}", layer=name,
            constraint="schedule-unknown")
    if (schedule == pipeline_schedule.PIPELINED
            and not pipeline_schedule.pipelinable(cfg, row_height,
                                                  acc_copies)):
        # Buffers too small (or UOP fields too narrow) to ping-pong
        # halves: fall back to the conservative scheme rather than fail.
        schedule = pipeline_schedule.SERIALIZED
    sched = pipeline_schedule.make_schedule(cfg, schedule)

    def _plan(double_buffer: bool, **caps) -> ChunkPlan:
        return plan_chunks(cfg, alpha, lam, beta, row_height,
                           row_groups=row_groups, col_groups=col_groups,
                           acc_copies=acc_copies,
                           double_buffer=double_buffer, **caps)

    # ---------------- UOPs + emission (per candidate plan) ----------------
    capacity = cfg.uop_buff_entries

    def _build(plan: ChunkPlan):
        """UOP DRAM layout + instruction emitter for ``plan`` under
        ``sched``.  Returns ``(uop_dram, emit)`` where ``emit(log)`` is
        re-callable — candidate plans are timed with stubbed DRAM bases
        (``log = lambda r: 0``) before any region exists."""
        lam_segs = list(_ranges(lam, plan.lam_c))
        chunk_list = [(i0, a_c, j0, b_c)
                      for i0, a_c in plan.alpha_segs
                      for j0, b_c in plan.beta_segs]
        gpc = len(lam_segs)                    # load groups per chunk

        def _gemm_uops(a_c: int, b_c: int, l_c: int, inp_off: int,
                       wgt_off: int, acc_off: int) -> List[isa.Uop]:
            return [isa.Uop(acc_idx=acc_off + (i * b_c + j) * row_height,
                            inp_idx=inp_off + i * l_c * row_height,
                            wgt_idx=wgt_off + j)
                    for i in range(a_c) for j in range(b_c)]

        def _alu_chunk_uops(spec, i0: int, a_c: int, j0: int, b_c: int,
                            acc_off: int) -> List[isa.Uop]:
            local = lambda v: _chunk_local_index(v, i0, a_c, j0, b_c, beta,
                                                 row_height)
            out: List[isa.Uop] = []
            if isinstance(spec, AluResidualOp):
                # The residual window sits right after the chunk's result
                # window in ACC SRAM.  One uop drives the whole factor-form
                # lattice: optionally a pre-shift SHR over the window
                # itself, then the vector-vector op (dst = result, src =
                # window).
                base = acc_off + a_c * b_c * row_height
                if spec.pre_shift:
                    out.append(isa.Uop(acc_idx=base, inp_idx=base,
                                       wgt_idx=0))
                out.append(isa.Uop(acc_idx=acc_off, inp_idx=base, wgt_idx=0))
                return out
            if isinstance(spec, AluIndexedImmOp):
                for v in spec.indices:
                    lv = local(v)
                    if lv is not None:
                        out.append(isa.Uop(acc_idx=acc_off + lv,
                                           inp_idx=acc_off + lv, wgt_idx=0))
            else:
                for dst, src in spec.pairs:
                    ld, ls = local(dst), local(src)
                    if (ld is None) != (ls is None):
                        raise AssertionError(   # plan alignment guarantees
                            f"pair ({dst}, {src}) straddles a chunk "
                            f"boundary")
                    if ld is not None:
                        out.append(isa.Uop(acc_idx=acc_off + ld,
                                           inp_idx=acc_off + ls, wgt_idx=0))
            return out

        chunk_alu_uops = [
            [None if isinstance(spec, AluImmOp)
             else _alu_chunk_uops(spec, i0, a_c, j0, b_c, sched.acc_base(ci))
             for spec in alu_ops]
            for ci, (i0, a_c, j0, b_c) in enumerate(chunk_list)]

        # GEMM uop sets are keyed by geometry *and* buffer phases: the
        # phase-p load half and phase-q ACC half shift every index.
        gemm_keys: List[Tuple[int, int, int, int, int]] = []
        for ci, (i0, a_c, j0, b_c) in enumerate(chunk_list):
            q = sched.chunk_phase(ci)
            for ki in range(gpc):
                key = (a_c, b_c, lam_segs[ki][1],
                       sched.load_phase(ci * gpc + ki), q)
                if key not in gemm_keys:
                    gemm_keys.append(key)

        def _uops_for(key) -> List[isa.Uop]:
            a_c, b_c, l_c, p, q = key
            return _gemm_uops(a_c, b_c, l_c, p * sched.inp_half,
                              p * sched.wgt_half, q * sched.acc_half)

        n_alu_uops = sum(len(lst) for lists in chunk_alu_uops
                         for lst in lists if lst is not None)
        pinned = sched.pinned_uops()
        n_pinned = len(pinned)
        resident_total = (n_pinned + sum(a * b for a, b, _, _, _ in gemm_keys)
                          + n_alu_uops)

        # Use-site records.  Each GEMM use is ``(wave, uop_bgn)``; each
        # indexed/pair ALU use is a list of ``(wave, uop_bgn, count)``
        # segments (one AluInsn per segment; chunks with no local entries
        # get none).  ``wave=None`` means "loaded by the preamble", i.e.
        # resident for the whole program.
        gemm_use: List[List[Tuple[Optional[int], int]]] = []
        alu_use: List[List[Optional[List[Tuple[Optional[int], int,
                                               int]]]]] = []
        waves: List[Tuple[int, int]] = []    # (dram_start, count) per wave
        uop_dram: List[isa.Uop] = list(pinned)

        if resident_total <= capacity:
            # Everything fits the buffer at once: one preamble LOAD_UOP,
            # SRAM slot = DRAM index (the original §3.3 layout).
            gemm_start: Dict[Tuple[int, int, int, int, int], int] = {}
            for key in gemm_keys:
                gemm_start[key] = len(uop_dram)
                uop_dram.extend(_uops_for(key))
            for ci, (i0, a_c, j0, b_c) in enumerate(chunk_list):
                q = sched.chunk_phase(ci)
                gemm_use.append([
                    (None, gemm_start[(a_c, b_c, lam_segs[ki][1],
                                       sched.load_phase(ci * gpc + ki), q)])
                    for ki in range(gpc)])
                uses: List[Optional[List[Tuple[Optional[int], int,
                                               int]]]] = []
                for lst in chunk_alu_uops[ci]:
                    if lst is None:
                        uses.append(None)
                    elif not lst:
                        uses.append([])  # no local entries in this chunk
                    else:
                        start = len(uop_dram)
                        uop_dram.extend(lst)
                        uses.append([(None, start, len(lst))])
                alu_use.append(uses)
            preamble_count = len(uop_dram)
        else:
            # Wave streaming: the pinned slots keep the reset/base uops;
            # slots n_pinned..capacity-1 are reloaded per wave.  Waves are
            # built in execution order, so a single monotone LOAD_UOP
            # sequence covers every use.
            preamble_count = n_pinned
            cap_w = capacity - n_pinned
            wave_maps: List[Dict[Tuple[int, int, int, int, int],
                                 Tuple[int, int]]] = []

            def _begin_wave() -> None:
                waves.append((len(uop_dram), 0))
                wave_maps.append({})

            def _place(key, lst: List[isa.Uop]) -> Tuple[int, int]:
                if key is not None and key in wave_maps[-1]:
                    return wave_maps[-1][key]
                start, count = waves[-1]
                if count + len(lst) > cap_w:
                    _begin_wave()
                    start, count = waves[-1]
                uop_dram.extend(lst)
                waves[-1] = (start, count + len(lst))
                entry = (len(waves) - 1, n_pinned + count)
                if key is not None:
                    wave_maps[-1][key] = entry
                return entry

            _begin_wave()
            for ci, (i0, a_c, j0, b_c) in enumerate(chunk_list):
                assert a_c * b_c <= cap_w, "planner exceeded the uop buffer"
                q = sched.chunk_phase(ci)
                row: List[Tuple[Optional[int], int]] = []
                for ki in range(gpc):
                    key = (a_c, b_c, lam_segs[ki][1],
                           sched.load_phase(ci * gpc + ki), q)
                    row.append(_place(key, _uops_for(key)))
                gemm_use.append(row)
                uses = []
                for lst in chunk_alu_uops[ci]:
                    if lst is None:
                        uses.append(None)
                        continue
                    segs: List[Tuple[Optional[int], int, int]] = []
                    off = 0
                    while off < len(lst):
                        avail = cap_w - waves[-1][1]
                        if avail <= 0:
                            _begin_wave()
                            avail = cap_w
                        n = min(avail, len(lst) - off)
                        w, bgn = _place(None, lst[off:off + n])
                        segs.append((w, bgn, n))
                        off += n
                    uses.append(segs)
                alu_use.append(uses)

        def emit(log) -> List[object]:
            insns: List[object] = []

            # -- program preamble: load UOPs, reset pair (§3.3 step 1) --
            insns.append(isa.MemInsn(
                isa.Opcode.LOAD, isa.MemId.UOP, sram_base=0,
                dram_base=log("uop"), y_size=1,
                x_size=preamble_count, x_stride=preamble_count))
            insns.append(isa.GemInsn(reset=1, uop_bgn=0, uop_end=1,
                                     iter_out=1, iter_in=1))

            loaded_wave: List[Optional[int]] = [None]

            def _ensure_wave(w: Optional[int]) -> None:
                if w is None or w == loaded_wave[0]:
                    return
                start, count = waves[w]
                insns.append(isa.MemInsn(
                    isa.Opcode.LOAD, isa.MemId.UOP, sram_base=n_pinned,
                    dram_base=log("uop") + start, y_size=1,
                    x_size=count, x_stride=count))
                loaded_wave[0] = w

            # -- chunk loop (§3.3 steps 2–5) --
            n_chunks = len(chunk_list)
            group = 0
            for ci, (i0, a_c, j0, b_c) in enumerate(chunk_list):
                acc_off = sched.acc_base(ci)
                slot = sched.base_uop_slot(ci)
                # The chunk's *first* Compute-module instruction waits for
                # the store that released this phase's ACC/OUT half — it
                # must be the first one (the ACC preload / reset also
                # writes the window; a later pop would leave a WAR race
                # with the draining store).
                store_wait = sched.chunk_pops_store(ci)
                if has_x:
                    # ACC preload (compute-module LOAD): chunk rows are
                    # strided runs of b_c·rh vectors out of the β·rh-wide
                    # block rows.
                    pre = isa.MemInsn(
                        isa.Opcode.LOAD, isa.MemId.ACC, sram_base=acc_off,
                        dram_base=log("acc") + (i0 * beta + j0) * row_height,
                        y_size=a_c, x_size=b_c * row_height,
                        x_stride=beta * row_height)
                    if store_wait:
                        pre.dep.pop_next = 1
                        store_wait = False
                    insns.append(pre)
                for ki, (k0, l_c) in enumerate(lam_segs):
                    li = isa.MemInsn(
                        isa.Opcode.LOAD, isa.MemId.INP,
                        sram_base=sched.inp_base(group),
                        dram_base=log("inp") + (i0 * lam + k0) * row_height,
                        y_size=a_c, x_size=l_c * row_height,
                        x_stride=lam * row_height)
                    if sched.load_pops_release(group):
                        li.dep.pop_next = 1  # wait for buffer-half release
                    lw = isa.MemInsn(
                        isa.Opcode.LOAD, isa.MemId.WGT,
                        sram_base=sched.wgt_base(group),
                        dram_base=log("wgt") + k0 * beta + j0,
                        y_size=l_c, x_size=b_c, x_stride=beta)
                    lw.dep.push_next = 1     # load group complete
                    insns.extend([li, lw])
                    group += 1

                    if not has_x and k0 == 0:
                        # no X preload: zero the chunk accumulator
                        rg = isa.GemInsn(
                            reset=1, uop_bgn=slot, uop_end=slot + 1,
                            iter_out=a_c * b_c, iter_in=row_height,
                            acc_factor_out=row_height, acc_factor_in=1)
                        if store_wait:
                            rg.dep.pop_next = 1
                            store_wait = False
                        insns.append(rg)
                    wave, start = gemm_use[ci][ki]
                    _ensure_wave(wave)
                    g = isa.GemInsn(
                        uop_bgn=start, uop_end=start + a_c * b_c,
                        iter_out=l_c, iter_in=row_height,
                        acc_factor_out=0, acc_factor_in=1,
                        inp_factor_out=row_height, inp_factor_in=1,
                        wgt_factor_out=b_c, wgt_factor_in=0)
                    g.dep.pop_prev = 1       # consume load group
                    g.dep.push_prev = 1      # release INP/WGT half
                    insns.append(g)

                for spec, use in zip(alu_ops, alu_use[ci]):
                    if isinstance(spec, AluImmOp):
                        insns.append(isa.AluInsn(
                            alu_opcode=spec.op, uop_bgn=slot,
                            uop_end=slot + 1,
                            iter_out=a_c * b_c, iter_in=row_height,
                            dst_factor_out=row_height, dst_factor_in=1,
                            src_factor_out=row_height, src_factor_in=1,
                            use_imm=1, imm=spec.imm))
                        continue
                    if isinstance(spec, AluResidualOp):
                        # Load the chunk's residual window (compute-module
                        # LOAD, same strided geometry as the chunk result)
                        # beside the result window, then run the
                        # factor-form lattice over every result vector:
                        # pre-shift SHR first when the scales need
                        # equalising, then the vector-vector op.
                        res_base = acc_off + a_c * b_c * row_height
                        insns.append(isa.MemInsn(
                            isa.Opcode.LOAD, isa.MemId.ACC,
                            sram_base=res_base,
                            dram_base=log("res")
                            + (i0 * beta + j0) * row_height,
                            y_size=a_c, x_size=b_c * row_height,
                            x_stride=beta * row_height))
                        pos = 0
                        for (wave, start, count) in use:
                            _ensure_wave(wave)
                            for t in range(count):
                                is_pre = pos == 0 and spec.pre_shift > 0
                                insns.append(isa.AluInsn(
                                    alu_opcode=(isa.AluOp.SHR if is_pre
                                                else spec.op),
                                    uop_bgn=start + t,
                                    uop_end=start + t + 1,
                                    iter_out=a_c * b_c, iter_in=row_height,
                                    dst_factor_out=row_height,
                                    dst_factor_in=1,
                                    src_factor_out=row_height,
                                    src_factor_in=1,
                                    use_imm=1 if is_pre else 0,
                                    imm=spec.pre_shift if is_pre else 0))
                                pos += 1
                        continue
                    use_imm = 1 if isinstance(spec, AluIndexedImmOp) else 0
                    imm = spec.imm if use_imm else 0
                    for (wave, start, count) in use:
                        _ensure_wave(wave)
                        insns.append(isa.AluInsn(
                            alu_opcode=spec.op, uop_bgn=start,
                            uop_end=start + count,
                            iter_out=1, iter_in=1, use_imm=use_imm,
                            imm=imm))
                insns[-1].dep.push_next = 1  # result ready for store
                if (sched.depth > 1 and ci == n_chunks - 1
                        and n_chunks >= sched.depth):
                    # Tail drain: with depth-2 overlap the store tokens of
                    # the last depth-1 chunks are never popped by a later
                    # chunk; consume the stale one here so FINISH's pop
                    # matches the *final* store's push.
                    insns[-1].dep.pop_next = 1

                st = isa.MemInsn(
                    isa.Opcode.STORE, isa.MemId.OUT, sram_base=acc_off,
                    dram_base=log("out") + (i0 * beta + j0) * row_height,
                    y_size=a_c, x_size=b_c * row_height,
                    x_stride=beta * row_height)
                st.dep.pop_prev = 1
                st.dep.push_prev = 1
                insns.append(st)

            fin = isa.FinishInsn()
            fin.dep.pop_next = 1             # last store completed
            insns.append(fin)
            return insns

        return uop_dram, emit

    # ---------------- candidate plans, picked by modeled makespan ----------
    if sched.depth > 1:
        base = _plan(True)
        candidates = [base]
        seen = {(base.alpha_segs, base.beta_segs, base.lam_c)}

        def _try(**caps) -> None:
            try:
                p = _plan(True, **caps)
            except CompileError:
                return                        # split collides with groups
            k = (p.alpha_segs, p.beta_segs, p.lam_c)
            if k not in seen:
                seen.add(k)
                candidates.append(p)

        # λ split → ≥2 load groups per chunk (double-buffered loads can
        # overlap GEMMs even inside a single chunk); α split → ≥2 chunks
        # (stores overlap the next chunk's compute).
        if base.lam_c > 1:
            _try(max_lam_c=-(-base.lam_c // 2))
        if base.alpha_c > 1:
            _try(max_alpha_c=-(-base.alpha_c // 2))
        if base.lam_c > 1 and base.alpha_c > 1:
            _try(max_lam_c=-(-base.lam_c // 2),
                 max_alpha_c=-(-base.alpha_c // 2))
    else:
        candidates = [_plan(False)]

    built = {id(p): _build(p) for p in candidates}
    if len(candidates) > 1:
        plan, _ = pipeline_schedule.choose_plan(
            candidates,
            lambda p: built[id(p)][1](lambda r: 0),
            cycle_model.simulate_pipeline)
    else:
        plan = candidates[0]
    uop_dram, emit = built[id(plan)]

    # ---------------- DRAM allocation (§2.2, order per §3.4) ----------------
    alloc = allocator if allocator is not None else DramAllocator(
        offset=dram_offset, page_bytes=cfg.page_bytes)
    pfx = f"{name}:" if allocator is not None else ""
    n_inp_vec = alpha * lam * row_height
    n_wgt_mat = lam * beta
    n_res_vec = alpha * beta * row_height
    regions = {
        "inp": alloc.alloc(pfx + "inp", "inp", cfg.inp_elem_bytes, n_inp_vec),
        "wgt": alloc.alloc(pfx + "wgt", "wgt", cfg.wgt_elem_bytes, n_wgt_mat),
    }
    if has_x:
        regions["acc"] = alloc.alloc(pfx + "acc", "acc", cfg.acc_elem_bytes,
                                     n_res_vec)
    if has_res:
        regions["res"] = alloc.alloc(pfx + "res", "acc", cfg.acc_elem_bytes,
                                     n_res_vec)
    regions["out"] = alloc.alloc(pfx + "out", "out", cfg.out_elem_bytes,
                                 n_res_vec)
    regions["uop"] = alloc.alloc(pfx + "uop", "uop", cfg.uop_elem_bytes,
                                 len(uop_dram))

    prog = VTAProgram(config=cfg, allocator=alloc, uops=uop_dram, name=name,
                      regions=regions, chunk_plan=plan,
                      schedule=sched.name, alu_ops=tuple(alu_ops))
    prog.set_segment("inp", inp_bin)
    prog.set_segment("wgt", wgt_bin)
    if has_x:
        prog.set_segment("acc", acc_bin)
    if has_res:
        prog.set_segment("res", res_bin)

    log = lambda r: regions[r].logical_addr(alloc.offset)
    prog.instructions = emit(log)

    # ---------------- expected output (oracle) ----------------
    acc_ref, out_ref = reference_result(A, B, X, alu_ops, cfg,
                                        row_height=row_height,
                                        residual=residual)
    prog.expected_out = out_ref
    prog.output_meta = OutputMeta(block_rows=alpha, block_cols=beta,
                                  row_height=row_height,
                                  valid_shape=(M, N))
    prog.finalize()
    return prog
