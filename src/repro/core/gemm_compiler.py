"""Operations definition: matrix op → VTA instructions + UOPs (paper §3.3).

``compile_matmul`` lowers ``C = A × B + X`` followed by element-wise ALU
post-ops down to a :class:`~repro.core.program.VTAProgram`:

* data definition (pad → split → binarise) per §3.2;
* DRAM allocation in the TVM reference order (INP, WGT, [ACC], OUT, UOP,
  INSN), each region on a fresh 4 KiB page (§2.2);
* the blocked-GEMM schedule of Fig. 7/8: ``LP_OUT = λ``,
  ``LP_IN = row_height``, one UOP per output block
  ``(ACC_IDX, INP_IDX, WGT_IDX) = ((i·β+j)·rh, (i·λ)·rh, j)``;
* buffer-capacity chunking (§3.3: "If the data do not fit into the buffers,
  steps 2 to 5 must be repeated");
* dependency flags wiring the Load/Compute/Store queues (§2.3), validated by
  the simulator's token checker.

The §5.1 "GeMM loop" metric falls out of the generated ``iter_out × iter_in
× n_uop`` products — LeNet-5 totals 2942 by construction (see
``tests/test_lenet_e2e.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import isa
from .dram import DramAllocator
from .hwconfig import VTAConfig, vta_default
from .layout import (matrix_padding, matrix_splitting, binarize_blocks,
                     should_pad_height, pad_to_multiple)
from .program import OutputMeta, VTAProgram


# ---------------------------------------------------------------------------
# ALU post-op specification
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AluImmOp:
    """Element-wise op with an immediate, applied to every result vector.

    ``relu``  → MAX(x, 0); ``shr`` → arithmetic shift right (requant);
    ``add``/``min``/``max`` with an immediate.
    """

    op: isa.AluOp
    imm: int = 0

    @staticmethod
    def relu() -> "AluImmOp":
        return AluImmOp(isa.AluOp.MAX, 0)

    @staticmethod
    def shr(shift: int) -> "AluImmOp":
        return AluImmOp(isa.AluOp.SHR, shift)


@dataclasses.dataclass(frozen=True)
class AluPairOp:
    """Vector-pair op ``acc[dst] = op(acc[dst], acc[src])`` over an explicit
    (dst, src) list — used for region ops such as average pooling (ADD
    pairs followed by an ``AluIndexedImmOp`` SHR).  Indices are global
    result-vector indices (block-major).  Only valid when the whole result
    fits in one SRAM chunk."""

    op: isa.AluOp
    pairs: Tuple[Tuple[int, int], ...]


@dataclasses.dataclass(frozen=True)
class AluIndexedImmOp:
    """Immediate op applied to an explicit list of result-vector indices."""

    op: isa.AluOp
    imm: int
    indices: Tuple[int, ...]


AluSpec = (AluImmOp, AluPairOp, AluIndexedImmOp)


# ---------------------------------------------------------------------------
# Chunk geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkPlan:
    """How the α×λ×β block grid is tiled to fit the SRAM buffers."""

    alpha: int
    lam: int
    beta: int
    alpha_c: int
    lam_c: int
    beta_c: int
    row_height: int

    @property
    def n_chunks(self) -> int:
        ceil = lambda a, b: -(-a // b)
        return ceil(self.alpha, self.alpha_c) * ceil(self.beta, self.beta_c)

    @property
    def single_chunk(self) -> bool:
        return (self.alpha_c, self.lam_c, self.beta_c) == (
            self.alpha, self.lam, self.beta)


def plan_chunks(cfg: VTAConfig, alpha: int, lam: int, beta: int,
                row_height: int) -> ChunkPlan:
    """Greedy deterministic tiling honouring every buffer capacity."""
    lam_c = max(1, min(lam, cfg.wgt_buff_matrices,
                       cfg.inp_buff_vectors // row_height))
    beta_c = max(1, min(beta, cfg.wgt_buff_matrices // lam_c,
                        cfg.acc_buff_vectors // row_height,
                        cfg.out_buff_vectors // row_height,
                        cfg.uop_buff_entries - 1))
    alpha_c = max(1, min(alpha,
                         cfg.inp_buff_vectors // (row_height * lam_c),
                         cfg.acc_buff_vectors // (row_height * beta_c),
                         cfg.out_buff_vectors // (row_height * beta_c),
                         (cfg.uop_buff_entries - 1) // beta_c))
    plan = ChunkPlan(alpha, lam, beta, alpha_c, lam_c, beta_c, row_height)
    _validate_plan(cfg, plan)
    return plan


def _validate_plan(cfg: VTAConfig, p: ChunkPlan) -> None:
    assert p.alpha_c * p.row_height * p.lam_c <= cfg.inp_buff_vectors
    assert p.lam_c * p.beta_c <= cfg.wgt_buff_matrices
    assert p.alpha_c * p.row_height * p.beta_c <= cfg.acc_buff_vectors
    assert p.alpha_c * p.row_height * p.beta_c <= cfg.out_buff_vectors
    assert p.alpha_c * p.beta_c + 1 <= cfg.uop_buff_entries


def _ranges(total: int, chunk: int):
    for start in range(0, total, chunk):
        yield start, min(chunk, total - start)


# ---------------------------------------------------------------------------
# Reference semantics (the pure-numpy oracle for expected_out.bin)
# ---------------------------------------------------------------------------

def reference_result(A: np.ndarray, B: np.ndarray, X: Optional[np.ndarray],
                     alu_ops: Sequence, cfg: VTAConfig,
                     row_height: Optional[int] = None
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Bit-accurate reference: returns ``(acc_int32, out_int8)`` on the
    *padded* geometry (block-major semantics are layout-only)."""
    bs = cfg.block_size
    if row_height is None:
        row_height = bs if should_pad_height(A) else 1
    Ap = matrix_padding(A, bs, pad_height=row_height > 1).astype(np.int32)
    Bp = matrix_padding(B, bs, pad_height=True).astype(np.int32)
    acc = Ap @ Bp   # int32 with wraparound handled by numpy int32 ops below
    acc = acc.astype(np.int64)
    if X is not None:
        Xp = np.zeros(acc.shape, dtype=np.int64)
        Xp[:X.shape[0], :X.shape[1]] = X.astype(np.int64)
        acc = acc + Xp
    acc = _wrap_int32(acc)

    beta = Bp.shape[1] // bs
    vec = _matrix_to_vectors(acc, bs, row_height)   # (n_vec, bs) block-major
    for spec in alu_ops:
        if isinstance(spec, AluImmOp):
            vec = _alu_apply(vec, spec.op, spec.imm, np.arange(len(vec)))
        elif isinstance(spec, AluIndexedImmOp):
            vec = _alu_apply(vec, spec.op, spec.imm, np.asarray(spec.indices))
        elif isinstance(spec, AluPairOp):
            for dst, src in spec.pairs:
                vec = _alu_pair(vec, spec.op, dst, src)
        else:
            raise TypeError(spec)
    acc = _vectors_to_matrix(vec, acc.shape, bs, row_height)
    out = (acc.astype(np.int64) & 0xFF).astype(np.uint8).view(np.int8) \
        .astype(np.int8)   # truncation (§2.1: OUT = truncated ACC)
    return acc.astype(np.int32), out


def _wrap_int32(x: np.ndarray) -> np.ndarray:
    return ((x.astype(np.int64) + 2**31) % 2**32 - 2**31).astype(np.int32)


def _alu_apply(vec, op, imm, idx):
    vec = vec.copy()
    sel = vec[idx].astype(np.int64)
    if op == isa.AluOp.MIN:
        sel = np.minimum(sel, imm)
    elif op == isa.AluOp.MAX:
        sel = np.maximum(sel, imm)
    elif op == isa.AluOp.ADD:
        sel = sel + imm
    elif op == isa.AluOp.SHR:
        sel = sel >> imm
    vec[idx] = _wrap_int32(sel)
    return vec


def _alu_pair(vec, op, dst, src):
    vec = vec.copy()
    a = vec[dst].astype(np.int64)
    b = vec[src].astype(np.int64)
    if op == isa.AluOp.MIN:
        r = np.minimum(a, b)
    elif op == isa.AluOp.MAX:
        r = np.maximum(a, b)
    elif op == isa.AluOp.ADD:
        r = a + b
    elif op == isa.AluOp.SHR:
        r = a >> (b & 31)
    vec[dst] = _wrap_int32(r)
    return vec


def _matrix_to_vectors(mat: np.ndarray, bs: int, row_height: int) -> np.ndarray:
    """(H, W) → (n_vec, bs) in block-major vector order (DRAM/SRAM order)."""
    h, w = mat.shape
    br, bc = h // row_height, w // bs
    blocks = mat.reshape(br, row_height, bc, bs).transpose(0, 2, 1, 3)
    return blocks.reshape(br * bc * row_height, bs)


def _vectors_to_matrix(vec: np.ndarray, shape, bs: int, row_height: int) -> np.ndarray:
    h, w = shape
    br, bc = h // row_height, w // bs
    blocks = vec.reshape(br, bc, row_height, bs).transpose(0, 2, 1, 3)
    return blocks.reshape(h, w)


# ---------------------------------------------------------------------------
# The compiler
# ---------------------------------------------------------------------------

def compile_matmul(A: np.ndarray, B: np.ndarray, *,
                   X: Optional[np.ndarray] = None,
                   bias: Optional[np.ndarray] = None,
                   alu_ops: Sequence = (),
                   cfg: Optional[VTAConfig] = None,
                   name: str = "matmul",
                   dram_offset: int = 0,
                   allocator: Optional[DramAllocator] = None) -> VTAProgram:
    """Compile ``C = A·B (+X|+bias)`` + element-wise post-ops to a VTA program.

    ``A`` int8 (M,K); ``B`` int8 (K,N); ``X`` int32 (M,N) accumulator preload
    or ``bias`` int32 (N,) broadcast over rows (the paper's C = A×B + X form,
    §2.3).  ``alu_ops`` is an ordered list of AluImmOp / AluPairOp /
    AluIndexedImmOp.

    ``allocator`` — pass a shared :class:`DramAllocator` to place several
    programs (network layers, §4.2) in one DRAM region; region names are
    then prefixed with ``name``.
    """
    cfg = cfg or vta_default()
    bs = cfg.block_size
    if A.ndim != 2 or B.ndim != 2 or A.shape[1] != B.shape[0]:
        raise ValueError(f"incompatible shapes {A.shape} @ {B.shape}")
    A = np.asarray(A, dtype=np.int8)
    B = np.asarray(B, dtype=np.int8)
    if bias is not None and X is not None:
        raise ValueError("pass either X or bias, not both")
    M, K = A.shape
    N = B.shape[1]
    if bias is not None:
        X = np.broadcast_to(np.asarray(bias, dtype=np.int32), (M, N)).copy()

    # ---------------- data definition (§3.2) ----------------
    pad_h = should_pad_height(A)
    row_height = bs if pad_h else A.shape[0]
    Ap = matrix_padding(A, bs, pad_height=pad_h)
    Bp = matrix_padding(B, bs, pad_height=True)
    a_split = matrix_splitting(Ap, bs)
    b_split = matrix_splitting(Bp, bs)
    alpha, lam = a_split.block_rows, a_split.block_cols
    beta = b_split.block_cols
    assert b_split.block_rows == lam, "K-padding mismatch"

    inp_bin = binarize_blocks(a_split, cfg.inp_dtype)
    wgt_bin = binarize_blocks(b_split, cfg.wgt_dtype, transpose=True)

    has_x = X is not None
    if has_x:
        Xp = np.zeros((alpha * row_height, beta * bs), dtype=np.int32)
        Xp[:M, :N] = X.astype(np.int32)
        x_split = matrix_splitting(Xp, bs)
        acc_bin = binarize_blocks(x_split, cfg.acc_dtype)

    # ---------------- chunk plan ----------------
    plan = plan_chunks(cfg, alpha, lam, beta, row_height)
    for spec in alu_ops:
        if isinstance(spec, (AluPairOp, AluIndexedImmOp)) and not plan.single_chunk:
            raise NotImplementedError(
                "indexed/pair ALU programs require a single-chunk result")

    # ---------------- UOPs ----------------
    uops: List[isa.Uop] = [isa.Uop(0, 0, 0)]     # uop@0: reset / simple ALU
    gemm_uop_start: Dict[Tuple[int, int, int], int] = {}

    def uop_block(a_c: int, b_c: int, l_c: int) -> int:
        key = (a_c, b_c, l_c)
        if key not in gemm_uop_start:
            start = len(uops)
            for i in range(a_c):
                for j in range(b_c):
                    uops.append(isa.Uop(acc_idx=(i * b_c + j) * row_height,
                                        inp_idx=i * l_c * row_height,
                                        wgt_idx=j))
            gemm_uop_start[key] = start
        return gemm_uop_start[key]

    # Pre-generate GEMM uops for every chunk shape (so the region size is
    # known before allocation).
    chunk_shapes = []
    for _, a_c in _ranges(alpha, plan.alpha_c):
        for _, b_c in _ranges(beta, plan.beta_c):
            for _, l_c in _ranges(lam, plan.lam_c):
                chunk_shapes.append((a_c, b_c, l_c))
                uop_block(a_c, b_c, l_c)

    # ALU uop lists (indexed ops / pair programs)
    alu_uop_start: List[Optional[int]] = []
    for spec in alu_ops:
        if isinstance(spec, AluImmOp):
            alu_uop_start.append(None)           # reuses uop@0
        elif isinstance(spec, AluIndexedImmOp):
            alu_uop_start.append(len(uops))
            for idx in spec.indices:
                uops.append(isa.Uop(acc_idx=idx, inp_idx=idx, wgt_idx=0))
        elif isinstance(spec, AluPairOp):
            alu_uop_start.append(len(uops))
            for dst, src in spec.pairs:
                uops.append(isa.Uop(acc_idx=dst, inp_idx=src, wgt_idx=0))
    if len(uops) > cfg.uop_buff_entries:
        raise NotImplementedError(
            f"{len(uops)} uops exceed the {cfg.uop_buff_entries}-entry buffer")

    # ---------------- DRAM allocation (§2.2, order per §3.4) ----------------
    alloc = allocator if allocator is not None else DramAllocator(
        offset=dram_offset, page_bytes=cfg.page_bytes)
    pfx = f"{name}:" if allocator is not None else ""
    n_inp_vec = alpha * lam * row_height
    n_wgt_mat = lam * beta
    n_res_vec = alpha * beta * row_height
    regions = {
        "inp": alloc.alloc(pfx + "inp", "inp", cfg.inp_elem_bytes, n_inp_vec),
        "wgt": alloc.alloc(pfx + "wgt", "wgt", cfg.wgt_elem_bytes, n_wgt_mat),
    }
    if has_x:
        regions["acc"] = alloc.alloc(pfx + "acc", "acc", cfg.acc_elem_bytes,
                                     n_res_vec)
    regions["out"] = alloc.alloc(pfx + "out", "out", cfg.out_elem_bytes,
                                 n_res_vec)
    regions["uop"] = alloc.alloc(pfx + "uop", "uop", cfg.uop_elem_bytes,
                                 len(uops))

    prog = VTAProgram(config=cfg, allocator=alloc, uops=uops, name=name,
                      regions=regions)
    prog.set_segment("inp", inp_bin)
    prog.set_segment("wgt", wgt_bin)
    if has_x:
        prog.set_segment("acc", acc_bin)

    log = lambda r: regions[r].logical_addr(alloc.offset)
    insns: List[object] = []

    # -- program preamble: load UOPs, reset pair (§3.3 steps 1) --
    insns.append(isa.MemInsn(isa.Opcode.LOAD, isa.MemId.UOP, sram_base=0,
                             dram_base=log("uop"), y_size=1,
                             x_size=len(uops), x_stride=len(uops)))
    insns.append(isa.GemInsn(reset=1, uop_bgn=0, uop_end=1,
                             iter_out=1, iter_in=1))

    # -- chunk loop (§3.3 steps 2–5) --
    load_groups = 0
    stores = 0
    for i0, a_c in _ranges(alpha, plan.alpha_c):
        for j0, b_c in _ranges(beta, plan.beta_c):
            first_gemm_of_chunk = True
            if has_x:
                # ACC preload (compute-module LOAD): chunk rows are strided
                # runs of b_c·rh vectors out of the β·rh-wide block rows.
                insns.append(isa.MemInsn(
                    isa.Opcode.LOAD, isa.MemId.ACC, sram_base=0,
                    dram_base=log("acc") + (i0 * beta + j0) * row_height,
                    y_size=a_c, x_size=b_c * row_height,
                    x_stride=beta * row_height))
            for k0, l_c in _ranges(lam, plan.lam_c):
                li = isa.MemInsn(
                    isa.Opcode.LOAD, isa.MemId.INP, sram_base=0,
                    dram_base=log("inp") + (i0 * lam + k0) * row_height,
                    y_size=a_c, x_size=l_c * row_height,
                    x_stride=lam * row_height)
                if load_groups > 0:
                    li.dep.pop_next = 1          # wait for compute buffer release
                lw = isa.MemInsn(
                    isa.Opcode.LOAD, isa.MemId.WGT, sram_base=0,
                    dram_base=log("wgt") + k0 * beta + j0,
                    y_size=l_c, x_size=b_c, x_stride=beta)
                lw.dep.push_next = 1             # load group complete
                insns.extend([li, lw])
                load_groups += 1

                if not has_x and k0 == 0:
                    # no X preload: zero the chunk accumulator
                    rg = isa.GemInsn(
                        reset=1, uop_bgn=0, uop_end=1,
                        iter_out=a_c * b_c, iter_in=row_height,
                        acc_factor_out=row_height, acc_factor_in=1)
                    if first_gemm_of_chunk and stores > 0:
                        rg.dep.pop_next = 1      # wait for previous store
                        first_gemm_of_chunk = False
                    insns.append(rg)
                start = uop_block(a_c, b_c, l_c)
                g = isa.GemInsn(
                    uop_bgn=start, uop_end=start + a_c * b_c,
                    iter_out=l_c, iter_in=row_height,
                    acc_factor_out=0, acc_factor_in=1,
                    inp_factor_out=row_height, inp_factor_in=1,
                    wgt_factor_out=b_c, wgt_factor_in=0)
                g.dep.pop_prev = 1               # consume load group
                g.dep.push_prev = 1              # release INP/WGT buffers
                if first_gemm_of_chunk and stores > 0:
                    g.dep.pop_next = 1           # wait for previous store
                first_gemm_of_chunk = False
                insns.append(g)

            n_vec_chunk = a_c * b_c * row_height
            for spec, ustart in zip(alu_ops, alu_uop_start):
                if isinstance(spec, AluImmOp):
                    insns.append(isa.AluInsn(
                        alu_opcode=spec.op, uop_bgn=0, uop_end=1,
                        iter_out=a_c * b_c, iter_in=row_height,
                        dst_factor_out=row_height, dst_factor_in=1,
                        src_factor_out=row_height, src_factor_in=1,
                        use_imm=1, imm=spec.imm))
                elif isinstance(spec, AluIndexedImmOp):
                    insns.append(isa.AluInsn(
                        alu_opcode=spec.op, uop_bgn=ustart,
                        uop_end=ustart + len(spec.indices),
                        iter_out=1, iter_in=1, use_imm=1, imm=spec.imm))
                elif isinstance(spec, AluPairOp):
                    insns.append(isa.AluInsn(
                        alu_opcode=spec.op, uop_bgn=ustart,
                        uop_end=ustart + len(spec.pairs),
                        iter_out=1, iter_in=1, use_imm=0))
            insns[-1].dep.push_next = 1          # result ready for store

            st = isa.MemInsn(
                isa.Opcode.STORE, isa.MemId.OUT, sram_base=0,
                dram_base=log("out") + (i0 * beta + j0) * row_height,
                y_size=a_c, x_size=b_c * row_height,
                x_stride=beta * row_height)
            st.dep.pop_prev = 1
            st.dep.push_prev = 1
            insns.append(st)
            stores += 1

    fin = isa.FinishInsn()
    fin.dep.pop_next = 1                         # last store completed
    insns.append(fin)

    prog.instructions = insns

    # ---------------- expected output (oracle) ----------------
    acc_ref, out_ref = reference_result(A, B, X, alu_ops, cfg,
                                        row_height=row_height)
    prog.expected_out = out_ref
    prog.output_meta = OutputMeta(block_rows=alpha, block_cols=beta,
                                  row_height=row_height,
                                  valid_shape=(M, N))
    prog.finalize()
    return prog
