"""Multi-layer network compilation + chained execution (paper §4.2, Fig. 12).

``compile_network`` lowers a layer list into per-layer VTA programs sharing
one global DRAM allocation (the paper: "the data are allocated in the DRAM
and the instructions are adapted to match this allocation strategy" — here
the layers compile directly against the shared allocator, so no relocation
pass is needed and every instruction's logical addresses are final).

``NetworkProgram.run_functional`` then executes the chain on the functional
simulator with the paper's host-side reshaping between VTA executions:

  (i)  binary-decode the OUT region → blocks → matrix → remove padding,
       extract pooled rows → ``mat2tensor``;
  (ii) next layer's ``im2row`` (or NCHW flatten) → pad → split → binarise →
       written into the next program's INP region of the shared DRAM image.

Stage (ii) recomputes bytes that the compiler already placed in the image
(the compiler compiled every layer against reference activations); the run
asserts they agree — any divergence is a compilation bug, which is exactly
the traceability check the paper's workflow enables.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .conv_lowering import flatten_tensor, im2row_batch, tensor2mat
from .cycle_model import CycleReport, analyze_programs
from .dram import DramAllocator
from .errors import CompileError
from .hwconfig import VTAConfig, vta_default
from .layer_compiler import (CompiledLayer, LayerSpec, compile_layer,
                             decode_layer_output, layer_matrices)
from .layout import (batch_matrix_to_binary, matrix_to_binary,
                     should_pad_height)
from .simulator import (SimReport, decode_out_region, decode_out_region_batch,
                        make_simulator, run_instructions)

# The real backend sets, enumerated once so refusal messages, the serving
# engine (repro.serving.vta) and the tests never drift out of sync again:
# ``serve`` executes a (batch, nbytes) DRAM stack — only the two batch
# engines can; ``serve_one`` runs the per-image simulators/kernel.
SERVE_BACKENDS = ("batched", "pallas")
SERVE_ONE_BACKENDS = ("oracle", "fast", "pallas")


@dataclasses.dataclass
class NetworkProgram:
    """Everything needed to run a compiled network on a VTA.

    ``input_sources``/``residual_sources`` generalise the chain to a DAG
    schedule (graph lowering, DESIGN.md §Graph): layer *k* reads its input
    from the semantic output of layer ``input_sources[k]`` (``-1`` = the
    network input) and — when ``residual_sources[k]`` is not None — stages
    that layer's output as its on-VTA residual operand.  ``None`` for both
    fields keeps the classic linear chain (layer k feeds layer k+1).
    """

    config: VTAConfig
    allocator: DramAllocator
    layers: List[CompiledLayer]
    input_tensor: np.ndarray
    input_sources: Optional[List[int]] = None
    residual_sources: Optional[List[Optional[int]]] = None

    def _sources(self) -> List[int]:
        if self.input_sources is not None:
            return self.input_sources
        return list(range(-1, len(self.layers) - 1))

    def _res_sources(self) -> List[Optional[int]]:
        if self.residual_sources is not None:
            return self.residual_sources
        return [None] * len(self.layers)

    # ------------------------------------------------------------------
    def gemm_loops(self) -> int:
        """§5.1 metric over the whole network (LeNet-5: 2942)."""
        return sum(l.program.gemm_loops() for l in self.layers)

    def gemm_loops_per_layer(self) -> List[int]:
        return [l.program.gemm_loops() for l in self.layers]

    def chunks_per_layer(self) -> List[int]:
        """SRAM chunks per layer (§3.3 "steps 2 to 5 must be repeated") —
        > 1 anywhere means the network genuinely exceeds a single SRAM
        residency and exercises the multi-chunk compiler (DESIGN.md §3)."""
        return [l.n_chunks for l in self.layers]

    def cycle_report(self) -> CycleReport:
        return analyze_programs([l.program for l in self.layers])

    def dram_image(self) -> np.ndarray:
        image = np.zeros(self.allocator.image_size(), dtype=np.uint8)
        for layer in self.layers:
            layer.program.place_segments(image)
        return image

    # ------------------------------------------------------------------
    def run_functional(self, *, check_chaining: bool = True,
                       backend: str = "oracle", fault_hook=None
                       ) -> Tuple[np.ndarray, List[SimReport]]:
        """Fig. 12: one VTA execution per layer + host reshaping between.

        Returns the final layer's semantic output (fc → (rows, F) int8
        matrix) and the per-execution simulator reports.  ``backend="fast"``
        runs each layer on the vectorised interpreter; per-layer instruction
        plans are compiled once and cached on the layer programs, so
        repeated runs (batch serving) pay only the array work.
        """
        image = self.dram_image()
        reports: List[SimReport] = []
        sems: List[np.ndarray] = []
        srcs, rsrcs = self._sources(), self._res_sources()
        for k, layer in enumerate(self.layers):
            if k > 0:        # layer 0's INP was placed at compile time
                sem_in = (self.input_tensor if srcs[k] < 0
                          else sems[srcs[k]])
                A, _, _ = layer_matrices(layer.spec,
                                         np.asarray(sem_in, dtype=np.int8))
                if check_chaining:
                    np.testing.assert_array_equal(
                        A, layer.input_matrix,
                        err_msg=f"layer {srcs[k]}->{k} reshaping mismatch")
                inp_bin, _ = matrix_to_binary(
                    A, self.config.block_size, self.config.inp_dtype)
                region = layer.program.regions["inp"]
                start = region.phys_addr - self.allocator.offset
                image[start:start + len(inp_bin)] = np.frombuffer(
                    inp_bin, dtype=np.uint8)
            if rsrcs[k] is not None:
                sem_res = (self.input_tensor if rsrcs[k] < 0
                           else sems[rsrcs[k]])
                self._stage_residual(image, layer, sem_res,
                                     check=check_chaining)
            sim = make_simulator(self.config, image, backend=backend)
            reports.append(run_instructions(
                sim, layer.program.instructions, program=layer.program,
                fault_hook=self._layer_hook(fault_hook, k)))
            image = sim.dram   # VTA wrote its OUT region
            out_mat = decode_out_region(layer.program, image)
            sems.append(decode_layer_output(layer, out_mat))
        return sems[-1], reports

    def verify(self, *, backend: str = "oracle"
               ) -> Tuple[np.ndarray, List[SimReport]]:
        """Run the chain and check the final output against the compiler's
        per-layer reference.  Returns (final output, reports)."""
        out, reports = self.run_functional(backend=backend)
        expected = self.layers[-1].ref_output_matrix
        if self.layers[-1].spec.kind == "conv":
            from .conv_lowering import mat2tensor
            expected = mat2tensor(expected, self.layers[-1].out_h,
                                  self.layers[-1].out_w)
        np.testing.assert_array_equal(out, expected)
        return out, reports

    # ------------------------------------------------------- serving --
    @staticmethod
    def _layer_hook(fault_hook, k: int):
        """Adapt a network-level ``hook(sim, layer_idx, insn_idx)`` to the
        simulator-level ``hook(sim, insn_idx)`` for layer ``k`` — the
        injection/watchdog point of DESIGN.md §Hardening."""
        if fault_hook is None:
            return None
        return lambda sim, i: fault_hook(sim, k, i)

    def plans(self) -> List[object]:
        """Per-layer compiled instruction plans, cached on the layer
        programs — the compile-once/serve-many contract: the returned
        objects are identical across repeated :meth:`serve` calls."""
        from .fast_simulator import plan_for
        return [plan_for(layer.program) for layer in self.layers]

    def input_signature(self) -> Tuple[Tuple[int, ...], np.dtype]:
        """(shape, dtype) one request image must have — the admission
        contract the serving engine (DESIGN.md §Serving) validates at
        submit time instead of failing layers deep into staging."""
        return tuple(self.input_tensor.shape), np.dtype(np.int8)

    def plan_shapes(self) -> List[Dict[str, int]]:
        """Per-layer compiled geometry the serving layer batches against:
        INP/OUT (and residual) region sizes plus chunk counts.  Purely
        introspective — reading it never compiles or invalidates plans."""
        shapes: List[Dict[str, int]] = []
        for layer in self.layers:
            regions = layer.program.regions
            shapes.append({
                "name": layer.spec.name,
                "inp_nbytes": regions["inp"].nbytes,
                "out_nbytes": regions["out"].nbytes,
                "res_nbytes": (regions["res"].nbytes
                               if "res" in regions else 0),
                "n_chunks": layer.n_chunks,
            })
        return shapes

    def padded_batch_sizes(self, max_batch: int) -> Tuple[int, ...]:
        """The closed set of stack shapes the engine serves at: powers of
        two up to ``max_batch`` (plus ``max_batch`` itself when it is not
        a power of two).  Padding a formed batch up to the next rung
        keeps the compile-once contract — the batch engines see a small
        fixed family of ``(B, nbytes)`` stacks instead of one shape per
        occupancy."""
        if max_batch < 1:
            raise CompileError(
                f"padding ladder needs max_batch >= 1, got {max_batch} "
                f"(a degenerate ladder would defer the failure to "
                f"padded_size deep inside a worker)",
                constraint="ladder-max-batch")
        sizes = []
        b = 1
        while b < max_batch:
            sizes.append(b)
            b *= 2
        sizes.append(max_batch)
        return tuple(sizes)

    def _stage_layer_input(self, dram_row: np.ndarray, layer: CompiledLayer,
                           semantic_input: np.ndarray) -> None:
        """§4.2 stage (ii) for one request: im2row/flatten → pad → split →
        binarise → written into the layer's INP region of ``dram_row``
        (a view into the batch stack, so writes land in place)."""
        A, _, _ = layer_matrices(layer.spec,
                                 np.asarray(semantic_input, dtype=np.int8))
        inp_bin, _ = matrix_to_binary(A, self.config.block_size,
                                      self.config.inp_dtype)
        region = layer.program.regions["inp"]
        if len(inp_bin) != region.nbytes:
            raise ValueError(
                f"layer {layer.spec.name!r}: staged input is "
                f"{len(inp_bin)} bytes, INP region holds {region.nbytes} — "
                f"request shape does not match the compiled geometry")
        start = region.phys_addr - self.allocator.offset
        dram_row[start:start + len(inp_bin)] = np.frombuffer(inp_bin,
                                                             dtype=np.uint8)

    def _stage_layer_input_batch(self, stack: np.ndarray,
                                 layer: CompiledLayer,
                                 sems: List[np.ndarray]) -> None:
        """Batched §4.2 stage (ii): all requests share one lowering
        geometry, so im2row and the pad/split/binarise pipeline run once
        over the whole stack (``im2row_batch`` / ``batch_matrix_to_binary``)
        instead of once per request."""
        spec = layer.spec
        arrs = np.stack([np.asarray(s, dtype=np.int8) for s in sems])
        if spec.kind == "conv":
            _, _, kh, kw = spec.weights.shape
            A = im2row_batch(arrs[:, 0], kh, kw, spec.stride, spec.padding)
        else:
            A = arrs.reshape(len(sems), 1, -1)       # NCHW flatten / (1, D)
        raw = batch_matrix_to_binary(A, self.config.block_size,
                                     self.config.inp_dtype)
        region = layer.program.regions["inp"]
        if raw.shape[1] != region.nbytes:
            raise ValueError(
                f"layer {layer.spec.name!r}: staged input is "
                f"{raw.shape[1]} bytes, INP region holds {region.nbytes} — "
                f"request shape does not match the compiled geometry")
        start = region.phys_addr - self.allocator.offset
        stack[:, start:start + raw.shape[1]] = raw

    def _stage_residual(self, dram_row: np.ndarray, layer: CompiledLayer,
                        semantic: np.ndarray, *, check: bool = False) -> None:
        """Stage a residual layer's skip operand: semantic int8 activation
        → int32 (M, N) matrix → ACC-format binary in the layer's ``res``
        region (the second on-VTA ALU operand, DESIGN.md §Graph)."""
        from .layer_compiler import residual_operand_matrix
        R = residual_operand_matrix(layer.spec, semantic,
                                    layer.residual_matrix.shape)
        if check:
            np.testing.assert_array_equal(
                R, layer.residual_matrix,
                err_msg=f"layer {layer.spec.name!r}: residual operand "
                        f"mismatch")
        raw, _ = matrix_to_binary(R, self.config.block_size,
                                  self.config.acc_dtype)
        region = layer.program.regions["res"]
        if len(raw) != region.nbytes:
            raise ValueError(
                f"layer {layer.spec.name!r}: staged residual is "
                f"{len(raw)} bytes, RES region holds {region.nbytes}")
        start = region.phys_addr - self.allocator.offset
        dram_row[start:start + len(raw)] = np.frombuffer(raw, dtype=np.uint8)

    def _stage_residual_batch(self, stack: np.ndarray, layer: CompiledLayer,
                              sems: List[np.ndarray]) -> None:
        """Batched residual staging: one geometry, one pad/split/binarise
        pass over the whole request stack (as `_stage_layer_input_batch`,
        but into the ``res`` region with ACC-format int32 structures)."""
        from .layer_compiler import residual_operand_matrix
        Rs = np.stack([residual_operand_matrix(layer.spec, s,
                                               layer.residual_matrix.shape)
                       for s in sems])
        raw = batch_matrix_to_binary(Rs, self.config.block_size,
                                     self.config.acc_dtype)
        region = layer.program.regions["res"]
        if raw.shape[1] != region.nbytes:
            raise ValueError(
                f"layer {layer.spec.name!r}: staged residual is "
                f"{raw.shape[1]} bytes, RES region holds {region.nbytes}")
        start = region.phys_addr - self.allocator.offset
        stack[:, start:start + raw.shape[1]] = raw

    def _as_image_list(self, images) -> List[np.ndarray]:
        """Normalise a request batch: a sequence of per-image tensors
        (each shaped like ``input_tensor``), or one stacked array whose
        leading axis is the batch — ``(B, C, H, W)`` for a conv-first
        network with ``(1, C, H, W)`` inputs, ``(B, D)`` for fc-first."""
        if isinstance(images, np.ndarray):
            want = self.input_tensor.shape
            if images.shape[1:] == want:                 # (B,) + full shape
                return [img for img in images]
            if images.ndim == len(want) and images.shape[1:] == want[1:]:
                return [img[None] for img in images]     # batch axis leads
            raise ValueError(
                f"cannot interpret stacked input of shape {images.shape} "
                f"as a batch of {want} images")
        imgs = list(images)
        if not imgs:
            raise ValueError("empty request batch")
        return [np.asarray(img) for img in imgs]

    def serve_one(self, image: np.ndarray, *, backend: str = "fast",
                  fault_hook=None, count_overflows: bool = False,
                  guard=None):
        """One inference request: stage the image into layer 0's INP
        region, then run the chained per-layer VTA executions (Fig. 12)
        with the host reshaping between.  The per-layer instruction plans
        are cached on the programs, so requests after the first pay no
        plan compilation.

        ``backend`` is one of :data:`SERVE_ONE_BACKENDS` — ``"fast"``
        (default, the vectorised plan-compiling interpreter), ``"oracle"``
        (the per-struct reference interpreter) or ``"pallas"`` (fused MXU
        kernel calls, :mod:`repro.core.pallas_backend`); the batch engine
        is :meth:`serve`'s, not this path's.  All are bit-identical.

        ``guard`` (a :class:`repro.harden.GuardPolicy`) routes the request
        through the integrity-guarded path — CRC verification, instruction
        validation, bounded restore-and-retry — and changes the return
        value to ``(output, GuardReport)`` (DESIGN.md §Hardening).
        ``fault_hook(sim, layer_idx, insn_idx)`` fires before each
        instruction of each layer (the harden/ injection point)."""
        if backend not in SERVE_ONE_BACKENDS:
            raise CompileError(
                f"serve_one supports backend in {SERVE_ONE_BACKENDS}, got "
                f"{backend!r} (the batch engines 'batched'/'pallas' are "
                f"serve()'s)", constraint="serve-one-backend")
        if guard is not None:
            from repro.harden import guards as _guards
            return _guards.guarded_serve_one(
                self, image, guard, backend=backend, fault_hook=fault_hook)
        image_mem = self.dram_image()
        self._stage_layer_input(image_mem, self.layers[0], image)
        sems: List[np.ndarray] = []
        srcs, rsrcs = self._sources(), self._res_sources()
        for k, layer in enumerate(self.layers):
            if k > 0:
                sem_in = image if srcs[k] < 0 else sems[srcs[k]]
                self._stage_layer_input(image_mem, layer, sem_in)
            if rsrcs[k] is not None:
                sem_res = image if rsrcs[k] < 0 else sems[rsrcs[k]]
                self._stage_residual(image_mem, layer, sem_res)
            sim = make_simulator(self.config, image_mem, backend=backend,
                                 count_overflows=count_overflows)
            run_instructions(sim, layer.program.instructions,
                             program=layer.program,
                             fault_hook=self._layer_hook(fault_hook, k))
            image_mem = sim.dram
            out_mat = decode_out_region(layer.program, image_mem)
            sems.append(decode_layer_output(layer, out_mat))
        return sems[-1]

    def serve(self, images, *, backend: str = "batched", fault_hook=None,
              count_overflows: bool = False, guard=None):
        """Compile-once/serve-many batched inference (DESIGN.md §Batching).

        ``images`` is a batch of requests (see :meth:`_as_image_list`).
        The whole batch moves through the layer chain together: one
        ``(batch, nbytes)`` DRAM stack, one batched VTA execution per
        layer over the layer's cached instruction plan, vectorised OUT
        decoding, and per-request host reshaping between layers.  Outputs
        are bit-identical to calling :meth:`serve_one` per request — the
        batch axis only amortises instruction decode and merges the
        per-instruction array work.

        ``backend="batched"`` (default) runs the vectorised instruction
        interpreter; ``backend="pallas"`` executes each layer as a fused
        MXU kernel call over the whole stack
        (:mod:`repro.core.pallas_backend`, ``interpret=True`` off-TPU) —
        bit-identical to the simulators on its truncation path.

        Returns ``(stacked outputs, per-layer batch-total reports)``: the
        leading output axis is the request index.

        ``guard`` (a :class:`repro.harden.GuardPolicy`) routes the batch
        through the integrity-guarded path and returns ``(outputs,
        reports, guard_reports)`` with one :class:`GuardReport` per
        request (DESIGN.md §Hardening).
        """
        if guard is not None:
            if backend != "batched":
                raise CompileError(
                    "guarded serving runs on the batched instruction "
                    "interpreter (its watchdog and injection hooks are "
                    "per-instruction); drop guard= or backend="
                    f"{backend!r}", constraint="serve-guard-backend")
            from repro.harden import guards as _guards
            return _guards.guarded_serve(self, images, guard,
                                         fault_hook=fault_hook)
        if backend not in SERVE_BACKENDS:
            raise CompileError(
                f"serve supports backend in {SERVE_BACKENDS} (the "
                f"per-image backends {SERVE_ONE_BACKENDS} are "
                f"serve_one()'s), got {backend!r}",
                constraint="serve-backend")
        imgs = self._as_image_list(images)
        from .fast_simulator import BatchFastSimulator, plan_for
        base = self.dram_image()
        stack = np.broadcast_to(base, (len(imgs), base.size)).copy()
        self._stage_layer_input_batch(stack, self.layers[0], imgs)
        reports: List[SimReport] = []
        all_sems: List[List[np.ndarray]] = []   # per layer, per request
        srcs, rsrcs = self._sources(), self._res_sources()
        for k, layer in enumerate(self.layers):
            if k > 0:
                src_sems = imgs if srcs[k] < 0 else all_sems[srcs[k]]
                self._stage_layer_input_batch(stack, layer, src_sems)
            if rsrcs[k] is not None:
                res_sems = imgs if rsrcs[k] < 0 else all_sems[rsrcs[k]]
                self._stage_residual_batch(stack, layer, res_sems)
            # the loop owns ``stack`` and re-reads it from ``sim.dram``, so
            # the engine's defensive copy is skipped
            if backend == "pallas":
                from .pallas_backend import BatchPallasSimulator
                sim = BatchPallasSimulator(self.config, stack,
                                           copy_dram=False)
                reports.append(sim.run_program(
                    layer.program,
                    fault_hook=self._layer_hook(fault_hook, k)))
            else:
                sim = BatchFastSimulator(self.config, stack,
                                         copy_dram=False,
                                         count_overflows=count_overflows)
                reports.append(sim.run(layer.program.instructions,
                                       plan=plan_for(layer.program),
                                       fault_hook=self._layer_hook(
                                           fault_hook, k)))
            stack = sim.dram
            out_mats = decode_out_region_batch(layer.program, stack)
            all_sems.append([decode_layer_output(layer, m)
                             for m in out_mats])
        return np.stack(all_sems[-1]), reports


def calibrate_network(specs: Sequence[LayerSpec],
                      images: Sequence[np.ndarray], *,
                      margin: int = 1, saturate: bool = False
                      ) -> Tuple[List[int], List[List[np.ndarray]]]:
    """Static per-layer requant shifts from a calibration set (§4.2
    discipline: shifts are fixed at compile time; the margin bit guards
    unseen inputs against int8 wrap-around).  Model-agnostic: works for
    any conv/fc chain with valid or same padding and avg/max pooling.

    Layer k's input depends on shifts < k, so calibration is sequential,
    and the images advance through each layer under the *device's*
    requant semantics (:func:`repro.core.layout.requant_int8` — wrap by
    default, clip under ``saturate=True``), with pinned
    ``spec.requant_shift`` values honoured exactly as :func:`compile_layer`
    honours them.  Anything else calibrates downstream layers against
    activations the machine never produces (DESIGN.md §Quantization).

    Returns ``(shifts, traces)`` where ``traces[k][i]`` is layer ``k``'s
    semantic output for calibration image ``i`` — bit-identical to what
    ``serve``/``serve_one`` produce for the same image, which the
    calibration-drift regression test asserts differentially.
    """
    from .conv_lowering import mat2tensor
    from .layer_compiler import (choose_requant_shift, layer_matrices,
                                 pool_divisor, pool_plan_for,
                                 reference_layer_acc)
    from .layout import requant_int8

    shifts: List[int] = []
    traces: List[List[np.ndarray]] = []
    currents = [np.asarray(img, np.int8) for img in images]
    for spec in specs:
        pool_div = 0
        accs = []
        geos = []
        for cur in currents:
            A, B, geo = layer_matrices(spec, cur)
            plan = pool_plan_for(spec, geo)
            pool_div = pool_divisor(plan)
            accs.append(reference_layer_acc(A, B, spec.bias, spec.relu, plan))
            geos.append((geo, plan))
        if spec.requant_shift is not None:
            shift = spec.requant_shift
        else:
            stacked = np.concatenate([a.reshape(-1) for a in accs])
            shift = choose_requant_shift(stacked,
                                         already_shifted=pool_div) + margin
        shifts.append(shift)
        # advance every calibration image through this layer
        nxt = []
        for acc, (geo, plan) in zip(accs, geos):
            out = requant_int8(acc >> (pool_div + shift), saturate=saturate)
            if spec.kind == "conv":
                oh = plan.out_h if plan else geo.out_h
                ow = plan.out_w if plan else geo.out_w
                nxt.append(mat2tensor(out, oh, ow))
            else:
                nxt.append(out)
        currents = nxt
        traces.append(list(currents))
    return shifts, traces


def calibrate_network_shifts(specs: Sequence[LayerSpec],
                             images: Sequence[np.ndarray],
                             margin: int = 1, *,
                             saturate: bool = False) -> List[int]:
    """Shift list only — see :func:`calibrate_network` (which also
    returns the per-layer calibration trace)."""
    return calibrate_network(specs, images, margin=margin,
                             saturate=saturate)[0]


def compile_network(specs: Sequence[LayerSpec], input_tensor: np.ndarray, *,
                    cfg: Optional[VTAConfig] = None,
                    dram_offset: int = 0,
                    schedule: str = "serialized") -> NetworkProgram:
    """Compile a network: every layer against one shared DRAM allocation,
    each layer's input taken from the previous layer's reference output."""
    cfg = cfg or vta_default()
    alloc = DramAllocator(offset=dram_offset, page_bytes=cfg.page_bytes)
    layers: List[CompiledLayer] = []
    current: np.ndarray = np.asarray(input_tensor, dtype=np.int8)
    for spec in specs:
        layer = compile_layer(spec, current, cfg=cfg, allocator=alloc,
                              schedule=schedule)
        layers.append(layer)
        # Reference output becomes the next layer's input (semantic form).
        ref = layer.ref_output_matrix
        if spec.kind == "conv":
            from .conv_lowering import mat2tensor
            current = mat2tensor(ref, layer.out_h, layer.out_w)
        else:
            current = ref
    return NetworkProgram(config=cfg, allocator=alloc, layers=layers,
                          input_tensor=np.asarray(input_tensor))
