"""Task-level pipeline scheduler (DESIGN.md §Pipeline).

The VTA's throughput comes from its decoupled access-execute pipeline:
the Load, Compute and Store modules run concurrently, synchronised only
by the four §2.3 dependency-token queues.  The compiler's *serialized*
schedule is conservative — every load group waits for the GEMM that
consumed the previous one, and every chunk waits for the previous
chunk's store — so the three modules effectively take turns.

This module implements the opt-in ``schedule="pipelined"`` emission
policy (threaded through ``compile_matmul`` / ``compile_layer`` /
``compile_network``):

* **Double-buffered loads** — the INP and WGT SRAMs are split into two
  halves and load groups alternate between them (phase ``g % 2``), so
  the Load module may run up to *two* groups ahead of the GEMM stream:
  load group *g* pops the buffer-release token of GEMM *g−2* instead of
  *g−1*, and the GEMM for group *g* reads UOPs whose INP/WGT indices are
  offset into the group's half.
* **Overlapped stores** — the ACC (and OUT) windows likewise alternate
  between two halves per *chunk* (phase ``ci % 2``), so the Store module
  can drain chunk *c* while Compute already accumulates chunk *c+1*:
  the chunk's first Compute-module instruction pops the store-release
  token of chunk *c−2* instead of *c−1*.
* **Makespan-driven chunk planning** — candidate :class:`ChunkPlan`
  tilings (maximal, λ split, α split) are each emitted and timed on the
  three-module concurrent timeline (``cycle_model.simulate_pipeline``);
  the plan with the smallest modeled makespan wins, instead of the
  SRAM-fit-only greedy choice.

Safety is not asserted, it is *checked*: :func:`check_program_hazards`
builds the happens-before relation implied by module program order plus
token matching (pop *k* of a queue happens-after push *k*) and verifies
that every pair of concurrent SRAM accesses that conflict (same buffer,
overlapping ranges, at least one write) is ordered.  ``validate_program``
(DESIGN.md §Hardening) runs this check after its dep-token dry run and
rejects races under the stable ``dep-token-hazard`` constraint id —
a token-relaxation bug is a silent-corruption bug and must never reach
the simulators.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import isa
from .hwconfig import VTAConfig
from .simulator import TokenQueues, VTAHazardError, module_of

SERIALIZED = "serialized"
PIPELINED = "pipelined"
SCHEDULES = (SERIALIZED, PIPELINED)


# ---------------------------------------------------------------------------
# Schedule policy queried by the emitter (gemm_compiler)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleSpec:
    """Emission policy for one program: buffer phase bases + token rules.

    ``depth`` is the pipelining degree: 1 keeps the serialized scheme
    (every phase base is 0, consumers wait for the immediately preceding
    producer), 2 is the double-buffered scheme (producers run one phase
    ahead).  ``*_half`` are the phase-1 SRAM bases."""

    name: str
    depth: int
    inp_half: int = 0
    wgt_half: int = 0
    acc_half: int = 0

    # -- buffer phases --
    def load_phase(self, group: int) -> int:
        return group % self.depth

    def chunk_phase(self, chunk: int) -> int:
        return chunk % self.depth

    def inp_base(self, group: int) -> int:
        return self.load_phase(group) * self.inp_half

    def wgt_base(self, group: int) -> int:
        return self.load_phase(group) * self.wgt_half

    def acc_base(self, chunk: int) -> int:
        return self.chunk_phase(chunk) * self.acc_half

    def base_uop_slot(self, chunk: int) -> int:
        """UOP slot driving reset / whole-window immediate-ALU lattices:
        slot 0 holds (0, 0, 0), slot 1 (pipelined only) holds
        (acc_half, acc_half, 0) for odd chunks."""
        return self.chunk_phase(chunk)

    def pinned_uops(self) -> List[isa.Uop]:
        pinned = [isa.Uop(0, 0, 0)]
        if self.depth > 1:
            pinned.append(isa.Uop(self.acc_half, self.acc_half, 0))
        return pinned

    # -- token rules --
    def load_pops_release(self, group: int) -> bool:
        """LOAD INP of ``group`` waits for the GEMM that last read this
        phase's buffer half (group − depth) to release it."""
        return group >= self.depth

    def chunk_pops_store(self, chunk: int) -> bool:
        """The chunk's first Compute-module instruction waits for the
        store that last read this phase's ACC/OUT half (chunk − depth)."""
        return chunk >= self.depth


def make_schedule(cfg: VTAConfig, schedule: str) -> ScheduleSpec:
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"expected one of {SCHEDULES}")
    if schedule == SERIALIZED:
        return ScheduleSpec(name=SERIALIZED, depth=1)
    return ScheduleSpec(name=PIPELINED, depth=2,
                        inp_half=cfg.inp_buff_vectors // 2,
                        wgt_half=cfg.wgt_buff_matrices // 2,
                        acc_half=cfg.acc_buff_vectors // 2)


def pipelinable(cfg: VTAConfig, row_height: int, acc_copies: int) -> bool:
    """Can this config double-buffer at all?  Each half must hold at
    least one row-height of INP, one WGT matrix, one ACC result window
    (× ``acc_copies``), and the odd-phase OUT window must stay inside the
    OUT buffer (the store reads OUT at the chunk's ACC base).  Phase-1
    UOP indices reach into the upper buffer halves, so the whole buffer
    must stay addressable by the §2.3 UOP fields (acc/inp 11 bits, wgt
    10 bits) — configs beyond that fall back to serialized."""
    return (cfg.inp_buff_vectors // 2 >= row_height
            and cfg.wgt_buff_matrices // 2 >= 1
            and cfg.acc_buff_vectors // 2 >= row_height * acc_copies
            and cfg.out_buff_vectors >= cfg.acc_buff_vectors // 2
            + row_height
            and cfg.uop_buff_entries >= 3
            and cfg.acc_buff_vectors <= 1 << 11
            and cfg.inp_buff_vectors <= 1 << 11
            and cfg.wgt_buff_matrices <= 1 << 10)


# ---------------------------------------------------------------------------
# Makespan-driven chunk-plan selection
# ---------------------------------------------------------------------------

def choose_plan(candidates, emit, simulate) -> Tuple[object, object]:
    """Pick the candidate plan with the smallest modeled makespan.

    ``emit(plan)`` builds the candidate's instruction stream (DRAM
    addresses irrelevant to timing may be stubbed); ``simulate(insns)``
    returns an object with ``makespan_cycles``.  Deterministic: ties keep
    the earliest candidate, so the maximal-tile plan wins when splitting
    buys nothing."""
    best = None
    for plan in candidates:
        report = simulate(emit(plan))
        if best is None or report.makespan_cycles < best[2].makespan_cycles:
            best = (plan, None, report)
    return best[0], best[2]


# ---------------------------------------------------------------------------
# Concurrent-hazard checker (the proof obligation of any token relaxation)
# ---------------------------------------------------------------------------

#: Access record: (buffer, lo, hi, is_write) with ``[lo, hi)`` in
#: structure units of that SRAM buffer.
_Access = Tuple[str, int, int, bool]


def _lattice_range(t, f_out: int, f_in: int, col: int,
                   uops: np.ndarray) -> Tuple[int, int]:
    lo = int(uops[:, col].min())
    hi = ((t.iter_out - 1) * f_out + (t.iter_in - 1) * f_in
          + int(uops[:, col].max()))
    return lo, hi + 1


def _insn_accesses(insn, cfg: VTAConfig,
                   uop_model: Optional[np.ndarray]) -> List[_Access]:
    """SRAM ranges ``insn`` touches.  ``uop_model`` is the symbolic UOP
    buffer at this point of the stream; ``None`` means unknown — GEMM/ALU
    then claim their whole operand buffers (conservative)."""
    if isinstance(insn, isa.MemInsn):
        kind = {isa.MemId.UOP: "uop", isa.MemId.INP: "inp",
                isa.MemId.WGT: "wgt", isa.MemId.ACC: "acc",
                isa.MemId.OUT: "out"}[insn.memory_type]
        if insn.opcode == isa.Opcode.LOAD:
            row_w = insn.x_pad_0 + insn.x_size + insn.x_pad_1
            span = (insn.y_pad_0 + insn.y_size + insn.y_pad_1) * row_w
            return [(kind, insn.sram_base, insn.sram_base + span, True)]
        # STORE OUT serializes the window to DRAM; the OUT bytes are the
        # truncation of the same ACC window (§2.1), so the store's result
        # depends on both ranges being quiescent.
        span = insn.y_size * insn.x_size
        return [("out", insn.sram_base, insn.sram_base + span, False),
                ("acc", insn.sram_base, insn.sram_base + span, False)]
    if isinstance(insn, isa.GemInsn):
        n_uop = max(0, insn.uop_end - insn.uop_bgn)
        if n_uop == 0 or insn.iter_out <= 0 or insn.iter_in <= 0:
            return []
        if uop_model is None:
            acc = [("acc", 0, cfg.acc_buff_vectors, True)]
            if insn.reset:
                return acc
            return acc + [("inp", 0, cfg.inp_buff_vectors, False),
                          ("wgt", 0, cfg.wgt_buff_matrices, False)]
        uops = uop_model[insn.uop_bgn:insn.uop_end]
        out: List[_Access] = []
        lo, hi = _lattice_range(insn, insn.acc_factor_out,
                                insn.acc_factor_in, 0, uops)
        out.append(("acc", lo, hi, True))
        if not insn.reset:
            lo, hi = _lattice_range(insn, insn.inp_factor_out,
                                    insn.inp_factor_in, 1, uops)
            out.append(("inp", lo, hi, False))
            lo, hi = _lattice_range(insn, insn.wgt_factor_out,
                                    insn.wgt_factor_in, 2, uops)
            out.append(("wgt", lo, hi, False))
        return out
    if isinstance(insn, isa.AluInsn):
        n_uop = max(0, insn.uop_end - insn.uop_bgn)
        if n_uop == 0 or insn.iter_out <= 0 or insn.iter_in <= 0:
            return []
        if uop_model is None:
            return [("acc", 0, cfg.acc_buff_vectors, True)]
        uops = uop_model[insn.uop_bgn:insn.uop_end]
        lo, hi = _lattice_range(insn, insn.dst_factor_out,
                                insn.dst_factor_in, 0, uops)
        out = [("acc", lo, hi, True)]
        if not insn.use_imm:
            lo, hi = _lattice_range(insn, insn.src_factor_out,
                                    insn.src_factor_in, 1, uops)
            out.append(("acc", lo, hi, False))
        return out
    return []                                   # FINISH


def _replay_uop_load(m: isa.MemInsn, uop_model: np.ndarray,
                     uop_raw: bytes, uop_base: int) -> None:
    """Advance the symbolic UOP model from the program's uop segment
    bytes, mirroring the LOAD UOP semantics (pads write zeros)."""
    nbytes = 4
    row_w = m.x_pad_0 + m.x_size + m.x_pad_1
    for y in range(m.y_size):
        lo = (m.dram_base + y * m.x_stride - uop_base) * nbytes
        raw = uop_raw[lo:lo + m.x_size * nbytes]
        words = np.frombuffer(raw, dtype="<u4").astype(np.int64)
        rows = np.stack([words & 0x7FF, (words >> 11) & 0x7FF,
                         (words >> 22) & 0x3FF], axis=1)
        dst = m.sram_base + (m.y_pad_0 + y) * row_w + m.x_pad_0
        uop_model[dst:dst + len(rows)] = rows


def check_concurrent_hazards(cfg: VTAConfig, instructions,
                             uop_raw: Optional[bytes] = None,
                             uop_base: int = 0) -> None:
    """Prove the token stream orders every conflicting SRAM access.

    Builds the happens-before DAG — module program order plus token edges
    (pop *k* of a queue happens-after push *k*, the ordering the §2.3
    counters guarantee) — then checks every pair of instructions on
    *different* modules whose SRAM ranges conflict (same buffer, overlap,
    at least one write) for an ordering path.  Raises
    :class:`VTAHazardError` naming the racing pair; also raises on a pop
    with no earlier matching push (the dry-run deadlock).

    ``uop_raw``/``uop_base`` give the program's uop segment bytes and its
    logical base address so GEMM/ALU ranges are exact; without them the
    lattices conservatively claim their whole operand buffers.
    """
    insns = list(instructions)
    uop_model = (np.zeros((cfg.uop_buff_entries, 3), dtype=np.int64)
                 if uop_raw is not None else None)

    accesses: List[List[_Access]] = []
    modules: List[str] = []
    reach: List[int] = []                # happens-before bitsets
    pushers: Dict[Tuple[str, str], List[int]] = {}
    pops_taken: Dict[Tuple[str, str], int] = {}
    last_of_module: Dict[str, int] = {}

    for i, insn in enumerate(insns):
        mod = module_of(insn)
        preds: List[int] = []
        if mod in last_of_module:
            preds.append(last_of_module[mod])
        pops = []
        if insn.dep.pop_prev:
            pops.append((TokenQueues._PREV[mod], mod))
        if insn.dep.pop_next:
            pops.append((TokenQueues._NEXT[mod], mod))
        for src, dst in pops:
            if src is None:
                raise VTAHazardError(f"{dst}: pop from nonexistent neighbour")
            q = (src, dst)
            k = pops_taken.get(q, 0)
            plist = pushers.get(q, ())
            if k >= len(plist):
                raise VTAHazardError(
                    f"dependency deadlock: insn {i} ({dst}) pop #{k + 1} "
                    f"from {src} has no matching push in the stream")
            preds.append(plist[k])
            pops_taken[q] = k + 1
        r = 0
        for p in preds:
            r |= reach[p] | (1 << p)
        reach.append(r)
        last_of_module[mod] = i
        if insn.dep.push_prev:
            pushers.setdefault((mod, TokenQueues._PREV[mod]), []).append(i)
        if insn.dep.push_next:
            pushers.setdefault((mod, TokenQueues._NEXT[mod]), []).append(i)

        accesses.append(_insn_accesses(insn, cfg, uop_model))
        modules.append(mod)
        if (uop_model is not None and isinstance(insn, isa.MemInsn)
                and insn.opcode == isa.Opcode.LOAD
                and insn.memory_type == isa.MemId.UOP):
            _replay_uop_load(insn, uop_model, uop_raw, uop_base)

    # conflict scan, grouped by buffer (program order is a topological
    # order, so i < j only ever needs "i happens-before j")
    by_buf: Dict[str, List[Tuple[int, int, int, bool]]] = {}
    for i, acc in enumerate(accesses):
        for buf, lo, hi, wr in acc:
            if hi > lo:
                by_buf.setdefault(buf, []).append((i, lo, hi, wr))
    for buf, lst in by_buf.items():
        for a in range(len(lst)):
            i, lo_i, hi_i, wr_i = lst[a]
            for b in range(a + 1, len(lst)):
                j, lo_j, hi_j, wr_j = lst[b]
                if i == j or modules[i] == modules[j]:
                    continue
                if not (wr_i or wr_j):
                    continue
                if lo_i >= hi_j or lo_j >= hi_i:
                    continue
                if not (reach[j] >> i) & 1:
                    raise VTAHazardError(
                        f"concurrent hazard: insn {i} ({modules[i]}, "
                        f"{'write' if wr_i else 'read'} {buf.upper()}"
                        f"[{lo_i}, {hi_i})) races insn {j} ({modules[j]}, "
                        f"{'write' if wr_j else 'read'} {buf.upper()}"
                        f"[{lo_j}, {hi_j})) — no dependency-token path "
                        f"orders them")


def check_program_hazards(prog) -> None:
    """:func:`check_concurrent_hazards` over a compiled
    :class:`~repro.core.program.VTAProgram`, with exact GEMM/ALU ranges
    from its uop segment when available."""
    uop_raw = prog.segments.get("uop") if prog.segments else None
    uop_base = 0
    if uop_raw is not None and "uop" in prog.regions:
        region = prog.regions["uop"]
        uop_base = ((region.phys_addr - prog.allocator.offset)
                    // prog.config.uop_elem_bytes)
    check_concurrent_hazards(prog.config, prog.instructions,
                             uop_raw=uop_raw, uop_base=uop_base)
