"""VTA program container + binary emission (paper §3.1, Fig. 5).

A ``VTAProgram`` bundles everything the compiler produces for one VTA
execution: the DRAM allocation, the data segments (INP/WGT/ACC/OUT/UOP/INSN
regions), the instruction stream and the UOPs, plus the metadata needed to
decode the result (§4.2 reshaping).  ``write_binaries`` emits the six binary
files of Fig. 5 (``input.bin``, ``weight.bin``, ``accumulator.bin``,
``uop.bin``, ``instructions.bin``, ``expected_out.bin``).
"""

from __future__ import annotations

import dataclasses
import pathlib
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from . import isa
from .dram import DramAllocator, Region
from .hwconfig import VTAConfig


@dataclasses.dataclass
class OutputMeta:
    """Geometry needed to decode the OUT region back into a matrix."""

    block_rows: int        # α
    block_cols: int        # β
    row_height: int        # block_size, or 1 for single-row matrices
    valid_shape: Tuple[int, int]   # unpadded (M, N) of the result


@dataclasses.dataclass
class VTAProgram:
    """One VTA execution.  ``regions`` maps the canonical region keys
    (inp/wgt/acc/out/uop/insn) to :class:`Region` handles — the allocator
    may be shared across the programs of a multi-layer network (§4.2), in
    which case the allocator-level names carry a per-layer prefix while the
    canonical keys stay stable."""

    config: VTAConfig
    allocator: DramAllocator
    instructions: List[object] = dataclasses.field(default_factory=list)
    uops: List[isa.Uop] = dataclasses.field(default_factory=list)
    regions: Dict[str, Region] = dataclasses.field(default_factory=dict)
    # canonical region key -> raw little-endian bytes
    segments: Dict[str, bytes] = dataclasses.field(default_factory=dict)
    output_meta: Optional[OutputMeta] = None
    expected_out: Optional[np.ndarray] = None
    name: str = "program"
    # The compiler's SRAM tiling (a gemm_compiler.ChunkPlan) — observability
    # for the §3.3 chunk loop (n_chunks, segment geometry); None for
    # hand-written instruction streams.
    chunk_plan: Optional[object] = None
    # Which task-level pipeline schedule the token stream implements
    # ("serialized" or "pipelined", DESIGN.md §Pipeline).  A requested
    # "pipelined" compile that falls back (buffers too small to
    # double-buffer) records "serialized" here.
    schedule: str = "serialized"
    # The ALU post-op spec the instruction stream implements (the
    # gemm_compiler AluSpec tuple) — the semantic record the pallas
    # backend lowers from (DESIGN.md §2).  ``None`` (hand-written
    # streams) marks the program as not pallas-executable.
    alu_ops: Optional[Tuple] = None
    # CRC32 of every segment, captured by finalize() — the integrity
    # reference the harden/ guards verify serves against (DESIGN.md
    # §Hardening).  Segment bytes are immutable, so the values stay valid
    # until a segment is replaced via set_segment (which refreshes them).
    segment_crcs: Dict[str, int] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------
    def region(self, name: str) -> Region:
        return self.regions[name]

    def set_segment(self, name: str, data: bytes) -> None:
        region = self.regions[name]
        if len(data) > region.nbytes:
            raise ValueError(
                f"segment {name!r}: {len(data)} bytes exceeds region size "
                f"{region.nbytes}")
        self.segments[name] = data
        if self.segment_crcs:
            self.segment_crcs[name] = zlib.crc32(data)

    def finalize(self) -> None:
        """Encode UOPs + instructions into their DRAM segments.

        The instruction region is allocated here (last, per the TVM
        reference order) because its size is only known once instruction
        generation has finished.  Also captures the per-segment CRC32
        reference values the runtime integrity guards verify against
        (DESIGN.md §Hardening).
        """
        self.set_segment("uop", isa.encode_uops(self.uops))
        if "insn" not in self.regions:
            self.regions["insn"] = self.allocator.alloc(
                f"{self.name}:insn", "insn", self.config.insn_elem_bytes,
                len(self.instructions))
        self.set_segment("insn", isa.encode_stream(self.instructions))
        self.segment_crcs = {name: zlib.crc32(data)
                             for name, data in self.segments.items()}

    # ------------------------------------------------------------------
    def dram_image(self) -> np.ndarray:
        """Materialise the full DRAM image (uint8) with every segment
        placed at its physical address."""
        image = np.zeros(self.allocator.image_size(), dtype=np.uint8)
        self.place_segments(image)
        return image

    def place_segments(self, image: np.ndarray) -> None:
        """Copy this program's segments into a (possibly shared) image."""
        for name, data in self.segments.items():
            region = self.regions[name]
            start = region.phys_addr - self.allocator.offset
            image[start:start + len(data)] = np.frombuffer(data, dtype=np.uint8)

    # ------------------------------------------------------------------
    def gemm_loops(self) -> int:
        """The §5.1 metric: loops of non-reset GeMM instructions (i.e. the
        loops that perform multiplications)."""
        return sum(i.loop_count for i in self.instructions
                   if isinstance(i, isa.GemInsn) and not i.reset)

    def alu_loops(self) -> int:
        return sum(i.loop_count for i in self.instructions
                   if isinstance(i, isa.AluInsn))

    def counts(self) -> Dict[str, int]:
        from collections import Counter
        c: Dict[str, int] = Counter()
        for i in self.instructions:
            if isinstance(i, isa.MemInsn):
                key = f"{i.opcode.name.lower()}_{i.memory_type.name.lower()}"
            else:
                key = type(i).__name__.replace("Insn", "").lower()
            c[key] += 1
        return dict(c)

    # ------------------------------------------------------------------
    _BIN_NAMES = {
        "inp": "input.bin",
        "wgt": "weight.bin",
        "acc": "accumulator.bin",
        "uop": "uop.bin",
        "insn": "instructions.bin",
    }

    def write_binaries(self, directory: str | pathlib.Path) -> Dict[str, pathlib.Path]:
        """Emit the Fig. 5 binary files."""
        directory = pathlib.Path(directory)
        directory.mkdir(parents=True, exist_ok=True)
        written: Dict[str, pathlib.Path] = {}
        for name, data in self.segments.items():
            region = self.regions[name]
            fname = self._BIN_NAMES.get(region.kind, f"{name}.bin")
            path = directory / fname
            path.write_bytes(data)
            written[name] = path
        if self.expected_out is not None:
            path = directory / "expected_out.bin"
            path.write_bytes(np.ascontiguousarray(self.expected_out).tobytes())
            written["expected_out"] = path
        return written
