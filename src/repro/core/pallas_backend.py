"""The Pallas backend: compiled VTA programs on the fused TPU kernel.

The fourth backend (DESIGN.md §2): where ``oracle``/``fast``/``batched``
*interpret* the instruction stream, this backend executes the *semantics* a
compiled :class:`~repro.core.program.VTAProgram` encodes — one
``kernels.vta_gemm`` MXU call per program (``interpret=True`` off-TPU, so
CPU-only CI runs the same kernel body) plus a bit-exact TensorAlu epilogue —
and commits the result to the same DRAM OUT region the simulators write.
Because it reads the INP/WGT/ACC/RES segments and writes OUT bytes through
the §3.2 layout (block-major vectors), it is a drop-in
``make_simulator(backend="pallas")`` engine: ``run_program``,
``NetworkProgram.run_functional/serve_one/serve`` and the differential
conformance suite drive it unchanged, and multi-chunk / LOAD_UOP-wave /
pipelined programs come along for free (chunking is an SRAM-residency
concern; the DRAM-level semantics this backend reproduces are identical).

Semantics contract (pinned by ``tests/test_pallas_backend.py``):

* ``saturate=False`` (default) — faithful §2.1 truncation; OUT bytes are
  **bit-identical** to the oracle for every compiled program (fuzzed in
  ``tests/test_batched_conformance.py``).
* ``saturate=True`` — the kernel's deliberate int8-saturation upgrade; OUT
  equals ``clip(acc, -128, 127)`` of the oracle's pre-truncation ACC.

When the program's ALU epilogue is exactly the fused-kernel form
(``[relu?][shr?]`` with a row-broadcast bias) the whole layer runs inside
``vta_gemm``; richer programs (pool pair lattices, indexed SHR, residual
ADD) run the GEMM on the kernel and the remaining TensorAlu ops as the
vectorised int32 epilogue below, which mirrors ``gemm_compiler``'s
reference semantics op for op (wraparound included).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import isa
from .errors import CompileError
from .gemm_compiler import (AluImmOp, AluIndexedImmOp, AluPairOp,
                            AluResidualOp, _wrap_int32)
from .hwconfig import VTAConfig
from .layout import truncate_int8
from .simulator import SimReport

try:  # jax + the kernels layer are optional at import time (clean skips)
    import jax  # noqa: F401
    import jax.numpy as jnp
    HAS_PALLAS = True
    _IMPORT_ERROR = None
except Exception as exc:  # pragma: no cover - exercised only without jax
    HAS_PALLAS = False
    _IMPORT_ERROR = exc


def _require_pallas() -> None:
    if not HAS_PALLAS:  # pragma: no cover - exercised only without jax
        raise CompileError(
            f"the pallas backend needs jax ({_IMPORT_ERROR});"
            f" use backend='fast' or 'oracle'",
            constraint="pallas-jax-missing")


# ---------------------------------------------------------------------------
# Program lowering (cached on the program, like fast_simulator.plan_for)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PallasPlan:
    """Geometry + epilogue lowering for one compiled program.

    ``fused`` marks ALU programs of the exact kernel-epilogue form
    (``[relu?][shr?]``): those run entirely inside ``vta_gemm``.  Region
    offsets are relative to the allocator-local DRAM image, byte sizes
    derived from the §3.2 block grid (α×λ×β, ``row_height``)."""

    alpha: int
    lam: int
    beta: int
    row_height: int
    block_size: int
    valid_shape: Tuple[int, int]
    alu_ops: Tuple
    fused: bool
    relu: bool
    shift: int
    # (byte offset, byte size) per region; None when the program has none
    inp: Tuple[int, int]
    wgt: Tuple[int, int]
    out: Tuple[int, int]
    acc: Optional[Tuple[int, int]]
    res: Optional[Tuple[int, int]]

    @property
    def padded_shape(self) -> Tuple[int, int]:
        return (self.alpha * self.row_height, self.beta * self.block_size)


def _fused_form(alu_ops) -> Optional[Tuple[bool, int]]:
    """``(relu, shift)`` when the epilogue is the kernel-fusable subset."""
    relu, shift = False, 0
    stage = 0                       # 0 = expect relu or shr, 1 = expect shr
    for spec in alu_ops:
        if not isinstance(spec, AluImmOp):
            return None
        if spec.op == isa.AluOp.MAX and spec.imm == 0 and stage == 0:
            relu, stage = True, 1
        elif spec.op == isa.AluOp.SHR and spec.imm >= 0:
            if shift:               # two SHRs do not fuse into one
                return None
            shift, stage = spec.imm, 2
        else:
            return None
    return relu, shift


def plan_pallas(prog) -> PallasPlan:
    """Lower ``prog`` for the pallas backend; cached on the program (the
    compile-once/serve-many contract shared with ``plan_for``)."""
    plan = getattr(prog, "_pallas_plan", None)
    if plan is not None:
        return plan
    if prog.chunk_plan is None or prog.output_meta is None \
            or prog.alu_ops is None:
        raise CompileError(
            f"program {prog.name!r} was not produced by compile_matmul; "
            f"the pallas backend lowers compiler metadata (chunk plan, "
            f"output meta, ALU spec), not raw instruction streams",
            constraint="pallas-program-metadata")
    cfg: VTAConfig = prog.config
    cp = prog.chunk_plan
    bs = cfg.block_size
    alpha, lam, beta, rh = cp.alpha, cp.lam, cp.beta, cp.row_height

    def _span(key: str, nbytes: int) -> Tuple[int, int]:
        region = prog.regions[key]
        return region.phys_addr - prog.allocator.offset, nbytes

    fused = _fused_form(prog.alu_ops)
    plan = PallasPlan(
        alpha=alpha, lam=lam, beta=beta, row_height=rh, block_size=bs,
        valid_shape=tuple(prog.output_meta.valid_shape),
        alu_ops=tuple(prog.alu_ops),
        fused=fused is not None,
        relu=fused[0] if fused else False,
        shift=fused[1] if fused else 0,
        inp=_span("inp", alpha * lam * rh * bs),
        wgt=_span("wgt", lam * beta * bs * bs),
        out=_span("out", alpha * beta * rh * bs),
        acc=(_span("acc", alpha * beta * rh * bs * 4)
             if "acc" in prog.regions else None),
        res=(_span("res", alpha * beta * rh * bs * 4)
             if "res" in prog.regions else None))
    prog._pallas_plan = plan
    return plan


# ---------------------------------------------------------------------------
# §3.2 layout codecs over a (B, nbytes) DRAM stack (B = 1 for one image)
# ---------------------------------------------------------------------------

def _decode_inp(stack: np.ndarray, p: PallasPlan) -> np.ndarray:
    """INP bytes → (B, α·rh, λ·bs) int8 padded A."""
    start, size = p.inp
    raw = stack[:, start:start + size].view(np.int8)
    b = stack.shape[0]
    blocks = raw.reshape(b, p.alpha, p.lam, p.row_height, p.block_size)
    return blocks.transpose(0, 1, 3, 2, 4).reshape(
        b, p.alpha * p.row_height, p.lam * p.block_size)


def _decode_wgt(stack: np.ndarray, p: PallasPlan) -> np.ndarray:
    """WGT bytes (blocks stored transposed, §3.2) → (B, λ·bs, β·bs) int8."""
    start, size = p.wgt
    raw = stack[:, start:start + size].view(np.int8)
    b, bs = stack.shape[0], p.block_size
    blocks = raw.reshape(b, p.lam, p.beta, bs, bs)   # each block is Bᵀ
    return blocks.transpose(0, 1, 4, 2, 3).reshape(
        b, p.lam * bs, p.beta * bs)


def _decode_acc32(stack: np.ndarray, p: PallasPlan,
                  span: Tuple[int, int]) -> np.ndarray:
    """ACC/RES bytes → (B, α·rh, β·bs) int32 (X preload / residual)."""
    start, size = span
    raw = stack[:, start:start + size].view("<i4")
    b = stack.shape[0]
    blocks = raw.reshape(b, p.alpha, p.beta, p.row_height, p.block_size)
    return blocks.transpose(0, 1, 3, 2, 4).reshape(
        b, p.alpha * p.row_height, p.beta * p.block_size)


def _encode_out(stack: np.ndarray, p: PallasPlan, out: np.ndarray) -> None:
    """(B, α·rh, β·bs) int8 result → OUT bytes, committed in place."""
    start, size = p.out
    b = stack.shape[0]
    blocks = out.reshape(b, p.alpha, p.row_height, p.beta, p.block_size)
    raw = np.ascontiguousarray(blocks.transpose(0, 1, 3, 2, 4))
    stack[:, start:start + size] = raw.reshape(b, -1).view(np.uint8)


def _to_vectors(mat: np.ndarray, p: PallasPlan) -> np.ndarray:
    """(B, H, W) → (B, n_vec, bs) block-major result vectors."""
    b = mat.shape[0]
    blocks = mat.reshape(b, p.alpha, p.row_height, p.beta, p.block_size)
    return blocks.transpose(0, 1, 3, 2, 4).reshape(
        b, p.alpha * p.beta * p.row_height, p.block_size)


def _to_matrix(vec: np.ndarray, p: PallasPlan) -> np.ndarray:
    b = vec.shape[0]
    blocks = vec.reshape(b, p.alpha, p.beta, p.row_height, p.block_size)
    return blocks.transpose(0, 1, 3, 2, 4).reshape(
        b, p.alpha * p.row_height, p.beta * p.block_size)


# ---------------------------------------------------------------------------
# The TensorAlu epilogue, vectorised over the batch (oracle semantics)
# ---------------------------------------------------------------------------

def _imm_apply(sel64: np.ndarray, op: isa.AluOp, imm: int) -> np.ndarray:
    if op == isa.AluOp.MIN:
        return np.minimum(sel64, imm)
    if op == isa.AluOp.MAX:
        return np.maximum(sel64, imm)
    if op == isa.AluOp.ADD:
        return sel64 + imm
    if op == isa.AluOp.SHR:
        return sel64 >> imm
    raise CompileError(f"unsupported ALU immediate op {op!r}",
                       constraint="pallas-alu-op")


def _pair_apply(vec: np.ndarray, op: isa.AluOp,
                pairs: Tuple[Tuple[int, int], ...]) -> np.ndarray:
    """``vec[:, dst] = op(vec[:, dst], vec[:, src])`` per pair, in pair
    order.  Disjoint dst/src lattices (every pool/GAP lowering) vectorise
    with duplicate-merging ufuncs — exact for ADD (mod-2³² congruence) and
    MIN/MAX (idempotent merges); anything order-dependent falls back to the
    sequential oracle loop."""
    dst = np.fromiter((d for d, _ in pairs), dtype=np.int64, count=len(pairs))
    src = np.fromiter((s for _, s in pairs), dtype=np.int64, count=len(pairs))
    sequential = (np.intersect1d(dst, src).size > 0
                  or (op not in (isa.AluOp.ADD, isa.AluOp.MIN, isa.AluOp.MAX)
                      and len(np.unique(dst)) != len(dst)))
    if sequential:
        out = vec.copy()
        for d, s in pairs:
            a = out[:, d].astype(np.int64)
            b = out[:, s].astype(np.int64)
            if op == isa.AluOp.MIN:
                r = np.minimum(a, b)
            elif op == isa.AluOp.MAX:
                r = np.maximum(a, b)
            elif op == isa.AluOp.ADD:
                r = a + b
            elif op == isa.AluOp.SHR:
                r = a >> (b & 31)
            else:
                raise CompileError(f"unsupported ALU pair op {op!r}",
                                   constraint="pallas-alu-op")
            out[:, d] = _wrap_int32(r)
        return out
    gathered = vec[:, src].astype(np.int64)
    acc = vec.astype(np.int64)
    idx = (slice(None), dst)
    if op == isa.AluOp.ADD:
        np.add.at(acc, idx, gathered)
    elif op == isa.AluOp.MAX:
        np.maximum.at(acc, idx, gathered)
    elif op == isa.AluOp.MIN:
        np.minimum.at(acc, idx, gathered)
    else:                                       # SHR with unique dst
        acc[idx] = acc[idx] >> (gathered & 31)
    out = vec.copy()
    touched = np.unique(dst)
    out[:, touched] = _wrap_int32(acc[:, touched])
    return out


def apply_alu_epilogue(vec: np.ndarray, alu_ops,
                       res_vec: Optional[np.ndarray]) -> np.ndarray:
    """The full TensorAlu program over (B, n_vec, bs) int32 vectors —
    op-for-op the semantics of ``gemm_compiler.reference_result``."""
    for spec in alu_ops:
        if isinstance(spec, AluImmOp):
            vec = _wrap_int32(_imm_apply(vec.astype(np.int64), spec.op,
                                         spec.imm))
        elif isinstance(spec, AluIndexedImmOp):
            idx = np.asarray(spec.indices, dtype=np.int64)
            vec = vec.copy()
            vec[:, idx] = _wrap_int32(
                _imm_apply(vec[:, idx].astype(np.int64), spec.op, spec.imm))
        elif isinstance(spec, AluPairOp):
            vec = _pair_apply(vec, spec.op, spec.pairs)
        elif isinstance(spec, AluResidualOp):
            if res_vec is None:
                raise CompileError(
                    "AluResidualOp requires a staged residual operand",
                    constraint="residual-operand-missing")
            r = res_vec.astype(np.int64)
            if spec.pre_shift:
                r = _wrap_int32(r >> spec.pre_shift).astype(np.int64)
            a = vec.astype(np.int64)
            if spec.op == isa.AluOp.MIN:
                m = np.minimum(a, r)
            elif spec.op == isa.AluOp.MAX:
                m = np.maximum(a, r)
            elif spec.op == isa.AluOp.ADD:
                m = a + r
            elif spec.op == isa.AluOp.SHR:
                m = a >> (r & 31)
            else:
                raise CompileError(
                    f"unsupported residual ALU op {spec.op!r}",
                    constraint="pallas-alu-op")
            vec = _wrap_int32(m)
        else:
            raise CompileError(f"unknown ALU spec {type(spec).__name__}",
                               constraint="pallas-alu-op")
    return vec


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def _kernel_gemm(a: np.ndarray, b: np.ndarray, bias: Optional[np.ndarray],
                 *, relu: bool, shift: int, saturate: bool, out_dtype,
                 gemm_backend: str) -> np.ndarray:
    """One fused-kernel call (the MXU leg).  ``gemm_backend`` is forwarded
    to ``ops.vta_matmul``: "pallas" runs the real kernel (interpret mode
    off-TPU), "xla" the semantically identical lowered reference, "auto"
    picks per platform."""
    from repro.kernels import ops as kernel_ops
    out = kernel_ops.vta_matmul(
        jnp.asarray(a), jnp.asarray(b),
        jnp.asarray(bias) if bias is not None else None,
        relu=relu, shift=shift, saturate=saturate, out_dtype=out_dtype,
        backend=gemm_backend)
    return np.array(out)          # writable copy (jax buffers are read-only)


def _commit_int8(acc: np.ndarray, saturate: bool) -> np.ndarray:
    """ACC → OUT commit: §2.1 truncation, or the saturation upgrade."""
    if saturate:
        return np.clip(acc, -128, 127).astype(np.int8)
    return truncate_int8(acc)


def _execute_stack(prog, stack: np.ndarray, *, saturate: bool,
                   gemm_backend: str) -> SimReport:
    """Run ``prog`` over every DRAM row of ``stack``, writing OUT bytes in
    place.  Weight-uniform batches collapse to a single stacked kernel
    call; varied weights (conformance fuzz) fall back to a per-row GEMM."""
    _require_pallas()
    p = plan_pallas(prog)
    b = stack.shape[0]
    mp, np_ = p.padded_shape
    m, n = p.valid_shape
    a = _decode_inp(stack, p)                       # (B, Mp, Kp)
    w = _decode_wgt(stack, p)                       # (B, Kp, Np)
    x = _decode_acc32(stack, p, p.acc) if p.acc else None
    res = _decode_acc32(stack, p, p.res) if p.res else None
    uniform_w = b == 1 or bool((w == w[0]).all())

    # A row-broadcast preload (the bias form every compiled layer uses)
    # fuses into the kernel.  The kernel broadcasts the bias to *every*
    # row including the §3.2 padding rows, where the oracle adds the
    # stored X pad rows instead — fusing therefore also requires A's pad
    # rows to be zero (true for every compiled image; the conformance
    # fuzz violates it with random bytes and takes the general path), so
    # the pad rows' oracle value is exactly 0 and can be committed
    # directly.  Pad *columns* need no special-casing in either form:
    # the kernel computes them from the same decoded WGT/bias bytes the
    # oracle reads.
    bias = None
    fuse_bias = x is None
    if x is not None and p.fused:
        rows_equal = bool((x[:, :m] == x[:, :1]).all())
        x_pad_zero = bool((x[:, m:] == 0).all())
        a_pad_zero = bool((a[:, m:] == 0).all())
        if rows_equal and x_pad_zero and a_pad_zero:
            bias, fuse_bias = x[:, 0], True

    if p.fused and fuse_bias:
        # -- whole program inside the kernel --------------------------------
        if uniform_w and (bias is None or b == 1
                          or bool((bias == bias[0]).all())):
            out = _kernel_gemm(
                a.reshape(b * mp, -1), w[0],
                bias[0] if bias is not None else None,
                relu=p.relu, shift=p.shift, saturate=saturate,
                out_dtype=jnp.int8, gemm_backend=gemm_backend)
            out = out.reshape(b, mp, np_)
        else:
            out = np.stack([
                _kernel_gemm(a[i], w[i],
                             bias[i] if bias is not None else None,
                             relu=p.relu, shift=p.shift, saturate=saturate,
                             out_dtype=jnp.int8, gemm_backend=gemm_backend)
                for i in range(b)])
        if bias is not None:
            out[:, m:, :] = 0          # oracle pad rows: 0·B + 0 preload
    else:
        # -- kernel GEMM + vectorised TensorAlu epilogue --------------------
        if uniform_w:
            acc = _kernel_gemm(a.reshape(b * mp, -1), w[0], None,
                               relu=False, shift=0, saturate=False,
                               out_dtype=jnp.int32,
                               gemm_backend=gemm_backend).reshape(b, mp, np_)
        else:
            acc = np.stack([
                _kernel_gemm(a[i], w[i], None, relu=False, shift=0,
                             saturate=False, out_dtype=jnp.int32,
                             gemm_backend=gemm_backend)
                for i in range(b)])
        if x is not None:                           # ACC preload (C = A·B+X)
            acc = _wrap_int32(acc.astype(np.int64) + x.astype(np.int64))
        vec = _to_vectors(acc, p)
        res_vec = _to_vectors(res, p) if res is not None else None
        vec = apply_alu_epilogue(vec, p.alu_ops, res_vec)
        out = _commit_int8(_to_matrix(vec, p), saturate)

    _encode_out(stack, p, out)
    report = SimReport()
    report.gemm_loops = b * prog.gemm_loops()
    report.alu_loops = b * prog.alu_loops()
    return report


# ---------------------------------------------------------------------------
# Simulator-shaped engines (make_simulator / run_instructions dispatch)
# ---------------------------------------------------------------------------

class PallasSimulator:
    """Drop-in engine for one DRAM image: ``.run_program(prog)`` executes
    the compiled program on the fused kernel and commits OUT into
    ``self.dram`` — the same observable contract as the simulators."""

    is_batch = False

    def __init__(self, cfg: VTAConfig, dram: np.ndarray, *,
                 saturate: bool = False, gemm_backend: str = "pallas",
                 copy_dram: bool = True, trace: bool = False,
                 count_overflows: bool = False):
        if trace or count_overflows:
            raise ValueError(
                "the pallas backend executes programs as fused kernel "
                "calls; per-instruction trace/overflow accounting needs a "
                "simulator backend (oracle/fast/batched)")
        _require_pallas()
        self.cfg = cfg
        self.dram = np.array(dram, dtype=np.uint8, copy=copy_dram)
        self.saturate = saturate
        self.gemm_backend = gemm_backend

    def run_program(self, prog, *, fault_hook=None) -> SimReport:
        if fault_hook is not None:
            raise ValueError(
                "fault_hook requires per-instruction execution; the pallas "
                "backend has no instruction stream to hook (use "
                "backend='oracle'/'fast'/'batched' for injection)")
        stack = self.dram.reshape(1, -1)
        report = _execute_stack(prog, stack, saturate=self.saturate,
                                gemm_backend=self.gemm_backend)
        self.dram = stack.reshape(-1)
        return report

    def run(self, instructions, *, plan=None, fault_hook=None) -> SimReport:
        raise CompileError(
            "the pallas backend lowers compiled programs, not raw "
            "instruction streams; call run_program(prog) (run_instructions "
            "dispatches automatically when a program is passed)",
            constraint="pallas-program-metadata")


class BatchPallasSimulator(PallasSimulator):
    """The batch-axis variant over a ``(batch, nbytes)`` DRAM stack —
    weight-uniform batches execute as one stacked kernel call."""

    is_batch = True

    def __init__(self, cfg: VTAConfig, dram_stack: np.ndarray, **kw):
        super().__init__(cfg, np.atleast_2d(dram_stack), **kw)

    def run_program(self, prog, *, fault_hook=None) -> SimReport:
        if fault_hook is not None:
            raise ValueError(
                "fault_hook requires per-instruction execution; the pallas "
                "backend has no instruction stream to hook (use "
                "backend='oracle'/'fast'/'batched' for injection)")
        return _execute_stack(prog, self.dram, saturate=self.saturate,
                              gemm_backend=self.gemm_backend)


def run_program_pallas(prog, *, saturate: bool = False,
                       gemm_backend: str = "pallas"
                       ) -> Tuple[np.ndarray, SimReport]:
    """Convenience driver: execute one compiled program on the pallas
    backend; returns the decoded unpadded (M, N) result + report."""
    from .simulator import decode_out_region
    sim = PallasSimulator(prog.config, prog.dram_image(), saturate=saturate,
                          gemm_backend=gemm_backend, copy_dram=False)
    report = sim.run_program(prog)
    return decode_out_region(prog, sim.dram), report
