"""Analytical Compute-module cycle model (paper §5.2).

The paper's cycle-accurate CHISEL simulation of LeNet-5 reports:

* 2972 cycles for the TensorGemm operations — i.e. 2942 GeMM loops plus
  instruction decode / buffer-availability checking overhead ("the VTA is
  able to almost complete an entire GeMM loop in each cycle");
* 6358 total Compute-module cycles (GEMM + ALU, without Load/Store);
* 9.8 µs at 650 MHz.

We model the Compute module as: 1 cycle per GeMM/ALU loop iteration +
``DECODE_CYCLES`` fixed cycles per compute instruction (decode + dependency
check + buffer availability).  ``DECODE_CYCLES`` is the single calibration
constant; the paper's own numbers pin it:

    2972 = 2942 loops + overhead; our compiler emits exactly 5 non-reset
    GeMM instructions for LeNet-5 (one per layer — every layer fits the
    SRAM in a single chunk)  →  30 / 5  →  DECODE_CYCLES = 6.

The 6358-cycle total additionally depends on the TVM-generated ALU
instruction stream, which the paper does not publish.  Our ALU schedule is
*leaner* (pool ÷4 and requant fuse into a single SHR on the surviving rows
only), so our total comes out below 6358 — the delta is reported as a
beyond-paper instruction-schedule optimisation in EXPERIMENTS.md §Paper.

The SIMD-CPU comparison (§5.2) follows the paper's own arithmetic: one GeMM
loop is ``block_size² = 256`` MACs, a 16-MAC/cycle CPU therefore needs 16×
the cycles per loop — 2972 × 16 = 47552 ("at least 47552 total cycles"),
and matching the VTA wall-time needs a ≈ 16 × 650 MHz ≈ 10 GHz clock.

Beyond the single-module §5.2 counter, :func:`simulate_pipeline` runs the
*three-module concurrent timeline* of the VTA's task-level pipeline
(DESIGN.md §Pipeline): the Load / Compute / Store modules each advance
through their own instruction sub-stream at the per-instruction costs
above, synchronised only by the §2.3 dependency tokens.  The makespan of
that timeline — slowest module plus its token-wait stalls — is the
hardware-honest figure the pipeline scheduler optimises for; the
serialized token scheme reproduces the §5.2 numbers on the Compute
module by construction (same per-instruction costs, same stream).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List

from . import isa
from .hwconfig import VTAConfig
from .program import VTAProgram

# Calibrated on the paper's published LeNet-5 measurement (see module doc).
DECODE_CYCLES = 6

# §5.2 hardware constants.
FPGA_CLOCK_HZ = 650e6
SIMD_MACS_PER_CYCLE = 16


@dataclasses.dataclass(frozen=True)
class CycleReport:
    gemm_loops: int
    gemm_insns: int
    alu_loops: int
    alu_insns: int
    reset_loops: int
    reset_insns: int
    # Compute-module LOADs (UOP waves + ACC preloads).  Multi-chunk and
    # uop-streaming programs (DESIGN.md §3) execute these on the Compute
    # module; they are reported separately so the paper-calibrated
    # ``total_compute_cycles`` stays comparable with §5.2.
    compute_load_insns: int = 0
    compute_load_structs: int = 0

    @property
    def tensor_gemm_cycles(self) -> int:
        """Cycles to execute the (non-reset) TensorGemm instructions,
        including decode + buffer checks (paper: 2972 for LeNet-5)."""
        return self.gemm_loops + DECODE_CYCLES * self.gemm_insns

    @property
    def tensor_alu_cycles(self) -> int:
        return self.alu_loops + DECODE_CYCLES * self.alu_insns

    @property
    def reset_cycles(self) -> int:
        return self.reset_loops + DECODE_CYCLES * self.reset_insns

    @property
    def compute_load_cycles(self) -> int:
        """Cycles the Compute module spends on LOAD UOP/ACC (1 cycle per
        structure + decode) — the §3.3 uop-wave / ACC-preload overhead of
        multi-chunk programs."""
        return (self.compute_load_structs
                + DECODE_CYCLES * self.compute_load_insns)

    @property
    def total_compute_cycles(self) -> int:
        """Total Compute-module cycles (paper: 6358 for LeNet-5; excludes
        Load/Store as in §5.2, and the compute-module LOADs which the
        paper's number does not break out — see
        ``total_compute_cycles_with_loads``)."""
        return (self.tensor_gemm_cycles + self.tensor_alu_cycles
                + self.reset_cycles)

    @property
    def total_compute_cycles_with_loads(self) -> int:
        """§5.2 total plus the compute-module LOAD UOP/ACC cycles — the
        honest multi-chunk figure (EXPERIMENTS.md §Paper)."""
        return self.total_compute_cycles + self.compute_load_cycles

    def execution_time_s(self, clock_hz: float = FPGA_CLOCK_HZ, *,
                         include_loads: bool = False) -> float:
        """Wall time at ``clock_hz``.  ``include_loads=True`` adds the
        compute-module LOAD UOP/ACC cycles — the honest figure for
        multi-chunk programs (EXPERIMENTS.md §Paper)."""
        cycles = (self.total_compute_cycles_with_loads if include_loads
                  else self.total_compute_cycles)
        return cycles / clock_hz

    def simd_cpu_cycles(self, block_size: int,
                        macs_per_cycle: int = SIMD_MACS_PER_CYCLE) -> int:
        """§5.2 comparison, the paper's arithmetic: a SIMD CPU needs
        ``block_size²/macs_per_cycle`` × the VTA's TensorGemm cycles
        (2972 × 16 = 47552 for LeNet-5)."""
        per_loop = block_size * block_size // macs_per_cycle
        return self.tensor_gemm_cycles * per_loop

    def equivalent_cpu_clock_hz(self, clock_hz: float = FPGA_CLOCK_HZ,
                                block_size: int = 16,
                                macs_per_cycle: int = SIMD_MACS_PER_CYCLE
                                ) -> float:
        """Clock a 16-MAC SIMD CPU would need to match the VTA wall-time
        (paper: ≈10 GHz — 16× the 650 MHz FPGA clock)."""
        per_loop = block_size * block_size // macs_per_cycle
        cpu_total = self.total_compute_cycles * per_loop
        return cpu_total / self.execution_time_s(clock_hz)


def analyze(instructions: Iterable[object]) -> CycleReport:
    gemm_loops = gemm_insns = alu_loops = alu_insns = 0
    reset_loops = reset_insns = 0
    compute_load_insns = compute_load_structs = 0
    for i in instructions:
        if isinstance(i, isa.GemInsn):
            if i.reset:
                reset_loops += i.loop_count
                reset_insns += 1
            else:
                gemm_loops += i.loop_count
                gemm_insns += 1
        elif isinstance(i, isa.AluInsn):
            alu_loops += i.loop_count
            alu_insns += 1
        elif (isinstance(i, isa.MemInsn) and i.opcode == isa.Opcode.LOAD
              and i.memory_type in (isa.MemId.UOP, isa.MemId.ACC)):
            compute_load_insns += 1
            compute_load_structs += i.y_size * i.x_size
    return CycleReport(gemm_loops=gemm_loops, gemm_insns=gemm_insns,
                       alu_loops=alu_loops, alu_insns=alu_insns,
                       reset_loops=reset_loops, reset_insns=reset_insns,
                       compute_load_insns=compute_load_insns,
                       compute_load_structs=compute_load_structs)


def analyze_program(prog: VTAProgram) -> CycleReport:
    return analyze(prog.instructions)


def analyze_programs(progs: List[VTAProgram]) -> CycleReport:
    insns: List[object] = []
    for p in progs:
        insns.extend(p.instructions)
    return analyze(insns)


# ---------------------------------------------------------------------------
# Three-module concurrent timeline (DESIGN.md §Pipeline)
# ---------------------------------------------------------------------------

MODULES = ("load", "compute", "store")


def insn_cycles(insn) -> int:
    """Modeled cycles one instruction occupies its module: 1 per GEMM/ALU
    loop iteration or per DMA'd structure, plus ``DECODE_CYCLES`` decode —
    the same costs that calibrate :class:`CycleReport` to §5.2, now
    applied uniformly to the Load and Store modules too."""
    if isinstance(insn, (isa.GemInsn, isa.AluInsn)):
        return insn.loop_count + DECODE_CYCLES
    if isinstance(insn, isa.MemInsn):
        return insn.y_size * insn.x_size + DECODE_CYCLES
    return DECODE_CYCLES            # FINISH: decode + final token pop


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    """Result of the three-module concurrent timeline simulation.

    ``busy_cycles[m]``  — cycles module *m* spends executing instructions;
    ``wait_cycles[m]``  — cycles *m* sits blocked on a dependency-token
    pop (§2.3) before an instruction may start;
    ``finish_cycles[m]`` — the timeline instant *m* retires its last
    instruction;
    ``makespan_cycles`` — max over modules, i.e. slowest module + its
    stalls — the wall-clock figure of the whole program.
    """

    busy_cycles: Dict[str, int]
    wait_cycles: Dict[str, int]
    finish_cycles: Dict[str, int]
    insns: Dict[str, int]
    makespan_cycles: int

    @property
    def total_busy_cycles(self) -> int:
        """Sum of per-module busy cycles — the fully-serial floor a
        token-serialized schedule degenerates to."""
        return sum(self.busy_cycles.values())

    def idle_cycles(self, module: str) -> int:
        """Cycles ``module`` is not executing over the whole makespan
        (token waits + tail idle after its last instruction)."""
        return self.makespan_cycles - self.busy_cycles[module]

    def execution_time_s(self, clock_hz: float = FPGA_CLOCK_HZ) -> float:
        return self.makespan_cycles / clock_hz

    def merged(self, other: "PipelineReport") -> "PipelineReport":
        """Sequential composition: program boundaries are full barriers
        (FINISH drains the pipeline), so busy/wait/makespan all add."""
        add = lambda a, b: {m: a[m] + b[m] for m in MODULES}
        return PipelineReport(
            busy_cycles=add(self.busy_cycles, other.busy_cycles),
            wait_cycles=add(self.wait_cycles, other.wait_cycles),
            finish_cycles=add(self.finish_cycles, other.finish_cycles),
            insns=add(self.insns, other.insns),
            makespan_cycles=self.makespan_cycles + other.makespan_cycles)


def simulate_pipeline(instructions: Iterable[object]) -> PipelineReport:
    """Simulate the Load/Compute/Store modules running concurrently.

    Each module consumes its sub-stream in order; an instruction starts at
    ``max(module clock, arrival of every token it pops)``.  Token *k*
    popped from a queue becomes available when the *k*-th push to that
    queue retires (the §2.3 counters admit exactly that matching: a pop
    can only proceed once the count has been raised *k* times).  Program
    order is a topological order of the resulting dependency DAG, so a
    single in-order sweep yields the exact concurrent schedule.

    Raises :class:`~repro.core.simulator.VTAHazardError` when a pop has no
    matching push anywhere earlier in the stream — the token stream would
    deadlock real hardware.
    """
    from .simulator import TokenQueues, VTAHazardError, module_of

    clock = {m: 0 for m in MODULES}
    busy = {m: 0 for m in MODULES}
    wait = {m: 0 for m in MODULES}
    ninsn = {m: 0 for m in MODULES}
    push_times: Dict[tuple, List[int]] = {}
    pops_taken: Dict[tuple, int] = {}

    for insn in instructions:
        mod = module_of(insn)
        ready = clock[mod]
        pops = []
        if insn.dep.pop_prev:
            pops.append((TokenQueues._PREV[mod], mod))
        if insn.dep.pop_next:
            pops.append((TokenQueues._NEXT[mod], mod))
        for src, dst in pops:
            if src is None:
                raise VTAHazardError(f"{dst}: pop from nonexistent neighbour")
            q = (src, dst)
            k = pops_taken.get(q, 0)
            times = push_times.get(q, ())
            if k >= len(times):
                raise VTAHazardError(
                    f"dependency deadlock: {dst} pop #{k + 1} from {src} "
                    f"has no matching push in the stream")
            ready = max(ready, times[k])
            pops_taken[q] = k + 1
        wait[mod] += ready - clock[mod]
        cycles = insn_cycles(insn)
        finish = ready + cycles
        clock[mod] = finish
        busy[mod] += cycles
        ninsn[mod] += 1
        if insn.dep.push_prev:
            push_times.setdefault((mod, TokenQueues._PREV[mod]), []).append(
                finish)
        if insn.dep.push_next:
            push_times.setdefault((mod, TokenQueues._NEXT[mod]), []).append(
                finish)

    return PipelineReport(busy_cycles=busy, wait_cycles=wait,
                          finish_cycles=dict(clock), insns=ninsn,
                          makespan_cycles=max(clock.values()))


def simulate_program(prog: VTAProgram) -> PipelineReport:
    return simulate_pipeline(prog.instructions)


def simulate_programs(progs: List[VTAProgram]) -> PipelineReport:
    """Network timeline: layer programs execute back-to-back, each ending
    in a FINISH barrier, so the per-layer timelines compose by addition."""
    reports = [simulate_program(p) for p in progs]
    merged = reports[0]
    for r in reports[1:]:
        merged = merged.merged(r)
    return merged
