"""Typed compiler diagnostics (certification-style traceability).

The paper's workflow argument rests on every compilation failure being
*traceable*: a rejected layer must name itself and the constraint it
violated, not die on a bare assert three stack frames deep.
:class:`CompileError` is the single exception type the lowering stack
raises for unsupported shapes, strides, pool kinds, SRAM-capacity
violations and requant overflows; it subclasses :class:`ValueError` so
pre-existing callers (and tests) that caught ``ValueError`` keep working.

Convention: ``layer`` names the :class:`~repro.core.layer_compiler.LayerSpec`
(or graph node) being compiled; ``constraint`` is a short machine-greppable
identifier of the violated rule (e.g. ``"conv-input-rank"``,
``"acc-chunk-capacity"``), stable across message rewordings.
"""

from __future__ import annotations

from typing import Optional


class CompileError(ValueError):
    """A layer/program cannot be lowered to the VTA.

    Attributes
    ----------
    layer:
        Name of the layer (or graph node) being compiled, when known.
    constraint:
        Short identifier of the violated constraint — stable for tests
        and tooling to match on, independent of message wording.
    """

    def __init__(self, message: str, *, layer: Optional[str] = None,
                 constraint: Optional[str] = None):
        self.layer = layer
        self.constraint = constraint
        parts = []
        if layer is not None:
            parts.append(f"layer {layer!r}: ")
        parts.append(message)
        if constraint is not None:
            parts.append(f" [constraint: {constraint}]")
        super().__init__("".join(parts))
