"""Tensor → matrix lowering: im2row / ker2col / mat2tensor (paper §4.1, Def. 3).

Conventions (NCHW, batch = 1 as in the paper's experiments):

* ``im2row``  — input tensor ``(1, C, H, W)`` with a ``kh×kw`` kernel,
  stride ``s`` and symmetric zero-padding ``pad`` becomes the
  ``(H'·W') × (C·kh·kw)`` input matrix ``A``; one row per output spatial
  position (row-major over (i, j)), patch elements channel-major then
  kernel-row then kernel-col — matching ``ker2col``.  ``pad > 0`` is the
  zero-padded ("same") convolution needed past LeNet-5 (DESIGN.md §3): the
  padding is materialised host-side before patch extraction, so the VTA
  program is unchanged — only the A matrix grows.
* ``ker2col`` — weight tensor ``(F, C, kh, kw)`` becomes the
  ``(C·kh·kw) × F`` weight matrix ``B`` (filter ``f`` in column ``f``).
* ``mat2tensor`` — output matrix ``(H'·W') × F`` back to ``(1, F, H', W')``.

``T_C = mat2tensor(im2row(T_A) × ker2col(T_B))`` (Def. 3) is asserted by
property tests against a direct convolution oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ConvGeometry:
    """Spatial geometry of one convolution (``pad=0`` → valid padding;
    ``pad=(k-1)//2`` with stride 1 → same padding)."""

    in_channels: int
    in_h: int
    in_w: int
    kh: int
    kw: int
    stride: int = 1
    pad: int = 0

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.pad - self.kh) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.pad - self.kw) // self.stride + 1

    @property
    def patch_len(self) -> int:
        return self.in_channels * self.kh * self.kw

    @property
    def n_positions(self) -> int:
        return self.out_h * self.out_w


def _pad_spatial(tensor: np.ndarray, pad: int) -> np.ndarray:
    if pad == 0:
        return tensor
    if pad < 0:
        raise ValueError(f"negative padding {pad}")
    return np.pad(tensor, ((0, 0), (0, 0), (pad, pad), (pad, pad)))


def im2row(tensor: np.ndarray, kh: int, kw: int, stride: int = 1,
           pad: int = 0) -> np.ndarray:
    """Input tensor ``(1, C, H, W)`` → input matrix ``(H'·W', C·kh·kw)``."""
    if tensor.ndim != 4 or tensor.shape[0] != 1:
        raise ValueError(f"expected (1, C, H, W) tensor, got {tensor.shape}")
    return im2row_batch(tensor, kh, kw, stride, pad)[0]


def im2row_batch(tensor: np.ndarray, kh: int, kw: int, stride: int = 1,
                 pad: int = 0) -> np.ndarray:
    """Batched im2row: ``(B, C, H, W)`` → ``(B, H'·W', C·kh·kw)``.

    One strided window view + transpose per batch — the per-request
    staging of the serving path (DESIGN.md §Batching) runs through here.
    Row ``b`` equals ``im2row(tensor[b:b+1], ...)`` exactly: patch rows
    ordered (i, j) row-major, each patch flattened channel-major.
    """
    if tensor.ndim != 4:
        raise ValueError(f"expected (B, C, H, W) tensor, got {tensor.shape}")
    b, c, h, w = tensor.shape
    geo = ConvGeometry(c, h, w, kh, kw, stride, pad)
    oh, ow = geo.out_h, geo.out_w
    if oh <= 0 or ow <= 0:
        raise ValueError("kernel larger than (padded) input")
    x = _pad_spatial(tensor, pad)
    win = np.lib.stride_tricks.sliding_window_view(x, (kh, kw), axis=(2, 3))
    win = win[:, :, ::stride, ::stride]          # (B, C, oh, ow, kh, kw)
    return np.ascontiguousarray(
        win.transpose(0, 2, 3, 1, 4, 5)).reshape(b, oh * ow, geo.patch_len)


def ker2col(weights: np.ndarray) -> np.ndarray:
    """Weight tensor ``(F, C, kh, kw)`` → weight matrix ``(C·kh·kw, F)``."""
    if weights.ndim != 4:
        raise ValueError(f"expected (F, C, kh, kw) tensor, got {weights.shape}")
    f = weights.shape[0]
    return np.ascontiguousarray(weights.reshape(f, -1).T)


def mat2tensor(mat: np.ndarray, out_h: int, out_w: int) -> np.ndarray:
    """Output matrix ``(H'·W', F)`` → output tensor ``(1, F, H', W')``."""
    if mat.ndim != 2 or mat.shape[0] != out_h * out_w:
        raise ValueError(
            f"matrix {mat.shape} incompatible with {out_h}×{out_w} output")
    f = mat.shape[1]
    return np.ascontiguousarray(
        mat.reshape(out_h, out_w, f).transpose(2, 0, 1)[None])


def tensor2mat(tensor: np.ndarray) -> np.ndarray:
    """Inverse of ``mat2tensor`` — ``(1, F, H, W)`` → ``(H·W, F)``.

    This is the host-side reshaping entry point when the *next* layer is
    fully connected on a 1×1 spatial map, or when re-running ``im2row``.
    """
    if tensor.ndim != 4 or tensor.shape[0] != 1:
        raise ValueError(f"expected (1, F, H, W) tensor, got {tensor.shape}")
    _, f, h, w = tensor.shape
    return np.ascontiguousarray(tensor[0].transpose(1, 2, 0).reshape(h * w, f))


def flatten_tensor(tensor: np.ndarray) -> np.ndarray:
    """Tensor ``(1, C, H, W)`` → FC input row ``(1, C·H·W)`` (NCHW order) —
    the conv→FC transition of §4.3 ("thanks to the fully-connected
    layers")."""
    return np.ascontiguousarray(tensor.reshape(1, -1))


def conv2d_reference(tensor: np.ndarray, weights: np.ndarray,
                     stride: int = 1, pad: int = 0) -> np.ndarray:
    """Direct int64 convolution oracle for Def.-3 property tests."""
    _, c, h, w = tensor.shape
    f, cw, kh, kw = weights.shape
    assert c == cw, (c, cw)
    geo = ConvGeometry(c, h, w, kh, kw, stride, pad)
    out = np.zeros((1, f, geo.out_h, geo.out_w), dtype=np.int64)
    x = _pad_spatial(tensor, pad)[0].astype(np.int64)
    wt = weights.astype(np.int64)
    for i in range(geo.out_h):
        for j in range(geo.out_w):
            patch = x[:, i * stride:i * stride + kh, j * stride:j * stride + kw]
            out[0, :, i, j] = (patch[None] * wt).sum(axis=(1, 2, 3))
    return out


# ---------------------------------------------------------------------------
# Pooling index plans (region-based non-linear op, §4.1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolPlan:
    """Pooling / spatial reduction as a VTA ALU program over ACC vectors.

    The conv-output matrix has one ACC vector per spatial position (per
    block column; for β > 1 the indices scale by the block geometry —
    handled by the layer compiler).  ``mode="avg"`` accumulates the 4
    window members into the *first* member's vector (3 ADD pairs), then
    divides by 4 with one SHR-2 (exact for the sum of four int32s in
    range).  ``mode="max"`` reduces the window with 3 MAX pairs and needs
    no division.  ``mode="gap"`` is global average pooling (DESIGN.md
    §Strided-lowering): a binary tree of ADD pairs folds every spatial
    position into row 0, then one SHR by ``div_shift = log2(H·W)`` divides
    exactly — which is why GAP requires a power-of-two position count.
    ``keep_rows`` lists the surviving matrix rows, in pooled row-major
    order — the host-side decode extracts exactly these rows (which is how
    the paper's layer-1 output is "decoded into a 196×6 matrix").  On
    multi-chunk results the GEMM compiler keeps each window's pairs inside
    one SRAM chunk (DESIGN.md §3); the GAP tree spans *every* row, so its
    pair groups pin the whole α range into a single chunk — a result too
    large for one ACC residency raises at compile time, never wrong bytes.

    ``rounds`` (GAP only) groups ``add_pairs`` into dependency levels of
    the reduction tree: pairs within one round touch disjoint vectors, so
    each round lowers to one vectorisable ALU instruction, while pairs in
    *different* rounds carry the read-after-write chain of the tree.
    Empty ``rounds`` means all pairs are independent (the 2×2 windows).
    """

    add_pairs: Tuple[Tuple[int, int], ...]
    shr_indices: Tuple[int, ...]
    keep_rows: Tuple[int, ...]
    out_h: int
    out_w: int
    mode: str = "avg"              # "avg" | "max" | "gap"
    div_shift: int = 2             # log2 of the ÷ folded into the requant SHR
    rounds: Tuple[Tuple[Tuple[int, int], ...], ...] = ()


def _pool2x2_windows(in_h: int, in_w: int):
    if in_h % 2 or in_w % 2:
        raise ValueError("2x2 pooling requires even spatial dims")
    oh, ow = in_h // 2, in_w // 2
    pairs = []
    keep = []
    for i in range(oh):
        for j in range(ow):
            base = (2 * i) * in_w + (2 * j)
            members = (base, base + 1, base + in_w, base + in_w + 1)
            for src in members[1:]:
                pairs.append((base, src))
            keep.append(base)
    return oh, ow, tuple(pairs), tuple(keep)


def avgpool2x2_plan(in_h: int, in_w: int) -> PoolPlan:
    """Average-pool 2×2/stride-2: 3 ADD pairs per window + SHR-2 (÷4)."""
    oh, ow, pairs, keep = _pool2x2_windows(in_h, in_w)
    return PoolPlan(add_pairs=pairs, shr_indices=keep, keep_rows=keep,
                    out_h=oh, out_w=ow, mode="avg", div_shift=2)


def maxpool2x2_plan(in_h: int, in_w: int) -> PoolPlan:
    """Max-pool 2×2/stride-2: 3 MAX pairs per window, no division —
    the ALU MAX pair program of DESIGN.md §3 (YOLO-style downsampling)."""
    oh, ow, pairs, keep = _pool2x2_windows(in_h, in_w)
    return PoolPlan(add_pairs=pairs, shr_indices=keep, keep_rows=keep,
                    out_h=oh, out_w=ow, mode="max", div_shift=0)


def global_avgpool_plan(in_h: int, in_w: int) -> PoolPlan:
    """Global average pooling over an ``in_h × in_w`` map (DESIGN.md
    §Strided-lowering): a ``log2(H·W)``-round binary tree of ADD pairs
    reduces every position's ACC vector into row 0, and one SHR by
    ``log2(H·W)`` turns the sum into the (floor) average — the ResNet/
    YOLO-NAS classification head, entirely on the TensorAlu.

    Requires a square power-of-two map so the division is exact in a
    single arithmetic shift; the layer compiler turns violations into
    typed :class:`~repro.core.errors.CompileError`\\ s.
    """
    n = in_h * in_w
    if in_h != in_w:
        raise ValueError(f"global avg pool needs a square map, got "
                         f"{in_h}x{in_w}")
    if n <= 0 or n & (n - 1):
        raise ValueError(f"global avg pool needs a power-of-two position "
                         f"count for the SHR division, got {in_h}x{in_w}")
    rounds: list = []
    step = 1
    while step < n:
        rounds.append(tuple((base, base + step)
                            for base in range(0, n, 2 * step)))
        step *= 2
    flat = tuple(p for rnd in rounds for p in rnd)
    return PoolPlan(add_pairs=flat, shr_indices=(0,), keep_rows=(0,),
                    out_h=1, out_w=1, mode="gap",
                    div_shift=n.bit_length() - 1, rounds=tuple(rounds))
