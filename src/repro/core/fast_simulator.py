"""Vectorized fast-path VTA simulator — compiled instruction plans.

The oracle interpreter (:mod:`repro.core.simulator`) executes LOAD/STORE,
GEMM and ALU element-by-element in Python loops: every GeMM loop of
Algorithm 1 is one Python iteration, every DRAM struct move one
``_struct_from_dram`` call.  This module replaces that inner-loop work with
batched numpy operations while staying bit-exact, in two stages:

1. **Plan compilation** (:func:`compile_plan`) — the instruction stream is
   decoded *once* into an :class:`InstructionPlan`: the ``iter_out ×
   iter_in × uop`` loop lattice of each GEMM/ALU instruction becomes
   precomputed index-offset arrays, and each LOAD/STORE becomes a strided
   byte-gather/scatter geometry.  Plans depend only on instruction fields
   (never on data), so they are cached per program (:func:`plan_for`) and
   amortised across repeated executions — the batch-serving case.

2. **Vectorized execution** (:class:`FastSimulator`) — LOAD/STORE run as
   strided slice copies, GEMM as one ``einsum`` over the uop batch per
   instruction with a merge-by-destination scatter-add, ALU as vectorized
   min/max/add/shift over the whole index lattice.

Bit-exactness is preserved against the oracle, including:

* int32 wrap-around — additions are merged in int64 and truncated once;
  this equals the oracle's per-step wrap because addition is associative
  modulo 2**32;
* the truncating ACC→OUT commit before every STORE;
* SHR masking (``y & 31``) and repeated-destination shift accumulation;
* the §5.1 observability counters (loop counts, DRAM traffic, trace) and
  the §2.3 dependency-token hazard checking, shared with the oracle via
  :class:`~repro.core.simulator.TokenQueues`.

ALU instructions whose lattice has read-after-write dependencies that no
order-independent merge can express (e.g. a vector-pair op whose source
vectors are also destinations) fall back to a per-lattice-point loop with
the oracle's exact semantics — correctness never depends on the compiler
emitting "nice" programs.

Multi-chunk uop-wave programs (DESIGN.md §3) need no special handling:
plans precompute only the *geometry* lattices, while GEMM/ALU steps gather
their uops from ``uop_buf`` at execution time — so mid-stream LOAD_UOP
waves that rewrite slots 1.. between instructions are observed exactly as
on the oracle, and the cached per-program plan stays valid across waves.

**Batched serving** (DESIGN.md §Batching): :class:`BatchFastSimulator` /
:func:`run_batch` execute one compiled plan over a ``(batch, nbytes)``
DRAM stack — batched strided LOAD/STORE, the GEMM as one exact BLAS
contraction over ``(batch, uop)``, the ALU vectorised across the batch —
bit-identical to looping a single-image simulator over the stack's rows
(enforced by tests/test_batched_conformance.py).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import isa
from .hwconfig import VTAConfig
from .layout import truncate_int8
from .simulator import (SimReport, TokenQueues, VTABoundsError,  # noqa: F401
                        VTAHazardError)

# Bound the per-chunk gather footprint of the GEMM einsum (the WGT gather
# materialises block_size² int64 per lattice point).
_GEMM_CHUNK_BYTES = 64 << 20

# The batched GEMM runs on BLAS sgemm: a float32 mantissa holds integers up
# to 2**24 exactly, and a per-lane dot of ``n`` int8×int8 products is
# bounded by n·2¹⁴ (the extreme product is (-128)·(-128) = 16384), so for
# dots up to this many terms the float path is bit-exact; larger
# contractions fall back to the (wrap-congruent) int32 einsum.
_F32_EXACT_MAX_TERMS = (1 << 24) // (128 * 128)       # 1024
_F32_EXACT_MAX_BS = _F32_EXACT_MAX_TERMS


# ---------------------------------------------------------------------------
# Plan steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _LoadStep:
    kind: str                   # uop | inp | wgt | acc | out
    mem: isa.MemId
    nbytes: int                 # bytes per structure
    zero_base: int              # SRAM span to clear (padding), len 0 = none
    zero_len: int
    sram_idx: np.ndarray        # (n,) destination structure indices
    byte_idx: np.ndarray        # (n, nbytes) DRAM byte gather lattice
    end_byte: int               # max byte index + 1, for the bounds check
    sram_end: int = 0           # max SRAM struct touched + 1 (pads included)
    contig: bool = False        # SRAM span and DRAM bytes both contiguous
    byte_start: int = 0         # first DRAM byte (contig fast path)


@dataclasses.dataclass
class _StoreStep:
    kind: str
    nbytes: int
    n: int                      # structures moved (sram_base..sram_base+n)
    sram_base: int
    byte_idx: Optional[np.ndarray]   # (n, nbytes) scatter, None -> row loop
    row_dram_starts: np.ndarray      # (y_size,) byte offsets (row-loop path)
    row_bytes: int
    end_byte: int


@dataclasses.dataclass
class _GemmStep:
    reset: bool
    u_idx: np.ndarray           # (nu,) uop buffer indices
    off_acc: np.ndarray         # (P,) iter_out×iter_in lattice offsets
    off_inp: np.ndarray
    off_wgt: np.ndarray
    loop_count: int


@dataclasses.dataclass
class _AluStep:
    op: isa.AluOp
    use_imm: bool
    imm: int
    u_idx: np.ndarray
    off_dst: np.ndarray         # (P,)
    off_src: np.ndarray
    loop_count: int


@dataclasses.dataclass
class _FinishStep:
    pass


@dataclasses.dataclass
class InstructionPlan:
    """A compiled instruction stream: one executable step per instruction.

    Dependency flags are read live from the instruction objects at
    execution time, so token-hazard behaviour tracks ``dep`` mutations;
    the precomputed index lattices assume the *geometry* fields are
    frozen after compilation.
    """

    steps: List[Tuple[object, object]]   # (insn, step payload)

    @property
    def n_insns(self) -> int:
        return len(self.steps)


# ---------------------------------------------------------------------------
# Plan compilation
# ---------------------------------------------------------------------------

_MEM_KIND = {
    isa.MemId.UOP: "uop", isa.MemId.INP: "inp", isa.MemId.WGT: "wgt",
    isa.MemId.ACC: "acc", isa.MemId.OUT: "out",
}


def _outer_offsets(iter_out: int, iter_in: int, f_out: int, f_in: int
                   ) -> np.ndarray:
    """Ravelled ``i_out*f_out + i_in*f_in`` lattice, loop order (out, in)."""
    io = np.arange(iter_out, dtype=np.int64) * f_out
    ii = np.arange(iter_in, dtype=np.int64) * f_in
    return (io[:, None] + ii[None, :]).reshape(-1)


def _compile_load(cfg: VTAConfig, m: isa.MemInsn) -> _LoadStep:
    kind = _MEM_KIND[m.memory_type]
    nbytes = cfg.elem_bytes(kind)
    row_w = m.x_pad_0 + m.x_size + m.x_pad_1
    total_rows = m.y_pad_0 + m.y_size + m.y_pad_1
    has_pad = (m.y_pad_0 or m.y_pad_1 or m.x_pad_0 or m.x_pad_1)
    zero_len = total_rows * row_w if has_pad else 0

    y = np.arange(m.y_size, dtype=np.int64)
    x = np.arange(m.x_size, dtype=np.int64)
    sram_idx = (m.sram_base + (m.y_pad_0 + y)[:, None] * row_w
                + m.x_pad_0 + x[None, :]).reshape(-1)
    log_addr = (m.dram_base + y[:, None] * m.x_stride + x[None, :]).reshape(-1)
    byte_idx = (log_addr[:, None] * nbytes
                + np.arange(nbytes, dtype=np.int64)[None, :])
    end_byte = int(byte_idx.max(initial=-1)) + 1
    n = sram_idx.size
    contig = bool(
        n and not has_pad
        and np.array_equal(sram_idx,
                           np.arange(sram_idx[0], sram_idx[0] + n))
        and np.array_equal(byte_idx.reshape(-1),
                           np.arange(byte_idx[0, 0],
                                     byte_idx[0, 0] + n * nbytes)))
    sram_end = max(m.sram_base + zero_len,
                   int(sram_idx.max(initial=m.sram_base - 1)) + 1)
    return _LoadStep(kind=kind, mem=m.memory_type, nbytes=nbytes,
                     zero_base=m.sram_base, zero_len=zero_len,
                     sram_idx=sram_idx, byte_idx=byte_idx, end_byte=end_byte,
                     sram_end=sram_end, contig=contig,
                     byte_start=int(byte_idx[0, 0]) if n else 0)


def _compile_store(cfg: VTAConfig, m: isa.MemInsn) -> _StoreStep:
    kind = _MEM_KIND[m.memory_type]
    if kind == "uop":
        raise ValueError("STORE UOP is not a valid VTA instruction")
    nbytes = cfg.elem_bytes(kind)
    n = m.y_size * m.x_size
    row_bytes = m.x_size * nbytes
    y = np.arange(m.y_size, dtype=np.int64)
    row_dram_starts = (m.dram_base + y * m.x_stride) * nbytes
    end_byte = int((row_dram_starts.max(initial=-nbytes) + row_bytes))
    # Overlapping rows (stride < x_size) must be written in order; the
    # single-scatter path requires disjoint rows.
    overlap = m.y_size > 1 and m.x_stride < m.x_size
    byte_idx = None
    if not overlap:
        if n:
            byte_idx = (row_dram_starts[:, None]
                        + np.arange(row_bytes, dtype=np.int64)[None, :]
                        ).reshape(n, nbytes)
        else:
            byte_idx = np.zeros((0, nbytes), dtype=np.int64)
    return _StoreStep(kind=kind, nbytes=nbytes, n=n, sram_base=m.sram_base,
                      byte_idx=byte_idx, row_dram_starts=row_dram_starts,
                      row_bytes=row_bytes, end_byte=end_byte)


def _compile_gemm(g: isa.GemInsn) -> _GemmStep:
    n_uop = max(0, g.uop_end - g.uop_bgn)
    u_idx = np.arange(g.uop_bgn, g.uop_bgn + n_uop, dtype=np.int64)
    return _GemmStep(
        reset=bool(g.reset), u_idx=u_idx,
        off_acc=_outer_offsets(g.iter_out, g.iter_in,
                               g.acc_factor_out, g.acc_factor_in),
        off_inp=_outer_offsets(g.iter_out, g.iter_in,
                               g.inp_factor_out, g.inp_factor_in),
        off_wgt=_outer_offsets(g.iter_out, g.iter_in,
                               g.wgt_factor_out, g.wgt_factor_in),
        loop_count=g.iter_out * g.iter_in * n_uop)


def _compile_alu(a: isa.AluInsn) -> _AluStep:
    n_uop = max(0, a.uop_end - a.uop_bgn)
    u_idx = np.arange(a.uop_bgn, a.uop_bgn + n_uop, dtype=np.int64)
    return _AluStep(
        op=a.alu_opcode, use_imm=bool(a.use_imm), imm=a.imm, u_idx=u_idx,
        off_dst=_outer_offsets(a.iter_out, a.iter_in,
                               a.dst_factor_out, a.dst_factor_in),
        off_src=_outer_offsets(a.iter_out, a.iter_in,
                               a.src_factor_out, a.src_factor_in),
        loop_count=a.iter_out * a.iter_in * n_uop)


def compile_plan(cfg: VTAConfig, instructions) -> InstructionPlan:
    """Decode an instruction stream into its array-form execution plan."""
    steps: List[Tuple[object, object]] = []
    for insn in instructions:
        if isinstance(insn, isa.MemInsn):
            step = (_compile_load(cfg, insn)
                    if insn.opcode == isa.Opcode.LOAD
                    else _compile_store(cfg, insn))
        elif isinstance(insn, isa.GemInsn):
            step = _compile_gemm(insn)
        elif isinstance(insn, isa.AluInsn):
            step = _compile_alu(insn)
        elif isinstance(insn, isa.FinishInsn):
            step = _FinishStep()
        else:
            raise TypeError(insn)
        steps.append((insn, step))
    return InstructionPlan(steps=steps)


def plan_for(prog) -> InstructionPlan:
    """Cached plan for a :class:`~repro.core.program.VTAProgram`.

    Recompiled when the instruction list changes (count or object
    identity).  Dependency flags are read live, so dep mutations need no
    invalidation; editing *geometry* fields of an existing instruction in
    place is not detected — call :func:`invalidate_plan` afterwards.
    """
    plan = getattr(prog, "_fast_plan", None)
    if (plan is None or plan.n_insns != len(prog.instructions)
            or any(step_insn is not insn for (step_insn, _), insn
                   in zip(plan.steps, prog.instructions))):
        plan = compile_plan(prog.config, prog.instructions)
        prog._fast_plan = plan
    return plan


def invalidate_plan(prog) -> None:
    if hasattr(prog, "_fast_plan"):
        del prog._fast_plan


# ---------------------------------------------------------------------------
# Scatter helpers (order-independent merges, exact modulo 2**32)
# ---------------------------------------------------------------------------

def _group(idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort ``idx``; return (order, sorted idx, group-start positions)."""
    order = np.argsort(idx, kind="stable")
    sidx = idx[order]
    starts = np.flatnonzero(np.r_[True, sidx[1:] != sidx[:-1]])
    return order, sidx, starts


def _scatter_add(acc64: np.ndarray, idx: np.ndarray, vals: np.ndarray) -> None:
    """``acc64[idx] += vals`` with duplicate destinations merged first."""
    if idx.size == 0:
        return
    order, sidx, starts = _group(idx)
    acc64[sidx[starts]] += np.add.reduceat(vals[order], starts, axis=0)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

class FastSimulator:
    """Vectorized VTA functional simulator — bit-exact vs the oracle."""

    def __init__(self, cfg: VTAConfig, dram: np.ndarray, *,
                 trace: bool = False, count_overflows: bool = False):
        if dram.dtype != np.uint8:
            raise TypeError("dram image must be uint8")
        self.cfg = cfg
        self.dram = dram.copy()
        self.trace = trace
        self.count_overflows = count_overflows
        bs = cfg.block_size
        self.uop_buf = np.zeros((cfg.uop_buff_entries, 3), dtype=np.int64)
        self.inp_buf = np.zeros((cfg.inp_buff_vectors, bs), dtype=np.int8)
        self.wgt_buf = np.zeros((cfg.wgt_buff_matrices, bs, bs), dtype=np.int8)
        self.acc_buf = np.zeros((cfg.acc_buff_vectors, bs), dtype=np.int32)
        self.out_buf = np.zeros((cfg.out_buff_vectors, bs), dtype=np.int8)
        self.tokens = TokenQueues()
        self.report = SimReport()

    # -------------------------------------------------------------- mem --
    def _buf_of(self, kind: str) -> np.ndarray:
        return {"uop": self.uop_buf, "inp": self.inp_buf,
                "wgt": self.wgt_buf, "acc": self.acc_buf,
                "out": self.out_buf}[kind]

    def _decode_structs(self, kind: str, raw: np.ndarray) -> np.ndarray:
        """(n, nbytes) uint8 → n structures in SRAM form."""
        n = raw.shape[0]
        bs = self.cfg.block_size
        if kind == "uop":
            words = raw.view("<u4").reshape(n).astype(np.int64)
            return np.stack([words & 0x7FF, (words >> 11) & 0x7FF,
                             (words >> 22) & 0x3FF], axis=1)
        if kind == "inp":
            return raw.view(np.int8).reshape(n, bs)
        if kind == "wgt":
            return raw.view(np.int8).reshape(n, bs, bs)
        if kind == "acc":
            return raw.view("<i4").reshape(n, bs).astype(np.int32)
        raise ValueError(kind)

    def _encode_structs(self, kind: str, data: np.ndarray) -> np.ndarray:
        """n structures → (n, nbytes) uint8 (little-endian)."""
        n = data.shape[0]
        if kind in ("inp", "out"):
            return np.ascontiguousarray(data).view(np.uint8).reshape(n, -1)
        if kind == "wgt":
            return np.ascontiguousarray(data).view(np.uint8).reshape(n, -1)
        if kind == "acc":
            return np.ascontiguousarray(
                data.astype("<i4")).view(np.uint8).reshape(n, -1)
        raise ValueError(kind)

    def _check_load(self, p: _LoadStep, cap: int, dram_len: int) -> None:
        """Shared LOAD bounds validation (single-image and batched).

        The SRAM check covers the *padding* span too — zero-fill through a
        slice used to clip silently past the buffer end while the oracle
        raised (the audited divergence; DESIGN.md §Hardening)."""
        if p.end_byte > dram_len:
            raise VTABoundsError(
                f"LOAD {p.kind.upper()} DRAM span ends at byte {p.end_byte} "
                f"> image size {dram_len}")
        if (p.zero_len or p.sram_idx.size) and p.sram_end > cap:
            raise VTABoundsError(
                f"LOAD {p.kind.upper()} SRAM span [{p.zero_base}, "
                f"{p.sram_end}) exceeds buffer capacity {cap} "
                f"(padding included)")

    def _exec_load(self, p: _LoadStep) -> None:
        buf = self._buf_of(p.kind)
        self._check_load(p, buf.shape[0], len(self.dram))
        if p.zero_len:
            buf[p.zero_base:p.zero_base + p.zero_len] = 0
        if p.sram_idx.size:
            raw = self.dram[p.byte_idx]
            buf[p.sram_idx] = self._decode_structs(p.kind, raw)
        self.report.dram_bytes_read += p.byte_idx.size

    def _check_store(self, p: _StoreStep, cap: int, dram_len: int) -> None:
        if p.end_byte > dram_len:
            raise VTABoundsError(
                f"STORE {p.kind.upper()} DRAM span ends at byte "
                f"{p.end_byte} > image size {dram_len}")
        if p.sram_base + p.n > cap:
            raise VTABoundsError(
                f"STORE {p.kind.upper()} SRAM span [{p.sram_base}, "
                f"{p.sram_base + p.n}) exceeds buffer capacity {cap}")

    def _exec_store(self, p: _StoreStep) -> None:
        if p.n == 0:
            return            # degenerate geometry: the oracle's loop is empty
        buf = self._buf_of(p.kind)
        self._check_store(p, buf.shape[0], len(self.dram))
        data = buf[p.sram_base:p.sram_base + p.n]
        raw = self._encode_structs(p.kind, data)
        if p.byte_idx is not None:
            self.dram[p.byte_idx] = raw
        else:                      # overlapping rows: write in order
            rows = raw.reshape(-1, p.row_bytes)
            for start, row in zip(p.row_dram_starts, rows):
                self.dram[start:start + p.row_bytes] = row
        self.report.dram_bytes_written += raw.size

    # ------------------------------------------------------------- gemm --
    def _lattice(self, off: np.ndarray, u_field: np.ndarray) -> np.ndarray:
        """(P,) outer offsets × (nu,) uop bases → (P·nu,) ravelled indices
        in the oracle's loop order (i_out, i_in, u)."""
        return (off[:, None] + u_field[None, :]).reshape(-1)

    def _check_uop_range(self, u_idx: np.ndarray, entries: int,
                         what: str) -> None:
        if u_idx.size and int(u_idx[-1]) >= entries:
            raise VTABoundsError(
                f"{what} uop range [{int(u_idx[0])}, {int(u_idx[-1]) + 1}) "
                f"exceeds UOP buffer capacity {entries}")

    @staticmethod
    def _check_lattice(idx: np.ndarray, cap: int, what: str) -> None:
        """Pre-mutation index-range check over a whole GEMM/ALU lattice."""
        if idx.size:
            hi = int(idx.max())
            if hi >= cap or int(idx.min()) < 0:
                raise VTABoundsError(
                    f"{what} index {hi if hi >= cap else int(idx.min())} "
                    f"out of range for buffer of {cap}")

    def _truncate_acc64(self, acc64: np.ndarray, out: np.ndarray) -> None:
        """int64 working copy → int32 buffer, counting wrapped lanes."""
        wrapped = acc64.astype(np.int32)
        if self.count_overflows:
            self.report.acc_overflow_lanes += int(
                np.count_nonzero(acc64 != wrapped))
        out[:] = wrapped

    def _exec_gemm(self, p: _GemmStep) -> None:
        if p.loop_count == 0:
            return
        self._check_uop_range(p.u_idx, self.uop_buf.shape[0], "GEMM")
        uop = self.uop_buf[p.u_idx]                      # (nu, 3)
        x_idx = self._lattice(p.off_acc, uop[:, 0])
        self._check_lattice(x_idx, self.acc_buf.shape[0], "GEMM ACC")
        if p.reset:
            self.acc_buf[x_idx] = 0
            self.report.gemm_reset_loops += p.loop_count
            return
        a_idx = self._lattice(p.off_inp, uop[:, 1])
        w_idx = self._lattice(p.off_wgt, uop[:, 2])
        self._check_lattice(a_idx, self.inp_buf.shape[0], "GEMM INP")
        self._check_lattice(w_idx, self.wgt_buf.shape[0], "GEMM WGT")
        bs = self.cfg.block_size
        chunk = max(1, _GEMM_CHUNK_BYTES // (bs * bs * 8))
        acc64 = self.acc_buf.astype(np.int64)
        for lo in range(0, x_idx.size, chunk):
            sl = slice(lo, lo + chunk)
            A = self.inp_buf[a_idx[sl]].astype(np.int64)     # (l, bs)
            W = self.wgt_buf[w_idx[sl]].astype(np.int64)     # (l, bs, bs)
            # out[l, i] = Σ_j A[l, j] · W[l, i, j]  (W stored transposed)
            prod = np.einsum("lij,lj->li", W, A)
            _scatter_add(acc64, x_idx[sl], prod)
        self._truncate_acc64(acc64, self.acc_buf)            # wrap-around
        self.report.gemm_loops += p.loop_count

    # -------------------------------------------------------------- alu --
    @staticmethod
    def _alu_elementwise(op: isa.AluOp, x: np.ndarray, y) -> np.ndarray:
        if op == isa.AluOp.MIN:
            return np.minimum(x, y)
        if op == isa.AluOp.MAX:
            return np.maximum(x, y)
        if op == isa.AluOp.ADD:
            return x + y
        if op == isa.AluOp.SHR:
            return x >> (y & 31)
        raise ValueError(op)

    def _exec_alu(self, p: _AluStep) -> None:
        if p.loop_count == 0:
            return
        self._check_uop_range(p.u_idx, self.uop_buf.shape[0], "ALU")
        uop = self.uop_buf[p.u_idx]
        d_idx = self._lattice(p.off_dst, uop[:, 0])
        self._check_lattice(d_idx, self.acc_buf.shape[0], "ALU ACC dst")
        acc64 = self.acc_buf.astype(np.int64)
        if p.use_imm:
            self._alu_imm(acc64, p, d_idx)
        else:
            s_idx = self._lattice(p.off_src, uop[:, 1])
            self._check_lattice(s_idx, self.acc_buf.shape[0], "ALU ACC src")
            if np.intersect1d(d_idx, s_idx).size:
                # Read-after-write across lattice points: oracle order.
                self._alu_sequential(acc64, p.op, d_idx, s_idx)
            else:
                self._alu_pair(acc64, p.op, d_idx, s_idx)
        self._truncate_acc64(acc64, self.acc_buf)
        self.report.alu_loops += p.loop_count

    def _alu_imm(self, acc64: np.ndarray, p: _AluStep,
                 d_idx: np.ndarray) -> None:
        imm = np.int64(p.imm)
        order, sidx, starts = _group(d_idx)
        ud = sidx[starts]
        if p.op in (isa.AluOp.MIN, isa.AluOp.MAX):
            # Idempotent under repetition.
            acc64[ud] = self._alu_elementwise(p.op, acc64[ud], imm)
        elif p.op == isa.AluOp.ADD:
            counts = np.diff(np.r_[starts, d_idx.size]).astype(np.int64)
            acc64[ud] += imm * counts[:, None]
        else:  # SHR: k repeated c times on an int32-range value = shift c·k
            counts = np.diff(np.r_[starts, d_idx.size]).astype(np.int64)
            shift = np.minimum((imm & 31) * counts, 63)
            acc64[ud] >>= shift[:, None]

    def _alu_pair(self, acc64: np.ndarray, op: isa.AluOp,
                  d_idx: np.ndarray, s_idx: np.ndarray) -> None:
        """Sources disjoint from destinations: pre-state gather is exact."""
        svals = acc64[s_idx]                              # (L, bs)
        order, sidx, starts = _group(d_idx)
        ud = sidx[starts]
        svals = svals[order]
        if op == isa.AluOp.ADD:
            acc64[ud] += np.add.reduceat(svals, starts, axis=0)
        elif op == isa.AluOp.MIN:
            acc64[ud] = np.minimum(acc64[ud],
                                   np.minimum.reduceat(svals, starts, axis=0))
        elif op == isa.AluOp.MAX:
            acc64[ud] = np.maximum(acc64[ud],
                                   np.maximum.reduceat(svals, starts, axis=0))
        else:  # SHR: per-lane shifts accumulate across duplicates
            shift = np.minimum(
                np.add.reduceat(svals & 31, starts, axis=0), 63)
            acc64[ud] >>= shift

    def _alu_sequential(self, acc64: np.ndarray, op: isa.AluOp,
                        d_idx: np.ndarray, s_idx: np.ndarray) -> None:
        """Oracle loop order for lattices with cross-point dependencies.

        Each step wraps to int32 before the next reads it, exactly as the
        hardware (and the oracle) would."""
        for d, s in zip(d_idx, s_idx):
            x = acc64[d]
            y = acc64[s]
            acc64[d] = self._alu_elementwise(op, x, y).astype(
                np.int32).astype(np.int64)

    # -------------------------------------------------------------- run --
    def _commit_out(self) -> None:
        """ACC → OUT truncation (§2.1: OUT vectors are truncated ACC)."""
        if self.count_overflows:
            self.report.acc_saturation_lanes += int(np.count_nonzero(
                (self.acc_buf < -128) | (self.acc_buf > 127)))
        self.out_buf[:] = truncate_int8(self.acc_buf)

    def run(self, instructions, plan: Optional[InstructionPlan] = None,
            *, fault_hook=None) -> SimReport:
        """Execute an instruction stream.  Pass a cached ``plan`` (from
        :func:`plan_for` / :func:`compile_plan`) to skip plan compilation;
        it must have been compiled from these instructions.
        ``fault_hook(sim, insn_idx)`` fires before each instruction — the
        harden subsystem's injection/watchdog point (DESIGN.md §Hardening).
        """
        if plan is None:
            plan = compile_plan(self.cfg, instructions)
        elif plan.n_insns != len(instructions):
            raise ValueError("plan does not match instruction stream")
        for i, (insn, step) in enumerate(plan.steps):
            if fault_hook is not None:
                fault_hook(self, i)
            self.tokens.pre(insn)
            if isinstance(step, _LoadStep):
                self._exec_load(step)
                tag = f"{insn.opcode.name} {insn.memory_type.name}"
            elif isinstance(step, _StoreStep):
                self._commit_out()
                self._exec_store(step)
                tag = f"{insn.opcode.name} {insn.memory_type.name}"
            elif isinstance(step, _GemmStep):
                self._exec_gemm(step)
                tag = f"GEMM{' reset' if step.reset else ''}"
            elif isinstance(step, _AluStep):
                self._exec_alu(step)
                tag = f"ALU {step.op.name}"
            else:
                tag = "FINISH"
            self.report.insn_executed += 1
            if self.trace:
                self.report.insn_trace.append(tag)
            self.tokens.post(insn)
            if isinstance(step, _FinishStep):
                break
        self.tokens.account(self.report)
        return self.report


# ---------------------------------------------------------------------------
# Batched execution: one plan, N DRAM images (DESIGN.md §Batching)
# ---------------------------------------------------------------------------

class BatchFastSimulator(FastSimulator):
    """One compiled :class:`InstructionPlan`, a ``(batch, nbytes)`` DRAM
    stack: the batch axis is vectorized through every instruction.

    Every SRAM buffer grows a leading batch axis; LOAD/STORE run as
    batched strided gathers/scatters, GEMM as one einsum over the whole
    ``batch × lattice`` with per-image indices flattened into one global
    index space (row *b*'s indices are offset by ``b · buffer_len``, so
    batches can never alias and the order-independent merges of the
    single-image path stay exact), and ALU reuses the single-image merge
    kernels over the same flattened space.  Semantically the run is
    bit-identical to looping a single-image simulator over the stack's
    rows — the differential conformance suite
    (``tests/test_batched_conformance.py``) enforces exactly that.

    The :class:`~repro.core.simulator.SimReport` accumulates *batch
    totals*: loop counts and DRAM traffic equal the sum over the
    per-image oracle reports (i.e. ``batch ×`` the single-image values),
    while ``insn_executed``/``insn_trace`` count the instruction stream
    once — it is fetched and decoded once, which is the whole point.
    """

    def __init__(self, cfg: VTAConfig, dram: np.ndarray, *,
                 trace: bool = False, copy_dram: bool = True,
                 count_overflows: bool = False):
        if dram.dtype != np.uint8:
            raise TypeError("dram stack must be uint8")
        if dram.ndim != 2 or dram.shape[0] < 1:
            raise ValueError(
                "batched dram image must be (batch, nbytes) with batch >= 1")
        self.cfg = cfg
        self.count_overflows = count_overflows
        self.batch = int(dram.shape[0])
        # copy_dram=False hands the stack over without the defensive copy —
        # the serve loop owns its stack and re-reads it from ``sim.dram``,
        # so the copy would be pure overhead there.
        self.dram = dram.copy() if copy_dram else dram
        self.trace = trace
        bs = cfg.block_size
        b = self.batch
        self.uop_buf = np.zeros((b, cfg.uop_buff_entries, 3), dtype=np.int64)
        self.inp_buf = np.zeros((b, cfg.inp_buff_vectors, bs), dtype=np.int8)
        self.wgt_buf = np.zeros((b, cfg.wgt_buff_matrices, bs, bs),
                                dtype=np.int8)
        self.acc_buf = np.zeros((b, cfg.acc_buff_vectors, bs), dtype=np.int32)
        self.out_buf = np.zeros((b, cfg.out_buff_vectors, bs), dtype=np.int8)
        self.tokens = TokenQueues()
        self.report = SimReport()
        # Batch-uniformity flags: True while every image in the batch holds
        # byte-identical UOP / WGT SRAM contents (the serving case — only
        # INP differs per request).  Uniform batches take the shared-lattice
        # fast paths: the uop lattice, the weight gather and the scatter
        # grouping are computed once per instruction instead of per image.
        # The flags start True (zero-initialised SRAM is uniform) and latch
        # False on the first non-uniform LOAD; the general per-image paths
        # stay bit-exact either way.
        self._uniform = {"uop": True, "wgt": True}

    # -------------------------------------------------------------- mem --
    def _exec_load(self, p: _LoadStep) -> None:
        buf = self._buf_of(p.kind)
        self._check_load(p, buf.shape[1], self.dram.shape[1])
        if p.zero_len:
            buf[:, p.zero_base:p.zero_base + p.zero_len] = 0
        if p.sram_idx.size:
            n = p.sram_idx.size
            if p.contig:                              # one strided slice
                raw = self.dram[:, p.byte_start:p.byte_start + n * p.nbytes]
            else:
                raw = self.dram[:, p.byte_idx]        # (B, n, nbytes)
            if p.kind in self._uniform and self._uniform[p.kind]:
                self._uniform[p.kind] = bool(np.all(raw == raw[:1]))
            # the gather can come back with transposed strides; the struct
            # decoders reinterpret the last axis, which must be contiguous
            raw = np.ascontiguousarray(raw).reshape(self.batch * n, p.nbytes)
            dec = self._decode_structs(p.kind, raw)
            if p.contig:
                s0 = int(p.sram_idx[0])
                buf[:, s0:s0 + n] = dec.reshape(
                    (self.batch, n) + dec.shape[1:])
            else:
                buf[:, p.sram_idx] = dec.reshape(
                    (self.batch, n) + dec.shape[1:])
        self.report.dram_bytes_read += p.byte_idx.size * self.batch

    def _exec_store(self, p: _StoreStep) -> None:
        if p.n == 0:
            return
        buf = self._buf_of(p.kind)
        self._check_store(p, buf.shape[1], self.dram.shape[1])
        data = buf[:, p.sram_base:p.sram_base + p.n]
        raw = self._encode_structs(
            p.kind, data.reshape((self.batch * p.n,) + data.shape[2:]))
        raw = raw.reshape(self.batch, p.n, p.nbytes)
        if p.byte_idx is not None:
            self.dram[:, p.byte_idx] = raw
        else:                      # overlapping rows: write in order
            rows = raw.reshape(self.batch, -1, p.row_bytes)
            for y, start in enumerate(p.row_dram_starts):
                self.dram[:, start:start + p.row_bytes] = rows[:, y]
        self.report.dram_bytes_written += raw.size

    # ------------------------------------------------------------ index --
    def _batch_lattice(self, off: np.ndarray, u_field: np.ndarray,
                       span: int, what: str) -> np.ndarray:
        """Per-image ``(P,)×(nu,)`` lattices → one flattened global index
        array, row *b* offset by ``b · span``.  Per-image indices are
        bounds-checked *before* the offset so an out-of-range program
        raises (as the oracle would) instead of aliasing into the next
        image's buffer."""
        lat = off[None, :, None] + u_field[:, None, :]        # (B, P, nu)
        if lat.size:
            hi = int(lat.max())
            if hi >= span or int(lat.min()) < 0:
                raise VTABoundsError(
                    f"{what} index {hi} out of range for buffer of {span}")
        lat = lat + (np.arange(self.batch, dtype=np.int64)
                     * span)[:, None, None]
        return lat.reshape(-1)

    # ------------------------------------------------------------- gemm --
    def _shared_lattice(self, off: np.ndarray, u_field: np.ndarray
                        ) -> np.ndarray:
        """Single-image lattice shared by the whole (uniform-UOP) batch."""
        return (off[:, None] + u_field[None, :]).reshape(-1)

    def _accum_rows(self, idx: np.ndarray, red: np.ndarray) -> None:
        """``acc_buf[:, idx] += red`` — int32 wrap, optionally counted."""
        if not self.count_overflows:
            self.acc_buf[:, idx] += red
            return
        wide = self.acc_buf[:, idx].astype(np.int64) + red.astype(np.int64)
        wrapped = wide.astype(np.int32)
        self.report.acc_overflow_lanes += int(
            np.count_nonzero(wide != wrapped))
        self.acc_buf[:, idx] = wrapped

    def _accum_flat(self, acc_flat: np.ndarray, idx: np.ndarray,
                    red: np.ndarray) -> None:
        """``acc_flat[idx] += red`` over the flattened batch index space."""
        if not self.count_overflows:
            acc_flat[idx] += red
            return
        wide = acc_flat[idx].astype(np.int64) + red.astype(np.int64)
        wrapped = wide.astype(np.int32)
        self.report.acc_overflow_lanes += int(
            np.count_nonzero(wide != wrapped))
        acc_flat[idx] = wrapped

    def _exec_gemm(self, p: _GemmStep) -> None:
        if p.loop_count == 0:
            return
        self._check_uop_range(p.u_idx, self.uop_buf.shape[1], "GEMM")
        if self._uniform["uop"]:
            self._gemm_shared(p)
        else:
            self._gemm_general(p)
        field = ("gemm_reset_loops" if p.reset else "gemm_loops")
        setattr(self.report, field,
                getattr(self.report, field) + p.loop_count * self.batch)

    def _gemm_shared(self, p: _GemmStep) -> None:
        """Uniform UOP buffers: one lattice, one scatter grouping — and,
        when the WGT buffers are uniform too (the serving case), one weight
        gather — for the whole batch.  Products accumulate in int32, which
        wraps mod 2**32 exactly like the oracle's per-step truncation."""
        uop = self.uop_buf[0, p.u_idx]                        # (nu, 3)
        x_idx = self._shared_lattice(p.off_acc, uop[:, 0])
        self._check_lattice(x_idx, self.acc_buf.shape[1], "GEMM ACC")
        if p.reset:
            self.acc_buf[:, x_idx] = 0
            return
        a_idx = self._shared_lattice(p.off_inp, uop[:, 1])
        w_idx = self._shared_lattice(p.off_wgt, uop[:, 2])
        self._check_lattice(a_idx, self.inp_buf.shape[1], "GEMM INP")
        self._check_lattice(w_idx, self.wgt_buf.shape[1], "GEMM WGT")
        bs = self.cfg.block_size
        b = self.batch
        w_uniform = self._uniform["wgt"]
        f32 = bs <= _F32_EXACT_MAX_BS
        # Fused-contraction form: when every destination vector receives
        # the same number ``c`` of lattice points (the compiled-matmul
        # k-loop shape), fold the duplicate-destination reduction into the
        # BLAS contraction itself — one (G, bs, c·bs) @ (G, c·bs, B) sgemm
        # stack computes GEMM *and* merge in one pass.  Exact while the
        # c·bs-term dot stays within float32's 2**24 integer range.
        shared_group = None
        if w_uniform:
            order, sidx, starts = _group(x_idx)
            shared_group = (order, sidx, starts)
            counts = np.diff(np.r_[starts, x_idx.size])
            if (counts.size and int(counts.min()) == int(counts.max())
                    and int(counts[0]) * bs <= _F32_EXACT_MAX_TERMS):
                self._gemm_shared_fused(a_idx, w_idx, order,
                                        sidx[starts], int(counts[0]))
                return
        per_point = bs * bs * (1 if w_uniform else b) * 4 + 9 * b * bs
        chunk = max(1, _GEMM_CHUNK_BYTES // per_point)
        for lo in range(0, x_idx.size, chunk):
            sl = slice(lo, lo + chunk)
            A = self.inp_buf[:, a_idx[sl]]                    # (B, l, bs)
            if w_uniform:
                W = self.wgt_buf[0, w_idx[sl]]                # (l, bs, bs)
                if f32:
                    # one BLAS sgemm stack: (l,bs,bs) @ (l,bs,B) — the
                    # weight operand is shared by the whole batch
                    prod = np.matmul(
                        W.astype(np.float32),
                        A.transpose(1, 2, 0).astype(np.float32)
                    ).transpose(2, 0, 1).astype(np.int32)     # (B, l, bs)
                else:
                    prod = np.einsum("lij,blj->bli", W, A, dtype=np.int32)
            else:
                W = self.wgt_buf[:, w_idx[sl]]                # (B, l, bs, bs)
                if f32:
                    prod = np.matmul(
                        W.astype(np.float32),
                        A.astype(np.float32)[..., None]
                    )[..., 0].astype(np.int32)
                else:
                    prod = np.einsum("blij,blj->bli", W, A, dtype=np.int32)
            # merge duplicate destinations, then one scatter-add; chunks
            # compose because int32 adds wrap exactly mod 2**32
            if shared_group is not None and chunk >= x_idx.size:
                order, sidx, starts = shared_group     # whole lattice: reuse
            else:
                order, sidx, starts = _group(x_idx[sl])
            red = np.add.reduceat(prod[:, order], starts, axis=1)
            self._accum_rows(sidx[starts], red)               # int32 wrap

    def _gemm_shared_fused(self, a_idx: np.ndarray, w_idx: np.ndarray,
                           order: np.ndarray, ud: np.ndarray,
                           c: int) -> None:
        """Uniform-W regular-lattice GEMM: destination-grouped operands,
        reduction fused into the matmul contraction (addition is
        commutative and the float32 dots are exact, so any within-group
        order gives the oracle's mod-2**32 result)."""
        bs = self.cfg.block_size
        b = self.batch
        ncon = c * bs                                 # contraction length
        g = ud.size
        ao = a_idx[order].reshape(g, c)
        wo = w_idx[order].reshape(g, c)
        per_group = ncon * (bs + b) * 8               # f32 Wg + Ag + prod
        gchunk = max(1, _GEMM_CHUNK_BYTES // per_group)
        for lo in range(0, g, gchunk):
            sl = slice(lo, lo + gchunk)
            Wg = self.wgt_buf[0, wo[sl]]              # (g, c, bs, bs)
            Wg = np.ascontiguousarray(
                Wg.transpose(0, 2, 1, 3)).reshape(-1, bs, ncon)
            Ag = self.inp_buf[:, ao[sl]]              # (B, g, c, bs)
            Ag = np.ascontiguousarray(
                Ag.transpose(1, 2, 3, 0)).reshape(-1, ncon, b)
            prod = np.matmul(Wg.astype(np.float32), Ag.astype(np.float32))
            red = prod.transpose(2, 0, 1).astype(np.int32)    # (B, g, bs)
            self._accum_rows(ud[sl], red)             # int32 wrap

    def _gemm_general(self, p: _GemmStep) -> None:
        """Per-image UOP buffers: flatten every image's lattice into one
        global index space (row *b* offset by ``b · buffer_len``) and run
        one einsum + scatter over the whole batch."""
        uop = self.uop_buf[:, p.u_idx]                        # (B, nu, 3)
        n_acc = self.acc_buf.shape[1]
        x_idx = self._batch_lattice(p.off_acc, uop[:, :, 0], n_acc, "ACC")
        bs = self.cfg.block_size
        acc_flat = self.acc_buf.reshape(-1, bs)
        if p.reset:
            acc_flat[x_idx] = 0
            return
        a_idx = self._batch_lattice(p.off_inp, uop[:, :, 1],
                                    self.inp_buf.shape[1], "INP")
        w_idx = self._batch_lattice(p.off_wgt, uop[:, :, 2],
                                    self.wgt_buf.shape[1], "WGT")
        inp_flat = self.inp_buf.reshape(-1, bs)
        wgt_flat = self.wgt_buf.reshape(-1, bs, bs)
        f32 = bs <= _F32_EXACT_MAX_BS
        chunk = max(1, _GEMM_CHUNK_BYTES // (bs * bs * 4))
        for lo in range(0, x_idx.size, chunk):
            sl = slice(lo, lo + chunk)
            A = inp_flat[a_idx[sl]]                           # (l, bs) int8
            W = wgt_flat[w_idx[sl]]                           # (l, bs, bs)
            if f32:
                prod = np.matmul(
                    W.astype(np.float32), A.astype(np.float32)[..., None]
                )[..., 0].astype(np.int32)
            else:
                prod = np.einsum("lij,lj->li", W, A, dtype=np.int32)
            order, sidx, starts = _group(x_idx[sl])
            red = np.add.reduceat(prod[order], starts, axis=0)
            self._accum_flat(acc_flat, sidx[starts], red)     # int32 wrap

    # -------------------------------------------------------------- alu --
    def _exec_alu(self, p: _AluStep) -> None:
        if p.loop_count == 0:
            return
        bs = self.cfg.block_size
        n_acc = self.acc_buf.shape[1]
        self._check_uop_range(p.u_idx, self.uop_buf.shape[1], "ALU")
        if self._uniform["uop"]:
            uop = self.uop_buf[0, p.u_idx]
            d_idx = self._shared_lattice(p.off_dst, uop[:, 0])
            self._check_lattice(d_idx, n_acc, "ALU ACC dst")
            if p.use_imm:
                self._alu_imm_shared(p, d_idx)
            else:
                s_idx = self._shared_lattice(p.off_src, uop[:, 1])
                # pre-offset bounds check, as in _batch_lattice: an
                # out-of-range source must raise (as the oracle does),
                # never read a neighbouring image's ACC rows
                self._check_lattice(s_idx, n_acc, "ALU ACC src")
                if np.intersect1d(d_idx, s_idx).size:
                    # Same RAW pattern on every image: flatten globally and
                    # run the oracle-order loop once per (image, point).
                    acc64 = self.acc_buf.astype(np.int64)
                    flat = acc64.reshape(-1, bs)
                    base = (np.arange(self.batch, dtype=np.int64)
                            * n_acc)[:, None]
                    gd = (d_idx[None, :] + base).reshape(-1)
                    gs = (s_idx[None, :] + base).reshape(-1)
                    self._alu_sequential(flat, p.op, gd, gs)
                    self.acc_buf[:] = acc64.astype(np.int32)
                else:
                    self._alu_pair_shared(p.op, d_idx, s_idx)
        else:
            uop = self.uop_buf[:, p.u_idx]
            d_idx = self._batch_lattice(p.off_dst, uop[:, :, 0], n_acc,
                                        "ACC dst")
            acc_flat = self.acc_buf.reshape(-1, bs)
            acc64 = acc_flat.astype(np.int64)
            if p.use_imm:
                self._alu_imm(acc64, p, d_idx)
            else:
                s_idx = self._batch_lattice(p.off_src, uop[:, :, 1], n_acc,
                                            "ACC src")
                if np.intersect1d(d_idx, s_idx).size:
                    # Flattened order is batch-major and batches are
                    # disjoint in the global index space, so this equals
                    # the oracle's per-image loop order on every image.
                    self._alu_sequential(acc64, p.op, d_idx, s_idx)
                else:
                    self._alu_pair(acc64, p.op, d_idx, s_idx)
            self._truncate_acc64(acc64, acc_flat)
        self.report.alu_loops += p.loop_count * self.batch

    def _alu_imm_shared(self, p: _AluStep, d_idx: np.ndarray) -> None:
        """Immediate-form ALU over a shared lattice: group once, apply the
        merged op across the batch axis (same merges as the single-image
        :meth:`FastSimulator._alu_imm`).  Only the touched ACC rows are
        widened to int64 and truncated back — untouched rows never move."""
        imm = np.int64(p.imm)
        order, sidx, starts = _group(d_idx)
        ud = sidx[starts]
        sub = self.acc_buf[:, ud].astype(np.int64)            # (B, G, bs)
        if p.op in (isa.AluOp.MIN, isa.AluOp.MAX):
            sub = self._alu_elementwise(p.op, sub, imm)
        elif p.op == isa.AluOp.ADD:
            counts = np.diff(np.r_[starts, d_idx.size]).astype(np.int64)
            sub += imm * counts[None, :, None]
        else:  # SHR
            counts = np.diff(np.r_[starts, d_idx.size]).astype(np.int64)
            shift = np.minimum((imm & 31) * counts, 63)
            sub >>= shift[None, :, None]
        wrapped = sub.astype(np.int32)                        # wrap-around
        if self.count_overflows:
            self.report.acc_overflow_lanes += int(
                np.count_nonzero(sub != wrapped))
        self.acc_buf[:, ud] = wrapped

    def _alu_pair_shared(self, op: isa.AluOp, d_idx: np.ndarray,
                         s_idx: np.ndarray) -> None:
        """Vector-pair ALU over a shared lattice (sources disjoint from
        destinations on every image); touched rows only, as above."""
        svals = self.acc_buf[:, s_idx].astype(np.int64)       # (B, L, bs)
        order, sidx, starts = _group(d_idx)
        ud = sidx[starts]
        svals = svals[:, order]
        sub = self.acc_buf[:, ud].astype(np.int64)            # (B, G, bs)
        if op == isa.AluOp.ADD:
            sub += np.add.reduceat(svals, starts, axis=1)
        elif op == isa.AluOp.MIN:
            sub = np.minimum(sub, np.minimum.reduceat(svals, starts, axis=1))
        elif op == isa.AluOp.MAX:
            sub = np.maximum(sub, np.maximum.reduceat(svals, starts, axis=1))
        else:  # SHR
            shift = np.minimum(
                np.add.reduceat(svals & 31, starts, axis=1), 63)
            sub >>= shift
        wrapped = sub.astype(np.int32)                        # wrap-around
        if self.count_overflows:
            self.report.acc_overflow_lanes += int(
                np.count_nonzero(sub != wrapped))
        self.acc_buf[:, ud] = wrapped


def run_batch(cfg: VTAConfig, dram_stack: np.ndarray, instructions, *,
              plan: Optional[InstructionPlan] = None, trace: bool = False,
              fault_hook=None, count_overflows: bool = False
              ) -> Tuple[np.ndarray, SimReport]:
    """Execute one instruction stream over a ``(batch, nbytes)`` DRAM stack.

    Returns ``(dram_stack_after, report)``.  Bit-identical to running the
    single-image simulator over each row of the stack independently; pass
    a cached ``plan`` (:func:`plan_for`) to amortise plan compilation
    across calls — the compile-once/serve-many path of
    :meth:`repro.core.network_compiler.NetworkProgram.serve`.
    """
    sim = BatchFastSimulator(cfg, np.asarray(dram_stack), trace=trace,
                             count_overflows=count_overflows)
    report = sim.run(instructions, plan=plan, fault_hook=fault_hook)
    return sim.dram, report
