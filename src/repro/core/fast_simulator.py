"""Vectorized fast-path VTA simulator — compiled instruction plans.

The oracle interpreter (:mod:`repro.core.simulator`) executes LOAD/STORE,
GEMM and ALU element-by-element in Python loops: every GeMM loop of
Algorithm 1 is one Python iteration, every DRAM struct move one
``_struct_from_dram`` call.  This module replaces that inner-loop work with
batched numpy operations while staying bit-exact, in two stages:

1. **Plan compilation** (:func:`compile_plan`) — the instruction stream is
   decoded *once* into an :class:`InstructionPlan`: the ``iter_out ×
   iter_in × uop`` loop lattice of each GEMM/ALU instruction becomes
   precomputed index-offset arrays, and each LOAD/STORE becomes a strided
   byte-gather/scatter geometry.  Plans depend only on instruction fields
   (never on data), so they are cached per program (:func:`plan_for`) and
   amortised across repeated executions — the batch-serving case.

2. **Vectorized execution** (:class:`FastSimulator`) — LOAD/STORE run as
   strided slice copies, GEMM as one ``einsum`` over the uop batch per
   instruction with a merge-by-destination scatter-add, ALU as vectorized
   min/max/add/shift over the whole index lattice.

Bit-exactness is preserved against the oracle, including:

* int32 wrap-around — additions are merged in int64 and truncated once;
  this equals the oracle's per-step wrap because addition is associative
  modulo 2**32;
* the truncating ACC→OUT commit before every STORE;
* SHR masking (``y & 31``) and repeated-destination shift accumulation;
* the §5.1 observability counters (loop counts, DRAM traffic, trace) and
  the §2.3 dependency-token hazard checking, shared with the oracle via
  :class:`~repro.core.simulator.TokenQueues`.

ALU instructions whose lattice has read-after-write dependencies that no
order-independent merge can express (e.g. a vector-pair op whose source
vectors are also destinations) fall back to a per-lattice-point loop with
the oracle's exact semantics — correctness never depends on the compiler
emitting "nice" programs.

Multi-chunk uop-wave programs (DESIGN.md §3) need no special handling:
plans precompute only the *geometry* lattices, while GEMM/ALU steps gather
their uops from ``uop_buf`` at execution time — so mid-stream LOAD_UOP
waves that rewrite slots 1.. between instructions are observed exactly as
on the oracle, and the cached per-program plan stays valid across waves.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import isa
from .hwconfig import VTAConfig
from .layout import truncate_int8
from .simulator import SimReport, TokenQueues, VTAHazardError  # noqa: F401

# Bound the per-chunk gather footprint of the GEMM einsum (the WGT gather
# materialises block_size² int64 per lattice point).
_GEMM_CHUNK_BYTES = 64 << 20


# ---------------------------------------------------------------------------
# Plan steps
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _LoadStep:
    kind: str                   # uop | inp | wgt | acc | out
    mem: isa.MemId
    nbytes: int                 # bytes per structure
    zero_base: int              # SRAM span to clear (padding), len 0 = none
    zero_len: int
    sram_idx: np.ndarray        # (n,) destination structure indices
    byte_idx: np.ndarray        # (n, nbytes) DRAM byte gather lattice
    end_byte: int               # max byte index + 1, for the bounds check


@dataclasses.dataclass
class _StoreStep:
    kind: str
    nbytes: int
    n: int                      # structures moved (sram_base..sram_base+n)
    sram_base: int
    byte_idx: Optional[np.ndarray]   # (n, nbytes) scatter, None -> row loop
    row_dram_starts: np.ndarray      # (y_size,) byte offsets (row-loop path)
    row_bytes: int
    end_byte: int


@dataclasses.dataclass
class _GemmStep:
    reset: bool
    u_idx: np.ndarray           # (nu,) uop buffer indices
    off_acc: np.ndarray         # (P,) iter_out×iter_in lattice offsets
    off_inp: np.ndarray
    off_wgt: np.ndarray
    loop_count: int


@dataclasses.dataclass
class _AluStep:
    op: isa.AluOp
    use_imm: bool
    imm: int
    u_idx: np.ndarray
    off_dst: np.ndarray         # (P,)
    off_src: np.ndarray
    loop_count: int


@dataclasses.dataclass
class _FinishStep:
    pass


@dataclasses.dataclass
class InstructionPlan:
    """A compiled instruction stream: one executable step per instruction.

    Dependency flags are read live from the instruction objects at
    execution time, so token-hazard behaviour tracks ``dep`` mutations;
    the precomputed index lattices assume the *geometry* fields are
    frozen after compilation.
    """

    steps: List[Tuple[object, object]]   # (insn, step payload)

    @property
    def n_insns(self) -> int:
        return len(self.steps)


# ---------------------------------------------------------------------------
# Plan compilation
# ---------------------------------------------------------------------------

_MEM_KIND = {
    isa.MemId.UOP: "uop", isa.MemId.INP: "inp", isa.MemId.WGT: "wgt",
    isa.MemId.ACC: "acc", isa.MemId.OUT: "out",
}


def _outer_offsets(iter_out: int, iter_in: int, f_out: int, f_in: int
                   ) -> np.ndarray:
    """Ravelled ``i_out*f_out + i_in*f_in`` lattice, loop order (out, in)."""
    io = np.arange(iter_out, dtype=np.int64) * f_out
    ii = np.arange(iter_in, dtype=np.int64) * f_in
    return (io[:, None] + ii[None, :]).reshape(-1)


def _compile_load(cfg: VTAConfig, m: isa.MemInsn) -> _LoadStep:
    kind = _MEM_KIND[m.memory_type]
    nbytes = cfg.elem_bytes(kind)
    row_w = m.x_pad_0 + m.x_size + m.x_pad_1
    total_rows = m.y_pad_0 + m.y_size + m.y_pad_1
    has_pad = (m.y_pad_0 or m.y_pad_1 or m.x_pad_0 or m.x_pad_1)
    zero_len = total_rows * row_w if has_pad else 0

    y = np.arange(m.y_size, dtype=np.int64)
    x = np.arange(m.x_size, dtype=np.int64)
    sram_idx = (m.sram_base + (m.y_pad_0 + y)[:, None] * row_w
                + m.x_pad_0 + x[None, :]).reshape(-1)
    log_addr = (m.dram_base + y[:, None] * m.x_stride + x[None, :]).reshape(-1)
    byte_idx = (log_addr[:, None] * nbytes
                + np.arange(nbytes, dtype=np.int64)[None, :])
    end_byte = int(byte_idx.max(initial=-1)) + 1
    return _LoadStep(kind=kind, mem=m.memory_type, nbytes=nbytes,
                     zero_base=m.sram_base, zero_len=zero_len,
                     sram_idx=sram_idx, byte_idx=byte_idx, end_byte=end_byte)


def _compile_store(cfg: VTAConfig, m: isa.MemInsn) -> _StoreStep:
    kind = _MEM_KIND[m.memory_type]
    if kind == "uop":
        raise ValueError("STORE UOP is not a valid VTA instruction")
    nbytes = cfg.elem_bytes(kind)
    n = m.y_size * m.x_size
    row_bytes = m.x_size * nbytes
    y = np.arange(m.y_size, dtype=np.int64)
    row_dram_starts = (m.dram_base + y * m.x_stride) * nbytes
    end_byte = int((row_dram_starts.max(initial=-nbytes) + row_bytes))
    # Overlapping rows (stride < x_size) must be written in order; the
    # single-scatter path requires disjoint rows.
    overlap = m.y_size > 1 and m.x_stride < m.x_size
    byte_idx = None
    if not overlap:
        if n:
            byte_idx = (row_dram_starts[:, None]
                        + np.arange(row_bytes, dtype=np.int64)[None, :]
                        ).reshape(n, nbytes)
        else:
            byte_idx = np.zeros((0, nbytes), dtype=np.int64)
    return _StoreStep(kind=kind, nbytes=nbytes, n=n, sram_base=m.sram_base,
                      byte_idx=byte_idx, row_dram_starts=row_dram_starts,
                      row_bytes=row_bytes, end_byte=end_byte)


def _compile_gemm(g: isa.GemInsn) -> _GemmStep:
    n_uop = max(0, g.uop_end - g.uop_bgn)
    u_idx = np.arange(g.uop_bgn, g.uop_bgn + n_uop, dtype=np.int64)
    return _GemmStep(
        reset=bool(g.reset), u_idx=u_idx,
        off_acc=_outer_offsets(g.iter_out, g.iter_in,
                               g.acc_factor_out, g.acc_factor_in),
        off_inp=_outer_offsets(g.iter_out, g.iter_in,
                               g.inp_factor_out, g.inp_factor_in),
        off_wgt=_outer_offsets(g.iter_out, g.iter_in,
                               g.wgt_factor_out, g.wgt_factor_in),
        loop_count=g.iter_out * g.iter_in * n_uop)


def _compile_alu(a: isa.AluInsn) -> _AluStep:
    n_uop = max(0, a.uop_end - a.uop_bgn)
    u_idx = np.arange(a.uop_bgn, a.uop_bgn + n_uop, dtype=np.int64)
    return _AluStep(
        op=a.alu_opcode, use_imm=bool(a.use_imm), imm=a.imm, u_idx=u_idx,
        off_dst=_outer_offsets(a.iter_out, a.iter_in,
                               a.dst_factor_out, a.dst_factor_in),
        off_src=_outer_offsets(a.iter_out, a.iter_in,
                               a.src_factor_out, a.src_factor_in),
        loop_count=a.iter_out * a.iter_in * n_uop)


def compile_plan(cfg: VTAConfig, instructions) -> InstructionPlan:
    """Decode an instruction stream into its array-form execution plan."""
    steps: List[Tuple[object, object]] = []
    for insn in instructions:
        if isinstance(insn, isa.MemInsn):
            step = (_compile_load(cfg, insn)
                    if insn.opcode == isa.Opcode.LOAD
                    else _compile_store(cfg, insn))
        elif isinstance(insn, isa.GemInsn):
            step = _compile_gemm(insn)
        elif isinstance(insn, isa.AluInsn):
            step = _compile_alu(insn)
        elif isinstance(insn, isa.FinishInsn):
            step = _FinishStep()
        else:
            raise TypeError(insn)
        steps.append((insn, step))
    return InstructionPlan(steps=steps)


def plan_for(prog) -> InstructionPlan:
    """Cached plan for a :class:`~repro.core.program.VTAProgram`.

    Recompiled when the instruction list changes (count or object
    identity).  Dependency flags are read live, so dep mutations need no
    invalidation; editing *geometry* fields of an existing instruction in
    place is not detected — call :func:`invalidate_plan` afterwards.
    """
    plan = getattr(prog, "_fast_plan", None)
    if (plan is None or plan.n_insns != len(prog.instructions)
            or any(step_insn is not insn for (step_insn, _), insn
                   in zip(plan.steps, prog.instructions))):
        plan = compile_plan(prog.config, prog.instructions)
        prog._fast_plan = plan
    return plan


def invalidate_plan(prog) -> None:
    if hasattr(prog, "_fast_plan"):
        del prog._fast_plan


# ---------------------------------------------------------------------------
# Scatter helpers (order-independent merges, exact modulo 2**32)
# ---------------------------------------------------------------------------

def _group(idx: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sort ``idx``; return (order, sorted idx, group-start positions)."""
    order = np.argsort(idx, kind="stable")
    sidx = idx[order]
    starts = np.flatnonzero(np.r_[True, sidx[1:] != sidx[:-1]])
    return order, sidx, starts


def _scatter_add(acc64: np.ndarray, idx: np.ndarray, vals: np.ndarray) -> None:
    """``acc64[idx] += vals`` with duplicate destinations merged first."""
    if idx.size == 0:
        return
    order, sidx, starts = _group(idx)
    acc64[sidx[starts]] += np.add.reduceat(vals[order], starts, axis=0)


# ---------------------------------------------------------------------------
# The simulator
# ---------------------------------------------------------------------------

class FastSimulator:
    """Vectorized VTA functional simulator — bit-exact vs the oracle."""

    def __init__(self, cfg: VTAConfig, dram: np.ndarray, *,
                 trace: bool = False):
        if dram.dtype != np.uint8:
            raise TypeError("dram image must be uint8")
        self.cfg = cfg
        self.dram = dram.copy()
        self.trace = trace
        bs = cfg.block_size
        self.uop_buf = np.zeros((cfg.uop_buff_entries, 3), dtype=np.int64)
        self.inp_buf = np.zeros((cfg.inp_buff_vectors, bs), dtype=np.int8)
        self.wgt_buf = np.zeros((cfg.wgt_buff_matrices, bs, bs), dtype=np.int8)
        self.acc_buf = np.zeros((cfg.acc_buff_vectors, bs), dtype=np.int32)
        self.out_buf = np.zeros((cfg.out_buff_vectors, bs), dtype=np.int8)
        self.tokens = TokenQueues()
        self.report = SimReport()

    # -------------------------------------------------------------- mem --
    def _buf_of(self, kind: str) -> np.ndarray:
        return {"uop": self.uop_buf, "inp": self.inp_buf,
                "wgt": self.wgt_buf, "acc": self.acc_buf,
                "out": self.out_buf}[kind]

    def _decode_structs(self, kind: str, raw: np.ndarray) -> np.ndarray:
        """(n, nbytes) uint8 → n structures in SRAM form."""
        n = raw.shape[0]
        bs = self.cfg.block_size
        if kind == "uop":
            words = raw.view("<u4").reshape(n).astype(np.int64)
            return np.stack([words & 0x7FF, (words >> 11) & 0x7FF,
                             (words >> 22) & 0x3FF], axis=1)
        if kind == "inp":
            return raw.view(np.int8).reshape(n, bs)
        if kind == "wgt":
            return raw.view(np.int8).reshape(n, bs, bs)
        if kind == "acc":
            return raw.view("<i4").reshape(n, bs).astype(np.int32)
        raise ValueError(kind)

    def _encode_structs(self, kind: str, data: np.ndarray) -> np.ndarray:
        """n structures → (n, nbytes) uint8 (little-endian)."""
        n = data.shape[0]
        if kind in ("inp", "out"):
            return np.ascontiguousarray(data).view(np.uint8).reshape(n, -1)
        if kind == "wgt":
            return np.ascontiguousarray(data).view(np.uint8).reshape(n, -1)
        if kind == "acc":
            return np.ascontiguousarray(
                data.astype("<i4")).view(np.uint8).reshape(n, -1)
        raise ValueError(kind)

    def _exec_load(self, p: _LoadStep) -> None:
        if p.end_byte > len(self.dram):
            raise IndexError(
                f"DRAM read out of range: {p.kind} load ends @{p.end_byte:#x}")
        buf = self._buf_of(p.kind)
        if p.zero_len:
            buf[p.zero_base:p.zero_base + p.zero_len] = 0
        if p.sram_idx.size:
            raw = self.dram[p.byte_idx]
            buf[p.sram_idx] = self._decode_structs(p.kind, raw)
        self.report.dram_bytes_read += p.byte_idx.size

    def _exec_store(self, p: _StoreStep) -> None:
        if p.n == 0:
            return            # degenerate geometry: the oracle's loop is empty
        if p.end_byte > len(self.dram):
            raise IndexError(
                f"DRAM write out of range: {p.kind} store ends "
                f"@{p.end_byte:#x}")
        buf = self._buf_of(p.kind)
        data = buf[p.sram_base:p.sram_base + p.n]
        if data.shape[0] < p.n:
            raise IndexError(f"SRAM read out of range: {p.kind} store")
        raw = self._encode_structs(p.kind, data)
        if p.byte_idx is not None:
            self.dram[p.byte_idx] = raw
        else:                      # overlapping rows: write in order
            rows = raw.reshape(-1, p.row_bytes)
            for start, row in zip(p.row_dram_starts, rows):
                self.dram[start:start + p.row_bytes] = row
        self.report.dram_bytes_written += raw.size

    # ------------------------------------------------------------- gemm --
    def _lattice(self, off: np.ndarray, u_field: np.ndarray) -> np.ndarray:
        """(P,) outer offsets × (nu,) uop bases → (P·nu,) ravelled indices
        in the oracle's loop order (i_out, i_in, u)."""
        return (off[:, None] + u_field[None, :]).reshape(-1)

    def _exec_gemm(self, p: _GemmStep) -> None:
        if p.loop_count == 0:
            return
        uop = self.uop_buf[p.u_idx]                      # (nu, 3)
        x_idx = self._lattice(p.off_acc, uop[:, 0])
        if p.reset:
            self.acc_buf[x_idx] = 0
            self.report.gemm_reset_loops += p.loop_count
            return
        a_idx = self._lattice(p.off_inp, uop[:, 1])
        w_idx = self._lattice(p.off_wgt, uop[:, 2])
        bs = self.cfg.block_size
        chunk = max(1, _GEMM_CHUNK_BYTES // (bs * bs * 8))
        acc64 = self.acc_buf.astype(np.int64)
        for lo in range(0, x_idx.size, chunk):
            sl = slice(lo, lo + chunk)
            A = self.inp_buf[a_idx[sl]].astype(np.int64)     # (l, bs)
            W = self.wgt_buf[w_idx[sl]].astype(np.int64)     # (l, bs, bs)
            # out[l, i] = Σ_j A[l, j] · W[l, i, j]  (W stored transposed)
            prod = np.einsum("lij,lj->li", W, A)
            _scatter_add(acc64, x_idx[sl], prod)
        self.acc_buf[:] = acc64.astype(np.int32)             # wrap-around
        self.report.gemm_loops += p.loop_count

    # -------------------------------------------------------------- alu --
    @staticmethod
    def _alu_elementwise(op: isa.AluOp, x: np.ndarray, y) -> np.ndarray:
        if op == isa.AluOp.MIN:
            return np.minimum(x, y)
        if op == isa.AluOp.MAX:
            return np.maximum(x, y)
        if op == isa.AluOp.ADD:
            return x + y
        if op == isa.AluOp.SHR:
            return x >> (y & 31)
        raise ValueError(op)

    def _exec_alu(self, p: _AluStep) -> None:
        if p.loop_count == 0:
            return
        uop = self.uop_buf[p.u_idx]
        d_idx = self._lattice(p.off_dst, uop[:, 0])
        acc64 = self.acc_buf.astype(np.int64)
        if p.use_imm:
            self._alu_imm(acc64, p, d_idx)
        else:
            s_idx = self._lattice(p.off_src, uop[:, 1])
            if np.intersect1d(d_idx, s_idx).size:
                # Read-after-write across lattice points: oracle order.
                self._alu_sequential(acc64, p.op, d_idx, s_idx)
            else:
                self._alu_pair(acc64, p.op, d_idx, s_idx)
        self.acc_buf[:] = acc64.astype(np.int32)
        self.report.alu_loops += p.loop_count

    def _alu_imm(self, acc64: np.ndarray, p: _AluStep,
                 d_idx: np.ndarray) -> None:
        imm = np.int64(p.imm)
        order, sidx, starts = _group(d_idx)
        ud = sidx[starts]
        if p.op in (isa.AluOp.MIN, isa.AluOp.MAX):
            # Idempotent under repetition.
            acc64[ud] = self._alu_elementwise(p.op, acc64[ud], imm)
        elif p.op == isa.AluOp.ADD:
            counts = np.diff(np.r_[starts, d_idx.size]).astype(np.int64)
            acc64[ud] += imm * counts[:, None]
        else:  # SHR: k repeated c times on an int32-range value = shift c·k
            counts = np.diff(np.r_[starts, d_idx.size]).astype(np.int64)
            shift = np.minimum((imm & 31) * counts, 63)
            acc64[ud] >>= shift[:, None]

    def _alu_pair(self, acc64: np.ndarray, op: isa.AluOp,
                  d_idx: np.ndarray, s_idx: np.ndarray) -> None:
        """Sources disjoint from destinations: pre-state gather is exact."""
        svals = acc64[s_idx]                              # (L, bs)
        order, sidx, starts = _group(d_idx)
        ud = sidx[starts]
        svals = svals[order]
        if op == isa.AluOp.ADD:
            acc64[ud] += np.add.reduceat(svals, starts, axis=0)
        elif op == isa.AluOp.MIN:
            acc64[ud] = np.minimum(acc64[ud],
                                   np.minimum.reduceat(svals, starts, axis=0))
        elif op == isa.AluOp.MAX:
            acc64[ud] = np.maximum(acc64[ud],
                                   np.maximum.reduceat(svals, starts, axis=0))
        else:  # SHR: per-lane shifts accumulate across duplicates
            shift = np.minimum(
                np.add.reduceat(svals & 31, starts, axis=0), 63)
            acc64[ud] >>= shift

    def _alu_sequential(self, acc64: np.ndarray, op: isa.AluOp,
                        d_idx: np.ndarray, s_idx: np.ndarray) -> None:
        """Oracle loop order for lattices with cross-point dependencies.

        Each step wraps to int32 before the next reads it, exactly as the
        hardware (and the oracle) would."""
        for d, s in zip(d_idx, s_idx):
            x = acc64[d]
            y = acc64[s]
            acc64[d] = self._alu_elementwise(op, x, y).astype(
                np.int32).astype(np.int64)

    # -------------------------------------------------------------- run --
    def _commit_out(self) -> None:
        """ACC → OUT truncation (§2.1: OUT vectors are truncated ACC)."""
        self.out_buf[:] = truncate_int8(self.acc_buf)

    def run(self, instructions, plan: Optional[InstructionPlan] = None
            ) -> SimReport:
        """Execute an instruction stream.  Pass a cached ``plan`` (from
        :func:`plan_for` / :func:`compile_plan`) to skip plan compilation;
        it must have been compiled from these instructions."""
        if plan is None:
            plan = compile_plan(self.cfg, instructions)
        elif plan.n_insns != len(instructions):
            raise ValueError("plan does not match instruction stream")
        for insn, step in plan.steps:
            self.tokens.pre(insn)
            if isinstance(step, _LoadStep):
                self._exec_load(step)
                tag = f"{insn.opcode.name} {insn.memory_type.name}"
            elif isinstance(step, _StoreStep):
                self._commit_out()
                self._exec_store(step)
                tag = f"{insn.opcode.name} {insn.memory_type.name}"
            elif isinstance(step, _GemmStep):
                self._exec_gemm(step)
                tag = f"GEMM{' reset' if step.reset else ''}"
            elif isinstance(step, _AluStep):
                self._exec_alu(step)
                tag = f"ALU {step.op.name}"
            else:
                tag = "FINISH"
            self.report.insn_executed += 1
            if self.trace:
                self.report.insn_trace.append(tag)
            self.tokens.post(insn)
            if isinstance(step, _FinishStep):
                break
        return self.report
