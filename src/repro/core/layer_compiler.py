"""One CNN layer → one VTA program (paper §4.2, Fig. 11).

A *layer* (paper §4.1) = one dense linear operation (convolution — valid or
zero-padded "same", stride 1 or 2 (DESIGN.md §Strided-lowering) — or fully
connected) + subsequent non-linear operations (ReLU on TensorAlu; average
pooling as an ALU ADD/SHR program; max pooling as an ALU MAX pair program;
global average pooling as an ALU ADD-pair tree reduction + one SHR; static
power-of-2 requantisation).  Layers
whose matrices exceed the SRAM compile to multi-chunk programs — the GEMM
compiler re-indexes the pool/requant uops against each chunk's local ACC
window (DESIGN.md §3), so nothing here is limited to single-chunk results.

The lowering is the extended pipeline of Fig. 11:

    tensor ──im2row/ker2col──▶ matrices ──pad/split/binarise──▶ data
    layer op ────────────────▶ GEMM + ALU instructions + UOPs

Requantisation discipline (hardware adaptation, DESIGN.md §2): the VTA OUT
path truncates ACC (int32) to int8, so every layer ends with an arithmetic
right shift that brings the live values into [-128, 127].  Shifts are
*static* — chosen at compile time from the reference activations — which is
precisely the predictable-execution property the paper targets.  For pooled
layers, the pool's ÷4 and the requant shift fuse into one SHR (2 + shift)
over the surviving rows.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .conv_lowering import (ConvGeometry, PoolPlan, avgpool2x2_plan,
                            flatten_tensor, global_avgpool_plan, im2row,
                            ker2col, mat2tensor, maxpool2x2_plan, tensor2mat)
from .dram import DramAllocator
from .errors import CompileError
from .gemm_compiler import (AluImmOp, AluIndexedImmOp, AluPairOp,
                            AluResidualOp, compile_matmul)
from .hwconfig import VTAConfig, vta_default
from .layout import pad_to_multiple, should_pad_height, truncate_int8
from .program import VTAProgram
from . import isa


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Hardware-agnostic description of one layer.

    conv: ``weights`` is ``(F, C, kh, kw)`` int8; input is a ``(1, C, H, W)``
    int8 tensor.  fc: ``weights`` is ``(D, F)`` int8; input is a ``(1, D)``
    int8 matrix (or a tensor, flattened NCHW).
    """

    name: str
    kind: str                      # "conv" | "fc"
    weights: np.ndarray
    bias: Optional[np.ndarray] = None     # int32 (F,)
    stride: int = 1
    padding: int = 0               # symmetric zero-padding (conv only)
    relu: bool = False
    pool: Optional[str] = None     # None | "avg2x2" | "max2x2" | "gap"
    requant_shift: Optional[int] = None   # None = choose statically
    # Residual-add fusion (DESIGN.md §Graph): the layer closes a skip
    # connection — after the GEMM result is requantised (``requant_shift``)
    # the skip operand is ACC-loaded and merged on the VTA with an ALU
    # vector-vector ADD (``residual_pre_shift`` equalises its scale), then
    # ``relu`` applies *post-add* and ``residual_shift`` requantises the
    # sum.  ``compile_layer`` must then receive the skip activation via
    # its ``residual=`` argument.  Pooling cannot fuse with a residual.
    residual_add: bool = False
    residual_pre_shift: int = 0
    residual_shift: Optional[int] = None  # None = choose statically

    def out_features(self) -> int:
        return (self.weights.shape[0] if self.kind == "conv"
                else self.weights.shape[1])


@dataclasses.dataclass
class CompiledLayer:
    """A compiled layer: the VTA program + the decode metadata the host
    needs for §4.2 reshaping."""

    spec: LayerSpec
    program: VTAProgram
    input_matrix: np.ndarray          # A (int8), pre-padding
    weight_matrix: np.ndarray         # B (int8), pre-padding
    requant_shift: int
    keep_rows: Optional[Tuple[int, ...]]   # pooled surviving rows, or None
    out_h: Optional[int] = None       # post-pool spatial dims (conv only)
    out_w: Optional[int] = None
    ref_output_matrix: Optional[np.ndarray] = None  # int8 (rows×F) post-reshape
    # Residual layers: the reference skip operand (int32 (M, N), add-time
    # scale) and the post-add requant shift actually compiled in.
    residual_matrix: Optional[np.ndarray] = None
    residual_shift: Optional[int] = None

    @property
    def gemm_loops(self) -> int:
        return self.program.gemm_loops()

    @property
    def n_chunks(self) -> int:
        """SRAM chunks the layer's GEMM was tiled into (§3.3 repetition)."""
        plan = self.program.chunk_plan
        return plan.n_chunks if plan is not None else 1


def _vec_index(row: int, col_block: int, beta: int, row_height: int) -> int:
    """ACC-vector index of matrix row ``row`` in block column ``col_block``
    (block-major SRAM layout, §3.2)."""
    block_row, within = divmod(row, row_height)
    return (block_row * beta + col_block) * row_height + within


def pool_plan_for(spec: LayerSpec,
                  geo: Optional[ConvGeometry]) -> Optional[PoolPlan]:
    """The pooling plan a LayerSpec asks for (None = no pooling).  The
    single place pool kinds are interpreted — unknown kinds raise here for
    the compiler and the calibration path alike."""
    if spec.pool is None:
        return None
    if geo is None:
        raise CompileError("pooling requires a conv layer", layer=spec.name,
                           constraint="pool-needs-conv")
    if spec.pool in ("avg2x2", "max2x2"):
        if geo.out_h % 2 or geo.out_w % 2:
            raise CompileError(
                f"2x2 pooling needs even conv output dims, got "
                f"{geo.out_h}x{geo.out_w}", layer=spec.name,
                constraint="pool-even-dims")
        return (avgpool2x2_plan if spec.pool == "avg2x2"
                else maxpool2x2_plan)(geo.out_h, geo.out_w)
    if spec.pool == "gap":
        check_gap_geometry(geo.out_h, geo.out_w, layer=spec.name)
        return global_avgpool_plan(geo.out_h, geo.out_w)
    raise CompileError(f"unsupported pool kind {spec.pool!r} (expected "
                       f"'avg2x2', 'max2x2' or 'gap')", layer=spec.name,
                       constraint="pool-kind")


def pool_divisor(pool_plan: Optional[PoolPlan]) -> int:
    """log2 of the pooling division folded into the requant shift
    (avg pool sums 4 members → ÷4; GAP sums H·W → ÷(H·W); max pool
    divides by nothing)."""
    return pool_plan.div_shift if pool_plan is not None else 0


def choose_requant_shift(acc: np.ndarray, *, already_shifted: int = 0) -> int:
    """Smallest shift s with ``max|acc >> (already_shifted + s)| <= 127``."""
    m = int(np.abs(acc.astype(np.int64) >> already_shifted).max(initial=0))
    shift = 0
    while (m >> shift) > 127:
        shift += 1
    return shift


def check_stride_tiling(geo: ConvGeometry, *, layer: str = "") -> None:
    """Stride-2 grid-coverage constraint (DESIGN.md §Strided-lowering).

    The strided window grid must reach the last *real* input pixel: the
    uncovered tail of the padded input is ``(in + 2·pad - k) mod stride``
    columns/rows wide, and anything beyond the trailing ``pad`` of those
    is input data the conv would silently ignore — which the compiler
    refuses (never silent wrong bytes).  Shared by the layer compiler and
    the graph shape-inference pass so the two front ends cannot drift.
    """
    if geo.stride == 1:
        return
    for axis, extent, k in (("height", geo.in_h, geo.kh),
                            ("width", geo.in_w, geo.kw)):
        leftover = (extent + 2 * geo.pad - k) % geo.stride
        if leftover > geo.pad:
            raise CompileError(
                f"stride-{geo.stride} windows (kernel {k}, pad {geo.pad}) "
                f"leave the last {leftover} input {axis} position(s) "
                f"uncovered — pad the input or adjust the kernel so the "
                f"strided grid lands flush", layer=layer,
                constraint="conv-stride-tiling")


def check_gap_geometry(out_h: int, out_w: int, *, layer: str = "") -> None:
    """Global-avg-pool map constraints (DESIGN.md §Strided-lowering): the
    ÷(H·W) must be one exact SHR, so the map must be square with a
    power-of-two position count.  Shared by the layer compiler and the
    graph shape-inference pass so the two front ends cannot drift."""
    if out_h != out_w:
        raise CompileError(
            f"global avg pool needs a square map, got {out_h}x{out_w}",
            layer=layer, constraint="gap-square")
    n = out_h * out_w
    if n & (n - 1):
        raise CompileError(
            f"global avg pool needs a power-of-two position count for "
            f"the exact SHR division, got {out_h}x{out_w}",
            layer=layer, constraint="gap-pow2")


def layer_matrices(spec: LayerSpec, inp: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, Optional[ConvGeometry]]:
    """Hardware-agnostic stage: tensors → (A, B) matrices (Def. 3).

    Every unsupported shape/stride raises a typed :class:`CompileError`
    naming the layer and the violated constraint (certification-style
    traceability — never a bare assert)."""
    if spec.kind == "conv":
        if inp.ndim != 4:
            raise CompileError(
                f"conv input must be a (1, C, H, W) tensor, got shape "
                f"{inp.shape}", layer=spec.name, constraint="conv-input-rank")
        if inp.shape[0] != 1:
            raise CompileError(
                f"conv compiles per-image (batch axis must be 1), got "
                f"batch {inp.shape[0]}; batching happens at serve time",
                layer=spec.name, constraint="conv-batch-one")
        if spec.weights.ndim != 4:
            raise CompileError(
                f"conv weights must be (F, C, kh, kw), got shape "
                f"{spec.weights.shape}", layer=spec.name,
                constraint="conv-weight-rank")
        if spec.stride < 1:
            raise CompileError(f"stride must be >= 1, got {spec.stride}",
                               layer=spec.name, constraint="conv-stride")
        if spec.stride > 2:
            raise CompileError(
                f"stride {spec.stride} unsupported — the strided lowering "
                f"covers strides 1 and 2 (DESIGN.md §Strided-lowering)",
                layer=spec.name, constraint="conv-stride-max")
        if spec.padding < 0:
            raise CompileError(f"padding must be >= 0, got {spec.padding}",
                               layer=spec.name, constraint="conv-padding")
        f, c, kh, kw = spec.weights.shape
        if inp.shape[1] != c:
            raise CompileError(
                f"channel mismatch: input has {inp.shape[1]} channels, "
                f"weights expect {c}", layer=spec.name,
                constraint="conv-channels")
        geo = ConvGeometry(c, inp.shape[2], inp.shape[3], kh, kw, spec.stride,
                           spec.padding)
        if geo.out_h <= 0 or geo.out_w <= 0:
            raise CompileError(
                f"kernel {kh}x{kw} (stride {spec.stride}, pad "
                f"{spec.padding}) does not fit the {inp.shape[2]}x"
                f"{inp.shape[3]} input", layer=spec.name,
                constraint="conv-kernel-fit")
        check_stride_tiling(geo, layer=spec.name)
        A = im2row(inp, kh, kw, spec.stride, spec.padding)
        B = ker2col(spec.weights)
        return A, B, geo
    if spec.kind == "fc":
        A = flatten_tensor(inp) if inp.ndim == 4 else np.asarray(inp)
        if A.ndim != 2:
            raise CompileError(
                f"fc input must be 2-D (or a flattenable NCHW tensor), got "
                f"shape {np.asarray(inp).shape}", layer=spec.name,
                constraint="fc-input-rank")
        B = np.asarray(spec.weights)
        if B.ndim != 2:
            raise CompileError(
                f"fc weights must be 2-D (D, F), got shape {B.shape}",
                layer=spec.name, constraint="fc-weight-rank")
        if A.shape[1] != B.shape[0]:
            raise CompileError(
                f"fc dimension mismatch: {A.shape} @ {B.shape}",
                layer=spec.name, constraint="fc-shape")
        return A, B, None
    raise CompileError(f"unknown layer kind {spec.kind!r} (expected 'conv' "
                       f"or 'fc')", layer=spec.name, constraint="layer-kind")


def reference_layer_acc(A: np.ndarray, B: np.ndarray,
                        bias: Optional[np.ndarray], relu: bool,
                        pool_plan: Optional[PoolPlan]) -> np.ndarray:
    """int64 accumulator right before the final SHR — used for the static
    requant-shift choice and overflow check."""
    acc = A.astype(np.int64) @ B.astype(np.int64)
    if bias is not None:
        acc = acc + bias.astype(np.int64)[None, :]
    if relu:
        acc = np.maximum(acc, 0)
    if pool_plan is not None:
        if pool_plan.mode == "gap":
            # every spatial position folds into row 0 (÷ in the requant)
            return acc.sum(axis=0, keepdims=True)
        pooled = np.zeros((len(pool_plan.keep_rows), acc.shape[1]),
                          dtype=np.int64)
        for r, base in enumerate(pool_plan.keep_rows):
            in_w = pool_plan.out_w * 2
            rows = [base, base + 1, base + in_w, base + in_w + 1]
            if pool_plan.mode == "max":
                pooled[r] = acc[rows].max(axis=0)
            else:
                pooled[r] = acc[rows].sum(axis=0)
        return pooled
    return acc


def residual_operand_matrix(spec: LayerSpec, residual: np.ndarray,
                            shape: Tuple[int, int]) -> np.ndarray:
    """Skip activation (semantic int8 tensor/matrix) → the int32 (M, N)
    second ACC operand of the layer's residual add.  The single place the
    conversion lives — compilation and run-time staging both route through
    it, so the geometries can never drift."""
    sem = np.asarray(residual)
    R = tensor2mat(sem.astype(np.int8)) if sem.ndim == 4 else sem
    if R.ndim != 2 or R.shape != shape:
        raise CompileError(
            f"residual operand (shape {sem.shape}) does not match the "
            f"layer's {shape} result", layer=spec.name,
            constraint="residual-shape")
    return R.astype(np.int32)


def _compile_residual_layer(spec: LayerSpec, A: np.ndarray, B: np.ndarray,
                            geo: Optional[ConvGeometry],
                            residual: Optional[np.ndarray], cfg: VTAConfig,
                            allocator: Optional[DramAllocator],
                            schedule: str = "serialized") -> CompiledLayer:
    """The residual-closing layer (DESIGN.md §Graph): GEMM → SHR(requant)
    → on-VTA vector-vector ADD with the ACC-loaded skip operand →
    optional ReLU → SHR(post-add requant)."""
    if spec.pool is not None:
        raise CompileError(
            "pooling cannot fuse with a residual add (downsample with a "
            "strided conv instead)", layer=spec.name,
            constraint="residual-no-pool")
    if residual is None:
        raise CompileError(
            "residual_add layer compiled without a residual operand",
            layer=spec.name, constraint="residual-operand-missing")
    if spec.residual_pre_shift < 0:
        raise CompileError(
            f"residual pre-shift must be >= 0, got "
            f"{spec.residual_pre_shift}", layer=spec.name,
            constraint="residual-pre-shift")
    M, N = A.shape[0], B.shape[1]
    R = residual_operand_matrix(spec, residual, (M, N))

    acc = A.astype(np.int64) @ B.astype(np.int64)
    if spec.bias is not None:
        acc = acc + spec.bias.astype(np.int64)[None, :]
    s_conv = (spec.requant_shift if spec.requant_shift is not None
              else choose_requant_shift(acc))
    t = (acc >> s_conv) + (R.astype(np.int64) >> spec.residual_pre_shift)
    if spec.relu:
        t = np.maximum(t, 0)
    s_add = (spec.residual_shift if spec.residual_shift is not None
             else choose_requant_shift(t))
    final = t >> s_add
    if np.abs(final).max(initial=0) > 127:
        raise CompileError(
            f"post-add requant shift {s_add} leaves values outside int8 — "
            f"increase residual_shift", layer=spec.name,
            constraint="requant-int8-range")

    alu_ops: List[object] = []
    if s_conv > 0:
        alu_ops.append(AluImmOp.shr(s_conv))
    alu_ops.append(AluResidualOp(isa.AluOp.ADD,
                                 pre_shift=spec.residual_pre_shift))
    if spec.relu:
        alu_ops.append(AluImmOp.relu())
    if s_add > 0:
        alu_ops.append(AluImmOp.shr(s_add))

    prog = compile_matmul(A, B, bias=spec.bias, alu_ops=alu_ops, residual=R,
                          cfg=cfg, name=spec.name, allocator=allocator,
                          schedule=schedule)
    out_h = geo.out_h if geo is not None else None
    out_w = geo.out_w if geo is not None else None
    return CompiledLayer(spec=spec, program=prog, input_matrix=A,
                         weight_matrix=B, requant_shift=s_conv,
                         keep_rows=None, out_h=out_h, out_w=out_w,
                         ref_output_matrix=truncate_int8(final),
                         residual_matrix=R, residual_shift=s_add)


def compile_layer(spec: LayerSpec, inp: np.ndarray, *,
                  cfg: Optional[VTAConfig] = None,
                  allocator: Optional[DramAllocator] = None,
                  residual: Optional[np.ndarray] = None,
                  schedule: str = "serialized") -> CompiledLayer:
    """Compile one layer (Fig. 11) down to a :class:`VTAProgram`.

    For residual layers (``spec.residual_add``) pass the skip activation
    — the semantic int8 output of the earlier layer — as ``residual``; it
    becomes the program's second ACC operand, merged on the VTA."""
    cfg = cfg or vta_default()
    bs = cfg.block_size
    A, B, geo = layer_matrices(spec, inp)
    if spec.residual_add:
        return _compile_residual_layer(spec, A, B, geo, residual, cfg,
                                       allocator, schedule=schedule)
    if residual is not None:
        raise CompileError(
            "residual operand passed to a layer without residual_add",
            layer=spec.name, constraint="residual-unexpected-operand")
    M, K = A.shape
    N = B.shape[1]

    # ---- pooling plan (indices in matrix-row space) ----
    pool_plan = pool_plan_for(spec, geo)

    # ---- static requant shift (+ overflow check) ----
    acc_pre_shift = reference_layer_acc(A, B, spec.bias, spec.relu, pool_plan)
    pool_div = pool_divisor(pool_plan)
    shift = (spec.requant_shift if spec.requant_shift is not None
             else choose_requant_shift(acc_pre_shift, already_shifted=pool_div))
    final = acc_pre_shift >> (pool_div + shift)
    if np.abs(final).max(initial=0) > 127:
        raise CompileError(
            f"requant shift {shift} leaves values outside int8 — increase "
            f"requant_shift", layer=spec.name,
            constraint="requant-int8-range")

    # ---- ALU program over ACC vectors (block-major indices) ----
    pad_h = should_pad_height(A)
    row_height = bs if pad_h else M
    beta = pad_to_multiple(N, bs) // bs
    alu_ops: List[object] = []
    if spec.relu:
        alu_ops.append(AluImmOp.relu())
    if pool_plan is not None:
        pool_op = isa.AluOp.MAX if pool_plan.mode == "max" else isa.AluOp.ADD
        # One AluPairOp per dependency level: 2×2 windows are one flat
        # independent set; the GAP tree emits one op per round so every
        # instruction's (dst, src) lattice stays disjoint (vectorisable)
        # while the read-after-write chain lives *between* instructions.
        rounds = pool_plan.rounds or (pool_plan.add_pairs,)
        for round_pairs in rounds:
            pairs = []
            for dst, src in round_pairs:
                for j in range(beta):
                    pairs.append((_vec_index(dst, j, beta, row_height),
                                  _vec_index(src, j, beta, row_height)))
            alu_ops.append(AluPairOp(pool_op, tuple(pairs)))
        total_shift = pool_div + shift
        if total_shift > 0:
            idx = []
            for r in pool_plan.keep_rows:
                for j in range(beta):
                    idx.append(_vec_index(r, j, beta, row_height))
            alu_ops.append(AluIndexedImmOp(isa.AluOp.SHR, total_shift,
                                           tuple(idx)))
    elif shift > 0:
        alu_ops.append(AluImmOp.shr(shift))

    prog = compile_matmul(A, B, bias=spec.bias, alu_ops=alu_ops, cfg=cfg,
                          name=spec.name, allocator=allocator,
                          schedule=schedule)

    # ---- reference post-reshape output matrix (int8) ----
    ref = truncate_int8(final)

    keep = pool_plan.keep_rows if pool_plan is not None else None
    out_h = out_w = None
    if geo is not None:
        out_h = pool_plan.out_h if pool_plan else geo.out_h
        out_w = pool_plan.out_w if pool_plan else geo.out_w
    return CompiledLayer(spec=spec, program=prog, input_matrix=A,
                         weight_matrix=B, requant_shift=shift,
                         keep_rows=keep, out_h=out_h, out_w=out_w,
                         ref_output_matrix=ref)


def verify_layer(layer: CompiledLayer, *, backend: str = "oracle"):
    """Run one compiled layer's program on the chosen simulator backend and
    assert it reproduces the compiler's expected OUT region.  Returns the
    :class:`~repro.core.simulator.SimReport`."""
    from .simulator import verify_program
    return verify_program(layer.program, backend=backend)


def decode_layer_output(layer: CompiledLayer, out_matrix: np.ndarray
                        ) -> np.ndarray:
    """§4.2 host reshaping, stage (i)+(ii) entry: from the decoded (M, N)
    VTA output matrix to the layer's *semantic* output.

    conv → ``(1, F, H', W')`` tensor (pooled rows extracted first);
    fc   → ``(rows, F)`` matrix.
    """
    if layer.keep_rows is not None:
        out_matrix = out_matrix[list(layer.keep_rows)]
    if layer.spec.kind == "conv":
        return mat2tensor(out_matrix, layer.out_h, layer.out_w)
    return out_matrix
