"""Paged DRAM allocator with Def.-1 logical addressing (paper §2.2).

The allocator reproduces the TVM allocation discipline the paper adopts as
its reference:

* the DRAM region assigned to the VTA starts at ``offset``;
* memory is managed in 4 KiB pages;
* **every** allocation advances the pointer to the start of the next page —
  even when the current page is untouched (Fig. 2: the very first 256-byte
  allocation lands on page 1, not page 0);
* allocations are physically contiguous;
* ``log_addr = (phy_addr - offset) // (precision × nb_elem)``  (Def. 1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class Region:
    """One allocated DRAM region holding ``count`` structures of
    ``struct_bytes`` each (= precision × nb_elem of Def. 1)."""

    name: str
    kind: str              # inp | wgt | acc | out | uop | insn
    phys_addr: int
    struct_bytes: int
    count: int

    @property
    def nbytes(self) -> int:
        return self.struct_bytes * self.count

    @property
    def end(self) -> int:
        return self.phys_addr + self.nbytes

    def logical_addr(self, offset: int = 0) -> int:
        """Def. 1: logical address of the first structure in the region."""
        return (self.phys_addr - offset) // self.struct_bytes

    def logical_of(self, index: int, offset: int = 0) -> int:
        if not 0 <= index < self.count:
            raise IndexError(f"structure {index} out of range for {self.name}")
        return self.logical_addr(offset) + index


class DramAllocator:
    """Fresh-page bump allocator (paper §2.2 / Fig. 2)."""

    def __init__(self, offset: int = 0, page_bytes: int = 4096):
        if page_bytes <= 0 or page_bytes & (page_bytes - 1):
            raise ValueError("page_bytes must be a positive power of two")
        self.offset = offset
        self.page_bytes = page_bytes
        self._ptr = offset          # next unexamined byte
        self.regions: List[Region] = []
        self._by_name: Dict[str, Region] = {}

    def _next_page(self, addr: int) -> int:
        """Start of the page strictly after ``addr``'s page.

        Fig. 2 semantics: the pointer always advances to the *next* page
        boundary before allocating, even if ``addr`` is already aligned.
        """
        rel = addr - self.offset
        return self.offset + (rel // self.page_bytes + 1) * self.page_bytes

    def alloc(self, name: str, kind: str, struct_bytes: int, count: int) -> Region:
        if count < 0 or struct_bytes <= 0:
            raise ValueError("bad allocation request")
        if name in self._by_name:
            raise ValueError(f"duplicate region name {name!r}")
        addr = self._next_page(self._ptr)
        # Def.-1 exactness: logical addresses are ⌊(phy−offset)/struct⌋, so
        # the region start must be struct-aligned (relative to the offset).
        # For the paper's profile every struct size divides the 4 KiB page
        # and this is a no-op; the TPU profile's 16 KiB WGT blocks exceed a
        # page and need the extra alignment (DESIGN.md §2).
        rel = addr - self.offset
        if rel % struct_bytes:
            rel = (rel // struct_bytes + 1) * struct_bytes
            addr = self.offset + rel
        region = Region(name=name, kind=kind, phys_addr=addr,
                        struct_bytes=struct_bytes, count=count)
        self._ptr = addr + region.nbytes
        self.regions.append(region)
        self._by_name[name] = region
        return region

    def __getitem__(self, name: str) -> Region:
        return self._by_name[name]

    def get(self, name: str) -> Optional[Region]:
        return self._by_name.get(name)

    @property
    def total_bytes(self) -> int:
        """Bytes from the offset through the end of the last region."""
        return self._ptr - self.offset

    def image_size(self) -> int:
        """Size of a DRAM image that covers every region (page-rounded)."""
        pages = (self.total_bytes + self.page_bytes - 1) // self.page_bytes
        return max(1, pages) * self.page_bytes
