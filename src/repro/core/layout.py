"""Data definition stage: padding, splitting, binarisation (paper §3.2).

All functions are pure numpy — this is host-side compiler code (the paper's
certification argument depends on it staying simple and traceable).  The
inverse transformations (``unsplit``/``unpad``/decode) implement the
host-side reshaping used for layer chaining (§4.2).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


def pad_to_multiple(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple


def truncate_int8(x: np.ndarray) -> np.ndarray:
    """The ACC→OUT truncation (§2.1): keep the low 8 bits, reinterpreted
    as int8.  The single definition of the idiom — the simulators' commit,
    the layer references and the model references all route through it.
    (A C-style integer downcast keeps exactly the low byte, so this is the
    former ``(x & 0xFF).astype(uint8)`` in one pass.)"""
    return np.asarray(x).astype(np.uint8).view(np.int8)


def requant_int8(x: np.ndarray, *, saturate: bool = False) -> np.ndarray:
    """Post-SHR ACC→OUT narrowing under the device's semantics: wrap
    (:func:`truncate_int8`) by default, clip with ``saturate=True`` —
    the same two modes the simulators expose.  The single definition
    shared by execution *and* calibration (DESIGN.md §Quantization):
    calibration advancing its images through any other narrowing would
    choose shifts for a machine that does not exist."""
    if saturate:
        return np.clip(np.asarray(x), -128, 127).astype(np.int8)
    return truncate_int8(x)


def matrix_padding(mat: np.ndarray, block_size: int, *,
                   pad_height: bool = True) -> np.ndarray:
    """Zero-pad ``mat`` on the right/bottom to ``block_size`` multiples.

    §3.2: WGT matrices pad both dimensions; INP/ACC are vector sets, so only
    the width is *constrained*, but heights are "(generally)" padded too as
    it simplifies instruction generation.  The one exception — reproducing
    the paper's LeNet-5 loop counts — is a single-row matrix (batch-1 FC
    input), which stays a single vector row (``pad_height=False``).
    """
    if mat.ndim != 2:
        raise ValueError("matrix_padding expects a 2-D array")
    h, w = mat.shape
    new_w = pad_to_multiple(w, block_size)
    new_h = pad_to_multiple(h, block_size) if pad_height else h
    if (new_h, new_w) == (h, w):
        return mat.copy()
    out = np.zeros((new_h, new_w), dtype=mat.dtype)
    out[:h, :w] = mat
    return out


def should_pad_height(mat: np.ndarray) -> bool:
    """The paper's "(generally)" rule, as reverse-engineered from the §5.1
    loop counts: multi-row matrices are height-padded (LP_IN = block_size);
    single-row matrices are kept as one vector row (LP_IN = 1)."""
    return mat.shape[0] > 1


@dataclasses.dataclass(frozen=True)
class SplitMatrix:
    """Result of ``matrix_splitting``: row-major list of blocks.

    ``block_rows``/``block_cols`` are the block-grid dims (α×λ for INP, λ×β
    for WGT).  ``row_height`` is the height of each block row — equal to
    ``block_size`` except for unpadded single-row matrices (height 1).
    """

    blocks: List[np.ndarray]
    block_rows: int
    block_cols: int
    row_height: int
    block_size: int

    @property
    def padded_shape(self) -> Tuple[int, int]:
        return (self.block_rows * self.row_height,
                self.block_cols * self.block_size)

    def block(self, i: int, j: int) -> np.ndarray:
        return self.blocks[i * self.block_cols + j]


def matrix_splitting(mat: np.ndarray, block_size: int) -> SplitMatrix:
    """§3.2: split a padded matrix into ``block_size``-wide blocks, row-major.

    Blocks are square except when the matrix is a single unpadded vector row
    (height < block_size), in which case each "block" is ``h × block_size``.
    """
    h, w = mat.shape
    if w % block_size:
        raise ValueError(f"width {w} not a multiple of block_size {block_size}")
    row_height = block_size if h % block_size == 0 else h
    if h % row_height:
        raise ValueError(f"height {h} not splittable into rows of {row_height}")
    block_rows = h // row_height
    block_cols = w // block_size
    blocks = [
        np.ascontiguousarray(mat[i * row_height:(i + 1) * row_height,
                                 j * block_size:(j + 1) * block_size])
        for i in range(block_rows) for j in range(block_cols)
    ]
    return SplitMatrix(blocks=blocks, block_rows=block_rows,
                       block_cols=block_cols, row_height=row_height,
                       block_size=block_size)


def matrix_unsplit(split: SplitMatrix) -> np.ndarray:
    """Inverse of ``matrix_splitting`` (layer-chaining reshape, §4.2)."""
    h, w = split.padded_shape
    out = np.zeros((h, w), dtype=split.blocks[0].dtype)
    for i in range(split.block_rows):
        for j in range(split.block_cols):
            out[i * split.row_height:(i + 1) * split.row_height,
                j * split.block_size:(j + 1) * split.block_size] = split.block(i, j)
    return out


def remove_padding(mat: np.ndarray, orig_shape: Tuple[int, int]) -> np.ndarray:
    h, w = orig_shape
    return np.ascontiguousarray(mat[:h, :w])


# ---------------------------------------------------------------------------
# Binarisation (§3.2)
# ---------------------------------------------------------------------------

def binarize_blocks(split: SplitMatrix, dtype: np.dtype, *,
                    transpose: bool = False) -> bytes:
    """Encode blocks to little-endian bytes in list order (left→right,
    top→bottom).  WGT blocks are stored transposed (``transpose=True``),
    the block *order* is unchanged (§3.2)."""
    dtype = np.dtype(dtype).newbyteorder("<")
    chunks = []
    for blk in split.blocks:
        data = blk.T if transpose else blk
        chunks.append(np.ascontiguousarray(data).astype(dtype, copy=False).tobytes())
    return b"".join(chunks)


def debinarize_blocks(raw: bytes, dtype: np.dtype, block_rows: int,
                      block_cols: int, row_height: int, block_size: int, *,
                      transpose: bool = False) -> SplitMatrix:
    """Inverse of ``binarize_blocks`` — used when decoding VTA output for
    layer chaining (§4.2 stage (i))."""
    dtype = np.dtype(dtype).newbyteorder("<")
    shape = (block_size, row_height) if transpose else (row_height, block_size)
    per_block = shape[0] * shape[1] * dtype.itemsize
    expected = per_block * block_rows * block_cols
    if len(raw) != expected:
        raise ValueError(f"binary size {len(raw)} != expected {expected}")
    blocks = []
    for k in range(block_rows * block_cols):
        blk = np.frombuffer(raw[k * per_block:(k + 1) * per_block],
                            dtype=dtype).reshape(shape)
        blocks.append(blk.T.copy() if transpose else blk.copy())
    return SplitMatrix(blocks=blocks, block_rows=block_rows,
                       block_cols=block_cols, row_height=row_height,
                       block_size=block_size)


def matrix_to_binary(mat: np.ndarray, block_size: int, dtype: np.dtype, *,
                     transpose: bool = False,
                     pad_height: bool | None = None) -> Tuple[bytes, SplitMatrix]:
    """Full data-definition pipeline for one matrix: pad → split → binarise."""
    if pad_height is None:
        pad_height = should_pad_height(mat)
    padded = matrix_padding(mat, block_size, pad_height=pad_height)
    split = matrix_splitting(padded, block_size)
    return binarize_blocks(split, dtype, transpose=transpose), split


def batch_matrix_to_binary(mats: np.ndarray, block_size: int,
                           dtype: np.dtype) -> np.ndarray:
    """Batched pad → split → binarise: ``(B, M, K)`` → ``(B, nbytes)`` uint8.

    Row ``b`` is byte-identical to ``matrix_to_binary(mats[b], ...)[0]`` —
    all images share one geometry, so the block split is a single reshape/
    transpose over the stack instead of B × per-block Python loops.  This
    is the INP-staging kernel of the serving path (DESIGN.md §Batching);
    the WGT-side ``transpose`` variant is not needed there (weights are
    staged once at compile time) and is intentionally not replicated.
    """
    if mats.ndim != 3:
        raise ValueError(f"expected a (B, M, K) stack, got {mats.shape}")
    b, h, w = mats.shape
    # all images share one geometry — derive it through the single-image
    # helpers (one representative pass) so the rules can never drift
    split0 = matrix_splitting(
        matrix_padding(mats[0], block_size,
                       pad_height=should_pad_height(mats[0])), block_size)
    new_h, new_w = split0.padded_shape
    row_height, br, bc = (split0.row_height, split0.block_rows,
                          split0.block_cols)
    padded = np.zeros((b, new_h, new_w), dtype=mats.dtype)
    padded[:, :h, :w] = mats
    blocks = padded.reshape(b, br, row_height, bc, block_size)
    blocks = blocks.transpose(0, 1, 3, 2, 4)      # block-major, row-major
    dt = np.dtype(dtype).newbyteorder("<")
    raw = np.ascontiguousarray(blocks).astype(dt, copy=False)
    return raw.view(np.uint8).reshape(b, -1)
