"""VTA instruction-set architecture: bit-level encode/decode.

Faithful to the VTA hardware spec (tvm/vta ``hw_spec.h``) referenced by the
paper (§2.3): 128-bit CISC instructions packed as two little-endian 64-bit
words, and 32-bit micro-ops (UOPs).  All field widths below are the VTA
defaults; the paper's Fig. 3/4 show the GeMM instruction and UOP layouts.

Instruction classes
-------------------
* ``MemInsn``  — LOAD / STORE (DRAM <-> SRAM, 2-D strided access + padding)
* ``GemInsn``  — TensorGemm (Algorithm 1 of the paper)
* ``AluInsn``  — TensorAlu  (element-wise MIN/MAX/ADD/SHR, optional immediate)
* ``FinishInsn`` — termination marker

Every instruction carries the 4 dependency flags (``DEPT_FLAG`` of §2.3)
used to synchronise the Fetch/Load/Compute/Store modules.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import ClassVar, Dict, List, Sequence, Tuple

INSN_BYTES = 16   # 128-bit instructions
UOP_BYTES = 4     # 32-bit UOPs


class Opcode(enum.IntEnum):
    LOAD = 0
    STORE = 1
    GEMM = 2
    FINISH = 3
    ALU = 4


class MemId(enum.IntEnum):
    """SRAM buffer identifiers for LOAD/STORE ``memory_type``."""

    UOP = 0
    WGT = 1
    INP = 2
    ACC = 3
    OUT = 4


class AluOp(enum.IntEnum):
    MIN = 0
    MAX = 1
    ADD = 2
    SHR = 3   # arithmetic shift right


# ---------------------------------------------------------------------------
# Bit packing helpers
# ---------------------------------------------------------------------------

def _pack(fields: Sequence[Tuple[int, int]]) -> int:
    """Pack ``(value, width)`` pairs LSB-first into one integer."""
    word = 0
    pos = 0
    for value, width in fields:
        if value < 0 or value >= (1 << width):
            raise ValueError(f"field value {value} does not fit in {width} bits")
        word |= value << pos
        pos += width
    return word


def _unpack(word: int, widths: Sequence[int]) -> List[int]:
    out = []
    pos = 0
    for width in widths:
        out.append((word >> pos) & ((1 << width) - 1))
        pos += width
    return out


@dataclasses.dataclass
class DepFlags:
    """The 4-bit DEPT_FLAG of §2.3: producer/consumer queue tokens."""

    pop_prev: int = 0
    pop_next: int = 0
    push_prev: int = 0
    push_next: int = 0

    def bits(self) -> List[Tuple[int, int]]:
        return [(self.pop_prev, 1), (self.pop_next, 1),
                (self.push_prev, 1), (self.push_next, 1)]

    @classmethod
    def from_bits(cls, vals: Sequence[int]) -> "DepFlags":
        return cls(*vals)


# ---------------------------------------------------------------------------
# Instructions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MemInsn:
    """LOAD/STORE: move ``y_size`` rows of ``x_size`` elements (stride
    ``x_stride``) between DRAM (logical ``dram_base``) and SRAM
    (``sram_base``), with optional zero-padding on either side."""

    opcode: Opcode
    memory_type: MemId
    sram_base: int
    dram_base: int
    y_size: int
    x_size: int
    x_stride: int
    y_pad_0: int = 0
    y_pad_1: int = 0
    x_pad_0: int = 0
    x_pad_1: int = 0
    dep: DepFlags = dataclasses.field(default_factory=DepFlags)

    # word0: opcode(3) dep(4) memory_type(3) sram_base(16) dram_base(32)
    # word1: y_size(16) x_size(16) x_stride(16) y_pad_0(4) y_pad_1(4)
    #        x_pad_0(4) x_pad_1(4)
    W0: ClassVar[List[int]] = [3, 1, 1, 1, 1, 3, 16, 32]
    W1: ClassVar[List[int]] = [16, 16, 16, 4, 4, 4, 4]

    def encode(self) -> bytes:
        w0 = _pack([(int(self.opcode), 3)] + self.dep.bits() +
                   [(int(self.memory_type), 3), (self.sram_base, 16),
                    (self.dram_base, 32)])
        w1 = _pack([(self.y_size, 16), (self.x_size, 16), (self.x_stride, 16),
                    (self.y_pad_0, 4), (self.y_pad_1, 4),
                    (self.x_pad_0, 4), (self.x_pad_1, 4)])
        return w0.to_bytes(8, "little") + w1.to_bytes(8, "little")

    @classmethod
    def decode(cls, raw: bytes) -> "MemInsn":
        w0 = int.from_bytes(raw[:8], "little")
        w1 = int.from_bytes(raw[8:], "little")
        f0 = _unpack(w0, cls.W0)
        f1 = _unpack(w1, cls.W1)
        return cls(opcode=Opcode(f0[0]), dep=DepFlags.from_bits(f0[1:5]),
                   memory_type=MemId(f0[5]), sram_base=f0[6], dram_base=f0[7],
                   y_size=f1[0], x_size=f1[1], x_stride=f1[2],
                   y_pad_0=f1[3], y_pad_1=f1[4], x_pad_0=f1[5], x_pad_1=f1[6])


@dataclasses.dataclass
class GemInsn:
    """TensorGemm instruction (paper Fig. 3 / Algorithm 1).

    ``iter_out``/``iter_in`` are LP_OUT/LP_IN; the six factors are the
    address increments of Algorithm 1 lines 5/7/8 (ACC/INP/WGT × OUT/IN).
    """

    reset: int = 0
    uop_bgn: int = 0
    uop_end: int = 0
    iter_out: int = 1
    iter_in: int = 1
    acc_factor_out: int = 0   # dst_factor_out
    acc_factor_in: int = 0    # dst_factor_in
    inp_factor_out: int = 0   # src_factor_out
    inp_factor_in: int = 0    # src_factor_in
    wgt_factor_out: int = 0
    wgt_factor_in: int = 0
    dep: DepFlags = dataclasses.field(default_factory=DepFlags)

    # word0: opcode(3) dep(4) reset(1) uop_bgn(13) uop_end(14)
    #        iter_out(14) iter_in(14)
    # word1: dst_out(11) dst_in(11) src_out(11) src_in(11) wgt_out(10) wgt_in(10)
    W0: ClassVar[List[int]] = [3, 1, 1, 1, 1, 1, 13, 14, 14, 14]
    W1: ClassVar[List[int]] = [11, 11, 11, 11, 10, 10]

    opcode: ClassVar[Opcode] = Opcode.GEMM

    def encode(self) -> bytes:
        w0 = _pack([(int(Opcode.GEMM), 3)] + self.dep.bits() +
                   [(self.reset, 1), (self.uop_bgn, 13), (self.uop_end, 14),
                    (self.iter_out, 14), (self.iter_in, 14)])
        w1 = _pack([(self.acc_factor_out, 11), (self.acc_factor_in, 11),
                    (self.inp_factor_out, 11), (self.inp_factor_in, 11),
                    (self.wgt_factor_out, 10), (self.wgt_factor_in, 10)])
        return w0.to_bytes(8, "little") + w1.to_bytes(8, "little")

    @classmethod
    def decode(cls, raw: bytes) -> "GemInsn":
        w0 = int.from_bytes(raw[:8], "little")
        w1 = int.from_bytes(raw[8:], "little")
        f0 = _unpack(w0, cls.W0)
        f1 = _unpack(w1, cls.W1)
        return cls(dep=DepFlags.from_bits(f0[1:5]), reset=f0[5],
                   uop_bgn=f0[6], uop_end=f0[7], iter_out=f0[8], iter_in=f0[9],
                   acc_factor_out=f1[0], acc_factor_in=f1[1],
                   inp_factor_out=f1[2], inp_factor_in=f1[3],
                   wgt_factor_out=f1[4], wgt_factor_in=f1[5])

    @property
    def loop_count(self) -> int:
        """GeMM loops executed by this instruction (the §5.1 metric)."""
        return self.iter_out * self.iter_in * max(0, self.uop_end - self.uop_bgn)


@dataclasses.dataclass
class AluInsn:
    """TensorAlu instruction: element-wise ops over ACC vectors."""

    alu_opcode: AluOp = AluOp.ADD
    reset: int = 0
    uop_bgn: int = 0
    uop_end: int = 0
    iter_out: int = 1
    iter_in: int = 1
    dst_factor_out: int = 0
    dst_factor_in: int = 0
    src_factor_out: int = 0
    src_factor_in: int = 0
    use_imm: int = 0
    imm: int = 0
    dep: DepFlags = dataclasses.field(default_factory=DepFlags)

    W0: ClassVar[List[int]] = [3, 1, 1, 1, 1, 1, 13, 14, 14, 14]
    W1: ClassVar[List[int]] = [11, 11, 11, 11, 2, 1, 16]

    opcode: ClassVar[Opcode] = Opcode.ALU

    def encode(self) -> bytes:
        imm16 = self.imm & 0xFFFF  # two's complement 16-bit immediate
        w0 = _pack([(int(Opcode.ALU), 3)] + self.dep.bits() +
                   [(self.reset, 1), (self.uop_bgn, 13), (self.uop_end, 14),
                    (self.iter_out, 14), (self.iter_in, 14)])
        w1 = _pack([(self.dst_factor_out, 11), (self.dst_factor_in, 11),
                    (self.src_factor_out, 11), (self.src_factor_in, 11),
                    (int(self.alu_opcode), 2), (self.use_imm, 1), (imm16, 16)])
        return w0.to_bytes(8, "little") + w1.to_bytes(8, "little")

    @classmethod
    def decode(cls, raw: bytes) -> "AluInsn":
        w0 = int.from_bytes(raw[:8], "little")
        w1 = int.from_bytes(raw[8:], "little")
        f0 = _unpack(w0, cls.W0)
        f1 = _unpack(w1, cls.W1)
        imm = f1[6]
        if imm >= 1 << 15:   # sign-extend
            imm -= 1 << 16
        return cls(dep=DepFlags.from_bits(f0[1:5]), reset=f0[5],
                   uop_bgn=f0[6], uop_end=f0[7], iter_out=f0[8], iter_in=f0[9],
                   dst_factor_out=f1[0], dst_factor_in=f1[1],
                   src_factor_out=f1[2], src_factor_in=f1[3],
                   alu_opcode=AluOp(f1[4]), use_imm=f1[5], imm=imm)

    @property
    def loop_count(self) -> int:
        return self.iter_out * self.iter_in * max(0, self.uop_end - self.uop_bgn)


@dataclasses.dataclass
class FinishInsn:
    dep: DepFlags = dataclasses.field(default_factory=DepFlags)
    opcode: ClassVar[Opcode] = Opcode.FINISH

    def encode(self) -> bytes:
        w0 = _pack([(int(Opcode.FINISH), 3)] + self.dep.bits())
        return w0.to_bytes(8, "little") + (0).to_bytes(8, "little")

    @classmethod
    def decode(cls, raw: bytes) -> "FinishInsn":
        w0 = int.from_bytes(raw[:8], "little")
        f0 = _unpack(w0, [3, 1, 1, 1, 1])
        return cls(dep=DepFlags.from_bits(f0[1:]))


Instruction = (MemInsn, GemInsn, AluInsn, FinishInsn)


def decode_insn(raw: bytes):
    """Decode one 128-bit instruction by opcode."""
    opcode = Opcode(int.from_bytes(raw[:8], "little") & 0b111)
    if opcode in (Opcode.LOAD, Opcode.STORE):
        return MemInsn.decode(raw)
    if opcode == Opcode.GEMM:
        return GemInsn.decode(raw)
    if opcode == Opcode.ALU:
        return AluInsn.decode(raw)
    return FinishInsn.decode(raw)


def encode_stream(insns) -> bytes:
    return b"".join(i.encode() for i in insns)


def decode_stream(raw: bytes):
    if len(raw) % INSN_BYTES:
        raise ValueError("instruction stream not a multiple of 16 bytes")
    return [decode_insn(raw[i:i + INSN_BYTES]) for i in range(0, len(raw), INSN_BYTES)]


# ---------------------------------------------------------------------------
# UOPs (paper Fig. 4 / Fig. 8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Uop:
    """32-bit micro-op: initial SRAM logical addresses for ACC/INP/WGT.

    For ALU instructions the fields are reused as (dst_idx, src_idx, -).
    """

    acc_idx: int = 0
    inp_idx: int = 0
    wgt_idx: int = 0

    W: ClassVar[List[int]] = [11, 11, 10]

    def encode(self) -> bytes:
        return _pack([(self.acc_idx, 11), (self.inp_idx, 11),
                      (self.wgt_idx, 10)]).to_bytes(4, "little")

    @classmethod
    def decode(cls, raw: bytes) -> "Uop":
        return cls(*_unpack(int.from_bytes(raw, "little"), cls.W))


def encode_uops(uops) -> bytes:
    return b"".join(u.encode() for u in uops)


def decode_uops(raw: bytes):
    if len(raw) % UOP_BYTES:
        raise ValueError("uop stream not a multiple of 4 bytes")
    return [Uop.decode(raw[i:i + UOP_BYTES]) for i in range(0, len(raw), UOP_BYTES)]
