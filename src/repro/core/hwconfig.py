"""VTA hardware configuration (paper §2.1).

The VTA is parameterised by ``block_size`` (default 16): INP/ACC/OUT are
vectors of ``block_size`` elements, WGT is a ``block_size × block_size``
matrix.  INP/WGT/OUT are int8, ACC is int32.  SRAM buffer capacities are the
VTA defaults quoted in §3.3: 2048 INP vectors, 1024 WGT matrices, 2048 ACC
vectors.

Two profiles ship with the framework:

* ``vta_default()``   — the paper's FPGA configuration (block 16), used for
  bit-exact reproduction of the paper's LeNet-5 results.
* ``vta_tpu()``       — the TPU-native "VTA-X" profile (block 128, MXU
  aligned), used by the Pallas kernel path (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class VTAConfig:
    block_size: int = 16
    # SRAM capacities, in units of data *structures* (vectors / matrices).
    inp_buff_vectors: int = 2048
    wgt_buff_matrices: int = 1024
    acc_buff_vectors: int = 2048
    out_buff_vectors: int = 2048
    uop_buff_entries: int = 8192
    # DRAM paging (§2.2)
    page_bytes: int = 4096
    dram_offset: int = 0
    # Data types (§2.1)
    inp_dtype: np.dtype = np.dtype(np.int8)
    wgt_dtype: np.dtype = np.dtype(np.int8)
    out_dtype: np.dtype = np.dtype(np.int8)
    acc_dtype: np.dtype = np.dtype(np.int32)

    # ------------------------------------------------------------------
    # Structure geometry (Def. 1 terms)
    # ------------------------------------------------------------------
    @property
    def inp_elem_bytes(self) -> int:
        """Bytes of one INP vector (= precision × nb_elem of Def. 1)."""
        return self.block_size * self.inp_dtype.itemsize

    @property
    def wgt_elem_bytes(self) -> int:
        return self.block_size * self.block_size * self.wgt_dtype.itemsize

    @property
    def acc_elem_bytes(self) -> int:
        return self.block_size * self.acc_dtype.itemsize

    @property
    def out_elem_bytes(self) -> int:
        return self.block_size * self.out_dtype.itemsize

    @property
    def uop_elem_bytes(self) -> int:
        return 4

    @property
    def insn_elem_bytes(self) -> int:
        return 16

    def elem_bytes(self, mem: str) -> int:
        return {
            "inp": self.inp_elem_bytes,
            "wgt": self.wgt_elem_bytes,
            "acc": self.acc_elem_bytes,
            "out": self.out_elem_bytes,
            "uop": self.uop_elem_bytes,
            "insn": self.insn_elem_bytes,
        }[mem]

    def buffer_capacity(self, mem: str) -> int:
        return {
            "inp": self.inp_buff_vectors,
            "wgt": self.wgt_buff_matrices,
            "acc": self.acc_buff_vectors,
            "out": self.out_buff_vectors,
            "uop": self.uop_buff_entries,
        }[mem]


def vta_default() -> VTAConfig:
    """The paper's FPGA configuration (block_size=16)."""
    return VTAConfig()


def vta_tpu() -> VTAConfig:
    """TPU-native profile: 128×128 int8 blocks (MXU aligned), VMEM-scaled
    buffers (16 MiB VMEM per TensorCore >> the FPGA's SRAM)."""
    return VTAConfig(
        block_size=128,
        inp_buff_vectors=8192,
        wgt_buff_matrices=512,
        acc_buff_vectors=8192,
        out_buff_vectors=8192,
        uop_buff_entries=8192,
    )
