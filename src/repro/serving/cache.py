"""KV-cache / recurrent-state containers for serving.

Three cache kinds, chosen per layer from the architecture's schedule
(DESIGN.md §4):

* **dense**    — (B, KV, S_max, D) k/v, *sequence-sharded over the model
  axis* ("seq") so a 32k×128-batch cache fits a pod (batch shards over
  ``data``, sequence over ``model``); used by global-attention layers.
* **windowed** — (B, KV, W, D) ring buffer with absolute-position slots;
  used by SWA / local-attention layers (memory is O(window), which is what
  makes ``long_500k`` runnable for mixtral/gemma3 local layers).
* **recurrent**— Mamba (conv tail + SSM state) or RWKV-6 (shift + WKV
  state): O(1) in sequence length.

Caches are built with the same (pattern × repeats) stacking as the model
parameters so the decode step scans over layers.

Legacy note: these are the seed's *LM* serving caches (legacy CI tier),
consumed by :mod:`repro.serving.engine`.  The VTA CNN serving subsystem
is :mod:`repro.serving.vta` (DESIGN.md §Serving) — stateless per-request
inference over compiled plans, no KV caches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.mamba import mamba_init_state
from repro.models.rwkv6 import rwkv6_init_state
from repro.models.transformer import find_period, schedule_items


def layer_cache_kind(cfg: ModelConfig, kind: str) -> str:
    if kind == "attn":
        return "dense"
    if kind in ("attn_local", "attn_swa"):
        return "windowed"
    return kind                               # mamba | rwkv6


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    ck = layer_cache_kind(cfg, kind)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    if ck == "dense":
        return {"k": jnp.zeros((batch, kv, max_seq, hd), dtype),
                "v": jnp.zeros((batch, kv, max_seq, hd), dtype)}
    if ck == "windowed":
        w = min(cfg.local_window, max_seq)
        return {"k": jnp.zeros((batch, kv, w, hd), dtype),
                "v": jnp.zeros((batch, kv, w, hd), dtype),
                "slot_pos": jnp.full((w,), -1, jnp.int32)}
    if ck == "mamba":
        conv, h = mamba_init_state(cfg, batch, dtype)
        return {"conv": conv, "h": h}
    if ck == "rwkv6":
        return rwkv6_init_state(cfg, batch, dtype)
    raise ValueError(kind)


def cache_logical(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    """Logical sharding of each cache leaf (resolved by launch code)."""
    ck = layer_cache_kind(cfg, kind)
    if ck == "dense":
        return {"k": ("batch", None, "seq", None),
                "v": ("batch", None, "seq", None)}
    if ck == "windowed":
        return {"k": ("batch", None, None, None),
                "v": ("batch", None, None, None),
                "slot_pos": (None,)}
    if ck == "mamba":
        return {"conv": ("batch", None, "tp"), "h": ("batch", "tp", None)}
    if ck == "rwkv6":
        return {"shift": ("batch", None), "wkv": ("batch", None, None, None),
                "cm_shift": ("batch", None)}
    raise ValueError(kind)


@dataclasses.dataclass
class CacheTree:
    """blocks: list (pattern position) of stacked caches (leading repeats
    dim); tail: list of per-layer caches.  Mirrors params structure."""

    blocks: List[Any]
    tail: List[Any]


def _stack(trees: List[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, *, enc_out: bool = False) -> CacheTree:
    items = schedule_items(cfg)
    if cfg.scan_layers:
        p, reps, tail = find_period(items)
    else:
        p, reps, tail = len(items), 1, 0
    if reps > 1:
        blocks = [
            _stack([init_layer_cache(cfg, items[pos][0], batch, max_seq,
                                     dtype)
                    for _ in range(reps)])
            for pos in range(p)]
        tail_caches = [init_layer_cache(cfg, kind, batch, max_seq, dtype)
                       for kind, _ in items[p * reps:]]
    else:
        blocks = []
        tail_caches = [init_layer_cache(cfg, kind, batch, max_seq, dtype)
                       for kind, _ in items]
    return CacheTree(blocks=blocks, tail=tail_caches)


def cache_logical_tree(cfg: ModelConfig) -> CacheTree:
    items = schedule_items(cfg)
    if cfg.scan_layers:
        p, reps, tail = find_period(items)
    else:
        p, reps, tail = len(items), 1, 0

    def stacked(kind):
        return jax.tree.map(lambda lg: (None,) + lg,
                            cache_logical(cfg, kind),
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                a is None or isinstance(a, str) for a in x))

    if reps > 1:
        blocks = [stacked(items[pos][0]) for pos in range(p)]
        tail = [cache_logical(cfg, kind) for kind, _ in items[p * reps:]]
    else:
        blocks = []
        tail = [cache_logical(cfg, kind) for kind, _ in items]
    return CacheTree(blocks=blocks, tail=tail)


jax.tree_util.register_dataclass(
    CacheTree, data_fields=["blocks", "tail"], meta_fields=[])
