"""Seeded deterministic load generation (DESIGN.md §Serving).

Two arrival processes, both pure functions of their seed so the
virtual-clock simulation replays bit-identically:

* **Poisson (open loop)** — exponential inter-arrival gaps at a given
  offered load in requests/second; models independent user traffic and
  is what the throughput–latency curves sweep
  (EXPERIMENTS.md §Serving-latency).
* **Closed loop** — N clients, each keeping exactly one request in
  flight and re-submitting ``think_s`` after its completion (or after a
  backpressure rejection); models a fixed client population and bounds
  concurrency by construction.

Sources speak one small interface consumed by
:func:`repro.serving.vta.simulate.simulate`: ``initial_arrivals()`` plus
``on_complete``/``on_reject`` callbacks that may schedule more arrivals,
and ``image_for(rid)`` when batches are really executed.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def poisson_arrival_times(rate_rps: float, n: int, seed: int,
                          start: float = 0.0) -> List[float]:
    """n seeded Poisson-process arrival times at ``rate_rps``."""
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    return list(start + np.cumsum(gaps))


def request_images(net, n: int, seed: int) -> List[np.ndarray]:
    """n seeded request images matching the network's compiled input
    signature (the engine's admission contract)."""
    shape, dtype = net.input_signature()
    rng = np.random.default_rng(seed)
    return [rng.integers(-64, 64, shape).astype(dtype) for _ in range(n)]


class PoissonSource:
    """Open-loop source: every arrival time is fixed up front."""

    def __init__(self, rate_rps: float, n: int, seed: int,
                 images: Optional[Sequence[np.ndarray]] = None):
        self.n = n
        self.times = poisson_arrival_times(rate_rps, n, seed)
        self.images = list(images) if images is not None else None

    def initial_arrivals(self) -> List[Tuple[float, int]]:
        return [(t, rid) for rid, t in enumerate(self.times)]

    def on_complete(self, rid: int, t: float) -> List[Tuple[float, int]]:
        return []

    def on_reject(self, rid: int, t: float) -> List[Tuple[float, int]]:
        return []        # open loop: a shed request is simply lost

    def image_for(self, rid: int) -> np.ndarray:
        if self.images is None:
            raise ValueError("PoissonSource built without images")
        return self.images[rid % len(self.images)]


class ClosedLoopSource:
    """Closed-loop source: ``clients`` requests in flight at most, each
    client re-submitting ``think_s`` after its previous request resolves,
    until ``n`` total requests have been issued."""

    def __init__(self, clients: int, n: int, *, think_s: float = 0.0,
                 stagger_s: float = 0.0, retry_s: float = 1e-3,
                 images: Optional[Sequence[np.ndarray]] = None):
        if clients < 1:
            raise ValueError(f"clients must be >= 1, got {clients}")
        if retry_s <= 0:
            # a zero-delay retry after a rejection would re-arrive into
            # the same queue state at the same virtual instant, forever
            raise ValueError(f"retry_s must be > 0, got {retry_s}")
        self.clients = clients
        self.n = n
        self.think_s = think_s
        self.stagger_s = stagger_s
        self.retry_s = retry_s
        self.images = list(images) if images is not None else None
        self.issued = 0
        self._owner: Dict[int, int] = {}       # rid -> client

    def _issue(self, client: int, t: float) -> List[Tuple[float, int]]:
        if self.issued >= self.n:
            return []
        rid = self.issued
        self.issued += 1
        self._owner[rid] = client
        return [(t, rid)]

    def initial_arrivals(self) -> List[Tuple[float, int]]:
        out: List[Tuple[float, int]] = []
        for c in range(min(self.clients, self.n)):
            out.extend(self._issue(c, c * self.stagger_s))
        return out

    def on_complete(self, rid: int, t: float) -> List[Tuple[float, int]]:
        return self._issue(self._owner[rid], t + self.think_s)

    def on_reject(self, rid: int, t: float) -> List[Tuple[float, int]]:
        """A rejected client backs off (strictly positive delay), then
        retries with a *new* request."""
        return self._issue(self._owner[rid],
                           t + max(self.think_s, self.retry_s))

    def image_for(self, rid: int) -> np.ndarray:
        if self.images is None:
            raise ValueError("ClosedLoopSource built without images")
        return self.images[rid % len(self.images)]
