"""Clock abstraction for the VTA serving engine (DESIGN.md §Serving).

Two implementations share one two-method interface (``now()`` /
``sleep_until()``):

* :class:`WallClock` — ``time.monotonic``; what the threaded
  :class:`~repro.serving.vta.engine.VTAServingEngine` runs on.
* :class:`VirtualClock` — a manually-advanced monotonic counter; what the
  discrete-event simulation (:mod:`repro.serving.vta.simulate`) and the
  seeded load generator run on, so latency traces are *hermetic*: the
  same seed produces bit-identical request traces and latency histograms
  on any machine, because no wall time ever enters the computation.
"""

from __future__ import annotations

import time


class WallClock:
    """Real monotonic time (the threaded engine's clock)."""

    def now(self) -> float:
        return time.monotonic()

    def sleep_until(self, t: float) -> None:
        delay = t - self.now()
        if delay > 0:
            time.sleep(delay)


class VirtualClock:
    """Deterministic manual-advance clock (the simulation's clock).

    ``advance_to`` enforces monotonicity — a discrete-event loop that
    tried to move time backwards has a scheduling bug, and failing loudly
    here is what keeps the determinism argument (DESIGN.md §Serving)
    sound.
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)

    def now(self) -> float:
        return self._now

    def advance_to(self, t: float) -> None:
        if t < self._now:
            raise ValueError(
                f"virtual clock cannot move backwards: at {self._now!r}, "
                f"asked to advance to {t!r}")
        self._now = float(t)

    def advance(self, dt: float) -> None:
        self.advance_to(self._now + dt)

    def sleep_until(self, t: float) -> None:
        # sleeping *is* advancing when time is virtual
        if t > self._now:
            self.advance_to(t)
