"""Async VTA serving subsystem (DESIGN.md §Serving).

The production-shaped layer over compiled
:class:`~repro.core.network_compiler.NetworkProgram` plans: a thread-safe
bounded request queue with typed backpressure, a max-batch/max-wait
dynamic batch former padding to the compiled-shape ladder, a worker pool
draining batches concurrently across the ``batched``/``pallas``
backends, per-request latency + SLO metrics, and a seeded virtual-clock
load generator + discrete-event simulation for hermetic latency curves
(EXPERIMENTS.md §Serving-latency).

Not to be confused with the seed's legacy LM serving modules
(:mod:`repro.serving.engine` / :mod:`repro.serving.cache` — transformer
prefill/decode, legacy CI tier only): VTA CNN inference deployments wire
*this* package.
"""

from .clock import VirtualClock, WallClock
from .engine import VTAServingEngine, serve_all
from .loadgen import (ClosedLoopSource, PoissonSource,
                      poisson_arrival_times, request_images)
from .metrics import RequestRecord, ServingMetrics, nearest_rank
from .policy import BatchPolicy, pad_ladder, padded_size, ready_count
from .queueing import (QueueClosed, QueueFull, RequestQueue, ServingError,
                       Ticket)
from .simulate import (ServiceModel, SimResult, calibrate_service_model,
                       simulate)

__all__ = [
    "BatchPolicy", "ClosedLoopSource", "PoissonSource", "QueueClosed",
    "QueueFull", "RequestQueue", "RequestRecord", "ServiceModel",
    "ServingError", "ServingMetrics", "SimResult", "Ticket",
    "VTAServingEngine", "VirtualClock", "WallClock",
    "calibrate_service_model", "nearest_rank", "pad_ladder",
    "padded_size", "poisson_arrival_times", "ready_count",
    "request_images", "serve_all", "simulate",
]
