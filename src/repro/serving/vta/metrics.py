"""Per-request latency + SLO accounting for the VTA serving engine.

Every served request leaves one :class:`RequestRecord` (enqueue →
dispatch → completion timestamps, formed/padded batch sizes, backend,
worker); :class:`ServingMetrics` aggregates them into the summary the
benchmarks publish (DESIGN.md §Serving, EXPERIMENTS.md §Serving-latency):
p50/p95/p99 latency, throughput, mean batch occupancy, and SLO-violation
counts.

Percentiles use the *nearest-rank* definition on the sorted latency list
— no interpolation — so a virtual-clock run's percentiles are exactly
reproducible across machines (the deterministic-replay benchmark row
compares them bit-for-bit).

``audit()`` is the self-check the CI smoke asserts empty: counter
conservation (submitted == completed + rejected + cancelled + failed +
in-flight), timestamp monotonicity per record, and an independent
recount of the SLO-violation counter.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from fractions import Fraction
from typing import Dict, List, Optional


@dataclasses.dataclass
class RequestRecord:
    """One completed request's life cycle."""

    rid: int
    enqueue_t: float
    dispatch_t: float
    complete_t: float
    batch_size: int          # real requests in the formed batch
    padded_size: int         # stack rows actually executed (ladder rung)
    backend: str
    worker: int

    @property
    def latency_s(self) -> float:
        return self.complete_t - self.enqueue_t

    @property
    def queue_wait_s(self) -> float:
        return self.dispatch_t - self.enqueue_t

    @property
    def service_s(self) -> float:
        return self.complete_t - self.dispatch_t

    def as_tuple(self):
        """Canonical comparable form (the deterministic-replay check)."""
        return (self.rid, self.enqueue_t, self.dispatch_t, self.complete_t,
                self.batch_size, self.padded_size, self.backend, self.worker)


def nearest_rank(sorted_values: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) on an ascending list:
    the element at rank ``ceil(q·n/100)`` (1-based; rank 1 for q=0).

    The ceiling is computed *exactly* over the rational ``q·n/100``
    (``fractions.Fraction``, no float product): the old
    ``int(q * n)`` truncated the product before the ceiling division,
    silently under-ranking every non-integer quantile — p99.9 of 1000
    samples read rank 999 instead of 1000.
    """
    if not sorted_values:
        raise ValueError("percentile of an empty sample")
    if not 0 <= q <= 100:
        raise ValueError(f"quantile must be in [0, 100], got {q}")
    n = len(sorted_values)
    rank = max(1, math.ceil(Fraction(q) * n / 100))
    return sorted_values[min(rank, n) - 1]


class ServingMetrics:
    """Thread-safe accumulator (one per engine / simulation run)."""

    def __init__(self, slo_s: Optional[float] = None):
        self.slo_s = slo_s
        self._lock = threading.Lock()
        self.records: List[RequestRecord] = []
        self.submitted = 0
        self.rejected = 0          # QueueFull admissions
        self.cancelled = 0         # discarded by non-draining shutdown
        self.failed = 0            # execution raised / guard unrecoverable
        self.slo_violations = 0

    # ------------------------------------------------------- recording --
    def on_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def on_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def on_cancel(self, n: int = 1) -> None:
        with self._lock:
            self.cancelled += n

    def on_fail(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def observe(self, record: RequestRecord) -> None:
        with self._lock:
            self.records.append(record)
            if self.slo_s is not None and record.latency_s > self.slo_s:
                self.slo_violations += 1

    # ------------------------------------------------------- reading ----
    def latencies_s(self) -> List[float]:
        with self._lock:
            return sorted(r.latency_s for r in self.records)

    def latency_histogram(self, n_bins: int = 20) -> List[int]:
        """Fixed-bin latency histogram over [0, max]; purely a function
        of the recorded latencies, so same-seed virtual-clock runs
        produce identical lists."""
        lats = self.latencies_s()
        if not lats:
            return [0] * n_bins
        top = lats[-1] or 1e-12
        counts = [0] * n_bins
        for lat in lats:
            idx = min(int(n_bins * lat / top), n_bins - 1)
            counts[idx] += 1
        return counts

    def summary(self) -> Dict[str, float]:
        lats = self.latencies_s()
        with self._lock:
            records = list(self.records)
            out: Dict[str, float] = {
                "submitted": self.submitted,
                "completed": len(records),
                "rejected": self.rejected,
                "cancelled": self.cancelled,
                "failed": self.failed,
                "slo_violations": self.slo_violations,
            }
        if records:
            span = (max(r.complete_t for r in records)
                    - min(r.enqueue_t for r in records))
            out["throughput_rps"] = (len(records) / span if span > 0
                                     else float("inf"))
            out["p50_ms"] = nearest_rank(lats, 50) * 1e3
            out["p95_ms"] = nearest_rank(lats, 95) * 1e3
            out["p99_ms"] = nearest_rank(lats, 99) * 1e3
            out["mean_latency_ms"] = sum(lats) / len(lats) * 1e3
            out["mean_batch_occupancy"] = (
                sum(r.batch_size for r in records) / len(records))
            out["mean_padded_size"] = (
                sum(r.padded_size for r in records) / len(records))
        return out

    def audit(self) -> List[str]:
        """Accounting self-check; returns the list of violations (empty =
        clean).  ``in_flight`` covers requests submitted but not yet
        resolved when the audit runs — an engine audited *after* drain
        must have zero."""
        errors: List[str] = []
        with self._lock:
            records = list(self.records)
            resolved = (len(records) + self.rejected + self.cancelled
                        + self.failed)
            if resolved > self.submitted:
                errors.append(
                    f"over-accounted: {resolved} resolved > "
                    f"{self.submitted} submitted")
            violations = self.slo_violations
        for r in records:
            if not (r.enqueue_t <= r.dispatch_t <= r.complete_t):
                errors.append(f"rid {r.rid}: non-monotonic timestamps "
                              f"{r.enqueue_t}/{r.dispatch_t}/{r.complete_t}")
            if not (1 <= r.batch_size <= r.padded_size):
                errors.append(f"rid {r.rid}: batch {r.batch_size} vs "
                              f"padded {r.padded_size}")
        if self.slo_s is not None:
            recount = sum(1 for r in records if r.latency_s > self.slo_s)
            if recount != violations:
                errors.append(f"slo_violations counter {violations} != "
                              f"recount {recount}")
        seen = set()
        for r in records:
            if r.rid in seen:
                errors.append(f"rid {r.rid}: completed twice")
            seen.add(r.rid)
        return errors

    def drained(self) -> bool:
        """True when every submitted request has been resolved."""
        with self._lock:
            return (len(self.records) + self.rejected + self.cancelled
                    + self.failed) == self.submitted
