"""Virtual-clock discrete-event simulation of the serving engine.

The hermetic half of the subsystem (DESIGN.md §Serving): the same
queue/batch-former policy the threaded engine runs
(:func:`~repro.serving.vta.policy.ready_count`, the same padding ladder),
driven by a :class:`~repro.serving.vta.clock.VirtualClock` over a seeded
arrival source, with batch service times taken from a deterministic
:class:`ServiceModel` instead of wall time.  Same seed + same model ⇒
bit-identical request traces and latency histograms on any machine —
the ``servelat/*/deterministic_replay`` benchmark row asserts exactly
that (EXPERIMENTS.md §Serving-latency).

When ``net`` is passed, every formed batch is *really executed* through
``NetworkProgram.serve`` (padded up the compiled-shape ladder, pad rows
sliced off), so the simulation doubles as the differential harness: the
outputs it returns must be bit-identical to a direct ``serve`` of the
same images, while latency accounting stays virtual.

Event loop: a single heap of ``(time, seq, kind)`` events — arrivals
(admission-checked against ``max_depth``), max-wait timers (scheduled at
``enqueue + max_wait`` so the float comparison in ``ready_count`` is
exact), and batch completions (which free their worker and may schedule
closed-loop re-submissions).  ``seq`` makes equal-time ordering
deterministic.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from .clock import VirtualClock
from .loadgen import request_images
from .metrics import RequestRecord, ServingMetrics
from .policy import BatchPolicy, pad_ladder, padded_size, ready_count


@dataclasses.dataclass(frozen=True)
class ServiceModel:
    """Deterministic batch service time: ``base + per_image * rows``.

    ``rows`` is the *padded* stack size — what the batch backend actually
    executes — so padding's cost is modeled, not hidden."""

    base_s: float
    per_image_s: float

    def service_s(self, padded_rows: int) -> float:
        return self.base_s + self.per_image_s * padded_rows


def calibrate_service_model(net, *, backend: str = "batched",
                            batch: int = 8, repeats: int = 3,
                            seed: int = 0) -> ServiceModel:
    """Fit a :class:`ServiceModel` from real timed serves at stack sizes
    1 and ``batch`` (median of ``repeats``).  Calibration is the one
    wall-clock step; everything downstream of the returned model is
    deterministic."""
    images = request_images(net, batch, seed)
    net.serve(images[:1], backend=backend)          # warm plans/kernels
    net.serve(images, backend=backend)

    def _median_serve_s(imgs) -> float:
        samples = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            net.serve(imgs, backend=backend)
            samples.append(time.perf_counter() - t0)
        return sorted(samples)[len(samples) // 2]

    t1 = _median_serve_s(images[:1])
    tb = _median_serve_s(images)
    per_image = max((tb - t1) / (batch - 1), 0.0) if batch > 1 else 0.0
    base = max(t1 - per_image, 1e-9)
    return ServiceModel(base_s=base, per_image_s=per_image)


@dataclasses.dataclass
class _SimRequest:
    rid: int
    enqueue_t: float


@dataclasses.dataclass
class SimResult:
    """What one simulation run produced."""

    metrics: ServingMetrics
    records: List[RequestRecord]            # completion order
    outputs: Optional[Dict[int, np.ndarray]]  # rid -> logits (net runs)

    def trace(self) -> List[tuple]:
        """Canonical comparable request trace (deterministic replay)."""
        return [r.as_tuple() for r in self.records]


def simulate(source, policy: BatchPolicy, service_model: ServiceModel, *,
             workers: int = 1, backend: str = "batched",
             slo_s: Optional[float] = None, net=None) -> SimResult:
    """Run the serving policy over a seeded arrival source on the virtual
    clock; see the module docstring for semantics."""
    if workers < 1:
        raise ValueError(f"workers must be >= 1, got {workers}")
    clock = VirtualClock()
    ladder = (net.padded_batch_sizes(policy.max_batch) if net is not None
              else pad_ladder(policy.max_batch))
    metrics = ServingMetrics(slo_s=slo_s)
    records: List[RequestRecord] = []
    outputs: Optional[Dict[int, np.ndarray]] = {} if net is not None else None

    events: list = []
    seq = itertools.count()

    def push(t: float, kind: str, payload) -> None:
        heapq.heappush(events, (t, next(seq), kind, payload))

    pending: deque = deque()
    free_workers = list(range(workers))

    def try_dispatch(now: float) -> None:
        while free_workers and pending:
            n = ready_count(len(pending), pending[0].enqueue_t, now, policy)
            if not n:
                return
            reqs = [pending.popleft() for _ in range(n)]
            widx = free_workers.pop(0)
            padded = padded_size(n, ladder)
            if net is not None:
                imgs = [source.image_for(r.rid) for r in reqs]
                exec_imgs = imgs + [imgs[-1]] * (padded - n)
                outs, _ = net.serve(exec_imgs, backend=backend)
                for r, out in zip(reqs, outs):
                    outputs[r.rid] = out
            push(now + service_model.service_s(padded), "complete",
                 (widx, reqs, now, n, padded))

    for t, rid in source.initial_arrivals():
        push(t, "arrival", rid)

    while events:
        t, _, kind, payload = heapq.heappop(events)
        clock.advance_to(t)
        if kind == "arrival":
            metrics.on_submit()
            if len(pending) >= policy.max_depth:
                metrics.on_reject()
                for t2, rid2 in source.on_reject(payload, t):
                    push(t2, "arrival", rid2)
            else:
                pending.append(_SimRequest(payload, t))
                push(t + policy.max_wait_s, "timer", None)
                try_dispatch(t)
        elif kind == "timer":
            try_dispatch(t)
        else:                                   # complete
            widx, reqs, dispatch_t, n, padded = payload
            free_workers.append(widx)
            free_workers.sort()                 # deterministic assignment
            for r in reqs:
                record = RequestRecord(
                    rid=r.rid, enqueue_t=r.enqueue_t,
                    dispatch_t=dispatch_t, complete_t=t,
                    batch_size=n, padded_size=padded,
                    backend=backend, worker=widx)
                metrics.observe(record)
                records.append(record)
                for t2, rid2 in source.on_complete(r.rid, t):
                    push(t2, "arrival", rid2)
            try_dispatch(t)

    assert not pending, "simulation ended with requests still queued"
    return SimResult(metrics=metrics, records=records, outputs=outputs)
