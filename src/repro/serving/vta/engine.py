"""Async VTA serving engine: queue → dynamic batch former → worker pool.

The production-shaped layer over compiled
:class:`~repro.core.network_compiler.NetworkProgram` plans (DESIGN.md
§Serving): callers ``submit()`` single images and get a
:class:`~repro.serving.vta.queueing.Ticket`; worker threads block on the
shared :class:`~repro.serving.vta.queueing.RequestQueue`, form batches
under the max-batch/max-wait :class:`~repro.serving.vta.policy.BatchPolicy`,
pad them up the compiled-shape ladder
(:meth:`NetworkProgram.padded_batch_sizes`), execute
``NetworkProgram.serve`` on their backend, and resolve the tickets.

Design points:

* **Per-worker backend selection** — ``backends=("batched", "pallas")``
  starts one worker per entry, so a deployment can drain the queue with
  the vectorised interpreter and the MXU kernel side by side; every
  backend is bit-identical per request (the conformance contract), so
  which worker serves a request is unobservable in the results.
* **Admission control** — submissions beyond ``max_depth`` raise
  :class:`~repro.serving.vta.queueing.QueueFull`; mis-shaped images are
  rejected at the door against :meth:`NetworkProgram.input_signature`.
* **Graceful drain** — ``shutdown(drain=True)`` closes the queue (new
  submissions raise ``QueueClosed``), lets workers finish every queued
  request, then joins them; ``drain=False`` cancels queued tickets with
  a typed error instead.  Either way no ticket is left unresolved.
* **Guarded serving** — ``guard=GuardPolicy()`` routes batches through
  the PR 6 integrity stack (DESIGN.md §Hardening).  Guarded execution
  mutates/restores shared network state on detection, so it is
  serialized across workers by an engine lock and pinned to the batched
  backend (the guard stack's typed refusal otherwise).
* **Compile-once under traffic** — workers share the per-layer cached
  instruction plans; the warmup pass at ``start()`` compiles them (and
  traces the pallas kernels) before the first request, so plan
  compilation never races and never lands in a request's latency.
"""

from __future__ import annotations

import itertools
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.errors import CompileError
from repro.core.network_compiler import SERVE_BACKENDS

from .clock import WallClock
from .metrics import RequestRecord, ServingMetrics
from .policy import BatchPolicy, padded_size
from .queueing import (QueueClosed, QueueFull, RequestQueue, ServingError,
                       Ticket)


class VTAServingEngine:
    """Threaded async serving over one compiled network."""

    def __init__(self, net, *, policy: Optional[BatchPolicy] = None,
                 backends: Sequence[str] = ("batched",),
                 guard=None, slo_s: Optional[float] = None,
                 warmup: bool = True, clock=None):
        if not backends:
            raise ValueError("engine needs at least one worker backend")
        for be in backends:
            if be not in SERVE_BACKENDS:
                raise CompileError(
                    f"engine worker backend must be in {SERVE_BACKENDS} "
                    f"(the per-image simulators serve no batch stack), "
                    f"got {be!r}", constraint="serve-backend")
        if guard is not None and any(be != "batched" for be in backends):
            raise CompileError(
                "guarded serving runs on the batched instruction "
                "interpreter only; drop guard= or use "
                "backends=('batched', ...)",
                constraint="serve-guard-backend")
        self.net = net
        self.policy = policy or BatchPolicy()
        self.backends = tuple(backends)
        self.guard = guard
        self.clock = clock or WallClock()
        self.metrics = ServingMetrics(slo_s=slo_s)
        self._ladder = net.padded_batch_sizes(self.policy.max_batch)
        self._signature = net.input_signature()
        self._queue = RequestQueue(self.policy)
        self._rid = itertools.count()
        self._threads: List[threading.Thread] = []
        self._started = False
        self._stopped = False
        self._warmup = warmup
        # guarded serving restores shared segments in place → serialize
        self._guard_lock = threading.Lock() if guard is not None else None

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "VTAServingEngine":
        if self._started:
            raise RuntimeError("engine already started")
        self._started = True
        if self._warmup:
            probe = np.zeros(self._signature[0], dtype=self._signature[1])
            for be in set(self.backends):
                self.net.serve([probe], backend=be)   # compile plans once
        for widx, be in enumerate(self.backends):
            t = threading.Thread(target=self._worker, args=(widx, be),
                                 name=f"vta-serve-{widx}-{be}", daemon=True)
            t.start()
            self._threads.append(t)
        return self

    def shutdown(self, *, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop accepting requests; with ``drain`` (default) serve every
        queued request first, otherwise cancel them with ``QueueClosed``.
        Idempotent."""
        if self._stopped:
            return
        self._stopped = True
        if not drain:
            cancelled = self._queue.cancel_pending()
            for ticket in cancelled:
                ticket.resolve(None, QueueClosed(
                    f"request {ticket.rid}: cancelled by non-draining "
                    f"shutdown"))
            self.metrics.on_cancel(len(cancelled))
        self._queue.close()
        for t in self._threads:
            t.join(timeout)

    def __enter__(self) -> "VTAServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # ---------------------------------------------------------- caller API
    def submit(self, image: np.ndarray) -> Ticket:
        """Enqueue one request; raises ``QueueFull`` under backpressure,
        ``QueueClosed`` after shutdown, ``ValueError`` on a mis-shaped
        image (validated against the compiled input signature)."""
        image = np.asarray(image)
        want_shape, want_dtype = self._signature
        if image.shape != want_shape:
            raise ValueError(
                f"request image shape {image.shape} != compiled input "
                f"signature {want_shape}")
        ticket = Ticket(next(self._rid), image.astype(want_dtype),
                        self.clock.now())
        self.metrics.on_submit()
        try:
            self._queue.submit(ticket)
        except QueueFull:
            self.metrics.on_reject()
            raise
        except QueueClosed:
            self.metrics.on_cancel()
            raise
        return ticket

    def depth(self) -> int:
        return self._queue.depth()

    # ---------------------------------------------------------- workers
    def _worker(self, widx: int, backend: str) -> None:
        while True:
            batch = self._queue.take_batch(self.clock)
            if batch is None:
                return
            self._execute(batch, widx, backend)

    def _execute(self, batch: List[Ticket], widx: int,
                 backend: str) -> None:
        dispatch_t = self.clock.now()
        images = [t.image for t in batch]
        padded = padded_size(len(images), self._ladder)
        exec_images = images + [images[-1]] * (padded - len(images))
        guard_reports = None
        try:
            if self.guard is not None:
                with self._guard_lock:
                    outs, _, guard_reports = self.net.serve(
                        exec_images, guard=self.guard)
            else:
                outs, _ = self.net.serve(exec_images, backend=backend)
        except Exception as exc:                      # noqa: BLE001
            self.metrics.on_fail(len(batch))
            err = ServingError(f"batch execution failed on "
                               f"{backend!r}: {type(exc).__name__}: {exc}")
            err.__cause__ = exc
            for ticket in batch:
                ticket.resolve(None, err)
            return
        complete_t = self.clock.now()
        for i, ticket in enumerate(batch):
            if guard_reports is not None:
                ticket.guard_report = guard_reports[i]
            if outs is None or (guard_reports is not None
                                and not guard_reports[i].ok):
                self.metrics.on_fail()
                ticket.resolve(None, ServingError(
                    f"request {ticket.rid}: guard outcome 'failed' — "
                    f"unrecoverable corruption, no result"))
                continue
            record = RequestRecord(
                rid=ticket.rid, enqueue_t=ticket.enqueue_t,
                dispatch_t=dispatch_t, complete_t=complete_t,
                batch_size=len(batch), padded_size=padded,
                backend=backend, worker=widx)
            ticket.record = record
            self.metrics.observe(record)
            ticket.resolve(outs[i])


def serve_all(engine: VTAServingEngine, images: Sequence[np.ndarray],
              *, timeout_s: float = 120.0
              ) -> Tuple[np.ndarray, List[Ticket]]:
    """Convenience driver: submit every image (blocking briefly on
    backpressure rather than shedding), wait for all results, return them
    stacked in submission order plus the tickets."""
    tickets = []
    for img in images:
        while True:
            try:
                tickets.append(engine.submit(img))
                break
            except QueueFull:
                threading.Event().wait(0.001)     # bounded retry backoff
    outs = [t.result(timeout=timeout_s) for t in tickets]
    return np.stack(outs), tickets
