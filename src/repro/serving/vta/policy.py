"""Dynamic-batching policy: when to dispatch, and at what stack shape.

The decision function :func:`ready_count` is deliberately *pure* — both
execution substrates call the same function with the same arguments:

* the threaded :class:`~repro.serving.vta.engine.VTAServingEngine`
  evaluates it under the queue lock with wall-clock time;
* the virtual-clock discrete-event simulation
  (:mod:`repro.serving.vta.simulate`) evaluates it at event boundaries.

That sharing is the core of the determinism argument (DESIGN.md
§Serving): the simulation exercises the *same* max-batch/max-wait policy
the production engine runs, only the clock differs.

Padding ladder: the batched backend executes a ``(B, nbytes)`` DRAM
stack for any ``B``, but serving every possible occupancy would touch a
new stack shape (and, on the pallas backend, a new kernel trace) per
batch.  :func:`pad_ladder` fixes a small closed set of compiled batch
shapes — powers of two up to ``max_batch`` — and :func:`padded_size`
rounds a formed batch up to the next rung.  Pad rows replicate the last
real request and are sliced off after execution; per-request results are
unaffected because the batched backend is bit-identical per stack row
(the conformance-fuzz contract, DESIGN.md §Batching).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.errors import CompileError


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Max-batch / max-wait dynamic batching + admission control.

    ``max_batch``   — most requests per formed batch (and the top rung of
                      the padding ladder).
    ``max_wait_s``  — longest the oldest queued request may wait before a
                      partial batch dispatches; ``0`` means *immediate*
                      dispatch of whatever is queued.
    ``max_depth``   — admission control: submissions beyond this queue
                      depth are rejected with a typed
                      :class:`~repro.serving.vta.queueing.QueueFull`
                      (backpressure, never silent dropping).
    """

    max_batch: int = 8
    max_wait_s: float = 0.002
    max_depth: int = 64

    def __post_init__(self):
        if self.max_batch < 1:
            # typed + constraint-tagged: the policy is where a degenerate
            # ladder is born, so it is rejected here at construction (and
            # again in pad_ladder for direct callers) instead of as a bare
            # ValueError deep in padded_size (CompileError subclasses
            # ValueError, so pre-existing catchers keep working)
            raise CompileError(
                f"max_batch must be >= 1, got {self.max_batch}",
                constraint="policy-max-batch")
        if self.max_wait_s < 0:
            raise ValueError(
                f"max_wait_s must be >= 0, got {self.max_wait_s}")
        if self.max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {self.max_depth}")


def ready_count(pending: int, oldest_enqueue_t: float, now: float,
                policy: BatchPolicy, *, closed: bool = False) -> int:
    """How many requests to dispatch right now (0 = keep waiting).

    Dispatch fires when the batch is full, when the oldest request has
    waited ``max_wait_s`` (compared as ``now >= enqueue + max_wait`` so a
    timer scheduled at exactly that sum triggers despite float rounding),
    or when the queue is closed and draining.
    """
    if pending <= 0:
        return 0
    if pending >= policy.max_batch:
        return policy.max_batch
    if closed or now >= oldest_enqueue_t + policy.max_wait_s:
        return pending
    return 0


def pad_ladder(max_batch: int) -> Tuple[int, ...]:
    """The closed set of compiled batch shapes: powers of two up to
    ``max_batch``, plus ``max_batch`` itself when it is not a power of
    two.  Non-positive ``max_batch`` is rejected with a typed
    :class:`~repro.core.errors.CompileError` — the old code silently
    returned the degenerate ladder ``(0,)``, deferring the failure to a
    bare ``ValueError`` in :func:`padded_size` at dispatch time."""
    if max_batch < 1:
        raise CompileError(
            f"padding ladder needs max_batch >= 1, got {max_batch}",
            constraint="ladder-max-batch")
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


def padded_size(n: int, ladder: Tuple[int, ...]) -> int:
    """Smallest ladder rung >= n (n must fit the ladder's top rung)."""
    for rung in ladder:
        if rung >= n:
            return rung
    raise ValueError(f"batch of {n} exceeds ladder top {ladder[-1]}")
