"""Thread-safe bounded request queue with typed admission control.

The front door of the async engine (DESIGN.md §Serving): submissions
beyond ``BatchPolicy.max_depth`` are rejected with :class:`QueueFull`
(backpressure the caller can act on — shed, retry, or degrade), and
submissions after ``close()`` raise :class:`QueueClosed`.  Nothing is
ever silently dropped.

``take_batch`` is the worker side: it blocks until the shared
:func:`~repro.serving.vta.policy.ready_count` decision function says a
batch is ready (full, or the oldest request aged past ``max_wait_s``, or
the queue is closed and draining), then pops the batch FIFO.  Returns
``None`` exactly once per worker when the queue is closed *and* empty —
the graceful drain-and-shutdown handshake.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, List, Optional

import numpy as np

from .policy import BatchPolicy, ready_count


class QueueFull(RuntimeError):
    """Admission control rejected the request (backpressure)."""

    def __init__(self, depth: int, max_depth: int):
        self.depth = depth
        self.max_depth = max_depth
        super().__init__(
            f"request queue full: depth {depth} >= max_depth {max_depth} "
            f"(backpressure — retry later or shed load)")


class QueueClosed(RuntimeError):
    """The queue no longer accepts submissions (shutdown in progress)."""


class ServingError(RuntimeError):
    """A request could not produce a result (execution failure or guard
    outcome ``failed``) — surfaced on ``Ticket.result()``, never as a
    silently missing/wrong answer."""


class Ticket:
    """Caller-side handle for one submitted request (a minimal future)."""

    def __init__(self, rid: int, image: np.ndarray, enqueue_t: float):
        self.rid = rid
        self.image = image
        self.enqueue_t = enqueue_t
        self.record = None                   # RequestRecord once completed
        self.guard_report = None             # GuardReport under guard=
        self._event = threading.Event()
        self._result: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    # worker side ------------------------------------------------------
    def resolve(self, result: Optional[np.ndarray],
                error: Optional[BaseException] = None) -> None:
        self._result = result
        self._error = error
        self._event.set()

    # caller side ------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid}: no result within "
                               f"{timeout}s")
        if self._error is not None:
            raise self._error
        return self._result


class RequestQueue:
    """FIFO of :class:`Ticket` with bounded depth and drain semantics."""

    def __init__(self, policy: BatchPolicy):
        self.policy = policy
        self._cond = threading.Condition()
        self._items: deque = deque()
        self._closed = False

    def depth(self) -> int:
        with self._cond:
            return len(self._items)

    def submit(self, ticket: Ticket) -> None:
        with self._cond:
            if self._closed:
                raise QueueClosed(
                    f"request {ticket.rid}: queue is closed (engine "
                    f"shutting down)")
            if len(self._items) >= self.policy.max_depth:
                raise QueueFull(len(self._items), self.policy.max_depth)
            self._items.append(ticket)
            self._cond.notify_all()

    def take_batch(self, clock) -> Optional[List[Ticket]]:
        """Block until a batch is ready per the shared policy; ``None``
        when closed and fully drained."""
        with self._cond:
            while True:
                now = clock.now()
                n = ready_count(
                    len(self._items),
                    self._items[0].enqueue_t if self._items else 0.0,
                    now, self.policy, closed=self._closed)
                if n:
                    batch = [self._items.popleft() for _ in range(n)]
                    self._cond.notify_all()   # free depth → unblock waiters
                    return batch
                if self._closed:              # closed and empty: drain done
                    return None
                if self._items:
                    # partial batch: sleep until the oldest request's
                    # max-wait deadline (submissions/close notify earlier)
                    deadline = (self._items[0].enqueue_t
                                + self.policy.max_wait_s)
                    self._cond.wait(timeout=max(0.0, deadline - now))
                else:
                    self._cond.wait()

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def cancel_pending(self) -> List[Ticket]:
        """Pop every queued ticket (the non-draining shutdown path); the
        caller resolves them with :class:`QueueClosed` errors."""
        with self._cond:
            items = list(self._items)
            self._items.clear()
            self._cond.notify_all()
            return items
