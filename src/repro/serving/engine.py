"""Serving engine: prefill + single-token decode over the cache tree.

``prefill``     — runs the prompt through the parallel (chunked-flash /
chunked-WKV / chunked-scan) forward while *writing* each layer's cache;
returns the last-position logits and the filled cache.

``decode_step`` — one new token against the caches.  Global-attention
layers read the sequence-sharded dense cache (GSPMD turns the softmax over
the sharded sequence axis into the distributed flash-decode merge);
windowed layers read the ring buffer; Mamba/RWKV layers advance their O(1)
states.  The layer stack scans with the same (pattern × repeats) structure
as training, so a 96-layer decode lowers as one pattern trace.

Legacy note: this is the seed's *LM* (transformer prefill/decode)
serving engine, exercised by the legacy CI tier only.  The VTA CNN
serving subsystem — async request queue, dynamic batching, worker pool
over compiled ``NetworkProgram`` plans — is :mod:`repro.serving.vta`
(DESIGN.md §Serving); deployments of the accelerator path wire that
package, not this module.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import (attention_qkv, constrain,
                                 constrain_seq, mlp_apply, norm_apply, rope)
from repro.models.mamba import mamba_apply
from repro.models.moe import moe_apply
from repro.models.rwkv6 import rwkv6_channel_mix, rwkv6_time_mix
from repro.models.transformer import (encode, find_period, schedule_items,
                                      unembed_logits)
from .cache import CacheTree, init_cache, layer_cache_kind

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Attention over caches
# ---------------------------------------------------------------------------

def _rope_theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "attn" and cfg.rope_theta_global is not None:
        return cfg.rope_theta_global
    return cfg.rope_theta


def _attn_scores_decode(cfg, q, k_cache, v_cache, mask):
    """q (B,H,1,D); cache (B,KV,S,D); mask (S,) or (B,1,1,S) bool."""
    group = cfg.n_heads // cfg.n_kv_heads
    b, h, _, hd = q.shape
    kv = cfg.n_kv_heads
    qg = q.reshape(b, kv, group, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * (hd ** -0.5)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, 1, hd).astype(q.dtype)


def attn_decode(bp, cfg: ModelConfig, kind: str, x: jax.Array,
                cache: Dict[str, jax.Array], pos: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B, 1, d); returns (out (B, 1, d), updated cache)."""
    b = x.shape[0]
    theta = _rope_theta(cfg, kind)
    q, k, v = attention_qkv(bp, cfg, x)
    posv = jnp.full((1,), 0, jnp.int32) + pos
    q = rope(q, posv, theta)
    k = rope(k, posv, theta)

    ck = layer_cache_kind(cfg, kind)
    if ck == "dense":
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), pos, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), pos, axis=2)
        s_max = k_cache.shape[2]
        mask = (jnp.arange(s_max) <= pos)[None, None, None, :]
        o = _attn_scores_decode(cfg, q, k_cache, v_cache, mask)
        new_cache = {"k": k_cache, "v": v_cache}
    else:                                       # windowed ring buffer
        w = cache["k"].shape[2]
        slot = pos % w
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=2)
        slot_pos = cache["slot_pos"].at[slot].set(pos)
        valid = (slot_pos >= 0) & (pos - slot_pos < cfg.local_window)
        o = _attn_scores_decode(cfg, q, k_cache, v_cache,
                                valid[None, None, None, :])
        new_cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
    out = o.transpose(0, 2, 1, 3).reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return out @ bp["wo"], new_cache


def attn_prefill(bp, cfg: ModelConfig, kind: str, x: jax.Array,
                 cache: Dict[str, jax.Array], q_offset: int = 0
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Parallel attention over the prompt + cache write.  x (B, S, d)."""
    from repro.models.layers import chunked_attention
    b, s, _ = x.shape
    theta = _rope_theta(cfg, kind)
    q, k, v = attention_qkv(bp, cfg, x)
    posv = q_offset + jnp.arange(s)
    q = rope(q, posv, theta)
    k = rope(k, posv, theta)
    window = (cfg.local_window if kind in ("attn_local", "attn_swa")
              else None)
    o = chunked_attention(q, k, v, causal=True, window=window,
                          q_offset=q_offset, q_chunk=cfg.q_chunk,
                          kv_chunk=cfg.kv_chunk,
                          causal_skip=cfg.causal_skip)
    ck = layer_cache_kind(cfg, kind)
    if ck == "dense":
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), q_offset, axis=2),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), q_offset, axis=2),
        }
    else:
        w = cache["k"].shape[2]
        take = min(w, s)
        k_tail = k[:, :, -take:]
        v_tail = v[:, :, -take:]
        pos_tail = posv[-take:]
        slots = pos_tail % w
        new_cache = {
            "k": cache["k"].at[:, :, slots].set(
                k_tail.astype(cache["k"].dtype)),
            "v": cache["v"].at[:, :, slots].set(
                v_tail.astype(cache["v"].dtype)),
            "slot_pos": cache["slot_pos"].at[slots].set(pos_tail),
        }
    out = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ bp["wo"], new_cache


# ---------------------------------------------------------------------------
# Per-layer prefill / decode
# ---------------------------------------------------------------------------

def block_prefill(bp, cfg: ModelConfig, h, kind: str, is_moe: bool, cache,
                  *, enc_out=None, q_offset: int = 0):
    hin = norm_apply(bp["norm1"], cfg, h)
    if kind.startswith("attn"):
        mix, cache = attn_prefill(bp["mix"], cfg, kind, hin, cache,
                                  q_offset=q_offset)
    elif kind == "mamba":
        mix, (conv, hs) = mamba_apply(bp["mix"], cfg, hin,
                                      state=(cache["conv"], cache["h"]),
                                      return_state=True)
        cache = {"conv": conv, "h": hs}
    elif kind == "rwkv6":
        mix, (shift, wkv) = rwkv6_time_mix(
            bp["mix"], cfg, hin, shift_prev=cache["shift"],
            wkv_state=cache["wkv"], return_state=True)
        cache = dict(cache, shift=shift, wkv=wkv)
    else:
        raise ValueError(kind)
    h = h + mix
    if enc_out is not None and "cross" in bp:
        from repro.models.layers import attention_apply
        hx = norm_apply(bp["norm_x"], cfg, h)
        h = h + attention_apply(bp["cross"], cfg, hx, kv_input=enc_out,
                                causal=False)
    hf = norm_apply(bp["norm2"], cfg, h)
    if kind == "rwkv6":
        out, cm_shift = rwkv6_channel_mix(
            bp["mix"], cfg, hf, shift_prev=cache["cm_shift"],
            return_state=True)
        cache = dict(cache, cm_shift=cm_shift)
        h = h + out
    elif is_moe:
        out, _ = moe_apply(bp["ffn"], cfg, hf)
        h = h + out
    else:
        h = h + mlp_apply(bp["ffn"], cfg, hf)
    return constrain_seq(h), cache


def block_decode(bp, cfg: ModelConfig, h, kind: str, is_moe: bool, cache,
                 pos, *, enc_out=None):
    hin = norm_apply(bp["norm1"], cfg, h)
    if kind.startswith("attn"):
        mix, cache = attn_decode(bp["mix"], cfg, kind, hin, cache, pos)
    elif kind == "mamba":
        mix, (conv, hs) = mamba_apply(bp["mix"], cfg, hin,
                                      state=(cache["conv"], cache["h"]),
                                      return_state=True)
        cache = {"conv": conv, "h": hs}
    elif kind == "rwkv6":
        mix, (shift, wkv) = rwkv6_time_mix(
            bp["mix"], cfg, hin, shift_prev=cache["shift"],
            wkv_state=cache["wkv"], return_state=True)
        cache = dict(cache, shift=shift, wkv=wkv)
    else:
        raise ValueError(kind)
    h = h + mix
    if enc_out is not None and "cross" in bp:
        from repro.models.layers import attention_apply
        hx = norm_apply(bp["norm_x"], cfg, h)
        h = h + attention_apply(bp["cross"], cfg, hx, kv_input=enc_out,
                                causal=False)
    hf = norm_apply(bp["norm2"], cfg, h)
    if kind == "rwkv6":
        out, cm_shift = rwkv6_channel_mix(
            bp["mix"], cfg, hf, shift_prev=cache["cm_shift"],
            return_state=True)
        cache = dict(cache, cm_shift=cm_shift)
        h = h + out
    elif is_moe:
        out, _ = moe_apply(bp["ffn"], cfg, hf)
        h = h + out
    else:
        h = h + mlp_apply(bp["ffn"], cfg, hf)
    return h, cache


# ---------------------------------------------------------------------------
# Whole-model prefill / decode
# ---------------------------------------------------------------------------

def _pattern(cfg: ModelConfig):
    items = schedule_items(cfg)
    if cfg.scan_layers:
        p, reps, tail = find_period(items)
    else:
        p, reps, tail = len(items), 1, 0
    if reps <= 1:
        return [], items
    return items[:p], items[p * reps:]


def prefill(params, cfg: ModelConfig, tokens: jax.Array, cache: CacheTree,
            *, prefix_embed=None, frames=None
            ) -> Tuple[jax.Array, CacheTree]:
    """Prompt (B, S) → (last-token logits (B, vocab), filled caches)."""
    pattern, tail_items = _pattern(cfg)
    enc_out = encode(params, cfg, frames) if cfg.encoder_layers else None
    h = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embed is not None:
        h = jnp.concatenate([prefix_embed.astype(h.dtype), h], axis=1)
    h = constrain(h, "batch", None, None)

    new_blocks = []
    if pattern:
        def body(h, xs):
            bp_slice, cache_slice = xs
            new_slice = []
            for posn, (kind, moe) in enumerate(pattern):
                h, c = block_prefill(bp_slice[posn], cfg, h, kind, moe,
                                     cache_slice[posn], enc_out=enc_out)
                new_slice.append(c)
            return h, new_slice

        h, new_blocks = jax.lax.scan(
            body, h, (params["blocks"], cache.blocks))

    new_tail = []
    for bp, c, (kind, moe) in zip(params["tail"], cache.tail, tail_items):
        h, c = block_prefill(bp, cfg, h, kind, moe, c, enc_out=enc_out)
        new_tail.append(c)

    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_logits(params, cfg, h[:, -1])
    return logits, CacheTree(blocks=new_blocks, tail=new_tail)


def decode_step(params, cfg: ModelConfig, cache: CacheTree,
                tokens: jax.Array, pos: jax.Array, *, enc_out=None
                ) -> Tuple[jax.Array, CacheTree]:
    """One token per sequence.  tokens (B,), pos scalar int32 (position of
    the new token).  Returns (logits (B, vocab), updated caches)."""
    pattern, tail_items = _pattern(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)[:, None]

    new_blocks = []
    if pattern:
        def body(h, xs):
            bp_slice, cache_slice = xs
            new_slice = []
            for posn, (kind, moe) in enumerate(pattern):
                h, c = block_decode(bp_slice[posn], cfg, h, kind, moe,
                                    cache_slice[posn], pos, enc_out=enc_out)
                new_slice.append(c)
            return h, new_slice

        h, new_blocks = jax.lax.scan(
            body, h, (params["blocks"], cache.blocks))

    new_tail = []
    for bp, c, (kind, moe) in zip(params["tail"], cache.tail, tail_items):
        h, c = block_decode(bp, cfg, h, kind, moe, c, pos, enc_out=enc_out)
        new_tail.append(c)

    h = norm_apply(params["final_norm"], cfg, h)
    logits = unembed_logits(params, cfg, h[:, 0])
    return logits, CacheTree(blocks=new_blocks, tail=new_tail)


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_tokens: int,
             max_seq: int, *, dtype=jnp.bfloat16, frames=None,
             prefix_embed=None) -> jax.Array:
    """Greedy generation driver (examples / tests)."""
    b, s = prompt.shape
    cache = init_cache(cfg, b, max_seq, dtype)
    logits, cache = prefill(params, cfg, prompt, cache, frames=frames,
                            prefix_embed=prefix_embed)
    enc_out = encode(params, cfg, frames) if cfg.encoder_layers else None
    tokens = [jnp.argmax(logits, -1)]
    pos = s + (prefix_embed.shape[1] if prefix_embed is not None else 0)

    def step(carry, _):
        tok, cache, pos = carry
        logits, cache = decode_step(params, cfg, cache, tok, pos,
                                    enc_out=enc_out)
        nxt = jnp.argmax(logits, -1)
        return (nxt, cache, pos + 1), nxt

    (_, cache, _), toks = jax.lax.scan(
        step, (tokens[0], cache, jnp.int32(pos)), None, length=n_tokens - 1)
    return jnp.concatenate([tokens[0][None], toks], 0).T    # (B, n_tokens)
