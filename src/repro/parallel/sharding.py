"""Sharding rules: logical axes → mesh axes (DESIGN.md §4).

The production mesh is ``(data, model)`` per pod (16×16) with an optional
leading pure-DP ``pod`` axis.  Parameters are sharded over *both* axes
(FSDP over ``data`` + tensor parallelism over ``model``); activations put
batch on ``(pod, data)`` and the hidden/head dimension on ``model``.

Logical axis names used by the model code:

  "fsdp"    → ("data",)            ZeRO-3 style parameter sharding
  "tp"      → ("model",)           tensor-parallel dimension
  "batch"   → ("pod", "data")      data-parallel batch
  "seq"     → ("model",)           sequence sharding (KV caches, SP norms)
  "expert"  → ("data",)            expert parallelism (opt-in)
  None      → replicated

``logical_to_spec`` resolves a tuple of logical names against the axes the
current mesh actually has, dropping mesh axes that don't exist (so the same
model code lowers on 1-device smoke meshes, 2-D pods and 3-D multi-pod
meshes).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

RULES = {
    "fsdp": ("data",),
    "tp": ("model",),
    "batch": ("pod", "data"),
    "seq": ("model",),
    "expert": ("data",),
    "vocab": ("model",),
}


def mesh_axis_names(mesh: Optional[Mesh] = None) -> Tuple[str, ...]:
    mesh = mesh or get_abstract_mesh()
    return tuple(mesh.axis_names)


def get_abstract_mesh():
    return jax.sharding.get_abstract_mesh()


def logical_to_spec(logical: Sequence[Optional[str]],
                    mesh: Mesh) -> P:
    """Resolve logical axis names to a PartitionSpec for ``mesh``."""
    names = set(mesh.axis_names)
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
            continue
        mapped = tuple(m for m in RULES[ax] if m in names)
        if not mapped:
            out.append(None)
        elif len(mapped) == 1:
            out.append(mapped[0])
        else:
            out.append(mapped)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_tree(logical_tree, mesh: Mesh):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda lg: logical_to_spec(lg, mesh),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def sharding_tree(logical_tree, mesh: Mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        spec_tree(logical_tree, mesh),
        is_leaf=lambda x: isinstance(x, P))


def divisible(n: int, mesh: Mesh, axis: str) -> bool:
    if axis not in mesh.axis_names:
        return True
    return n % mesh.shape[axis] == 0


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative mesh geometry (used by configs and the launcher)."""

    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    def build(self) -> Mesh:
        return jax.make_mesh(self.shape, self.axes)

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


SINGLE_POD = MeshSpec((16, 16), ("data", "model"))
MULTI_POD = MeshSpec((2, 16, 16), ("pod", "data", "model"))


def smoke_mesh() -> Mesh:
    """1-device mesh with the production axis names — model code paths are
    identical, every spec resolves to replicated."""
    return jax.make_mesh((1, 1), ("data", "model"))
