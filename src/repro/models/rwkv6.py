"""RWKV-6 "Finch" (attention-free, data-dependent decay) — arXiv:2404.05892.

Time-mix with per-channel data-dependent decay ``w_t`` (LoRA-parameterised),
bonus ``u``, token-shift lerps, per-head group-norm and SiLU gate; channel-
mix with squared-ReLU.  Training/prefill run the **chunked-parallel WKV**
(intra-chunk matmuls on the MXU + inter-chunk recurrent state), decode is a
true O(1)-state recurrence (``long_500k`` runs with constant memory).

The WKV recurrence per head (key dim = value dim = N):

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ
    o_t = r_tᵀ · (S_{t-1} + diag(u) k_t v_tᵀ)

Chunked form (chunk L, log-space cumulated decays for stability):
``r̃_t = r_t ⊙ A⁻_t``, ``k̃_i = k_i / A_i`` with ``A_t = Π_{s≤t} w_s``,
intra-chunk scores ``r̃ k̃ᵀ`` strictly-lower-masked + ``u`` diagonal, and
state carry ``S' = diag(A_L) S + (k ⊙ A_L/A_i)ᵀ v``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import constrain
from .params import ParamDef

W_LORA = 64


def rwkv6_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return {
        # token-shift lerp coefficients (r, k, v, w, g)
        "mu_r": ParamDef((d,), (None,), init="zeros"),
        "mu_k": ParamDef((d,), (None,), init="zeros"),
        "mu_v": ParamDef((d,), (None,), init="zeros"),
        "mu_w": ParamDef((d,), (None,), init="zeros"),
        "mu_g": ParamDef((d,), (None,), init="zeros"),
        # projections
        "wr": ParamDef((d, d), ("fsdp", "tp")),
        "wk": ParamDef((d, d), ("fsdp", "tp")),
        "wv": ParamDef((d, d), ("fsdp", "tp")),
        "wg": ParamDef((d, d), ("fsdp", "tp")),
        "wo": ParamDef((d, d), ("tp", "fsdp")),
        # data-dependent decay (LoRA) + base, and the bonus u
        "w_base": ParamDef((d,), (None,), init="zeros"),
        "w1": ParamDef((d, W_LORA), ("fsdp", None), scale=0.01),
        "w2": ParamDef((W_LORA, d), (None, "tp"), scale=0.01),
        "u": ParamDef((h, n), (None, None), scale=0.5),
        # per-head group norm
        "ln_scale": ParamDef((d,), (None,), init="ones"),
        "ln_bias": ParamDef((d,), (None,), init="zeros"),
        # channel mix
        "mu_ck": ParamDef((d,), (None,), init="zeros"),
        "mu_cr": ParamDef((d,), (None,), init="zeros"),
        "ck": ParamDef((d, cfg.d_ff), ("fsdp", "tp")),
        "cv": ParamDef((cfg.d_ff, d), ("tp", "fsdp")),
        "cr": ParamDef((d, d), ("fsdp", "tp")),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """x (B,S,d) → x shifted right by one (x_{t-1}); prev fills t=0."""
    pad = (jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None])
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu


def chunked_wkv(r, k, v, w, u, *, chunk: int = 64,
                state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """r/k/v/w (B, H, S, N) — returns (out (B,H,S,N), final state (B,H,N,N)).

    f32 throughout (decay ratios within a chunk stay representable for
    chunk ≤ 64)."""
    b, h, s, n = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32
    r, k, v = r.astype(f32), k.astype(f32), v.astype(f32)
    lw = jnp.log(jnp.clip(w.astype(f32), 1e-8, 1.0))        # (B,H,S,N) ≤ 0

    rc = r.reshape(b, h, nc, chunk, n).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, h, nc, chunk, n).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, h, nc, chunk, n).transpose(2, 0, 1, 3, 4)
    lwc = lw.reshape(b, h, nc, chunk, n).transpose(2, 0, 1, 3, 4)

    if state is None:
        state = jnp.zeros((b, h, n, n), f32)

    def step(S, inp):
        rr, kk, vv, lww = inp                                # (B,H,L,N)
        cum = jnp.cumsum(lww, axis=2)                        # A_t (incl. t)
        a_incl = jnp.exp(cum)
        a_excl = jnp.exp(cum - lww)                          # A_{t-1}·(≤1)
        r_t = rr * a_excl
        k_t = kk * jnp.exp(-cum)                             # k / A_t
        # inter-chunk: r̃ @ S
        inter = jnp.einsum("bhln,bhnm->bhlm", r_t, S)
        # intra-chunk: strictly-lower scores + u-diagonal
        scores = jnp.einsum("bhln,bhmn->bhlm", r_t, k_t)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        scores = jnp.where(mask[None, None], scores, 0.0)
        intra = jnp.einsum("bhlm,bhmn->bhln", scores, vv)
        diag = jnp.einsum("bhln,bhln->bhl", rr * u[None, :, None, :], kk)
        intra = intra + diag[..., None] * vv
        out = inter + intra
        # state advance
        a_total = jnp.exp(cum[:, :, -1])                     # (B,H,N)
        k_scale = kk * jnp.exp(cum[:, :, -1:, :] - cum)      # k ⊙ A_L/A_t
        S_new = S * a_total[..., None] + jnp.einsum(
            "bhln,bhlm->bhnm", k_scale, vv)
        return S_new, out

    state, outs = jax.lax.scan(step, state, (rc, kc, vc, lwc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, n)
    return out, state


def _decay(p, xw: jax.Array) -> jax.Array:
    """Data-dependent decay w_t ∈ (0,1): exp(-exp(base + LoRA))."""
    lora = jnp.tanh(xw @ p["w1"]) @ p["w2"]
    return jnp.exp(-jnp.exp(
        (p["w_base"] + lora).astype(jnp.float32)))


def _group_norm(p, x: jax.Array, n: int, eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm over the flattened (B,S,d) with head groups."""
    b, s, d = x.shape
    xg = x.reshape(b, s, d // n, n).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    out = xg.reshape(b, s, d) * p["ln_scale"].astype(jnp.float32) \
        + p["ln_bias"].astype(jnp.float32)
    return out


def rwkv6_time_mix(p, cfg: ModelConfig, x: jax.Array, *,
                   shift_prev: Optional[jax.Array] = None,
                   wkv_state: Optional[jax.Array] = None,
                   return_state: bool = False):
    """x (B,S,d) → (B,S,d) [, (last_x, wkv_state)]."""
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    xs = _token_shift(x, shift_prev)
    r = _lerp(x, xs, p["mu_r"]) @ p["wr"]
    k = _lerp(x, xs, p["mu_k"]) @ p["wk"]
    v = _lerp(x, xs, p["mu_v"]) @ p["wv"]
    g = _lerp(x, xs, p["mu_g"]) @ p["wg"]
    w = _decay(p, _lerp(x, xs, p["mu_w"]))                   # (B,S,d) f32

    heads = lambda t: t.reshape(b, s, h, n).transpose(0, 2, 1, 3)
    out, state = chunked_wkv(heads(r), heads(k), heads(v), heads(w),
                             p["u"].astype(jnp.float32), state=wkv_state)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    out = _group_norm(p, out, n)
    out = (out * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype) @ p["wo"]
    out = constrain(out, "batch", None, None)
    if return_state:
        return out, (x[:, -1], state)
    return out


def rwkv6_channel_mix(p, cfg: ModelConfig, x: jax.Array, *,
                      shift_prev: Optional[jax.Array] = None,
                      return_state: bool = False):
    xs = _token_shift(x, shift_prev)
    xk = _lerp(x, xs, p["mu_ck"])
    xr = _lerp(x, xs, p["mu_cr"])
    kk = jnp.maximum(xk @ p["ck"], 0)
    kk = kk * kk
    kk = constrain(kk, "batch", None, "tp")
    out = jax.nn.sigmoid((xr @ p["cr"]).astype(jnp.float32)).astype(x.dtype) \
        * (kk @ p["cv"])
    out = constrain(out, "batch", None, None)
    if return_state:
        return out, x[:, -1]
    return out


# ---------------------------------------------------------------------------
# O(1) decode step (serving)
# ---------------------------------------------------------------------------

def rwkv6_decode_step(p, cfg: ModelConfig, x: jax.Array,
                      shift_prev: jax.Array, wkv_state: jax.Array,
                      cm_shift_prev: jax.Array):
    """Single-token recurrent step.  x (B, d); states threaded explicitly.

    Returns (out (B, d) *time-mix only*, new (shift, wkv_state)); channel
    mix is a separate call so the block wrapper can place the norms."""
    out, (new_shift, new_state) = rwkv6_time_mix(
        p, cfg, x[:, None], shift_prev=shift_prev, wkv_state=wkv_state,
        return_state=True)
    return out[:, 0], (new_shift, new_state)


def rwkv6_channel_decode_step(p, cfg: ModelConfig, x: jax.Array,
                              shift_prev: jax.Array):
    out, new_shift = rwkv6_channel_mix(
        p, cfg, x[:, None], shift_prev=shift_prev, return_state=True)
    return out[:, 0], new_shift


def rwkv6_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    return {
        "shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, n, n), jnp.float32),
        "cm_shift": jnp.zeros((batch, d), dtype),
    }
