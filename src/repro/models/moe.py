"""Mixture-of-Experts layer: top-k routing, capacity-based dispatch.

GSPMD-friendly **group-local** factorised dispatch (DESIGN.md §4, and the
§Perf hillclimb on mixtral):

Tokens are first reshaped to (G, T/G) groups, where G is the mesh's
batch-sharding extent — so every routing/cumsum/gather/scatter step has a
leading group axis *sharded over data* and runs entirely shard-local.  The
naive global formulation made XLA materialise and all-reduce the full
(E, C_global, d) dispatch tensor per layer (~8 TB/step for mixtral train);
group-local dispatch eliminates those collectives — only the expert FFN
einsum's FSDP weight gathers remain.

Per group:
  1. router: top-k softmax gates per token;
  2. slot assignment: position-in-expert via cumsum over the flattened
     (slot-major) assignment list — first-choice assignments win capacity;
  3. gather ``x[idx]`` → (E, C, d); batched expert FFN ``gecd,edf->gecf``;
     scatter-add back with the gate weights.

Capacity is per-group (= per data shard), as in deployed MoE systems;
tokens beyond a group's capacity are dropped.  Aux losses: Switch-style
load balance + router z-loss.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import constrain
from .params import ParamDef


def moe_defs(cfg: ModelConfig, *, expert_parallel: bool = False
             ) -> Dict[str, ParamDef]:
    d = cfg.d_model
    m = cfg.moe
    e_ax = "expert" if expert_parallel else None
    defs: Dict[str, ParamDef] = {
        "router": ParamDef((d, m.n_experts), (None, None), scale=0.02),
    }
    ff = m.d_ff_expert
    if cfg.act in ("swiglu", "geglu"):
        defs["wg"] = ParamDef((m.n_experts, d, ff), (e_ax, "fsdp", "tp"))
        defs["wu"] = ParamDef((m.n_experts, d, ff), (e_ax, "fsdp", "tp"))
    else:
        defs["wu"] = ParamDef((m.n_experts, d, ff), (e_ax, "fsdp", "tp"))
    defs["wd"] = ParamDef((m.n_experts, ff, d), (e_ax, "tp", "fsdp"))
    if m.n_shared_experts:
        sff = ff * m.n_shared_experts
        defs["shared_wg"] = ParamDef((d, sff), ("fsdp", "tp"))
        defs["shared_wu"] = ParamDef((d, sff), ("fsdp", "tp"))
        defs["shared_wd"] = ParamDef((sff, d), ("tp", "fsdp"))
    return defs


def _n_groups(t: int) -> int:
    """Batch-sharding extent of the current mesh that divides t.

    Group-local dispatch only pays off when each group still holds a
    meaningful token count — decode steps (T = batch, e.g. 128 tokens)
    regressed 3–4× with 8-token groups (measured, EXPERIMENTS.md §Perf),
    so small batches keep the single-group dispatch (the tensors are tiny
    there: T·d ≈ 1.6 MB for mixtral decode)."""
    if t < 4096:
        return 1
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and t % (g * mesh.shape[a]) == 0:
            g *= mesh.shape[a]
    return g


def _expert_ffn(p, cfg: ModelConfig, xe: jax.Array) -> jax.Array:
    """Batched expert FFN: xe (G, E, C, d) → (G, E, C, d)."""
    if cfg.act in ("swiglu", "geglu"):
        g = jnp.einsum("gecd,edf->gecf", xe, p["wg"])
        u = jnp.einsum("gecd,edf->gecf", xe, p["wu"])
        act = jax.nn.silu if cfg.act == "swiglu" else (
            lambda t: jax.nn.gelu(t, approximate=True))
        h = act(g) * u
    elif cfg.act == "sq_relu":
        r = jnp.maximum(jnp.einsum("gecd,edf->gecf", xe, p["wu"]), 0)
        h = r * r
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, p["wu"]),
                        approximate=True)
    h = constrain(h, "batch", None, None, "tp")
    return jnp.einsum("gecf,efd->gecd", h, p["wd"])


def moe_apply(p, cfg: ModelConfig, x: jax.Array, *,
              capacity: Optional[int] = None
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x (B, S, d) → (same shape, aux-loss dict)."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    t = b * s
    n_g = _n_groups(t)
    tg = t // n_g                                            # tokens/group
    xt = constrain(x.reshape(n_g, tg, d), "batch", None, None)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                  # (G, tg, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, m.top_k)    # (G, tg, k)
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if capacity is None:
        capacity = int(tg * m.top_k * m.capacity_factor / m.n_experts) or 1

    # ---- slot assignment, per group (slot-major priority) ----
    flat_expert = expert_ids.transpose(0, 2, 1).reshape(n_g, -1)  # (G, k·tg)
    onehot = jax.nn.one_hot(flat_expert, m.n_experts, dtype=jnp.int32)
    slot = jnp.cumsum(onehot, axis=1) - 1                    # (G, k·tg, E)
    flat_slot = jnp.take_along_axis(
        slot, flat_expert[..., None], axis=2)[..., 0]        # (G, k·tg)
    keep = flat_slot < capacity
    flat_token = jnp.tile(jnp.arange(tg), (n_g, m.top_k))
    flat_gate = gate_vals.transpose(0, 2, 1).reshape(n_g, -1) * keep

    # ---- index buffer (G, E, C): which local token feeds each slot ----
    gidx = jnp.arange(n_g)[:, None]
    s_clip = jnp.where(keep, flat_slot, capacity - 1)
    idx = jnp.full((n_g, m.n_experts, capacity), tg, jnp.int32)
    gates = jnp.zeros((n_g, m.n_experts, capacity), jnp.float32)
    idx = idx.at[gidx, flat_expert, s_clip].set(
        jnp.where(keep, flat_token, tg), mode="drop")
    gates = gates.at[gidx, flat_expert, s_clip].set(
        jnp.where(keep, flat_gate, 0.0), mode="drop")
    idx = constrain(idx, "batch", None, None)
    gates = constrain(gates, "batch", None, None)

    # ---- dispatch / expert FFN / combine (all group-local) ----
    xt_pad = jnp.concatenate([xt, jnp.zeros((n_g, 1, d), xt.dtype)], 1)
    xe = jnp.take_along_axis(
        xt_pad, idx.reshape(n_g, -1)[..., None], axis=1
    ).reshape(n_g, m.n_experts, capacity, d)
    xe = constrain(xe, "batch", None, None, None)
    ye = _expert_ffn(p, cfg, xe)
    ye = ye * gates[..., None].astype(ye.dtype)
    out = jnp.zeros((n_g, tg + 1, d), ye.dtype)
    out = out.at[gidx, idx.reshape(n_g, -1)].add(
        ye.reshape(n_g, -1, d), mode="drop")
    out = out[:, :tg]
    out = constrain(out, "batch", None, None)

    if m.n_shared_experts:
        act = jax.nn.silu if cfg.act == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        if "shared_wg" in p:
            h = act(xt @ p["shared_wg"]) * (xt @ p["shared_wu"])
        else:
            h = act(xt @ p["shared_wu"])
        out = out + h @ p["shared_wd"]

    # ---- aux losses (global means across groups) ----
    density = jax.nn.one_hot(expert_ids[..., 0], m.n_experts).mean((0, 1))
    router_prob = probs.mean((0, 1))
    aux = {
        "load_balance": (m.n_experts
                         * jnp.sum(density * router_prob)).astype(jnp.float32),
        "router_z": jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2).astype(jnp.float32),
    }
    return out.reshape(b, s, d).astype(x.dtype), aux
