"""LeNet-5 (paper §4.3) — the paper's demonstration workload.

Architecture (LeCun et al. 1998, as the paper uses it):

  L1 conv 1→6   k5  + ReLU + avgpool 2×2     (1,1,32,32) → (1,6,14,14)
  L2 conv 6→16  k5  + ReLU + avgpool 2×2     → (1,16,5,5)
  L3 conv 16→120 k5 + ReLU                   → (1,120,1,1)
  L4 fc  120→84 + ReLU
  L5 fc  84→10

Two references live here:

* ``lenet5_specs`` + ``reference_forward_int8`` — the exact integer
  semantics of the VTA execution (int8 weights, int32 accumulate, static
  power-of-2 requant, truncation).  The compiled network must match this
  bit-for-bit.
* ``reference_forward_float`` — a float32 JAX forward pass over the
  dequantised weights, standing in for the paper's PyTorch reference model
  (torch is not available here; recorded in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.conv_lowering import conv2d_reference
from repro.core.layer_compiler import LayerSpec


@dataclasses.dataclass
class LeNetWeights:
    conv1_w: np.ndarray   # (6, 1, 5, 5)  int8
    conv1_b: np.ndarray   # (6,)          int32
    conv2_w: np.ndarray   # (16, 6, 5, 5)
    conv2_b: np.ndarray
    conv3_w: np.ndarray   # (120, 16, 5, 5)
    conv3_b: np.ndarray
    fc4_w: np.ndarray     # (120, 84)
    fc4_b: np.ndarray
    fc5_w: np.ndarray     # (84, 10)
    fc5_b: np.ndarray


def lenet5_random_weights(seed: int = 0, scale: int = 16) -> LeNetWeights:
    """Deterministic int8 weights in a narrow range (so activations stay
    well-behaved under the static power-of-2 requant discipline)."""
    rng = np.random.default_rng(seed)
    w = lambda *s: rng.integers(-scale, scale + 1, s, dtype=np.int64).astype(np.int8)
    b = lambda n: rng.integers(-64, 65, (n,), dtype=np.int64).astype(np.int32)
    return LeNetWeights(
        conv1_w=w(6, 1, 5, 5), conv1_b=b(6),
        conv2_w=w(16, 6, 5, 5), conv2_b=b(16),
        conv3_w=w(120, 16, 5, 5), conv3_b=b(120),
        fc4_w=w(120, 84), fc4_b=b(84),
        fc5_w=w(84, 10), fc5_b=b(10),
    )


def lenet5_specs(weights: LeNetWeights,
                 requant_shifts: Optional[Sequence[Optional[int]]] = None
                 ) -> List[LayerSpec]:
    """The five LayerSpecs of §4.3.  ``requant_shifts`` pins the per-layer
    shifts (None entries = choose statically at compile time)."""
    s = list(requant_shifts) if requant_shifts is not None else [None] * 5
    return [
        LayerSpec("l1_conv", "conv", weights.conv1_w, weights.conv1_b,
                  relu=True, pool="avg2x2", requant_shift=s[0]),
        LayerSpec("l2_conv", "conv", weights.conv2_w, weights.conv2_b,
                  relu=True, pool="avg2x2", requant_shift=s[1]),
        LayerSpec("l3_conv", "conv", weights.conv3_w, weights.conv3_b,
                  relu=True, requant_shift=s[2]),
        LayerSpec("l4_fc", "fc", weights.fc4_w, weights.fc4_b,
                  relu=True, requant_shift=s[3]),
        LayerSpec("l5_fc", "fc", weights.fc5_w, weights.fc5_b,
                  relu=False, requant_shift=s[4]),
    ]


# ---------------------------------------------------------------------------
# Integer reference (the semantics the VTA must match bit-for-bit)
# ---------------------------------------------------------------------------

def _requant(acc: np.ndarray, pool_div: int, shift: int) -> np.ndarray:
    from repro.core.layout import truncate_int8
    return truncate_int8(acc >> (pool_div + shift))


def _avgpool_sum(t: np.ndarray) -> np.ndarray:
    """Sum over 2×2 windows (division folded into the requant shift)."""
    _, c, h, w = t.shape
    return (t[:, :, 0::2, 0::2] + t[:, :, 0::2, 1::2]
            + t[:, :, 1::2, 0::2] + t[:, :, 1::2, 1::2])


def reference_forward_int8(weights: LeNetWeights, image: np.ndarray,
                           shifts: Sequence[int]
                           ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Bit-exact integer forward pass; returns (logits_int8 (1,10),
    per-layer activations)."""
    acts: Dict[str, np.ndarray] = {}
    x = image.astype(np.int64)

    def conv_block(x, w, b, shift, pool):
        acc = conv2d_reference(x.astype(np.int8), w) + b[None, :, None, None]
        acc = np.maximum(acc, 0)
        if pool:
            acc = _avgpool_sum(acc)
            return _requant(acc, 2, shift).astype(np.int64)
        return _requant(acc, 0, shift).astype(np.int64)

    x = conv_block(x, weights.conv1_w, weights.conv1_b.astype(np.int64),
                   shifts[0], True);  acts["l1"] = x.astype(np.int8)
    x = conv_block(x, weights.conv2_w, weights.conv2_b.astype(np.int64),
                   shifts[1], True);  acts["l2"] = x.astype(np.int8)
    x = conv_block(x, weights.conv3_w, weights.conv3_b.astype(np.int64),
                   shifts[2], False); acts["l3"] = x.astype(np.int8)

    v = x.reshape(1, -1)                      # (1, 120)
    acc = v @ weights.fc4_w.astype(np.int64) + weights.fc4_b.astype(np.int64)
    acc = np.maximum(acc, 0)
    v = _requant(acc, 0, shifts[3]).astype(np.int64); acts["l4"] = v.astype(np.int8)

    acc = v @ weights.fc5_w.astype(np.int64) + weights.fc5_b.astype(np.int64)
    logits = _requant(acc, 0, shifts[4]);  acts["l5"] = logits
    return logits, acts


# ---------------------------------------------------------------------------
# Float reference (stands in for the paper's PyTorch model)
# ---------------------------------------------------------------------------

def reference_forward_float(weights: LeNetWeights, image: np.ndarray
                            ) -> np.ndarray:
    """Float32 JAX forward over the same (integer-valued) weights — the
    classification reference; imported lazily so core/ stays JAX-free."""
    import jax.numpy as jnp
    from jax import lax

    x = jnp.asarray(image, jnp.float32)

    def conv(x, w, b, pool):
        y = lax.conv_general_dilated(
            x, jnp.asarray(w, jnp.float32), (1, 1), "VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        y = jnp.maximum(y + jnp.asarray(b, jnp.float32)[None, :, None, None], 0)
        if pool:
            y = (y[:, :, 0::2, 0::2] + y[:, :, 0::2, 1::2]
                 + y[:, :, 1::2, 0::2] + y[:, :, 1::2, 1::2]) / 4.0
        return y

    x = conv(x, weights.conv1_w, weights.conv1_b, True)
    x = conv(x, weights.conv2_w, weights.conv2_b, True)
    x = conv(x, weights.conv3_w, weights.conv3_b, False)
    v = x.reshape(1, -1)
    v = jnp.maximum(v @ jnp.asarray(weights.fc4_w, jnp.float32)
                    + jnp.asarray(weights.fc4_b, jnp.float32), 0)
    logits = (v @ jnp.asarray(weights.fc5_w, jnp.float32)
              + jnp.asarray(weights.fc5_b, jnp.float32))
    return np.asarray(logits)


def synthetic_digit(seed: int = 0) -> np.ndarray:
    """A deterministic 32×32 int8 test image (MNIST-like dynamic range)."""
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 128, (1, 1, 32, 32), dtype=np.int64)
    return img.astype(np.int8)


def calibrate_shifts(weights: LeNetWeights, images: Sequence[np.ndarray],
                     margin: int = 1) -> List[int]:
    """Static per-layer requant shifts from a calibration set (§4.2
    discipline; see :func:`repro.core.network_compiler.
    calibrate_network_shifts` for the model-agnostic implementation)."""
    from repro.core.network_compiler import calibrate_network_shifts
    return calibrate_network_shifts(lenet5_specs(weights), images,
                                    margin=margin)
